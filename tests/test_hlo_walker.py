"""Parser edge cases for ``launch/hlo_walker`` (ISSUE 6 satellite) on
hand-written HLO: tuple-shaped results, nested fusions (virtual for the
HBM proxy), ``while`` with and without ``known_trip_count``, and the dot
operand formats of both old XLA (bare operand names, resolved through the
computation symbol table) and new XLA (types printed inline).

``tests/test_substrates.py::TestHLOWalker`` covers the happy path on real
compiled programs; these fixtures pin the textual corner cases so an XLA
pretty-printer change breaks a unit test here, not an analysis downstream.
"""
from repro.launch.hlo_walker import _bytes_of, analyze_hlo, parse_hlo

_WHILE_TRIPPED = """\
HloModule m

%body (p: (f32[4,8], f32[8,4], f32[4,4])) -> (f32[4,8], f32[8,4], f32[4,4]) {
  %p = (f32[4,8]{1,0}, f32[8,4]{1,0}, f32[4,4]{1,0}) parameter(0)
  %a = f32[4,8]{1,0} get-tuple-element((f32[4,8]{1,0}, f32[8,4]{1,0}, f32[4,4]{1,0}) %p), index=0
  %b = f32[8,4]{1,0} get-tuple-element((f32[4,8]{1,0}, f32[8,4]{1,0}, f32[4,4]{1,0}) %p), index=1
  %d = f32[4,4]{1,0} dot(f32[4,8]{1,0} %a, f32[8,4]{1,0} %b), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  ROOT %t = (f32[4,8]{1,0}, f32[8,4]{1,0}, f32[4,4]{1,0}) tuple(%a, %b, %d)
}

%cond (p: (f32[4,8], f32[8,4], f32[4,4])) -> pred[] {
  %p = (f32[4,8]{1,0}, f32[8,4]{1,0}, f32[4,4]{1,0}) parameter(0)
  ROOT %lt = pred[] constant(false)
}

ENTRY %main (x: f32[4,8], y: f32[8,4]) -> (f32[4,8], f32[8,4], f32[4,4]) {
  %x = f32[4,8]{1,0} parameter(0)
  %y = f32[8,4]{1,0} parameter(1)
  %z = f32[4,4]{1,0} constant(0)
  %init = (f32[4,8]{1,0}, f32[8,4]{1,0}, f32[4,4]{1,0}) tuple(%x, %y, %z)
  ROOT %w = (f32[4,8]{1,0}, f32[8,4]{1,0}, f32[4,4]{1,0}) while((f32[4,8]{1,0}, f32[8,4]{1,0}, f32[4,4]{1,0}) %init), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"10"}}
}
"""

_DOT_FLOPS = 2.0 * (4 * 4) * 8   # 2 * result_elems * contracted k


class TestWhileTripCounts:
    def test_known_trip_count_multiplies_body(self):
        stats = analyze_hlo(_WHILE_TRIPPED)
        assert stats.while_trips == {"w": 10}
        assert stats.dot_flops == 10 * _DOT_FLOPS

    def test_missing_trip_count_defaults_to_one(self):
        text = _WHILE_TRIPPED.replace(
            ', backend_config={"known_trip_count":{"n":"10"}}', "")
        stats = analyze_hlo(text)
        assert stats.while_trips == {}
        assert stats.dot_flops == _DOT_FLOPS

    def test_parse_records_body_and_condition_calls(self):
        comps = parse_hlo(_WHILE_TRIPPED)
        assert comps["__entry_name__"] == "main"
        kinds = {(callee, trip) for callee, kind, trip
                 in comps["main"].calls if kind == "while"}
        assert kinds == {("cond", 10), ("body", 10)}


_TUPLE_COLLECTIVE = """\
HloModule m

%add (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %s = f32[] add(f32[] %a, f32[] %b)
}

ENTRY %main (x: f32[4], y: bf16[8]) -> (f32[4], bf16[8]) {
  %x = f32[4]{0} parameter(0)
  %y = bf16[8]{0} parameter(1)
  ROOT %ar = (f32[4]{0}, bf16[8]{0}) all-reduce(f32[4]{0} %x, bf16[8]{0} %y), replica_groups={}, to_apply=%add
}
"""


class TestTupleResults:
    def test_bytes_of_tuple_type(self):
        assert _bytes_of("(f32[4]{0}, bf16[8]{0})") == 16 + 16

    def test_tuple_all_reduce_counts_once_sums_all_arrays(self):
        stats = analyze_hlo(_TUPLE_COLLECTIVE)
        assert stats.collective_counts == {"all-reduce": 1}
        assert stats.collective_bytes == {"all-reduce": 32.0}
        # the f32 share feeds the TPU-corrected estimate (bf16 emulation)
        assert stats.collective_bytes_f32 == 16.0
        assert stats.collective_bytes_tpu == 32.0 - 8.0


_NESTED_FUSION = """\
HloModule m

%fused_inner (p: f32[16,16]) -> f32[16,16] {
  %p = f32[16,16]{1,0} parameter(0)
  %c = f32[16,16]{1,0} copy(f32[16,16]{1,0} %p)
  ROOT %d = f32[16,16]{1,0} dot(f32[16,16]{1,0} %c, f32[16,16]{1,0} %p), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}

%fused_outer (p: f32[16,16]) -> f32[16,16] {
  %p = f32[16,16]{1,0} parameter(0)
  ROOT %inner = f32[16,16]{1,0} fusion(f32[16,16]{1,0} %p), kind=kLoop, calls=%fused_inner
}

ENTRY %main (x: f32[16,16]) -> f32[16,16] {
  %x = f32[16,16]{1,0} parameter(0)
  ROOT %f = f32[16,16]{1,0} fusion(f32[16,16]{1,0} %x), kind=kLoop, calls=%fused_outer
}
"""


class TestNestedFusions:
    def test_fusion_internals_are_virtual_for_hbm(self):
        """Ops inside (nested) fused computations move no HBM of their
        own -- only the dot contributes, through the fusion chain."""
        stats = analyze_hlo(_NESTED_FUSION)
        assert stats.dot_flops == 2.0 * (16 * 16) * 16
        # the copy inside %fused_inner must NOT be charged 2x result bytes;
        # dot HBM = lhs + rhs + out = 3 * 16*16*4
        assert stats.hbm_bytes == 3 * 16 * 16 * 4

    def test_nested_reachability(self):
        comps = parse_hlo(_NESTED_FUSION)
        assert ("fused_outer", "fusion", 1) in comps["main"].calls
        assert ("fused_inner", "fusion", 1) in comps["fused_outer"].calls


_DOT_OLD_FORMAT = """\
HloModule m

ENTRY %main (a: f32[6,32], b: f32[32,10]) -> f32[6,10] {
  %a = f32[6,32]{1,0} parameter(0)
  %b = f32[32,10]{1,0} parameter(1)
  ROOT %d = f32[6,10]{1,0} dot(%a, %b), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}
"""

_DOT_NEW_FORMAT = """\
HloModule m

ENTRY %main (a: f32[6,32], b: f32[32,10]) -> f32[6,10] {
  %a = f32[6,32]{1,0} parameter(0)
  %b = f32[32,10]{1,0} parameter(1)
  ROOT %d = f32[6,10]{1,0} dot(f32[6,32]{1,0} %a, f32[32,10]{1,0} %b), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}
"""


class TestDotOperandFormats:
    def test_old_format_resolves_lhs_via_symbol_table(self):
        stats = analyze_hlo(_DOT_OLD_FORMAT)
        assert stats.dot_flops == 2.0 * (6 * 10) * 32

    def test_new_format_reads_inline_operand_type(self):
        stats = analyze_hlo(_DOT_NEW_FORMAT)
        assert stats.dot_flops == 2.0 * (6 * 10) * 32
