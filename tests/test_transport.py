"""Compressed update transport (DESIGN.md §12): quantization error bounds,
error-feedback telescoping, top-k sparsification residuals, cross-engine
equivalence under a FIXED transport config, mid-buffer save/restore with
non-empty accumulators, and checkpoint back-compat for pre-transport
checkpoints.

Equivalence philosophy: compression is a step function (int8 rounding),
and the SVD realloc downstream has sign/rotation freedom, so comparing a
COMPRESSED run against an UNCOMPRESSED run on raw factors is ill-posed --
1-ulp input differences flip rounding decisions and singular-vector signs.
The invariants that ARE exact: (a) the same transport config produces
identical traces on the sequential and batched engines (same host-side
encode order); (b) identical quantized inputs aggregate identically across
backends and meshes; (c) a restored run continues bit-compatibly.
"""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_compat import given, settings, strategies as st

from repro.federation.experiment import build_experiment
from repro.federation.transport import (QuantFactor, TransportConfig,
                                        UpdateTransport, _encode_pair,
                                        dequantize, is_quantized)

# ---------------------------------------------------------------------------
# quantization layer
# ---------------------------------------------------------------------------


def _rand_pair(rng, d=16, r=8, n=12, zero_cols=0):
    b = rng.normal(size=(d, r)).astype(np.float32)
    a = rng.normal(size=(r, n)).astype(np.float32)
    if zero_cols:
        b[:, r - zero_cols:] = 0.0
        a[r - zero_cols:, :] = 0.0
    return b, a


class TestQuantizeRoundtrip:
    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(min_value=0, max_value=10_000),
           zero_cols=st.integers(min_value=0, max_value=4))
    def test_int8_error_bounded_by_half_scale(self, seed, zero_cols):
        """|x - deq(Q(x))| <= scale/2 elementwise: the absmax grid covers
        the column's range, so rounding is the only error source."""
        rng = np.random.default_rng(seed)
        b, a = _rand_pair(rng, zero_cols=zero_cols)
        zb, za = np.zeros_like(b), np.zeros_like(a)
        qb, qa, _, _ = _encode_pair(jnp.asarray(b), jnp.asarray(a), zb, za,
                                    mode="int8", top_k=None)
        for x, qf in ((b, qb), (a, qa)):
            err = np.abs(x - np.asarray(dequantize(qf)))
            bound = np.broadcast_to(np.asarray(qf.scale) / 2.0, x.shape)
            assert (err <= bound + 1e-7).all()

    @settings(max_examples=6, deadline=None)
    @given(seed=st.integers(min_value=0, max_value=10_000))
    def test_zero_rank_columns_decode_exactly_zero(self, seed):
        """Rank-level awareness for free: columns beyond a client's r_k are
        all-zero under masked training, get scale 0, decode to exact 0 --
        so omega's zero-columns stay zero bit-for-bit."""
        rng = np.random.default_rng(seed)
        b, a = _rand_pair(rng, zero_cols=3)
        zb, za = np.zeros_like(b), np.zeros_like(a)
        qb, qa, _, _ = _encode_pair(jnp.asarray(b), jnp.asarray(a), zb, za,
                                    mode="int8", top_k=None)
        assert (np.asarray(qb.scale)[..., -3:] == 0.0).all()
        assert (np.asarray(dequantize(qb))[:, -3:] == 0.0).all()
        assert (np.asarray(dequantize(qa))[-3:, :] == 0.0).all()

    def test_bf16_mode_unit_scales(self):
        rng = np.random.default_rng(0)
        b, a = _rand_pair(rng)
        qb, qa, _, _ = _encode_pair(jnp.asarray(b), jnp.asarray(a),
                                    np.zeros_like(b), np.zeros_like(a),
                                    mode="bf16", top_k=None)
        assert qb.q.dtype == jnp.bfloat16 and (np.asarray(qb.scale) == 1).all()
        np.testing.assert_allclose(np.asarray(dequantize(qa)), a,
                                   rtol=1e-2, atol=1e-2)


class TestErrorFeedback:
    @settings(max_examples=6, deadline=None)
    @given(seed=st.integers(min_value=0, max_value=10_000),
           rounds=st.integers(min_value=2, max_value=6))
    def test_residuals_telescope(self, seed, rounds):
        """sum_t deq(q_t) == sum_t x_t + e_0 - e_K: the compressed SUM
        tracks the uncompressed sum to within one residual, so compression
        noise does not accumulate across rounds."""
        rng = np.random.default_rng(seed)
        eb = np.zeros((16, 8), np.float32)
        ea = np.zeros((8, 12), np.float32)
        sum_x_b = np.zeros_like(eb)
        sum_q_b = np.zeros_like(eb)
        for _ in range(rounds):
            b, a = _rand_pair(rng)
            qb, qa, rb, ra = _encode_pair(jnp.asarray(b), jnp.asarray(a),
                                          eb, ea, mode="int8", top_k=None)
            sum_x_b += b
            sum_q_b += np.asarray(dequantize(qb))
            eb, ea = np.asarray(rb), np.asarray(ra)
        np.testing.assert_allclose(sum_q_b + eb, sum_x_b,
                                   rtol=1e-5, atol=1e-5)

    def test_topk_drops_into_residual(self):
        """Top-k keeps the k most energetic rank columns; the dropped
        columns' full mass lands in the residual and re-enters next round."""
        rng = np.random.default_rng(3)
        b, a = _rand_pair(rng)
        b[:, 0] *= 10.0; b[:, 1] *= 10.0          # two dominant columns
        qb, qa, rb, ra = _encode_pair(jnp.asarray(b), jnp.asarray(a),
                                      np.zeros_like(b), np.zeros_like(a),
                                      mode="int8", top_k=2)
        kept = np.asarray(qb.scale)[0] > 0
        assert kept.sum() == 2 and kept[0] and kept[1]
        # dropped columns: deq == 0, residual == x exactly
        np.testing.assert_array_equal(np.asarray(rb)[:, ~kept], b[:, ~kept])
        np.testing.assert_array_equal(np.asarray(ra)[~kept, :], a[~kept, :])


# ---------------------------------------------------------------------------
# engine matrix under a FIXED transport config
# ---------------------------------------------------------------------------

_TINY = dict(fl_overrides={"num_clients": 6, "participation": 1.0,
                           "num_rounds": 8, "local_batch_size": 4},
             lora_overrides={"rank_levels": (4, 8), "rank_probs": (0.5, 0.5)},
             num_classes=4, d_model=32, samples_per_class=8,
             batches_per_round=1)


def _run(engine, mode, rounds=3, **kw):
    exp = build_experiment("raflora", round_engine=engine,
                           transport=TransportConfig(mode=mode), **_TINY,
                           **kw)
    exp.server.run(rounds)
    if engine == "async":
        exp.server.drain_pending()
    return exp


def _adapter_products(server):
    """{adapter path: lora_b @ lora_a}: the SVD realloc's sign/rotation
    freedom cancels in the product (b_g = U sqrt(S), a_g = sqrt(S) V^T),
    so products -- unlike raw factors -- compare across runs."""
    flat = jax.tree_util.tree_flatten_with_path(server.global_lora)[0]
    d = {tuple(str(getattr(p, "key", p)) for p in path): np.asarray(leaf)
         for path, leaf in flat}
    keys = sorted({k[:-1] for k in d if k[-1] == "lora_b"})
    return {k: d[k + ("lora_b",)] @ d[k + ("lora_a",)] for k in keys}


class TestEngineMatrix:
    @pytest.mark.parametrize("mode", ["int8", "bf16"])
    def test_sequential_equals_batched(self, mode):
        """Same encode order, same quantized bytes, same aggregation. int8
        rounding is a step function, so the engines' differing f32 op order
        (per-client loop vs stacked vmap) can flip single quantization
        decisions -- agreement is to quantization-step tolerance, compared
        on effective PRODUCTS (sign/rotation-invariant)."""
        seq = _run("sequential", mode)
        bat = _run("batched", mode)
        np.testing.assert_allclose(seq.server.energy.higher_rank_ratio,
                                   bat.server.energy.higher_rank_ratio,
                                   rtol=5e-3, atol=5e-4)
        ps, pb = _adapter_products(seq.server), _adapter_products(bat.server)
        assert sorted(ps) == sorted(pb)
        for k in ps:
            np.testing.assert_allclose(ps[k], pb[k], atol=2e-4,
                                       err_msg=str(k))

    def test_sharded_tracks_batched(self):
        """The quantized psum collective folds scale*sqrt(omega) into one
        column vector (one fewer f32 round-trip than the local path), so
        agreement is to f32-association tolerance, not bit-exact."""
        from repro.launch.mesh import make_fl_mesh
        bat = _run("batched", "int8", rounds=2)
        shd = _run("sharded", "int8", rounds=2,
                   mesh=make_fl_mesh(jax.device_count()))
        np.testing.assert_allclose(shd.server.energy.higher_rank_ratio,
                                   bat.server.energy.higher_rank_ratio,
                                   rtol=5e-3, atol=5e-4)

    @pytest.mark.parametrize("engine", ["async", "event"])
    def test_buffered_engines_run_and_accumulate(self, engine):
        """Async/event engines trigger at their own cadence (different
        cohort compositions than the sync engines -- no trace equality to
        assert), but compression must leave them healthy: finite energies,
        rounds recorded, and error-feedback state for every participant."""
        kw = {}
        if engine == "async":
            exp = _run("async", "int8", rounds=4, pipeline_depth=2,
                       staleness_gamma=0.8)
        else:
            from repro.federation.events import (EventScheduler,
                                                 standard_trigger,
                                                 standard_straggler_latency)
            exp = build_experiment(
                "raflora", round_engine="async",
                transport=TransportConfig(mode="int8"), **_TINY)
            exp.server.set_event_scheduler(EventScheduler(
                standard_straggler_latency(0.5), standard_trigger("count", 6),
                round_interval=1.0))
            exp.server.run(4)
            exp.server.drain_pending()
        assert len(exp.server.history) >= 2
        assert np.isfinite(exp.server.energy.higher_rank_ratio).all()
        state = exp.server.transport.state_arrays()
        assert state, "error-feedback accumulators must be non-empty"
        assert all(v.dtype == np.float32 for v in state.values())


# ---------------------------------------------------------------------------
# checkpointing: mid-buffer resume + pre-transport back-compat
# ---------------------------------------------------------------------------


def _async_exp():
    return build_experiment("raflora", round_engine="async",
                            pipeline_depth=2, staleness_gamma=0.8,
                            transport=TransportConfig(mode="int8"), **_TINY)


class TestTransportCheckpoint:
    def test_mid_buffer_resume_equals_uninterrupted(self, tmp_path):
        """Save mid-buffer (pending client updates in flight, error-feedback
        accumulators non-empty), restore into a fresh server, continue:
        the resumed run must equal the uninterrupted one."""
        full = _async_exp()
        full.server.run(5)
        full.server.drain_pending()

        part = _async_exp()
        part.server.run(3)
        assert part.server._pending, "must save mid-buffer"
        assert part.server.transport.has_state(), \
            "accumulators must be non-empty at save time"
        path = str(tmp_path / "tx_ckpt")
        part.server.save(path)

        resumed = _async_exp()
        resumed.server.restore(path)
        # accumulators round-trip bit-exactly
        want = part.server.transport.state_arrays()
        got = resumed.server.transport.state_arrays()
        assert sorted(want) == sorted(got)
        for k in want:
            np.testing.assert_array_equal(want[k], got[k])
        resumed.server.run(2)
        resumed.server.drain_pending()

        for sf, sr in zip(full.server.history, resumed.server.history):
            assert sf.clients == sr.clients and sf.ranks == sr.ranks
            np.testing.assert_allclose(sf.mean_client_loss,
                                       sr.mean_client_loss, rtol=1e-6)
        np.testing.assert_allclose(full.server.energy.higher_rank_ratio,
                                   resumed.server.energy.higher_rank_ratio,
                                   rtol=1e-5, atol=1e-6)
        for a, b in zip(jax.tree.leaves(full.server.global_lora),
                        jax.tree.leaves(resumed.server.global_lora)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-5, atol=1e-6)

    def test_pre_transport_checkpoint_restores_with_warning(self, tmp_path):
        """Back-compat (bugfix satellite): a checkpoint written BEFORE the
        transport existed has no accumulator sidecar -- restore() must not
        KeyError; accumulators zero-init with a warning."""
        old = build_experiment("raflora", round_engine="batched", **_TINY)
        old.server.run(2)
        path = str(tmp_path / "pre_transport")
        old.server.save(path)

        new = build_experiment("raflora", round_engine="batched",
                               transport=TransportConfig(mode="int8"),
                               **_TINY)
        with pytest.warns(RuntimeWarning,
                          match="predates the compressed update transport"):
            new.server.restore(path)
        assert not new.server.transport.has_state()
        new.server.run(1)          # zero-init accumulators: training resumes
        assert new.server.transport.has_state()

    def test_quantized_pending_plans_roundtrip(self, tmp_path):
        """The async pending buffer may hold QUANTIZED factor pairs; the
        plan (de)serialization must preserve payload dtype + scales."""
        part = _async_exp()
        part.server.run(3)
        assert part.server._pending

        def quant_leaves(plans):
            out = {}
            for plan in plans:
                for gi, (members, r_max, factors) in \
                        enumerate(plan.group_factors):
                    for parent, val in factors.items():
                        if is_quantized(val[0]):
                            out[(plan.round, gi, parent)] = val
            return out

        old_leaves = quant_leaves(part.server._pending)
        assert old_leaves, "pending buffer must hold quantized factors"
        path = str(tmp_path / "pending")
        part.server.save(path)
        resumed = _async_exp()
        resumed.server.restore(path)
        new_leaves = quant_leaves(resumed.server._pending)
        assert sorted(old_leaves) == sorted(new_leaves)
        for key, (ob, oa) in old_leaves.items():
            for old, new in zip((ob, oa), new_leaves[key]):
                assert is_quantized(new)
                assert np.asarray(new.q).dtype == np.asarray(old.q).dtype
                np.testing.assert_array_equal(np.asarray(old.q),
                                              np.asarray(new.q))
                np.testing.assert_array_equal(np.asarray(old.scale),
                                              np.asarray(new.scale))


# ---------------------------------------------------------------------------
# transport state machinery
# ---------------------------------------------------------------------------


class TestUpdateTransportState:
    def test_state_roundtrip_and_ghost_discard(self):
        tr = UpdateTransport(TransportConfig(mode="int8"))
        rng = np.random.default_rng(1)
        b = rng.normal(size=(3, 8, 4)).astype(np.float32)
        a = rng.normal(size=(3, 4, 8)).astype(np.float32)
        out = tr.encode_group([5, -1, 9],
                              {("L",): (jnp.asarray(b), jnp.asarray(a))})
        assert is_quantized(out[("L",)][0])
        state = tr.state_arrays()
        assert set(state) == {"c5/L/b", "c5/L/a", "c9/L/b", "c9/L/a"}
        tr2 = UpdateTransport(TransportConfig(mode="int8"))
        tr2.load_state_arrays(state)
        for k, v in tr2.state_arrays().items():
            np.testing.assert_array_equal(v, state[k])

    def test_magnitudes_pass_through(self):
        tr = UpdateTransport(TransportConfig(mode="int8"))
        m = jnp.ones((7,))
        out = tr.encode_client(0, {(("proj",), "m"): m})
        assert out[(("proj",), "m")] is m

    def test_payload_bytes(self):
        tr8 = UpdateTransport(TransportConfig(mode="int8"))
        tr16 = UpdateTransport(TransportConfig(mode="bf16"))
        d, n, r = 64, 64, 8
        f32 = (d * r + r * n) * 4
        assert tr8.payload_bytes(d, n, r) < f32 / 3
        assert tr16.payload_bytes(d, n, r) < f32 / 1.9
