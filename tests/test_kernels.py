"""Pallas kernel validation: shape/dtype sweeps, assert_allclose vs the
pure-jnp oracles in kernels/ref.py (interpret mode on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.lora_apply import lora_apply_pallas
from repro.kernels.rank_partition_agg import rank_partition_agg_pallas


class TestLoRAApplyKernel:
    @pytest.mark.parametrize("m,k,n,r", [
        (64, 128, 64, 8), (128, 256, 192, 16), (64, 64, 64, 64),
        (256, 128, 128, 32),
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_shape_dtype_sweep(self, m, k, n, r, dtype):
        key = jax.random.PRNGKey(m * 1000 + k + n + r)
        ks = jax.random.split(key, 4)
        x = jax.random.normal(ks[0], (m, k), dtype)
        w = (jax.random.normal(ks[1], (k, n)) * 0.05).astype(dtype)
        a = (jax.random.normal(ks[2], (r, k)) * 0.1).astype(dtype)
        b = (jax.random.normal(ks[3], (n, r)) * 0.1).astype(dtype)
        got = lora_apply_pallas(x, w, a, b, 0.5, block_m=64, block_n=64,
                                block_k=64)
        want = ref.lora_apply_ref(x, w, a, b, 0.5)
        tol = 1e-5 if dtype == jnp.float32 else 3e-2
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32),
                                   atol=tol, rtol=tol)

    def test_zero_adapter_is_plain_matmul(self):
        key = jax.random.PRNGKey(0)
        x = jax.random.normal(key, (64, 64))
        w = jax.random.normal(jax.random.fold_in(key, 1), (64, 64))
        a = jnp.zeros((8, 64))
        b = jnp.zeros((64, 8))
        got = lora_apply_pallas(x, w, a, b, 1.0, block_m=64, block_n=64,
                                block_k=64)
        np.testing.assert_allclose(np.asarray(got), np.asarray(x @ w),
                                   atol=1e-4)

    def test_ops_wrapper_pads_odd_shapes(self):
        """The jit wrapper must handle non-128-aligned shapes."""
        key = jax.random.PRNGKey(3)
        x = jax.random.normal(key, (3, 17, 100))
        w = jax.random.normal(jax.random.fold_in(key, 1), (100, 72)) * 0.1
        a = jax.random.normal(jax.random.fold_in(key, 2), (12, 100)) * 0.1
        b = jax.random.normal(jax.random.fold_in(key, 3), (72, 12)) * 0.1
        got = ops.lora_apply(x, w, a, b, 0.7)
        want = ref.lora_apply_ref(x.reshape(-1, 100), w, a, b,
                                  0.7).reshape(3, 17, 72)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-4)


class TestRankPartitionAggKernel:
    @pytest.mark.parametrize("m,d,r,n", [
        (2, 64, 8, 64), (6, 128, 32, 96), (10, 64, 64, 64),
    ])
    def test_sweep(self, m, d, r, n):
        key = jax.random.PRNGKey(d + r)
        bs = jax.random.normal(key, (m, d, r))
        as_ = jax.random.normal(jax.random.fold_in(key, 1), (m, r, n))
        om = jax.random.uniform(jax.random.fold_in(key, 2), (m, r))
        got = rank_partition_agg_pallas(bs, as_, om, block_d=64,
                                        block_n=n if n % 64 else 64)
        want = ref.rank_partition_agg_ref(bs, as_, om)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-4)

    def test_fallback_client(self):
        key = jax.random.PRNGKey(9)
        bs = jax.random.normal(key, (3, 64, 16))
        as_ = jax.random.normal(jax.random.fold_in(key, 1), (3, 16, 64))
        om = jax.random.uniform(jax.random.fold_in(key, 2), (3, 16))
        gb = jax.random.normal(jax.random.fold_in(key, 3), (64, 16))
        ga = jax.random.normal(jax.random.fold_in(key, 4), (16, 64))
        fb = (jnp.arange(16) >= 8).astype(jnp.float32)
        got = ops.rank_partition_agg(bs, as_, om, gb, ga, fb)
        want = ref.rank_partition_agg_ref(bs, as_, om) + (gb * fb) @ ga
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-4)

    def test_kernel_equals_paper_aggregation(self):
        """End-to-end: kernel backend == dense backend inside Aggregator."""
        from repro.core import Aggregator
        key = jax.random.PRNGKey(11)
        ranks = [4, 8, 16]
        factors = []
        for i, r in enumerate(ranks):
            kb, ka = jax.random.split(jax.random.fold_in(key, i))
            factors.append((jax.random.normal(kb, (32, r)),
                            jax.random.normal(ka, (r, 48))))
        gb, ga = jnp.zeros((32, 16)), jnp.zeros((16, 48))
        r_d = Aggregator("raflora", [4, 8, 16], backend="dense") \
            .aggregate_layer(factors, ranks, [1., 1., 1.], gb, ga)
        r_k = Aggregator("raflora", [4, 8, 16], backend="kernel") \
            .aggregate_layer(factors, ranks, [1., 1., 1.], gb, ga)
        np.testing.assert_allclose(np.asarray(r_d.b_g @ r_d.a_g),
                                   np.asarray(r_k.b_g @ r_k.a_g), atol=1e-4)


class TestSSDScanKernel:
    @pytest.mark.parametrize("B,L,H,P,G,N,chunk", [
        (2, 64, 8, 16, 2, 24, 16),
        (1, 32, 4, 8, 1, 16, 8),
        (2, 128, 8, 32, 4, 16, 32),
    ])
    def test_sweep_vs_sequential(self, B, L, H, P, G, N, chunk):
        key = jax.random.PRNGKey(B + L + H)
        ks = jax.random.split(key, 6)
        x = jax.random.normal(ks[0], (B, L, H, P))
        dt = jax.nn.softplus(jax.random.normal(ks[1], (B, L, H)))
        alog = jax.random.normal(ks[2], (H,)) * 0.5
        b = jax.random.normal(ks[3], (B, L, G, N)) * 0.3
        c = jax.random.normal(ks[4], (B, L, G, N)) * 0.3
        d = jax.random.normal(ks[5], (H,))
        y_k, s_k = ops.ssd_scan(x, dt, alog, b, c, d, chunk=chunk)
        y_r, s_r = ref.ssd_scan_sequential_ref(x, dt, alog, b, c, d)
        np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r),
                                   atol=2e-4, rtol=1e-3)
        np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_r),
                                   atol=2e-4, rtol=1e-3)

    def test_initial_state_carry(self):
        """Scanning [first half] then [second half with carried state] must
        equal one full scan -- the prefill-continuation invariant."""
        key = jax.random.PRNGKey(5)
        B, L, H, P, G, N = 1, 64, 4, 8, 1, 16
        ks = jax.random.split(key, 6)
        x = jax.random.normal(ks[0], (B, L, H, P))
        dt = jax.nn.softplus(jax.random.normal(ks[1], (B, L, H)))
        alog = jax.random.normal(ks[2], (H,)) * 0.5
        b = jax.random.normal(ks[3], (B, L, G, N)) * 0.3
        c = jax.random.normal(ks[4], (B, L, G, N)) * 0.3
        d = jnp.zeros((H,))
        half = L // 2
        y1, s1 = ops.ssd_scan(x[:, :half], dt[:, :half], alog, b[:, :half],
                              c[:, :half], d, chunk=16)
        y2, s2 = ops.ssd_scan(x[:, half:], dt[:, half:], alog, b[:, half:],
                              c[:, half:], d, chunk=16, init_state=s1)
        y_full, s_full = ops.ssd_scan(x, dt, alog, b, c, d, chunk=16)
        np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                                   np.asarray(y_full), atol=2e-4, rtol=1e-3)
        np.testing.assert_allclose(np.asarray(s2), np.asarray(s_full),
                                   atol=2e-4, rtol=1e-3)

    def test_chunked_jnp_matches_sequential(self):
        """The model's chunked path (the kernel's oracle) is itself checked
        against the token-by-token recurrence."""
        key = jax.random.PRNGKey(6)
        B, L, H, P, G, N = 2, 48, 4, 8, 2, 12
        ks = jax.random.split(key, 6)
        x = jax.random.normal(ks[0], (B, L, H, P))
        dt = jax.nn.softplus(jax.random.normal(ks[1], (B, L, H)))
        alog = jax.random.normal(ks[2], (H,)) * 0.5
        b = jax.random.normal(ks[3], (B, L, G, N)) * 0.3
        c = jax.random.normal(ks[4], (B, L, G, N)) * 0.3
        d = jax.random.normal(ks[5], (H,))
        y_c, s_c = ref.ssd_scan_ref(x, dt, alog, b, c, d, chunk=16)
        y_s, s_s = ref.ssd_scan_sequential_ref(x, dt, alog, b, c, d)
        np.testing.assert_allclose(np.asarray(y_c), np.asarray(y_s),
                                   atol=2e-4, rtol=1e-3)
        np.testing.assert_allclose(np.asarray(s_c), np.asarray(s_s),
                                   atol=2e-4, rtol=1e-3)


class TestKernelModelIntegration:
    def test_mamba2_model_with_kernel_matches_jnp_path(self):
        """Full mamba2 block with use_kernels=True (Pallas SSD, interpret
        mode) must match the pure-jnp chunked path."""
        from repro.configs import LoRAConfig, get_config
        from repro.models import build_model
        key = jax.random.PRNGKey(0)
        cfg = get_config("mamba2-1.3b").reduced()
        lora = LoRAConfig(rank_levels=(4, 8))
        toks = jax.random.randint(key, (2, 32), 0, cfg.vocab_size)
        outs = {}
        for use_kernels in (False, True):
            m = build_model(cfg, lora, dtype=jnp.float32, remat=False,
                            use_kernels=use_kernels)
            params = m.init(key)
            logits, _, _ = m.forward_seq(params, {"tokens": toks},
                                         lora_rank=8)
            outs[use_kernels] = np.asarray(logits)
        np.testing.assert_allclose(outs[False], outs[True], atol=5e-4,
                                   rtol=1e-3)
