"""Pallas kernel validation: shape/dtype sweeps, assert_allclose vs the
pure-jnp oracles in kernels/ref.py (interpret mode on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_compat import given, settings, strategies as st

from repro.kernels import ops, ref
from repro.kernels.lora_apply import lora_apply_pallas
from repro.kernels.rank_partition_agg import (
    gram_left_layered_pallas, gram_right_layered_pallas,
    rank_partition_agg_layered_pallas, rank_partition_agg_pallas,
    weighted_stack_a_layered_pallas, weighted_stack_b_layered_pallas)


class TestLoRAApplyKernel:
    @pytest.mark.parametrize("m,k,n,r", [
        (64, 128, 64, 8), (128, 256, 192, 16), (64, 64, 64, 64),
        (256, 128, 128, 32),
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_shape_dtype_sweep(self, m, k, n, r, dtype):
        key = jax.random.PRNGKey(m * 1000 + k + n + r)
        ks = jax.random.split(key, 4)
        x = jax.random.normal(ks[0], (m, k), dtype)
        w = (jax.random.normal(ks[1], (k, n)) * 0.05).astype(dtype)
        a = (jax.random.normal(ks[2], (r, k)) * 0.1).astype(dtype)
        b = (jax.random.normal(ks[3], (n, r)) * 0.1).astype(dtype)
        got = lora_apply_pallas(x, w, a, b, 0.5, block_m=64, block_n=64,
                                block_k=64)
        want = ref.lora_apply_ref(x, w, a, b, 0.5)
        tol = 1e-5 if dtype == jnp.float32 else 3e-2
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32),
                                   atol=tol, rtol=tol)

    def test_zero_adapter_is_plain_matmul(self):
        key = jax.random.PRNGKey(0)
        x = jax.random.normal(key, (64, 64))
        w = jax.random.normal(jax.random.fold_in(key, 1), (64, 64))
        a = jnp.zeros((8, 64))
        b = jnp.zeros((64, 8))
        got = lora_apply_pallas(x, w, a, b, 1.0, block_m=64, block_n=64,
                                block_k=64)
        np.testing.assert_allclose(np.asarray(got), np.asarray(x @ w),
                                   atol=1e-4)

    def test_ops_wrapper_pads_odd_shapes(self):
        """The jit wrapper must handle non-128-aligned shapes."""
        key = jax.random.PRNGKey(3)
        x = jax.random.normal(key, (3, 17, 100))
        w = jax.random.normal(jax.random.fold_in(key, 1), (100, 72)) * 0.1
        a = jax.random.normal(jax.random.fold_in(key, 2), (12, 100)) * 0.1
        b = jax.random.normal(jax.random.fold_in(key, 3), (72, 12)) * 0.1
        got = ops.lora_apply(x, w, a, b, 0.7)
        want = ref.lora_apply_ref(x.reshape(-1, 100), w, a, b,
                                  0.7).reshape(3, 17, 72)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-4)

    def test_direct_call_pads_non_divisible(self):
        """lora_apply_pallas itself (not just the ops wrapper) must accept
        extents that do not divide the block sizes (PR-4 pad-to-tile
        convention; ISSUE 9 regression shapes M=300, N=520, r=12)."""
        key = jax.random.PRNGKey(9)
        x = jax.random.normal(key, (300, 130))
        w = jax.random.normal(jax.random.fold_in(key, 1), (130, 520)) * 0.1
        a = jax.random.normal(jax.random.fold_in(key, 2), (12, 130)) * 0.1
        b = jax.random.normal(jax.random.fold_in(key, 3), (520, 12)) * 0.1
        got = lora_apply_pallas(x, w, a, b, 1.7, block_m=256, block_n=512,
                                block_k=128)
        want = ref.lora_apply_ref(x, w, a, b, 1.7)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-4)


class TestBatchedLoRAApplyKernel:
    """Paged multi-adapter serving kernel (DESIGN.md §11)."""

    def _pages(self, key, p, r8, k, n, ranks):
        ks = jax.random.split(key, 3)
        a_pages = jax.random.normal(ks[0], (p, r8, k)) * 0.1
        b_pages = jax.random.normal(ks[1], (p, n, r8)) * 0.1
        # heterogeneous effective ranks: omega-style zero columns
        col = jnp.arange(r8)
        mask = col[None, :] < jnp.asarray(ranks)[:, None]      # (P, r8)
        a_pages = a_pages * mask[:, :, None]
        b_pages = b_pages * mask[:, None, :]
        scales = jnp.asarray([2.0 / r for r in ranks], jnp.float32)
        return a_pages, b_pages, scales

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_heterogeneous_ranks_vs_per_request_dense(self, dtype):
        """Each request row applies its own (A, B, rank, scale); padded
        rank columns are zero and must be inert. Reference = per-request
        dense truncation at the page's true rank."""
        ranks = (4, 8, 16)
        p, r8, k, n = len(ranks), 16, 72, 56
        key = jax.random.PRNGKey(11)
        a_pages, b_pages, scales = self._pages(
            jax.random.fold_in(key, 0), p, r8, k, n, ranks)
        x = jax.random.normal(jax.random.fold_in(key, 1), (5, 7, k))
        ids = jax.random.randint(jax.random.fold_in(key, 2), (5, 7), 0, p)
        got = ops.batched_lora_apply(
            x.astype(dtype), jnp.asarray(0.1 * np.eye(k, n), dtype),
            a_pages.astype(dtype), b_pages.astype(dtype), scales, ids)
        # per-request dense reference with TRUE truncation (not padding)
        w = 0.1 * np.eye(k, n, dtype=np.float32)
        xf = np.asarray(x, np.float32).reshape(-1, k)
        idf = np.asarray(ids).reshape(-1)
        want = np.empty((xf.shape[0], n), np.float32)
        for t in range(xf.shape[0]):
            pg = int(idf[t])
            r = ranks[pg]
            a = np.asarray(a_pages, np.float32)[pg, :r]
            b = np.asarray(b_pages, np.float32)[pg, :, :r]
            want[t] = xf[t] @ w + float(scales[pg]) * (xf[t] @ a.T) @ b.T
        want = want.reshape(5, 7, n)
        tol = 1e-4 if dtype == jnp.float32 else 3e-2
        np.testing.assert_allclose(np.asarray(got, np.float32), want,
                                   atol=tol, rtol=tol)

    def test_matches_ref_oracle_odd_shapes(self):
        ranks = (8, 16, 4, 8)
        p, r8, k, n = len(ranks), 16, 100, 72
        key = jax.random.PRNGKey(23)
        a_pages, b_pages, scales = self._pages(
            jax.random.fold_in(key, 0), p, r8, k, n, ranks)
        w = jax.random.normal(jax.random.fold_in(key, 1), (k, n)) * 0.1
        x = jax.random.normal(jax.random.fold_in(key, 2), (13, k))
        ids = jax.random.randint(jax.random.fold_in(key, 3), (13,), 0, p)
        got = ops.batched_lora_apply(x, w, a_pages, b_pages, scales, ids)
        want = ref.batched_lora_apply_ref(x, w, a_pages, b_pages, scales,
                                          ids)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-4)

    def test_single_page_equals_single_adapter(self):
        """One page + uniform ids must reproduce ops.lora_apply exactly
        (same fused math, different gather path)."""
        key = jax.random.PRNGKey(31)
        k, n, r = 64, 64, 8
        x = jax.random.normal(key, (11, k))
        w = jax.random.normal(jax.random.fold_in(key, 1), (k, n)) * 0.1
        a = jax.random.normal(jax.random.fold_in(key, 2), (r, k)) * 0.1
        b = jax.random.normal(jax.random.fold_in(key, 3), (n, r)) * 0.1
        got = ops.batched_lora_apply(
            x, w, a[None], b[None], jnp.ones((1,), jnp.float32),
            jnp.zeros((11,), jnp.int32))
        want = ops.lora_apply(x, w, a, b, 1.0)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-5)


class TestRankPartitionAggKernel:
    @pytest.mark.parametrize("m,d,r,n", [
        (2, 64, 8, 64), (6, 128, 32, 96), (10, 64, 64, 64),
    ])
    def test_sweep(self, m, d, r, n):
        key = jax.random.PRNGKey(d + r)
        bs = jax.random.normal(key, (m, d, r))
        as_ = jax.random.normal(jax.random.fold_in(key, 1), (m, r, n))
        om = jax.random.uniform(jax.random.fold_in(key, 2), (m, r))
        got = rank_partition_agg_pallas(bs, as_, om, block_d=64,
                                        block_n=n if n % 64 else 64)
        want = ref.rank_partition_agg_ref(bs, as_, om)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-4)

    def test_fallback_client(self):
        key = jax.random.PRNGKey(9)
        bs = jax.random.normal(key, (3, 64, 16))
        as_ = jax.random.normal(jax.random.fold_in(key, 1), (3, 16, 64))
        om = jax.random.uniform(jax.random.fold_in(key, 2), (3, 16))
        gb = jax.random.normal(jax.random.fold_in(key, 3), (64, 16))
        ga = jax.random.normal(jax.random.fold_in(key, 4), (16, 64))
        fb = (jnp.arange(16) >= 8).astype(jnp.float32)
        got = ops.rank_partition_agg(bs, as_, om, gb, ga, fb)
        want = ref.rank_partition_agg_ref(bs, as_, om) + (gb * fb) @ ga
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-4)

    def test_kernel_equals_paper_aggregation(self):
        """End-to-end: kernel backend == dense backend inside Aggregator."""
        from repro.core import Aggregator
        key = jax.random.PRNGKey(11)
        ranks = [4, 8, 16]
        factors = []
        for i, r in enumerate(ranks):
            kb, ka = jax.random.split(jax.random.fold_in(key, i))
            factors.append((jax.random.normal(kb, (32, r)),
                            jax.random.normal(ka, (r, 48))))
        gb, ga = jnp.zeros((32, 16)), jnp.zeros((16, 48))
        r_d = Aggregator("raflora", [4, 8, 16], backend="dense") \
            .aggregate_layer(factors, ranks, [1., 1., 1.], gb, ga)
        r_k = Aggregator("raflora", [4, 8, 16], backend="kernel") \
            .aggregate_layer(factors, ranks, [1., 1., 1.], gb, ga)
        np.testing.assert_allclose(np.asarray(r_d.b_g @ r_d.a_g),
                                   np.asarray(r_k.b_g @ r_k.a_g), atol=1e-4)


class TestPadToTile:
    """Non-tile-divisible shapes (ISSUE 4 satellite): the kernels used to
    assert ``d % bd == 0`` and crash ``backend="kernel"`` on odd adapter
    shapes; they now pad to the tile with zeros and slice back."""

    def test_dense_kernel_odd_shapes(self):
        key = jax.random.PRNGKey(0)
        bs = jax.random.normal(key, (3, 300, 8))
        as_ = jax.random.normal(jax.random.fold_in(key, 1), (3, 8, 520))
        om = jax.random.uniform(jax.random.fold_in(key, 2), (3, 8))
        got = rank_partition_agg_pallas(bs, as_, om, block_d=256,
                                        block_n=256)
        want = ref.rank_partition_agg_ref(bs, as_, om)
        assert got.shape == (300, 520)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-4)

    def test_layered_kernel_odd_shapes(self):
        key = jax.random.PRNGKey(1)
        bs = jax.random.normal(key, (2, 3, 300, 8))
        as_ = jax.random.normal(jax.random.fold_in(key, 1), (2, 3, 8, 520))
        om = jax.random.uniform(jax.random.fold_in(key, 2), (3, 8))
        got = rank_partition_agg_layered_pallas(bs, as_, om, block_d=256,
                                                block_n=256)
        assert got.shape == (2, 300, 520)
        for ll in range(2):
            want = ref.rank_partition_agg_ref(bs[ll], as_[ll], om)
            np.testing.assert_allclose(np.asarray(got[ll]),
                                       np.asarray(want), atol=1e-4)

    def test_fused_stack_gram_odd_shapes(self):
        """The fused factored kernels inherit pad-to-tile for odd d / n."""
        key = jax.random.PRNGKey(2)
        d, n = 300, 520
        bs = jax.random.normal(key, (1, 3, d, 8))
        as_ = jax.random.normal(jax.random.fold_in(key, 1), (1, 3, 8, n))
        om = jax.random.uniform(jax.random.fold_in(key, 2), (3, 8))
        u = weighted_stack_b_layered_pallas(bs, om, block_d=256)
        v = weighted_stack_a_layered_pallas(as_, om, block_n=256)
        u_ref, v_ref = ref.factored_stack_ref(bs[0], as_[0], om)
        np.testing.assert_allclose(np.asarray(u[0]), np.asarray(u_ref),
                                   atol=1e-5)
        np.testing.assert_allclose(np.asarray(v[0]), np.asarray(v_ref),
                                   atol=1e-5)
        g_u = gram_left_layered_pallas(u, block_d=256)
        g_v = gram_right_layered_pallas(v, block_n=256)
        gu_ref, gv_ref = ref.gram_cores_ref(u_ref, v_ref)
        np.testing.assert_allclose(np.asarray(g_u[0]), np.asarray(gu_ref),
                                   atol=1e-3, rtol=1e-5)
        np.testing.assert_allclose(np.asarray(g_v[0]), np.asarray(gv_ref),
                                   atol=1e-3, rtol=1e-5)

    def test_gram_multiblock_mirror(self):
        """R > br exercises the symmetric-Gram optimization: only
        upper-triangle blocks are accumulated on-chip and the lower half
        is mirrored -- must be exact and exactly symmetric."""
        key = jax.random.PRNGKey(4)
        u = jax.random.normal(key, (1, 100, 256))          # br=128: 2x2
        v = jax.random.normal(jax.random.fold_in(key, 1), (1, 256, 132))
        g_u = gram_left_layered_pallas(u, block_d=64)
        g_v = gram_right_layered_pallas(v, block_n=64)
        gu_ref, gv_ref = ref.gram_cores_ref(u[0], v[0])
        np.testing.assert_allclose(np.asarray(g_u[0]), np.asarray(gu_ref),
                                   atol=2e-3, rtol=1e-5)
        np.testing.assert_allclose(np.asarray(g_v[0]), np.asarray(gv_ref),
                                   atol=2e-3, rtol=1e-5)
        assert np.array_equal(np.asarray(g_u[0]), np.asarray(g_u[0]).T)

    def test_ops_wrapper_end_to_end_odd_shapes(self):
        """Whole kernel-backend aggregation at (d, n) = (300, 520)."""
        from repro.core import Aggregator
        key = jax.random.PRNGKey(3)
        factors = []
        for i, r in enumerate([4, 8]):
            kb, ka = jax.random.split(jax.random.fold_in(key, i))
            factors.append((jax.random.normal(kb, (300, r)) * 0.1,
                            jax.random.normal(ka, (r, 520)) * 0.1))
        gb, ga = jnp.zeros((300, 8)), jnp.zeros((8, 520))
        r_d = Aggregator("raflora", [4, 8], backend="dense") \
            .aggregate_layer(factors, [4, 8], [1., 2.], gb, ga)
        r_k = Aggregator("raflora", [4, 8], backend="kernel") \
            .aggregate_layer(factors, [4, 8], [1., 2.], gb, ga)
        scale = float(np.abs(np.asarray(r_d.sigma)).max())
        np.testing.assert_allclose(np.asarray(r_d.b_g @ r_d.a_g),
                                   np.asarray(r_k.b_g @ r_k.a_g),
                                   atol=1e-3 * max(1.0, scale))


LEVELS = (4, 8, 16)


def _het_stack(seed, ranks, d, n, dtype):
    key = jax.random.PRNGKey(seed)
    factors = []
    for i, r in enumerate(ranks):
        kb, ka = jax.random.split(jax.random.fold_in(key, i))
        factors.append(((jax.random.normal(kb, (d, r))).astype(dtype),
                        (jax.random.normal(ka, (r, n))).astype(dtype)))
    return factors


class TestFusedFactoredProperty:
    """Property tests (ISSUE 4 satellite): the kernel-factored product
    B_g A_g and spectrum match the dense reference on random
    heterogeneous-rank stacks, with and without the Eq. 8 fallback
    augmentation, across f32/bf16 inputs.

    Tolerances scale with sigma_max and are LOOSER than the QR-route
    equivalences in test_svd.py: the kernel path's Gram cores square the
    condition number (DESIGN.md §4.3), so agreement is ~sqrt(eps)
    relative, not eps."""

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("with_fallback", [False, True])
    @settings(max_examples=4, deadline=None)
    @given(seed=st.integers(0, 10_000),
           m=st.integers(1, 5),
           rank_idx=st.lists(st.integers(0, len(LEVELS) - 1),
                             min_size=1, max_size=5))
    def test_kernel_matches_dense(self, dtype, with_fallback, seed, m,
                                  rank_idx):
        from repro.core import Aggregator
        from repro.core.partitions import omega_raflora
        d, n = 24, 40
        if with_fallback:
            # all clients below the top level => the (8, 16] partition is
            # empty and the Eq. 8 fallback indicator is active
            ranks = [LEVELS[i % 2] for i in rank_idx[:m]] or [4]
        else:
            ranks = [LEVELS[i] for i in rank_idx[:m]] + [max(LEVELS)]
        n_k = [1.0 + (i % 3) for i in range(len(ranks))]
        _, fb = omega_raflora(ranks, n_k, LEVELS)
        assert bool(fb.any()) == with_fallback
        factors = _het_stack(seed, ranks, d, n, dtype)
        key = jax.random.PRNGKey(seed + 1)
        gb = jax.random.normal(key, (max(LEVELS), d)).T.astype(dtype)
        ga = jax.random.normal(jax.random.fold_in(key, 1),
                               (max(LEVELS), n)).astype(dtype)
        res = {}
        for backend in ("dense", "kernel"):
            agg = Aggregator("raflora", LEVELS, backend=backend)
            res[backend] = agg.aggregate_layer(factors, ranks, n_k,
                                               global_b=gb, global_a=ga)
        scale = max(1.0, float(np.abs(np.asarray(res["dense"].sigma)).max()))
        np.testing.assert_allclose(
            np.asarray(res["dense"].sigma), np.asarray(res["kernel"].sigma),
            atol=1e-3 * scale)
        np.testing.assert_allclose(
            np.asarray(res["dense"].b_g @ res["dense"].a_g),
            np.asarray(res["kernel"].b_g @ res["kernel"].a_g),
            atol=2e-3 * scale)


class TestSSDScanKernel:
    @pytest.mark.parametrize("B,L,H,P,G,N,chunk", [
        (2, 64, 8, 16, 2, 24, 16),
        (1, 32, 4, 8, 1, 16, 8),
        (2, 128, 8, 32, 4, 16, 32),
    ])
    def test_sweep_vs_sequential(self, B, L, H, P, G, N, chunk):
        key = jax.random.PRNGKey(B + L + H)
        ks = jax.random.split(key, 6)
        x = jax.random.normal(ks[0], (B, L, H, P))
        dt = jax.nn.softplus(jax.random.normal(ks[1], (B, L, H)))
        alog = jax.random.normal(ks[2], (H,)) * 0.5
        b = jax.random.normal(ks[3], (B, L, G, N)) * 0.3
        c = jax.random.normal(ks[4], (B, L, G, N)) * 0.3
        d = jax.random.normal(ks[5], (H,))
        y_k, s_k = ops.ssd_scan(x, dt, alog, b, c, d, chunk=chunk)
        y_r, s_r = ref.ssd_scan_sequential_ref(x, dt, alog, b, c, d)
        np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r),
                                   atol=2e-4, rtol=1e-3)
        np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_r),
                                   atol=2e-4, rtol=1e-3)

    def test_initial_state_carry(self):
        """Scanning [first half] then [second half with carried state] must
        equal one full scan -- the prefill-continuation invariant."""
        key = jax.random.PRNGKey(5)
        B, L, H, P, G, N = 1, 64, 4, 8, 1, 16
        ks = jax.random.split(key, 6)
        x = jax.random.normal(ks[0], (B, L, H, P))
        dt = jax.nn.softplus(jax.random.normal(ks[1], (B, L, H)))
        alog = jax.random.normal(ks[2], (H,)) * 0.5
        b = jax.random.normal(ks[3], (B, L, G, N)) * 0.3
        c = jax.random.normal(ks[4], (B, L, G, N)) * 0.3
        d = jnp.zeros((H,))
        half = L // 2
        y1, s1 = ops.ssd_scan(x[:, :half], dt[:, :half], alog, b[:, :half],
                              c[:, :half], d, chunk=16)
        y2, s2 = ops.ssd_scan(x[:, half:], dt[:, half:], alog, b[:, half:],
                              c[:, half:], d, chunk=16, init_state=s1)
        y_full, s_full = ops.ssd_scan(x, dt, alog, b, c, d, chunk=16)
        np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                                   np.asarray(y_full), atol=2e-4, rtol=1e-3)
        np.testing.assert_allclose(np.asarray(s2), np.asarray(s_full),
                                   atol=2e-4, rtol=1e-3)

    def test_chunked_jnp_matches_sequential(self):
        """The model's chunked path (the kernel's oracle) is itself checked
        against the token-by-token recurrence."""
        key = jax.random.PRNGKey(6)
        B, L, H, P, G, N = 2, 48, 4, 8, 2, 12
        ks = jax.random.split(key, 6)
        x = jax.random.normal(ks[0], (B, L, H, P))
        dt = jax.nn.softplus(jax.random.normal(ks[1], (B, L, H)))
        alog = jax.random.normal(ks[2], (H,)) * 0.5
        b = jax.random.normal(ks[3], (B, L, G, N)) * 0.3
        c = jax.random.normal(ks[4], (B, L, G, N)) * 0.3
        d = jax.random.normal(ks[5], (H,))
        y_c, s_c = ref.ssd_scan_ref(x, dt, alog, b, c, d, chunk=16)
        y_s, s_s = ref.ssd_scan_sequential_ref(x, dt, alog, b, c, d)
        np.testing.assert_allclose(np.asarray(y_c), np.asarray(y_s),
                                   atol=2e-4, rtol=1e-3)
        np.testing.assert_allclose(np.asarray(s_c), np.asarray(s_s),
                                   atol=2e-4, rtol=1e-3)


class TestKernelModelIntegration:
    def test_mamba2_model_with_kernel_matches_jnp_path(self):
        """Full mamba2 block with use_kernels=True (Pallas SSD, interpret
        mode) must match the pure-jnp chunked path."""
        from repro.configs import LoRAConfig, get_config
        from repro.models import build_model
        key = jax.random.PRNGKey(0)
        cfg = get_config("mamba2-1.3b").reduced()
        lora = LoRAConfig(rank_levels=(4, 8))
        toks = jax.random.randint(key, (2, 32), 0, cfg.vocab_size)
        outs = {}
        for use_kernels in (False, True):
            m = build_model(cfg, lora, dtype=jnp.float32, remat=False,
                            use_kernels=use_kernels)
            params = m.init(key)
            logits, _, _ = m.forward_seq(params, {"tokens": toks},
                                         lora_rank=8)
            outs[use_kernels] = np.asarray(logits)
        np.testing.assert_allclose(outs[False], outs[True], atol=5e-4,
                                   rtol=1e-3)
