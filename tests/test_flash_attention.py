"""Flash-attention Pallas kernel: shape/mode sweeps vs the naive oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


class TestFlashAttention:
    @pytest.mark.parametrize("B,L,H,KVH,D", [
        (2, 48, 4, 2, 16), (1, 64, 8, 1, 32), (2, 64, 6, 6, 16),
        (1, 128, 4, 4, 64),
    ])
    @pytest.mark.parametrize("causal", [True, False])
    def test_sweep(self, B, L, H, KVH, D, causal):
        key = jax.random.PRNGKey(B * 100 + L + H)
        ks = jax.random.split(key, 3)
        q = jax.random.normal(ks[0], (B, L, H, D))
        k = jax.random.normal(ks[1], (B, L, KVH, D))
        v = jax.random.normal(ks[2], (B, L, KVH, D))
        got = ops.flash_attention(q, k, v, causal=causal)
        want = ref.flash_attention_ref(q, k, v, causal=causal)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=2e-5, rtol=1e-4)

    @pytest.mark.parametrize("window", [4, 16, 40])
    def test_sliding_window(self, window):
        key = jax.random.PRNGKey(7)
        ks = jax.random.split(key, 3)
        q = jax.random.normal(ks[0], (1, 40, 4, 16))
        k = jax.random.normal(ks[1], (1, 40, 2, 16))
        v = jax.random.normal(ks[2], (1, 40, 2, 16))
        got = ops.flash_attention(q, k, v, causal=True, window=window)
        want = ref.flash_attention_ref(q, k, v, causal=True, window=window)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=2e-5, rtol=1e-4)

    def test_ragged_length_padding(self):
        key = jax.random.PRNGKey(9)
        ks = jax.random.split(key, 3)
        q = jax.random.normal(ks[0], (2, 33, 4, 16))
        k = jax.random.normal(ks[1], (2, 33, 4, 16))
        v = jax.random.normal(ks[2], (2, 33, 4, 16))
        got = ops.flash_attention(q, k, v, causal=True)
        want = ref.flash_attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=2e-5, rtol=1e-4)

    def test_matches_model_blockwise_path(self):
        """The kernel and the model's lax.scan blockwise attention agree."""
        from repro.models.layers.attention import blockwise_attention
        key = jax.random.PRNGKey(11)
        ks = jax.random.split(key, 3)
        q = jax.random.normal(ks[0], (2, 64, 8, 32))
        k = jax.random.normal(ks[1], (2, 64, 2, 32))
        v = jax.random.normal(ks[2], (2, 64, 2, 32))
        got = ops.flash_attention(q, k, v, causal=True)
        want = blockwise_attention(q, k, v, causal=True, block_q=16,
                                   block_kv=16)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=2e-5, rtol=1e-4)
