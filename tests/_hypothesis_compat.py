"""Offline stand-in for ``hypothesis``: deterministic fixed-grid sampling.

The container has no network access, so ``pip install hypothesis`` is not an
option; without this shim five tier-1 test modules fail at collection. The
shim reproduces the tiny API surface those modules use -- ``given``,
``settings``, ``strategies.{integers,floats,lists,sampled_from}`` -- by
drawing a fixed, seeded grid of examples per test (seeded from the test's
qualified name, so runs are reproducible and order-independent). Real
``hypothesis`` is still preferred whenever it is importable; test modules
fall back here via try/except import.

Shrinking, ``@example``, and stateful testing are intentionally out of
scope: the goal is deterministic offline coverage, not minimal
counterexamples.
"""
from __future__ import annotations

import functools
import inspect
import zlib
from typing import Any, Callable, List, Sequence

import numpy as np

_DEFAULT_MAX_EXAMPLES = 10
_EXAMPLE_CAP = 50          # keep offline suite wall time bounded


class Strategy:
    """A deterministic sampler: ``sample(rng)`` draws one example."""

    def __init__(self, sample_fn: Callable[[np.random.Generator], Any],
                 edge_cases: Sequence[Any] = ()):
        self._sample = sample_fn
        # served first, before random draws -- cheap boundary coverage
        self.edge_cases = list(edge_cases)

    def sample(self, rng: np.random.Generator) -> Any:
        return self._sample(rng)


class _Strategies:
    """Namespace mirroring ``hypothesis.strategies``."""

    @staticmethod
    def integers(min_value: int, max_value: int) -> Strategy:
        return Strategy(
            lambda rng: int(rng.integers(min_value, max_value + 1)),
            edge_cases=[min_value, max_value])

    @staticmethod
    def floats(min_value: float, max_value: float, **_kw) -> Strategy:
        return Strategy(
            lambda rng: float(rng.uniform(min_value, max_value)),
            edge_cases=[min_value, max_value])

    @staticmethod
    def sampled_from(elements) -> Strategy:
        elems = list(elements)
        return Strategy(lambda rng: elems[int(rng.integers(len(elems)))],
                        edge_cases=elems[:1])

    @staticmethod
    def lists(elem: Strategy, min_size: int = 0,
              max_size: int = 10) -> Strategy:
        def draw(rng):
            size = int(rng.integers(min_size, max_size + 1))
            return [elem.sample(rng) for _ in range(size)]
        edge = [[e] * max(min_size, 1) for e in elem.edge_cases[:1]] \
            if min_size <= 1 or elem.edge_cases else []
        return Strategy(draw, edge_cases=edge)


strategies = _Strategies()


class HealthCheck:
    """Placeholder constants so ``suppress_health_check=`` parses."""
    all = ()
    too_slow = None
    data_too_large = None
    filter_too_much = None


def settings(max_examples: int = None, deadline=None, **_kw):
    """Decorator recording max_examples for the enclosing ``given``."""
    def deco(fn):
        if max_examples is not None:
            fn._compat_max_examples = max_examples
        return fn
    return deco


class _Assumption(Exception):
    pass


def assume(condition) -> bool:
    """Skip the current example when its precondition fails."""
    if not condition:
        raise _Assumption()
    return True


def note(*_a, **_kw) -> None:
    pass


def given(*arg_strategies: Strategy, **kw_strategies: Strategy):
    """Run the test over a deterministic grid of drawn examples.

    Examples = the strategies' edge cases (zipped positionally) followed by
    seeded random draws, up to min(settings.max_examples, cap). The RNG seed
    derives from the test's qualified name so each test sees a stable but
    distinct grid.
    """
    def deco(fn):
        sig = inspect.signature(fn)
        param_names = list(sig.parameters)
        positional = [p for p in param_names if p != "self"]
        strat = dict(zip(positional, arg_strategies))
        strat.update(kw_strategies)
        n_examples = min(getattr(fn, "_compat_max_examples",
                                 _DEFAULT_MAX_EXAMPLES), _EXAMPLE_CAP)
        seed = zlib.crc32(getattr(fn, "__qualname__", fn.__name__)
                          .encode("utf-8"))

        def edge_grid() -> List[dict]:
            depth = max((len(s.edge_cases) for s in strat.values()),
                        default=0)
            grid = []
            for i in range(depth):
                ex = {}
                for name, s in strat.items():
                    if not s.edge_cases:
                        break
                    ex[name] = s.edge_cases[min(i, len(s.edge_cases) - 1)]
                else:
                    grid.append(ex)
            return grid

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            rng = np.random.default_rng(seed)
            examples = edge_grid()[:n_examples]
            while len(examples) < n_examples:
                examples.append({k: s.sample(rng)
                                 for k, s in strat.items()})
            ran_any = False
            for drawn in examples:
                try:
                    fn(*args, **drawn, **kwargs)
                    ran_any = True
                except _Assumption:
                    continue
            assert ran_any or not examples, \
                "every drawn example was rejected by assume()"

        # hide the drawn params from pytest's fixture resolution while
        # keeping real fixtures (and self) visible
        remaining = [p for name, p in sig.parameters.items()
                     if name not in strat]
        wrapper.__signature__ = sig.replace(parameters=remaining)
        try:
            del wrapper.__wrapped__
        except AttributeError:
            pass
        return wrapper
    return deco


__all__ = ["given", "settings", "strategies", "assume", "note",
           "HealthCheck", "Strategy"]
