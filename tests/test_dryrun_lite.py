"""Dry-run machinery tests that do NOT need 512 devices: input specs,
plan/skip logic, roofline math, and the fl-aggregation lowering on the
host mesh."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, INPUT_SHAPES, get_config
from repro.launch.hlo_analysis import (ICI_BW, PEAK_FLOPS, RooflineReport,
                                       active_params, model_flops_estimate)
from repro.launch.inputs import input_specs


class TestInputSpecs:
    @pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
    @pytest.mark.parametrize("shape_name", list(INPUT_SHAPES))
    def test_specs_exist_and_shapes_match(self, arch, shape_name):
        cfg = get_config(arch)
        shape = INPUT_SHAPES[shape_name]
        if shape.mode == "decode" and not cfg.supports_decode:
            pytest.skip("encoder-only")
        specs = input_specs(cfg, shape)
        assert specs, "no inputs produced"
        if shape.mode == "decode":
            assert specs["token"].shape == (shape.global_batch, 1)
        else:
            total = 0
            if "tokens" in specs:
                total += specs["tokens"].shape[1]
            if "embeds" in specs and cfg.frontend.kind == "vision":
                total += specs["embeds"].shape[1]
            if "embeds" in specs and cfg.frontend.kind == "audio":
                total = specs["embeds"].shape[1]
            assert total == shape.seq_len
        # pure stand-ins: ShapeDtypeStructs only, nothing allocated
        for v in specs.values():
            assert isinstance(v, jax.ShapeDtypeStruct)

    def test_frontend_stub_embeddings(self):
        """Audio/VLM shapes deliver precomputed embeddings (the one
        allowed stub)."""
        cfg = get_config("hubert-xlarge")
        specs = input_specs(cfg, INPUT_SHAPES["train_4k"])
        assert specs["embeds"].shape == (256, 4096, 1280)
        cfg = get_config("qwen2-vl-7b")
        specs = input_specs(cfg, INPUT_SHAPES["train_4k"])
        assert specs["embeds"].shape[1] == cfg.frontend.tokens_per_item
        assert "positions" in specs    # M-RoPE 3-stream ids


class TestRooflineMath:
    def test_terms_and_bottleneck(self):
        rep = RooflineReport(arch="x", shape="y", mesh="16x16", chips=256,
                             hlo_flops=256 * PEAK_FLOPS,  # 1 second compute
                             hlo_bytes=0.0, coll_bytes=256 * ICI_BW * 2.0,
                             coll_breakdown={})
        assert np.isclose(rep.t_compute, 1.0)
        assert np.isclose(rep.t_collective, 2.0)
        assert rep.bottleneck == "collective"

    def test_active_params_moe(self):
        cfg = get_config("deepseek-v2-236b")
        total = cfg.num_params()
        active = active_params(cfg)
        assert active < 0.15 * total       # ~21B of 236B
        dense = get_config("qwen2-7b")
        assert active_params(dense) == dense.num_params()

    def test_model_flops_modes(self):
        cfg = get_config("gemma-2b")
        tr = model_flops_estimate(cfg, INPUT_SHAPES["train_4k"])
        pf = model_flops_estimate(cfg, INPUT_SHAPES["prefill_32k"])
        dc = model_flops_estimate(cfg, INPUT_SHAPES["decode_32k"])
        assert tr == 6.0 * cfg.num_params() * 256 * 4096
        assert pf == 2.0 * cfg.num_params() * 32 * 32768
        assert dc == 2.0 * cfg.num_params() * 128


class TestPlanLogic:
    def test_long_500k_uses_swa_for_attention_archs(self):
        # plan() lives in dryrun which sets XLA flags; re-implement check
        # at the config level instead
        for name in ("qwen2-7b", "gemma-2b", "nemotron-4-340b"):
            cfg = get_config(name)
            assert not cfg.supports_long_context()
            swa = cfg.with_sliding_window(8192, global_every=0)
            assert swa.supports_long_context()
        for name in ("mamba2-1.3b", "hymba-1.5b"):
            assert get_config(name).supports_long_context()


class TestFLAggregationLowering:
    def test_lowers_on_host_mesh(self):
        """The paper's aggregation as a jit-compiled distributed program
        (full 512-device version exercised by launch/fl_dryrun.py)."""
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.core.svd import (factored_from_weighted,
                                    svd_realloc_factored)
        from repro.launch.mesh import make_host_mesh
        mesh = make_host_mesh()

        def agg(bs, as_, omega):
            u, v = factored_from_weighted(bs, as_, omega)
            return svd_realloc_factored(u, v, 16)

        sh = lambda spec: NamedSharding(mesh, spec)
        bs = jax.ShapeDtypeStruct((4, 64, 16), jnp.float32,
                                  sharding=sh(P("data", None, None)))
        as_ = jax.ShapeDtypeStruct((4, 16, 64), jnp.float32,
                                   sharding=sh(P("data", None, None)))
        om = jax.ShapeDtypeStruct((4, 16), jnp.float32,
                                  sharding=sh(P("data", None)))
        compiled = jax.jit(agg).lower(bs, as_, om).compile()
        assert compiled.cost_analysis() is not None
