"""Sharded round engine (ISSUE 2 tentpole): shard_map'd client training +
psum-backed aggregation must reproduce the sequential reference engine.

Under plain tier-1 the host exposes a single CPU device, so the mesh is
(1,) and the collectives are degenerate (the code path is identical, the
psum is an identity); ``tools/ci.sh shard-smoke`` re-runs this module under
a forced 8-virtual-device CPU platform where the psums are real. A
subprocess test keeps one genuinely multi-device equivalence check in
tier-1 even on single-device hosts.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.federation.experiment import build_experiment


def _one_round(method, engine, *, num_clients=10, participation=0.5,
               lora_over=None, mesh=None, batches_per_round=1):
    lora_over = lora_over or {"rank_levels": (4, 8, 16),
                              "rank_probs": (0.34, 0.33, 0.33)}
    exp = build_experiment(
        method,
        fl_overrides={"num_rounds": 1, "num_clients": num_clients,
                      "participation": participation},
        lora_overrides=lora_over,
        samples_per_class=30, num_classes=6, d_model=32,
        batches_per_round=batches_per_round, round_engine=engine, mesh=mesh)
    hist = exp.server.run(1)
    return exp, hist


def _assert_round_equal(runs, ref="sequential", other="sharded"):
    (e1, h1), (e2, h2) = runs[ref], runs[other]
    for s1, s2 in zip(h1, h2):
        assert s1.clients == s2.clients and s1.ranks == s2.ranks
        np.testing.assert_allclose(s1.mean_client_loss, s2.mean_client_loss,
                                   rtol=1e-4)
        if s1.sigma_probe is not None:
            np.testing.assert_allclose(s1.sigma_probe, s2.sigma_probe,
                                       rtol=1e-4, atol=1e-4)
    r_max = e1.server.lora_cfg.r_max
    f1 = e1.server._extract_factors(e1.server.global_lora, r_max)
    f2 = e2.server._extract_factors(e2.server.global_lora, r_max)
    for parent in f1:
        if isinstance(parent, tuple) and len(parent) == 2 \
                and parent[1] == "m":
            np.testing.assert_allclose(np.asarray(f1[parent]),
                                       np.asarray(f2[parent]),
                                       rtol=1e-4, atol=1e-5)
            continue
        d1 = np.asarray(f1[parent][0] @ f1[parent][1])
        d2 = np.asarray(f2[parent][0] @ f2[parent][1])
        np.testing.assert_allclose(
            d1, d2, atol=1e-4 * max(1.0, np.abs(d1).max()))
    # FLoRA folds dW into the base weights: compare those too
    for a, b in zip(jax.tree.leaves(e1.server.base),
                    jax.tree.leaves(e2.server.base)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)


class TestShardedEquivalence:
    """sharded == sequential per round, every method, heterogeneous ranks,
    and a sampled-client count (5) NOT divisible by any shard count > 1 --
    the ghost-client padding path is always exercised on multi-device."""

    @pytest.mark.parametrize("method", ["fedavg", "hetlora", "flora",
                                        "flexlora", "raflora", "ffa"])
    def test_sharded_matches_sequential(self, method):
        lora_over = ({"rank_levels": (8,), "rank_probs": (1.0,)}
                     if method == "fedavg"       # fedavg needs equal ranks
                     else None)
        runs = {eng: _one_round(method, eng, lora_over=lora_over)
                for eng in ("sequential", "sharded")}
        _assert_round_equal(runs)

    def test_sharded_matches_batched(self):
        """The two accelerated engines agree with each other too."""
        runs = {eng: _one_round("raflora", eng)
                for eng in ("batched", "sharded")}
        _assert_round_equal(runs, ref="batched")

    def test_uneven_clients_explicit_mesh(self):
        """3 sampled clients over every available shard count: ghost
        padding must be exact for any (clients % shards) remainder."""
        from repro.launch.mesh import make_fl_mesh
        ref = _one_round("raflora", "sequential", num_clients=6,
                         participation=0.5)
        for shards in {1, jax.device_count()}:
            runs = {"sequential": ref,
                    "sharded": _one_round("raflora", "sharded",
                                          num_clients=6, participation=0.5,
                                          mesh=make_fl_mesh(shards))}
            _assert_round_equal(runs)

    def test_multi_device_subprocess(self):
        """One genuinely multi-device equivalence check even when this
        process sees a single CPU device: re-run the raflora equivalence in
        a subprocess with a forced 8-virtual-device host platform."""
        if jax.device_count() > 1:
            pytest.skip("already multi-device in-process")
        code = (
            "from tests.test_sharded_engine import _one_round, "
            "_assert_round_equal\n"
            "runs = {e: _one_round('raflora', e)\n"
            "        for e in ('sequential', 'sharded')}\n"
            "_assert_round_equal(runs)\n"
            "import jax; assert jax.device_count() == 8\n"
            "print('MULTI_DEVICE_OK')\n")
        env = dict(os.environ)
        env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        env["JAX_PLATFORMS"] = "cpu"
        root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
        env["PYTHONPATH"] = os.pathsep.join(
            [os.path.join(root, "src"), root,
             env.get("PYTHONPATH", "")]).rstrip(os.pathsep)
        out = subprocess.run([sys.executable, "-c", code], env=env,
                             capture_output=True, text=True, timeout=1200)
        assert out.returncode == 0, out.stderr[-4000:]
        assert "MULTI_DEVICE_OK" in out.stdout


class TestShardedFallbackPath:
    """Eq. 8 empty-partition fallback through ``aggregate_grouped_sharded``:
    the global columns must be appended exactly ONCE, after the cross-shard
    reduction, for both backends."""

    @pytest.mark.parametrize("backend", ["dense", "factored"])
    def test_matches_eager_reference(self, backend):
        from repro.core.aggregation import Aggregator, pad_stack
        from repro.launch.mesh import make_fl_mesh
        key = jax.random.PRNGKey(0)
        b4 = jax.random.normal(key, (16, 4))
        a4 = jax.random.normal(jax.random.fold_in(key, 1), (4, 16))
        bs, as_ = pad_stack([(b4, a4)], 8)
        g_b = jax.random.normal(jax.random.fold_in(key, 2), (16, 8))
        g_a = jax.random.normal(jax.random.fold_in(key, 3), (8, 16))
        agg = Aggregator("raflora", (4, 8), backend=backend)
        ref = agg.aggregate_layer([(b4, a4)], [4], [1.0],
                                  global_b=g_b, global_a=g_a)
        # pad the single real client to one per shard with ghosts (n_k=0);
        # ghost factor rows are junk on purpose -- zero weights must kill
        # them exactly
        mesh = make_fl_mesh()
        n = mesh.shape["data"]
        bs_p = jnp.concatenate([bs] * n)
        as_p = jnp.concatenate([as_] * n)
        res = agg.aggregate_grouped_sharded(
            [[bs_p]], [[as_p]], [4] * n, [1.0] + [0.0] * (n - 1), mesh,
            global_bs=[g_b], global_as=[g_a])
        np.testing.assert_allclose(np.asarray(ref.b_g @ ref.a_g),
                                   np.asarray(res.b_g[0] @ res.a_g[0]),
                                   atol=1e-4)


class TestDoRAMagnitudeEquivalence:
    """The ``(parent, "m")`` weighted-FedAvg path with HETEROGENEOUS group
    orders: odd clients train 1 local step, even clients 2, so the batched
    and sharded engines stack clients in group order != sampled order and
    must permute the magnitude weights to match (ISSUE 2 satellite)."""

    @pytest.mark.parametrize("other", ["batched", "sharded"])
    def test_heterogeneous_group_orders(self, other):
        def make(engine):
            # 4 clients keep two step-count groups (odd/even clients train
            # 1/2 steps) at the smallest stacked shapes -- the 6-client
            # variant compiled visibly larger programs for no extra
            # ordering coverage (ROADMAP "Test wall time")
            exp = build_experiment(
                "raflora",
                fl_overrides={"num_rounds": 1, "num_clients": 4,
                              "participation": 1.0, "local_batch_size": 4,
                              "partition": "iid"},
                lora_overrides={"variant": "dora",
                                "rank_levels": (4, 8, 16),
                                "rank_probs": (0.34, 0.33, 0.33)},
                samples_per_class=16, num_classes=4, d_model=32,
                batches_per_round=2, round_engine=engine)
            inner = exp.server.batch_fn
            exp.server.batch_fn = (lambda cid, rng:
                                   inner(cid, rng)[:1 + cid % 2])
            return exp, exp.server.run(1)

        runs = {eng: make(eng) for eng in ("sequential", other)}
        # at least two step-count groups, or the ordering is not exercised
        seq_srv = runs["sequential"][0].server
        steps = {len(seq_srv.batch_fn(c, np.random.default_rng(0)))
                 for c in runs["sequential"][1][0].clients}
        assert len(steps) > 1, steps
        _assert_round_equal(runs, ref="sequential", other=other)
        # magnitudes must have actually moved (not a vacuous comparison)
        import jax.tree_util as jtu
        mags = [np.asarray(x) for p, x in
                jtu.tree_leaves_with_path(seq_srv.global_lora)
                if str(getattr(p[-1], "key", "")) == "lora_m"]
        assert mags and all(np.isfinite(m).all() for m in mags)


class TestZeroBatchClient:
    """Regression (ISSUE 2 satellite): a client whose round yields ZERO
    batches trains 0 steps and reports NaN; ``np.nanmean`` must keep the
    round stat finite in every engine (the old ``np.mean`` poisoned it)."""

    @pytest.mark.parametrize("engine", ["sequential", "batched", "sharded"])
    def test_round_stat_survives_zero_batch_client(self, engine):
        exp = build_experiment(
            "raflora",
            fl_overrides={"num_rounds": 1, "num_clients": 4,
                          "participation": 1.0},
            lora_overrides={"rank_levels": (4, 8),
                            "rank_probs": (0.5, 0.5)},
            samples_per_class=20, num_classes=4, d_model=32,
            batches_per_round=1, round_engine=engine)
        srv = exp.server
        inner = srv.batch_fn
        srv.batch_fn = (lambda cid, rng:
                        [] if cid == 1 else inner(cid, rng))
        stats = srv.run_round()
        assert 1 in stats.clients  # participation=1.0: all clients sampled
        assert np.isfinite(stats.mean_client_loss)

    def test_zero_batch_equivalence_across_engines(self):
        """The zero-batch client contributes its (untrained) global factors
        with its data weight -- identically in all three engines."""
        def make(engine):
            exp = build_experiment(
                "raflora",
                fl_overrides={"num_rounds": 1, "num_clients": 4,
                              "participation": 1.0},
                lora_overrides={"rank_levels": (4, 8),
                                "rank_probs": (0.5, 0.5)},
                samples_per_class=20, num_classes=4, d_model=32,
                batches_per_round=1, round_engine=engine)
            inner = exp.server.batch_fn
            exp.server.batch_fn = (lambda cid, rng:
                                   [] if cid == 1 else inner(cid, rng))
            return exp, exp.server.run(1)
        runs = {eng: make(eng)
                for eng in ("sequential", "batched", "sharded")}
        _assert_round_equal(runs, ref="sequential", other="batched")
        _assert_round_equal(runs, ref="sequential", other="sharded")
