"""Unit tests for ``analysis/host_cost`` (the host-side half of the
complexity certifier): the tracing shim's lifecycle and accounting, the
instrumented federation hooks, and the registry-independence regression
test -- per-round host cost must not move when the registry grows from
1k to 100k registered clients at a fixed cohort (the ROADMAP
million-client tripwire, gated as a contract by tools/certify_scaling.py
and pinned here as a plain assertion).
"""
import numpy as np
import pytest

from repro.analysis import host_cost
from repro.analysis.host_cost import HostCostMonitor, measure_rounds


class TestShim:
    def test_inactive_hooks_are_noops(self):
        host_cost.tick("nobody/listening", 100)
        host_cost.alloc("nobody/listening", 1 << 20)
        mon = HostCostMonitor()
        assert mon.total_loop_iters == 0
        assert mon.total_alloc_bytes == 0

    def test_tick_and_alloc_accumulate_under_monitor(self):
        with HostCostMonitor() as mon:
            host_cost.tick("loop/a", 5)
            host_cost.tick("loop/a", 3)
            host_cost.alloc("buf", 64)
        assert mon.loop_iters == {"loop/a": 8}
        assert mon.alloc_bytes == {"buf": 64}

    def test_numpy_constructors_traced_and_restored(self):
        orig_zeros = np.zeros
        with HostCostMonitor() as mon:
            np.zeros((16,), np.float32)          # 64 B
            np.asarray([1.0, 2.0])               # 16 B
        assert np.zeros is orig_zeros            # patch undone on exit
        assert mon.alloc_bytes["np.zeros"] == 64
        assert mon.alloc_bytes["np.asarray"] == 16
        before = mon.total_alloc_bytes
        np.zeros((1024,))                        # monitor closed: unseen
        assert mon.total_alloc_bytes == before

    def test_mark_isolates_phase_deltas(self):
        with HostCostMonitor() as mon:
            host_cost.tick("x", 2)
            mon.mark("round0")
            host_cost.tick("x", 7)
            host_cost.alloc("y", 10)
            mon.mark("round1")
        p0, p1 = mon.phases
        assert (p0.loop_iters, p0.alloc_bytes) == (2, 0)
        assert (p1.loop_iters, p1.alloc_bytes) == (7, 10)
        assert p1.loop_detail == {"x": 7}

    def test_nesting_raises(self):
        with HostCostMonitor():
            with pytest.raises(RuntimeError, match="nested"):
                with HostCostMonitor():
                    pass


class TestRegistryHooks:
    def test_sample_round_preserves_rng_stream(self):
        """The tick hook must not consume rng draws: sampling through the
        instrumented registry is bit-exact with a direct rng.choice."""
        from repro.configs.base import FLConfig, LoRAConfig
        from repro.federation.topology import ClientRegistry
        fl = FLConfig(num_clients=12)
        lora = LoRAConfig(rank_levels=(4, 8), rank_probs=(0.5, 0.5))
        shards = [np.arange(i, i + 3) for i in range(12)]
        reg = ClientRegistry.create(fl, lora, shards)
        expected = np.random.default_rng(7).choice(12, size=5,
                                                   replace=False)
        got = reg.sample_round(5, np.random.default_rng(7))
        np.testing.assert_array_equal(got, expected)

    def test_inflate_appends_aliased_shards(self):
        from repro.configs.base import FLConfig, LoRAConfig
        from repro.federation.topology import ClientRegistry
        fl = FLConfig(num_clients=4)
        lora = LoRAConfig(rank_levels=(4, 8), rank_probs=(0.5, 0.5))
        shards = [np.arange(i, i + 3) for i in range(4)]
        reg = ClientRegistry.create(fl, lora, shards)
        reg.inflate(1000)
        assert reg.num_clients == 1000
        assert set(np.unique(reg.ranks)) <= {4, 8}
        # shards are references onto the original arrays, not copies
        assert reg.shards[4] is reg.shards[0]
        assert reg.shards[999] is reg.shards[999 % 4]
        reg.inflate(10)                          # shrink request: no-op
        assert reg.num_clients == 1000


def _tiny_experiment():
    from repro.federation.experiment import build_experiment
    return build_experiment(
        "raflora",
        fl_overrides={"num_rounds": 60, "num_clients": 16,
                      "participation": 0.5, "partition": "iid"},
        lora_overrides={"rank_levels": (8,), "rank_probs": (1.0,)},
        num_classes=4, d_model=32, samples_per_class=20,
        batches_per_round=1, backend="factored")


@pytest.mark.slow
class TestRoundCostIndependentOfRegistry:
    def test_1k_vs_100k_registered_clients(self):
        """Satellite regression test: growing the registry 100x at a
        fixed cohort must leave per-round loop iterations EXACTLY equal
        and per-round allocated bytes within noise (rng-dependent
        sampling can shuffle which equal-size shards are touched)."""
        exp = _tiny_experiment()
        exp.registry.inflate(1_000)
        small = measure_rounds(exp.server, rounds=3, warmup=1)
        exp.registry.inflate(100_000)
        large = measure_rounds(exp.server, rounds=3, warmup=1)
        assert large["loop_iters"] == small["loop_iters"]
        assert large["alloc_bytes"] == pytest.approx(
            small["alloc_bytes"], rel=0.01)
        # the hooks themselves are alive: every phase saw the planner,
        # the sampler and the aggregator loops
        detail = large["phases"][-1]["loop_detail"]
        for label in ("registry/sample", "server/plan_clients",
                      "server/agg_members"):
            assert detail.get(label, 0) > 0, detail
