"""PEFT variants (paper Table 5): DoRA and QLoRA through the full stack."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import LoRAConfig, get_config
from repro.models import build_model
from repro.models.layers.dense import (dense_apply, dense_init,
                                       dora_magnitude_init,
                                       quantize_dequantize)


class TestDoRA:
    def test_zero_adapter_preserves_direction_scaled_weight(self, rng_key):
        """With B=0 (init), DoRA must reproduce the plain dense layer
        exactly (m initialized to the column norms)."""
        p = dense_init(rng_key, 32, 16, lora_rank=4)
        p["lora_m"] = dora_magnitude_init(p["w"])
        x = jax.random.normal(jax.random.fold_in(rng_key, 1), (8, 32))
        y_dora = dense_apply(p, x, lora_rank=4)
        y_plain = x @ p["w"]
        np.testing.assert_allclose(np.asarray(y_dora), np.asarray(y_plain),
                                   atol=1e-5)

    def test_magnitude_controls_column_scale(self, rng_key):
        p = dense_init(rng_key, 16, 8, lora_rank=4)
        p["lora_m"] = dora_magnitude_init(p["w"]) * 2.0
        x = jax.random.normal(jax.random.fold_in(rng_key, 1), (4, 16))
        y = dense_apply(p, x, lora_rank=4)
        y_plain = x @ p["w"]
        np.testing.assert_allclose(np.asarray(y), 2 * np.asarray(y_plain),
                                   atol=1e-4)

    def test_model_init_adds_magnitudes(self, rng_key):
        cfg = get_config("gemma-2b").reduced()
        lora = LoRAConfig(rank_levels=(4, 8), rank_probs=(0.5, 0.5),
                          variant="dora")
        model = build_model(cfg, lora, dtype=jnp.float32, remat=False)
        params = model.init(rng_key)
        leaves = jax.tree_util.tree_leaves_with_path(params)
        m_leaves = [p for p, _ in leaves
                    if str(getattr(p[-1], "key", "")) == "lora_m"]
        assert len(m_leaves) == 4  # q,k,v,o adapters

    def test_dora_trains_and_decodes(self, rng_key):
        from conftest import small_batch
        from repro.core.lora import split_lora
        from repro.launch.steps import build_train_step
        cfg = get_config("qwen2-7b").reduced()
        lora = LoRAConfig(rank_levels=(4, 8), rank_probs=(0.5, 0.5),
                          variant="dora")
        model = build_model(cfg, lora, dtype=jnp.float32, remat=False,
                            block_q=16, block_kv=16)
        params = model.init(rng_key)
        base, lo = split_lora(params)
        batch = small_batch(cfg, rng_key, batch=2, seq=32)
        step, opt = build_train_step(model, 8)
        st = opt.init(lo)
        l0 = None
        for _ in range(3):
            lo, st, m = step(lo, st, base, batch, jnp.float32(1e-2))
            l0 = l0 or float(m["loss"])
        assert float(m["loss"]) < l0
        # magnitudes actually moved
        from repro.core.lora import adapter_paths
        # decode still exact
        # (magnitude affects dense weights identically in decode path)


class TestQLoRA:
    @pytest.mark.parametrize("bits", [4, 8])
    def test_quantization_error_bounded(self, rng_key, bits):
        w = jax.random.normal(rng_key, (64, 32))
        wq = quantize_dequantize(w, bits)
        scale = np.abs(np.asarray(w)).max(axis=-2) / (2 ** (bits - 1) - 1)
        err = np.abs(np.asarray(w - wq))
        assert (err <= scale[None, :] * 0.5 + 1e-6).all()

    def test_model_init_quantizes_adapted_layers(self, rng_key):
        cfg = get_config("gemma-2b").reduced()
        lora_q = LoRAConfig(rank_levels=(4,), rank_probs=(1.0,),
                            variant="qlora", quant_bits=4)
        m_q = build_model(cfg, lora_q, dtype=jnp.float32, remat=False)
        m_f = build_model(cfg, LoRAConfig(rank_levels=(4,),
                                          rank_probs=(1.0,)),
                          dtype=jnp.float32, remat=False)
        p_q = m_q.init(rng_key)
        p_f = m_f.init(rng_key)
        wq = p_q["layers"]["attn"]["q"]["w"]
        wf = p_f["layers"]["attn"]["q"]["w"]
        assert not np.allclose(np.asarray(wq), np.asarray(wf))
        # few distinct levels per column
        col = np.asarray(wq)[0, :, 0]
        assert len(np.unique(np.round(col, 6))) <= 16


class TestVariantFederation:
    def test_dora_magnitudes_fedavg(self):
        """Server round with DoRA: magnitudes must change via weighted
        averaging (and stay finite)."""
        from repro.federation.experiment import build_experiment
        exp = build_experiment(
            "raflora",
            fl_overrides={"num_rounds": 2, "num_clients": 6,
                          "participation": 0.5},
            lora_overrides={"variant": "dora"},
            num_classes=6, d_model=64, samples_per_class=30,
            batches_per_round=1)
        before = [np.asarray(x) for p, x in
                  jax.tree_util.tree_leaves_with_path(exp.server.global_lora)
                  if str(getattr(p[-1], "key", "")) == "lora_m"]
        exp.server.run(2)
        after = [np.asarray(x) for p, x in
                 jax.tree_util.tree_leaves_with_path(exp.server.global_lora)
                 if str(getattr(p[-1], "key", "")) == "lora_m"]
        assert len(before) > 0
        changed = any(not np.allclose(b, a) for b, a in zip(before, after))
        assert changed
        assert all(np.isfinite(a).all() for a in after)
