"""Event-driven async rounds on the virtual clock (ISSUE 5 tentpole).

Scenario test matrix:

* scheduler PROPERTY tests (pure host simulation, no training): every
  dispatched update is consumed exactly once (or lost to a dropout),
  staleness vectors derive from arrival times, trigger-specific firing
  invariants (count == K per fire; timeout spacing; staleness bound),
  seeded determinism of the whole event stream;
* weight properties of partial-cohort (``present``-masked) aggregation:
  absent clients contribute exactly nothing, per-partition omega totals
  match the present-subset-only computation, gamma=1 preserves totals;
* the HEADLINE equivalence: ``CountTrigger(depth * clients_per_round)``
  with the unit-latency trace is BIT-equal to the ``pipeline_depth=depth``
  cadence path for every method in ``METHODS`` on the dense, factored and
  kernel backends (the event engine inherits the whole correctness
  lattice: sequential == batched == async@cadence == async@events);
* straggler / dropout / rejoin / mid-run-join scenarios end-to-end;
* seeded determinism + JSONL trace record/replay of full federated runs;
* RNG stream hygiene (disjoint per-client latency streams under
  adversarial seed pairs; client-isolation of draws) and byte-stable
  serialization of the fire log + scheduler state across same-seed runs
  (protocol-verifier satellites, DESIGN.md §10).
"""
import json
import os

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline: deterministic fixed-grid shim
    from _hypothesis_compat import given, settings, strategies as st

from repro.core.aggregation import METHODS, Aggregator
from repro.data.traces import (TraceRecord, constant_trace, read_trace,
                               trace_schedule, write_trace)
from repro.federation.events import (BimodalLatency, ClientLifecycle,
                                     ConstantLatency, CountTrigger,
                                     EventScheduler, LifecycleEvent,
                                     LognormalLatency, RecordingLatency,
                                     StalenessBoundTrigger,
                                     StragglerTailLatency, TimeoutTrigger,
                                     TraceLatency)
from repro.federation.experiment import build_experiment


# ---------------------------------------------------------------------------
# pure scheduler simulation (no training, host-only, fast)
# ---------------------------------------------------------------------------

def _drive(sched, plans, *, drain=True):
    """Run a client-id-only schedule through the scheduler, consuming at
    every fire like the server does. Returns [(fire_time, ready)] with
    ready = {plan_round: {member: arrival_time}}."""
    fires = []
    for r, clients in enumerate(plans):
        sched.dispatch(r, clients)
        for t in sched.advance_window():
            fires.append((t, sched.take_ready()))
    if drain:
        for t in sched.drain():
            fires.append((t, sched.take_ready()))
    return fires


def _consumed_members(fires):
    return [(pr, m) for _, ready in fires
            for pr, rd in ready.items() for m in rd]


def _random_plans(seed, n_plans, n_clients, m):
    rng = np.random.default_rng(seed)
    return [sorted(rng.choice(n_clients, size=m, replace=False).tolist())
            for _ in range(n_plans)]


def _make_trigger(kind, m):
    return {"count": CountTrigger(2 * m),
            "timeout": TimeoutTrigger(1.7),
            "staleness": StalenessBoundTrigger(2)}[kind]


def _make_latency(kind, seed):
    return {"lognormal": LognormalLatency(median=1.0, sigma=0.5, seed=seed),
            "bimodal": BimodalLatency(fast=0.7, slow=3.1, slow_prob=0.3,
                                      seed=seed),
            "straggler": StragglerTailLatency(median=0.9, sigma=0.3,
                                              tail_scale=5.0,
                                              straggler_frac=0.25,
                                              seed=seed)}[kind]


class TestSchedulerProperties:
    """Trigger invariants over the trigger x latency-model grid."""

    @given(seed=st.integers(0, 50),
           trig=st.sampled_from(["count", "timeout", "staleness"]),
           lat=st.sampled_from(["lognormal", "bimodal", "straggler"]))
    @settings(max_examples=24, deadline=None)
    def test_every_update_consumed_exactly_once(self, seed, trig, lat):
        m, plans = 4, _random_plans(seed, 6, 10, 4)
        sched = EventScheduler(_make_latency(lat, seed),
                               _make_trigger(trig, m))
        fires = _drive(sched, plans)
        consumed = _consumed_members(fires)
        want = [(pr, j) for pr, cl in enumerate(plans)
                for j in range(len(cl))]
        assert sorted(consumed) == want           # exactly once, no dupes
        assert sorted(sched.completed_plans()) == list(range(len(plans)))

    @given(seed=st.integers(0, 50),
           lat=st.sampled_from(["lognormal", "bimodal", "straggler"]))
    @settings(max_examples=15, deadline=None)
    def test_staleness_matches_arrival_order(self, seed, lat):
        """Within one fire, staleness = floor((T - arrival) / interval):
        recomputed from the logged arrival times, non-increasing in
        arrival time, and 0 for the freshest arrivals at a count fire."""
        m = 4
        sched = EventScheduler(_make_latency(lat, seed), CountTrigger(2 * m),
                               round_interval=1.0)
        fires = _drive(sched, _random_plans(seed + 1, 6, 10, m))
        assert fires
        for t, ready in fires:
            pairs = sorted((a, sched.staleness_of(t, a))
                           for rd in ready.values() for a in rd.values())
            for (a1, s1), (a2, s2) in zip(pairs, pairs[1:]):
                assert a1 <= a2 and s1 >= s2      # older => at least as stale
            for a, s in pairs:
                assert s == max(0, int(np.floor((t - a) / 1.0 + 1e-9)))

    @given(seed=st.integers(0, 60))
    @settings(max_examples=12, deadline=None)
    def test_count_trigger_consumes_exactly_k(self, seed):
        m, k = 3, 6
        sched = EventScheduler(LognormalLatency(sigma=0.7, seed=seed),
                               CountTrigger(k))
        fires = _drive(sched, _random_plans(seed, 8, 9, m), drain=False)
        for _, ready in fires:
            assert sum(len(rd) for rd in ready.values()) == k

    @given(seed=st.integers(0, 60), timeout=st.floats(0.8, 3.0))
    @settings(max_examples=12, deadline=None)
    def test_timeout_trigger_fire_spacing(self, seed, timeout):
        sched = EventScheduler(LognormalLatency(sigma=0.6, seed=seed),
                               TimeoutTrigger(timeout))
        fires = _drive(sched, _random_plans(seed, 7, 8, 3), drain=False)
        times = [t for t, _ in fires]
        for t1, t2 in zip(times, times[1:]):
            assert t2 - t1 >= timeout - 1e-6

    @given(seed=st.integers(0, 60), bound=st.integers(0, 3))
    @settings(max_examples=12, deadline=None)
    def test_staleness_bound_respected(self, seed, bound):
        """No consumed update ever exceeds the bound at a non-forced fire
        (the end-of-run drain may force-flush whatever remains)."""
        sched = EventScheduler(LognormalLatency(sigma=0.6, seed=seed),
                               StalenessBoundTrigger(bound),
                               round_interval=1.0)
        for r, clients in enumerate(_random_plans(seed, 7, 8, 3)):
            sched.dispatch(r, clients)
            for t in sched.advance_window():
                ready = sched.take_ready()
                stal = [sched.staleness_of(t, a)
                        for rd in ready.values() for a in rd.values()]
                assert max(stal) <= bound

    @given(seed=st.integers(0, 80),
           trig=st.sampled_from(["count", "timeout", "staleness"]))
    @settings(max_examples=12, deadline=None)
    def test_seeded_determinism_of_event_stream(self, seed, trig):
        plans = _random_plans(seed, 6, 10, 4)

        def run():
            sched = EventScheduler(
                LognormalLatency(median=1.0, sigma=0.5, seed=seed),
                _make_trigger(trig, 4))
            fires = _drive(sched, plans)
            return [(t, sorted((pr, m, a) for pr, rd in ready.items()
                               for m, a in rd.items()))
                    for t, ready in fires], sched.fire_log
        (f1, log1), (f2, log2) = run(), run()
        assert f1 == f2
        assert log1 == log2

    def test_dropout_cancels_in_flight_updates(self):
        """A dropout loses exactly the dropped client's in-flight updates;
        everything else is still consumed exactly once."""
        plans = [[0, 1, 2], [0, 1, 3], [0, 2, 3]]
        lifecycle = ClientLifecycle([LifecycleEvent(1.2, "dropout", 1)])
        sched = EventScheduler(ConstantLatency(2.0), CountTrigger(3),
                               lifecycle=lifecycle)
        fires = _drive(sched, plans)
        consumed = _consumed_members(fires)
        # client 1's dispatches at t=0 and t=1 arrive at t=2, t=3 > 1.2:
        # both in flight at the dropout, both lost; plan 2 avoids client 1
        lost = {(0, 1), (1, 1)}
        want = sorted(set((pr, j) for pr, cl in enumerate(plans)
                          for j in range(len(cl))) - lost)
        assert sorted(consumed) == want
        assert sched.active_clients(4).tolist() == [0, 2, 3]

    def test_rejoin_restores_sampling_pool(self):
        lifecycle = ClientLifecycle([LifecycleEvent(0.5, "dropout", 2),
                                     LifecycleEvent(2.5, "rejoin", 2)])
        sched = EventScheduler(ConstantLatency(1.0), CountTrigger(2),
                               lifecycle=lifecycle)
        sched.dispatch(0, [0, 1])
        for _ in sched.advance_window():
            sched.take_ready()
        assert sched.active_clients(4).tolist() == [0, 1, 3]
        for r in (1, 2):
            sched.dispatch(r, [0, 1])
            for _ in sched.advance_window():
                sched.take_ready()
        assert sched.active_clients(4) is None    # everyone active again

    def test_drain_stops_at_arrival_horizon(self):
        """A lifecycle event scripted far beyond the last arrival must not
        drag the drain's clock (and thus the force-fire's staleness) out
        to it -- the drain ends at the arrival horizon."""
        lifecycle = ClientLifecycle([LifecycleEvent(50.0, "rejoin", 3)])
        sched = EventScheduler(ConstantLatency(2.0), CountTrigger(100),
                               round_interval=1.0, lifecycle=lifecycle)
        sched.dispatch(0, [0, 1])
        for _ in sched.advance_window():
            sched.take_ready()
        fires = []
        for t in sched.drain():
            fires.append((t, sched.take_ready()))
        assert sched.clock.now == 2.0          # horizon, NOT t=50
        assert [t for t, _ in fires] == [2.0]  # forced flush at horizon
        assert sum(len(rd) for rd in fires[0][1].values()) == 2
        assert sched.fire_log[-1].max_staleness == 0

    def test_unit_latency_staleness_equals_plan_age(self):
        """The cadence-reduction identity at the scheduler level: with
        latency == round_interval and a count trigger of depth*m, the
        staleness of plan j's updates at the fire ending round k-1 is
        (k-1) - j, the cadence engine's plan age."""
        m, depth = 3, 3
        sched = EventScheduler(ConstantLatency(1.0),
                               CountTrigger(depth * m), round_interval=1.0)
        fires = _drive(sched, _random_plans(0, 6, 9, m), drain=False)
        assert [t for t, _ in fires] == [3.0, 6.0]
        for t, ready in fires:
            for pr, rd in ready.items():
                for a in rd.values():
                    want = (int(t) - 1) - pr
                    assert sched.staleness_of(t, a) == want


# ---------------------------------------------------------------------------
# RNG stream hygiene + byte-stable replay (protocol-verifier satellites)
# ---------------------------------------------------------------------------

class TestLatencyStreamHygiene:
    """Per-client latency streams come from ``SeedSequence([seed, client])``
    -- distinct (seed, client) pairs must yield disjoint draw sequences,
    even for adversarial seed pairs (swapped entries, off-by-one) that a
    naive ``seed + client`` or ``seed ^ client`` scheme would collide."""

    @staticmethod
    def _draws(seed, client, k=8):
        lat = LognormalLatency(median=1.0, sigma=0.5, seed=seed)
        return tuple(lat.sample(client) for _ in range(k))

    @given(seed=st.integers(0, 10_000))
    @settings(max_examples=25, deadline=None)
    def test_streams_pairwise_disjoint_for_adversarial_seed_pairs(self, seed):
        clients = range(4)
        # adversarial pairings: identical sum, xor, and swapped roles
        pairs = {(seed, c) for c in clients}
        pairs |= {(seed + 1, c) for c in clients}
        pairs |= {(c, seed % 17) for c in clients}      # role swap
        streams = {p: self._draws(*p) for p in pairs}
        items = sorted(streams.items())
        for i, (p1, s1) in enumerate(items):
            for p2, s2 in items[i + 1:]:
                assert not set(s1) & set(s2), (p1, p2)

    @given(seed=st.integers(0, 10_000), k=st.integers(1, 6))
    @settings(max_examples=15, deadline=None)
    def test_stream_depends_only_on_seed_and_client(self, seed, k):
        """Sampling OTHER clients in between (in any order) never perturbs
        a client's own stream -- the isolation scenario edits rely on."""
        solo = self._draws(seed, 2, k)
        lat = LognormalLatency(median=1.0, sigma=0.5, seed=seed)
        interleaved = []
        for i in range(k):
            lat.sample(3 + (i % 2))        # noise draws on clients 3, 4
            interleaved.append(lat.sample(2))
            lat.sample(0)
        assert tuple(interleaved) == solo


class TestFireLogByteStability:
    """Two same-seed runs serialize to IDENTICAL bytes -- fire log, fire
    times, consumed members and the final scheduler state_dict. Equality
    of parsed objects is weaker: byte identity is what the checkpoint and
    audit artifacts diff on."""

    @staticmethod
    def _run_bytes(seed):
        sched = EventScheduler(
            BimodalLatency(fast=0.7, slow=3.1, slow_prob=0.3, seed=seed),
            TimeoutTrigger(1.5),
            lifecycle=ClientLifecycle([LifecycleEvent(1.2, "dropout", 1),
                                       LifecycleEvent(3.4, "rejoin", 1)]))
        fires = _drive(sched, _random_plans(seed, 5, 8, 3))
        blob = {
            "fires": [[t, sorted([pr, m, a] for pr, rd in ready.items()
                                 for m, a in rd.items())]
                      for t, ready in fires],
            "log": [repr(f) for f in sched.fire_log],
            "state": sched.state_dict(),
        }
        return json.dumps(blob, sort_keys=True, default=repr).encode()

    def test_same_seed_runs_byte_identical(self):
        for seed in (0, 7, 123):
            assert self._run_bytes(seed) == self._run_bytes(seed)

    def test_different_seed_runs_differ(self):
        assert self._run_bytes(11) != self._run_bytes(12)


# ---------------------------------------------------------------------------
# partial-cohort (present-masked) weight properties
# ---------------------------------------------------------------------------

n_k_strategy = st.lists(st.integers(1, 300), min_size=3, max_size=10)


class TestPresentMaskWeights:
    """Absent (not-yet-arrived) clients contribute exactly nothing, and the
    present subset's weights are EXACTLY the subset-only computation --
    totals preserved under gamma=1 (no silent down-weighting)."""

    @given(n_k=n_k_strategy, seed=st.integers(0, 50))
    @settings(max_examples=15, deadline=None)
    def test_omega_totals_match_subset_only(self, n_k, seed):
        rng = np.random.default_rng(seed)
        levels = (4, 8, 16)
        ranks = [int(r) for r in rng.choice(levels, size=len(n_k))]
        present = rng.random(len(n_k)) < 0.6
        if not present.any():
            present[0] = True
        agg = Aggregator("raflora", levels)
        warg, fb = agg._present_weight_args(ranks, np.asarray(n_k, float),
                                            present)
        idx = np.flatnonzero(present)
        warg_sub, fb_sub = agg._weight_args(
            [ranks[i] for i in idx], np.asarray(n_k, float)[idx])
        np.testing.assert_array_equal(warg[idx], np.asarray(warg_sub))
        assert not warg[~present].any()          # absent rows exactly zero
        if fb is None:
            assert fb_sub is None
        else:
            np.testing.assert_array_equal(np.asarray(fb),
                                          np.asarray(fb_sub))

    @given(n_k=n_k_strategy, seed=st.integers(0, 50))
    @settings(max_examples=15, deadline=None)
    def test_fedavg_family_weights_total_one_over_present(self, n_k, seed):
        rng = np.random.default_rng(seed)
        present = rng.random(len(n_k)) < 0.6
        if not present.any():
            present[0] = True
        agg = Aggregator("hetlora", (8,))
        warg, _ = agg._present_weight_args([8] * len(n_k),
                                           np.asarray(n_k, float), present)
        assert np.isclose(warg.sum(), 1.0)
        assert not warg[~present].any()

    def test_absent_clients_change_nothing(self):
        """aggregate_grouped with a present mask equals aggregating the
        present subset's stacks alone (absent factor columns are pure
        zero-weight passengers)."""
        import jax
        key = jax.random.PRNGKey(3)
        m, d, n, r = 6, 12, 10, 8
        bs = jax.random.normal(key, (m, 1, d, r))
        as_ = jax.random.normal(jax.random.fold_in(key, 1), (m, 1, r, n))
        gb = jax.random.normal(jax.random.fold_in(key, 2), (1, d, r))
        ga = jax.random.normal(jax.random.fold_in(key, 3), (1, r, n))
        ranks = [4, 8, 4, 8, 4, 8]
        n_k = [10, 20, 30, 40, 50, 60]
        present = [True, False, True, True, False, True]
        idx = np.flatnonzero(present)
        for method in ("flexlora", "raflora", "hetlora"):
            agg = Aggregator(method, (4, 8), backend="dense")
            masked = agg.aggregate_grouped(
                [[bs]], [[as_]], ranks, n_k, global_bs=[gb], global_as=[ga],
                present=present)
            subset = agg.aggregate_grouped(
                [[bs[idx]]], [[as_[idx]]], [ranks[i] for i in idx],
                [n_k[i] for i in idx], global_bs=[gb], global_as=[ga])
            np.testing.assert_allclose(
                np.asarray(masked.b_g @ masked.a_g),
                np.asarray(subset.b_g @ subset.a_g), atol=1e-5)


# ---------------------------------------------------------------------------
# end-to-end scenarios (training runs -- slow tier)
# ---------------------------------------------------------------------------

EXP_KW = dict(
    fl_overrides={"num_rounds": 4, "num_clients": 8, "participation": 0.5},
    lora_overrides={"rank_levels": (4, 8, 16),
                    "rank_probs": (0.34, 0.33, 0.33)},
    samples_per_class=20, num_classes=4, d_model=32, batches_per_round=1)


def _extract_products(server):
    r_max = server.lora_cfg.r_max
    out = {}
    for parent, val in server._extract_factors(server.global_lora,
                                               r_max).items():
        if isinstance(parent, tuple) and len(parent) == 2 \
                and parent[1] == "m":
            out[parent] = np.asarray(val)
        else:
            out[parent] = np.asarray(val[0] @ val[1])
    return out


def _assert_servers_equal(s1, s2, *, atol=0.0):
    assert [s.clients for s in s1.history] == [s.clients for s in s2.history]
    l1 = [s.mean_client_loss for s in s1.history]
    l2 = [s.mean_client_loss for s in s2.history]
    np.testing.assert_allclose(l1, l2, rtol=0, atol=atol)
    np.testing.assert_allclose(s1.energy.rho_r1, s2.energy.rho_r1,
                               rtol=0, atol=atol)
    p1, p2 = _extract_products(s1), _extract_products(s2)
    for parent in p1:
        np.testing.assert_allclose(p1[parent], p2[parent], rtol=0, atol=atol)


@pytest.mark.slow
class TestUnitLatencyCadenceEquivalence:
    """HEADLINE (ISSUE 5 acceptance): CountTrigger(depth * M) + the
    unit-latency trace is BIT-equal to the ``pipeline_depth=depth`` cadence
    async path -- per round, for every method, on every backend."""

    DEPTH = 2

    def _cadence(self, method, backend, lora_over=None):
        kw = dict(EXP_KW)
        if lora_over:
            kw = {**kw, "lora_overrides": lora_over}
        exp = build_experiment(method, round_engine="async",
                               pipeline_depth=self.DEPTH, backend=backend,
                               **kw)
        exp.server.run(4)
        return exp

    def _event(self, method, backend, lora_over=None):
        kw = dict(EXP_KW)
        if lora_over:
            kw = {**kw, "lora_overrides": lora_over}
        m = 4                                      # 8 clients * 0.5
        sched = EventScheduler(ConstantLatency(1.0),
                               CountTrigger(self.DEPTH * m),
                               round_interval=1.0)
        exp = build_experiment(method, round_engine="async",
                               event_scheduler=sched, backend=backend, **kw)
        exp.server.run(4)
        return exp

    @pytest.mark.parametrize("backend", ("dense", "factored", "kernel"))
    @pytest.mark.parametrize("method", METHODS)
    def test_count_trigger_unit_trace_matches_cadence(self, method,
                                                      backend):
        lora_over = ({"rank_levels": (8,), "rank_probs": (1.0,)}
                     if method == "fedavg"       # fedavg needs equal ranks
                     else None)
        cad = self._cadence(method, backend, lora_over)
        evt = self._event(method, backend, lora_over)
        _assert_servers_equal(cad.server, evt.server, atol=0.0)
        # the event run also carried virtual time and its fire log matches
        # the cadence: one aggregation per DEPTH rounds, full cohorts
        sched = evt.server.event_scheduler
        assert [s.virtual_time for s in evt.server.history] == \
            [1.0, 2.0, 3.0, 4.0]
        assert [f.consumed for f in sched.fire_log] == [8, 8]
        assert all(f.max_staleness == self.DEPTH - 1
                   for f in sched.fire_log)


@pytest.mark.slow
class TestEventScenarios:
    """Straggler / dropout / join scenarios end-to-end through training."""

    def test_timeout_with_stragglers_partial_cohorts(self):
        """Straggler-tail latency + timeout trigger: fires consume PARTIAL
        cohorts (stragglers excluded until they arrive), every trained
        update is still aggregated exactly once by the end."""
        sched = EventScheduler(
            StragglerTailLatency(median=0.8, sigma=0.2, tail_scale=6.0,
                                 straggler_clients=(0, 1, 2, 3), seed=11),
            TimeoutTrigger(2.0), round_interval=1.0)
        exp = build_experiment("raflora", round_engine="async",
                               event_scheduler=sched, **EXP_KW)
        exp.server.run(4)
        exp.server.drain_pending()
        m = exp.server.fl.clients_per_round
        consumed = sum(f.consumed for f in sched.fire_log)
        assert consumed == 4 * m                   # exactly once overall
        assert len(sched.fire_log) >= 2
        assert any(f.consumed < 2 * m for f in sched.fire_log)  # partial
        assert all(np.isfinite(s.mean_client_loss)
                   for s in exp.server.history)
        assert len(exp.server._pending) == 0

    def test_staleness_bound_trigger_run(self):
        sched = EventScheduler(
            LognormalLatency(median=1.2, sigma=0.5, seed=7),
            StalenessBoundTrigger(1), round_interval=1.0)
        exp = build_experiment("raflora", round_engine="async",
                               event_scheduler=sched, **EXP_KW)
        exp.server.run(4)
        exp.server.drain_pending()
        assert all(f.max_staleness <= 1 for f in sched.fire_log[:-1])
        assert sum(f.consumed for f in sched.fire_log) == \
            4 * exp.server.fl.clients_per_round

    def test_dropout_and_midrun_join(self):
        """A dropout leaves the pool (and loses its in-flight update); a
        mid-run join enters the registry and the pool; the run completes
        with every surviving update aggregated exactly once."""
        # the joined client reuses client 0's data shard; id 8 == current
        # registry size (8 clients)
        kw = {**EXP_KW,
              "fl_overrides": {**EXP_KW["fl_overrides"], "num_rounds": 6}}
        probe = build_experiment("raflora", round_engine="batched", **kw)
        shard = probe.registry.shards[0]
        lifecycle = ClientLifecycle([
            LifecycleEvent(1.5, "dropout", 2),
            LifecycleEvent(2.5, "join", 8, rank=16, shard=shard),
        ])
        sched = EventScheduler(ConstantLatency(2.0), CountTrigger(4),
                               round_interval=1.0, lifecycle=lifecycle)
        exp = build_experiment("raflora", round_engine="async",
                               event_scheduler=sched, **kw)
        exp.server.run(6)
        exp.server.drain_pending()
        assert exp.server.registry.num_clients == 9
        sampled = [c for s in exp.server.history for c in s.clients]
        rounds_after_drop = exp.server.history[2:]
        assert all(2 not in s.clients for s in rounds_after_drop)
        dispatched = len(sampled)
        consumed = sum(f.consumed for f in sched.fire_log)
        # in-flight updates of client 2 at drop time are lost, nothing else
        lost = dispatched - consumed
        early = [c for s in exp.server.history[:2] for c in s.clients]
        assert lost == early.count(2)
        assert all(np.isfinite(s.mean_client_loss)
                   for s in exp.server.history)


@pytest.mark.slow
class TestSeededDeterminismAndTraceReplay:
    """Same seed + same trace => identical global factors (bitwise)."""

    def _run(self, latency, rounds=3):
        sched = EventScheduler(latency, TimeoutTrigger(1.5),
                               round_interval=1.0)
        exp = build_experiment("raflora", round_engine="async",
                               event_scheduler=sched, **EXP_KW)
        exp.server.run(rounds)
        exp.server.drain_pending()
        return exp, sched

    def test_same_seed_identical_run(self):
        e1, s1 = self._run(LognormalLatency(median=1.0, sigma=0.6, seed=9))
        e2, s2 = self._run(LognormalLatency(median=1.0, sigma=0.6, seed=9))
        assert s1.fire_log == s2.fire_log
        _assert_servers_equal(e1.server, e2.server, atol=0.0)

    def test_jsonl_trace_roundtrip(self, tmp_path):
        records = [TraceRecord(0, 1.25), TraceRecord(3, 0.5),
                   TraceRecord(1, 4.0)]
        path = str(tmp_path / "lat.jsonl")
        write_trace(path, records)
        back = read_trace(path)
        assert back == records
        assert trace_schedule(back) == [0, 3, 1]
        unit = constant_trace([2, 5, 2], latency=2.0)
        assert all(r.latency == 2.0 for r in unit)

    def test_recorded_trace_replays_identically(self, tmp_path):
        """Record a heterogeneous-latency run to JSONL, replay it through
        TraceLatency: identical fire log and bitwise-identical factors."""
        rec = RecordingLatency(
            BimodalLatency(fast=0.8, slow=2.6, slow_prob=0.4, seed=21))
        e1, s1 = self._run(rec)
        path = str(tmp_path / "trace.jsonl")
        write_trace(path, rec.records)

        e2, s2 = self._run(TraceLatency(read_trace(path)))
        assert s1.fire_log == s2.fire_log
        _assert_servers_equal(e1.server, e2.server, atol=0.0)
