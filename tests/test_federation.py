"""Integration tests: the full federated loop (Algorithm 1) end-to-end,
including the paper's headline claims at CPU scale."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import FLConfig, LoRAConfig
from repro.core.lora import (adapter_paths, lora_only, merge_lora,
                             pad_adapters, split_lora, truncate_adapters)
from repro.federation.experiment import build_experiment


@pytest.fixture(scope="module")
def quick():
    def make(method, **kw):
        over = {"num_rounds": 6, "num_clients": 10, "participation": 0.5}
        over.update(kw.pop("fl_overrides", {}))
        return build_experiment(method, fl_overrides=over,
                                samples_per_class=40, num_classes=8,
                                d_model=64, batches_per_round=1, **kw)
    return make


@pytest.mark.slow
class TestRoundLoop:
    def test_loss_decreases_and_accuracy_improves(self, quick):
        exp = quick("raflora")
        acc0 = exp.eval_accuracy()
        hist = exp.server.run(6)
        assert hist[-1].mean_client_loss < hist[0].mean_client_loss
        assert exp.eval_accuracy() > acc0

    def test_round_stats_recorded(self, quick):
        exp = quick("flexlora")
        exp.server.run(3)
        h = exp.server.history
        assert len(h) == 3
        assert all(len(s.clients) == 5 for s in h)
        assert all(r in (4, 8, 16, 24, 32) for s in h for r in s.ranks)
        assert len(exp.server.energy.rho_r1) == 3

    def test_lr_linear_decay(self, quick):
        exp = quick("raflora")
        exp.server.run(3)
        lrs = [s.lr for s in exp.server.history]
        assert lrs[0] > lrs[1] > lrs[2]

    @pytest.mark.parametrize("method", ["hetlora", "flora", "flexlora",
                                        "raflora"])
    def test_all_methods_run(self, quick, method):
        exp = quick(method, fl_overrides={"num_rounds": 2})
        exp.server.run(2)
        assert np.isfinite(exp.server.history[-1].mean_client_loss)

    def test_checkpoint_roundtrip(self, quick, tmp_path):
        exp = quick("raflora")
        exp.server.run(2)
        path = str(tmp_path / "ckpt")
        exp.server.save(path)
        acc = exp.eval_accuracy()
        exp2 = quick("raflora")
        exp2.server.restore(path)
        assert exp2.server.round_idx == 2
        assert abs(exp2.eval_accuracy() - acc) < 1e-6


@pytest.mark.slow
class TestCheckpointResumeState:
    """ISSUE 2 satellites: ``restore`` must bring back the rng stream, the
    energy trace, and the round history -- a resumed run previously drew a
    DIFFERENT client-sampling sequence and judged collapse on a truncated
    trace."""

    def test_energy_trace_respects_ctor_args(self):
        from repro.core.energy import EnergyTrace
        tr = EnergyTrace((4, 8), rho_r1=[0.5, 0.6], eff_rank=[2.0, 3.0],
                         breakdown=[{"rank_1_4": 1.0}, {"rank_1_4": 0.9}])
        assert tr.rho_r1 == [0.5, 0.6]          # was silently reset to []
        assert tr.eff_rank == [2.0, 3.0]
        assert len(tr.breakdown) == 2
        assert EnergyTrace((4, 8)).rho_r1 == []  # default still empty
        back = EnergyTrace.from_state(tr.state_dict())
        assert back.rho_r1 == tr.rho_r1
        assert back.collapsed() == tr.collapsed()

    def test_resume_reproduces_uninterrupted_run(self, quick, tmp_path):
        """save -> restore -> run must reproduce the uninterrupted run's
        client-sampling sequence EXACTLY (and its stats to float noise)."""
        full = quick("raflora")
        full.server.run(4)

        part = quick("raflora")
        part.server.run(2)
        path = str(tmp_path / "resume_ckpt")
        part.server.save(path)

        resumed = quick("raflora")
        resumed.server.restore(path)
        assert resumed.server.round_idx == 2
        assert len(resumed.server.history) == 2
        assert len(resumed.server.energy.rho_r1) == 2
        resumed.server.run(2)

        assert len(resumed.server.history) == 4
        for s_full, s_res in zip(full.server.history,
                                 resumed.server.history):
            assert s_full.clients == s_res.clients   # exact sampling stream
            assert s_full.ranks == s_res.ranks
            np.testing.assert_allclose(s_full.mean_client_loss,
                                       s_res.mean_client_loss, rtol=1e-5)
        np.testing.assert_allclose(full.server.energy.rho_r1,
                                   resumed.server.energy.rho_r1, rtol=1e-5)
        assert (full.server.energy.collapsed()
                == resumed.server.energy.collapsed())

    def test_resume_with_server_momentum_matches_uninterrupted(self, quick,
                                                               tmp_path):
        """ISSUE 3 satellite: ``save``/``restore`` must carry the
        FactoredServerMomentum (B_m, A_m) state -- a resumed
        ``server_momentum_beta > 0`` run previously restarted momentum
        from zero and diverged from the uninterrupted run."""
        kw = dict(server_momentum_beta=0.9)
        full = quick("raflora", **kw)
        full.server.run(4)

        part = quick("raflora", **kw)
        part.server.run(2)
        assert part.server.server_momentum.state   # momentum accumulated
        path = str(tmp_path / "momentum_ckpt")
        part.server.save(path)

        resumed = quick("raflora", **kw)
        resumed.server.restore(path)
        assert resumed.server.server_momentum.state  # state restored
        resumed.server.run(2)

        for s_full, s_res in zip(full.server.history,
                                 resumed.server.history):
            assert s_full.clients == s_res.clients
            np.testing.assert_allclose(s_full.mean_client_loss,
                                       s_res.mean_client_loss, rtol=1e-5)
            np.testing.assert_allclose(s_full.sigma_probe, s_res.sigma_probe,
                                       rtol=1e-4, atol=1e-5)
        for a, b in zip(jax.tree.leaves(full.server.global_lora),
                        jax.tree.leaves(resumed.server.global_lora)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-4, atol=1e-5)


@pytest.mark.slow
class TestPaperClaims:
    """The paper's qualitative claims, reproduced in-training (not just in
    the closed-form theory model)."""

    def test_flexlora_collapses_raflora_prevents(self):
        results = {}
        for method in ("flexlora", "raflora"):
            exp = build_experiment(method,
                                   fl_overrides={"num_rounds": 12},
                                   samples_per_class=60, num_classes=12,
                                   d_model=96, batches_per_round=1)
            exp.server.run(12)
            results[method] = exp.server.energy.higher_rank_ratio
        # FlexLoRA: higher-rank energy decays markedly (rank collapse);
        # raFLoRA: preserved
        assert results["flexlora"][-1] < 0.5 * results["flexlora"][0]
        assert results["raflora"][-1] > 0.8 * results["raflora"][0]

    def test_single_participant_equivalence(self):
        """Sec 6.5: with one max-rank client per round raFLoRA reduces to
        FlexLoRA (no dilution to correct). NOTE: if the lone client's rank
        is below r_max the two DIFFER by design -- raFLoRA's Eq. 8 fallback
        retains the global higher-rank slices where FlexLoRA zeroes them --
        so equivalence is asserted for rank == r_max clients."""
        outs = {}
        for method in ("flexlora", "raflora"):
            exp = build_experiment(
                method, fl_overrides={"num_rounds": 2, "num_clients": 4,
                                      "participation": 0.25, "seed": 7},
                lora_overrides={"rank_levels": (16, 32),
                                "rank_probs": (0.0, 1.0)},  # all r_max
                samples_per_class=30, num_classes=6, d_model=64,
                batches_per_round=1)
            exp.server.run(2)
            outs[method] = jax.tree.leaves(exp.server.global_lora)
        for a, b in zip(outs["flexlora"], outs["raflora"]):
            if a is None:
                continue
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=1e-4)

    def test_low_rank_single_client_keeps_global_tail(self):
        """The Eq. 8 fallback in action: one rank-4 client must not erase
        the global update's higher-rank partitions under raFLoRA."""
        import jax.numpy as jnp
        from repro.core import aggregate_flexlora, aggregate_raflora, pad_stack
        key = jax.random.PRNGKey(0)
        b4 = jax.random.normal(key, (16, 4))
        a4 = jax.random.normal(jax.random.fold_in(key, 1), (4, 16))
        bs, as_ = pad_stack([(b4, a4)], 8)
        g_b = jax.random.normal(jax.random.fold_in(key, 2), (16, 8))
        g_a = jax.random.normal(jax.random.fold_in(key, 3), (8, 16))
        res_ra = aggregate_raflora(bs, as_, [4], [1.0], rank_levels=[4, 8],
                                   global_b=g_b, global_a=g_a,
                                   backend="dense")
        res_fl = aggregate_flexlora(bs, as_, [4], [1.0], backend="dense")
        # flexlora: pure client update (rank <= 4); raflora adds the global
        # [5..8] slice back
        tail = np.asarray(g_b[:, 4:]) @ np.asarray(g_a[4:, :])
        diff = np.asarray(res_ra.b_g @ res_ra.a_g
                          - res_fl.b_g @ res_fl.a_g)
        np.testing.assert_allclose(diff, tail, atol=1e-3)


@pytest.mark.slow
class TestRoundEngineEquivalence:
    """The batched round engine (vmapped client groups + bucketed stacked
    aggregation) must reproduce the sequential reference engine to float
    tolerance -- for every aggregation method."""

    @pytest.mark.parametrize("method", ["fedavg", "hetlora", "flora",
                                        "flexlora", "raflora", "ffa"])
    def test_batched_matches_sequential(self, method):
        """One round from identical state must match to <=1e-4 relative.

        NOTE deliberately a single round: across MULTIPLE rounds the two
        engines drift apart chaotically -- the truncated SVD's noise-tail
        directions are nearly degenerate, so a ~1e-5 same-round difference
        moves the kept subspace and training amplifies it. That sensitivity
        is a property of SVD reallocation, not an engine bug; per-round
        equivalence is the invariant the engines guarantee."""
        from repro.core.aggregation import METHODS
        assert method in METHODS
        lora_over = ({"rank_levels": (8,), "rank_probs": (1.0,)}
                     if method == "fedavg"       # fedavg needs equal ranks
                     else {"rank_levels": (4, 8, 16),
                           "rank_probs": (0.34, 0.33, 0.33)})
        runs = {}
        for engine in ("sequential", "batched"):
            exp = build_experiment(
                method,
                fl_overrides={"num_rounds": 1, "num_clients": 8,
                              "participation": 0.5},
                lora_overrides=lora_over,
                samples_per_class=30, num_classes=6, d_model=32,
                batches_per_round=1, round_engine=engine)
            hist = exp.server.run(1)
            runs[engine] = (exp, hist)
        (e_seq, h_seq), (e_bat, h_bat) = runs["sequential"], runs["batched"]
        for s1, s2 in zip(h_seq, h_bat):
            assert s1.clients == s2.clients and s1.ranks == s2.ranks
            np.testing.assert_allclose(s1.mean_client_loss,
                                       s2.mean_client_loss, rtol=1e-4)
            if s1.sigma_probe is not None:
                np.testing.assert_allclose(s1.sigma_probe, s2.sigma_probe,
                                           rtol=1e-4, atol=1e-4)
        # adapter products (sign-stable, unlike raw SVD factors)
        r_max = e_seq.server.lora_cfg.r_max
        f_seq = e_seq.server._extract_factors(e_seq.server.global_lora,
                                              r_max)
        f_bat = e_bat.server._extract_factors(e_bat.server.global_lora,
                                              r_max)
        for parent in f_seq:
            if isinstance(parent, tuple) and len(parent) == 2 \
                    and parent[1] == "m":
                np.testing.assert_allclose(np.asarray(f_seq[parent]),
                                           np.asarray(f_bat[parent]),
                                           rtol=1e-4, atol=1e-5)
                continue
            d1 = np.asarray(f_seq[parent][0] @ f_seq[parent][1])
            d2 = np.asarray(f_bat[parent][0] @ f_bat[parent][1])
            np.testing.assert_allclose(
                d1, d2, atol=1e-4 * max(1.0, np.abs(d1).max()))
        # FLoRA folds dW into the base weights: compare those too
        for a, b in zip(jax.tree.leaves(e_seq.server.base),
                        jax.tree.leaves(e_bat.server.base)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-4, atol=1e-5)

    def test_masked_group_training_matches_per_rank(self):
        """train_group_masked (all ranks, one dispatch) == train_group (per
        rank group) == sequential train, on the same clients."""
        exp = build_experiment(
            "raflora",
            fl_overrides={"num_rounds": 1, "num_clients": 4,
                          "participation": 1.0},
            lora_overrides={"rank_levels": (4, 16),
                            "rank_probs": (0.5, 0.5)},
            samples_per_class=20, num_classes=4, d_model=32,
            batches_per_round=1)
        srv = exp.server
        rng = np.random.default_rng(0)
        clients = list(range(4))
        ranks = [int(srv.registry.ranks[c]) for c in clients]
        batches = [srv.batch_fn(c, rng) for c in clients]
        lr = 1e-3
        # sequential reference, per client
        seq = [srv.trainer.train(srv.base, srv.global_lora, r, b, lr)[0]
               for r, b in zip(ranks, batches)]
        # per-rank-group vmapped training
        rank_groups = {}
        for i, r in enumerate(ranks):
            rank_groups.setdefault(r, []).append(i)
        grp = {}
        for rank, idxs in rank_groups.items():
            g_stacks = [jax.tree.map(lambda *xs: jnp.stack(xs),
                                     *[batches[i][t] for i in idxs])
                        for t in range(len(batches[idxs[0]]))]
            lora_g, _ = srv.trainer.train_group(
                srv.base, srv.global_lora, rank, g_stacks, lr, len(idxs))
            for j, i in enumerate(idxs):
                grp[i] = jax.tree.map(lambda x: x[j], lora_g)
        # masked all-rank group
        steps = len(batches[0])
        stacks = [jax.tree.map(lambda *xs: jnp.stack(xs),
                               *[b[t] for b in batches])
                  for t in range(steps)]
        lora_m, _ = srv.trainer.train_group_masked(
            srv.base, srv.global_lora, ranks, stacks, lr)
        r_max = srv.lora_cfg.r_max
        for i, rank in enumerate(ranks):
            f_seq = srv._extract_factors(seq[i], rank)
            f_grp = srv._extract_factors(grp[i], rank)
            f_msk = srv._extract_factors(
                jax.tree.map(lambda x: x[i], lora_m), r_max)
            for parent, (b_s, a_s) in f_seq.items():
                if isinstance(parent, tuple) and len(parent) == 2 \
                        and parent[1] == "m":
                    continue
                b_g, a_g = f_grp[parent]
                np.testing.assert_allclose(np.asarray(b_g), np.asarray(b_s),
                                           rtol=1e-4, atol=1e-5)
                np.testing.assert_allclose(np.asarray(a_g), np.asarray(a_s),
                                           rtol=1e-4, atol=1e-5)
                b_m, a_m = f_msk[parent]
                # masked factors are zero beyond rank: slice for comparison
                np.testing.assert_allclose(
                    np.asarray(b_m[..., :rank]), np.asarray(b_s),
                    rtol=1e-4, atol=1e-5)
                np.testing.assert_allclose(
                    np.asarray(a_m[..., :rank, :]), np.asarray(a_s),
                    rtol=1e-4, atol=1e-5)
                assert not np.any(np.asarray(b_m[..., rank:]))
                assert not np.any(np.asarray(a_m[..., rank:, :]))


class TestLoRATreeUtils:
    def test_split_merge_roundtrip(self, rng_key):
        from repro.configs import get_config
        from repro.models import build_model
        cfg = get_config("qwen2-7b").reduced()
        model = build_model(cfg, LoRAConfig(rank_levels=(4, 8)),
                            dtype=jnp.float32, remat=False)
        params = model.init(rng_key)
        base, lora = split_lora(params)
        merged = merge_lora(base, lora)
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(merged)):
            assert a is b or np.array_equal(np.asarray(a), np.asarray(b))

    def test_truncate_pad_roundtrip(self, rng_key):
        from repro.configs import get_config
        from repro.models import build_model
        cfg = get_config("gemma-2b").reduced()
        model = build_model(cfg, LoRAConfig(rank_levels=(4, 8, 16)),
                            dtype=jnp.float32, remat=False)
        _, lora = split_lora(model.init(rng_key))
        trunc = truncate_adapters(lora, 4)
        padded = pad_adapters(trunc, 16)
        # shapes restored; content equals truncation then zero-fill
        for p, l in zip(jax.tree.leaves(padded), jax.tree.leaves(lora)):
            assert p.shape == l.shape

    def test_adapter_paths_found(self, rng_key):
        from repro.configs import get_config
        from repro.models import build_model
        cfg = get_config("mamba2-1.3b").reduced()
        model = build_model(cfg, LoRAConfig(), dtype=jnp.float32,
                            remat=False)
        params = model.init(rng_key)
        paths = adapter_paths(params)
        # mamba2 lora targets: ssm in/out projections
        assert len(paths) == 2
        for ab in paths.values():
            assert set(ab) == {"a", "b"}
