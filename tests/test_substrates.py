"""Substrate tests: data partitioning, optimizer, schedules, checkpointing,
energy metrics, sharding specs, HLO walker."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline: deterministic fixed-grid shim
    from _hypothesis_compat import given, settings, strategies as st

from repro.core.energy import (effective_rank, energy_breakdown,
                               higher_rank_energy_ratio, rho)
from repro.data import (ClusterClassification, SequenceCopy, batches,
                        make_partition)
from repro.optim import AdamW, get_schedule


class TestPartitioning:
    @pytest.mark.parametrize("kind", ["iid", "dirichlet", "pathological"])
    def test_covers_all_indices_without_duplication_iid(self, kind):
        labels = np.random.default_rng(0).integers(0, 20, size=2000)
        shards = make_partition(kind, labels, 10, alpha=1.0,
                                labels_per_client=5, seed=0)
        assert len(shards) == 10
        assert all(len(s) > 0 for s in shards)
        if kind == "iid":
            allidx = np.concatenate(shards)
            assert len(np.unique(allidx)) == 2000

    def test_dirichlet_skew_increases_with_small_alpha(self):
        labels = np.random.default_rng(0).integers(0, 20, size=4000)

        def skew(alpha):
            shards = make_partition("dirichlet", labels, 10, alpha=alpha,
                                    seed=1)
            # mean per-client label entropy (lower = more skewed)
            ents = []
            for s in shards:
                counts = np.bincount(labels[s], minlength=20) + 1e-9
                p = counts / counts.sum()
                ents.append(-(p * np.log(p)).sum())
            return np.mean(ents)

        assert skew(0.05) < skew(100.0)

    def test_pathological_label_limit(self):
        labels = np.random.default_rng(0).integers(0, 20, size=4000)
        shards = make_partition("pathological", labels, 10, alpha=1.0,
                                labels_per_client=3, seed=0)
        for s in shards:
            assert len(np.unique(labels[s])) <= 3

    def test_batches_iterator(self):
        x = np.arange(100).reshape(50, 2).astype(np.float32)
        y = np.arange(50)
        rng = np.random.default_rng(0)
        got = list(batches(x, y, 16, rng))
        assert len(got) == 3
        assert all(b[0].shape == (16, 2) for b in got)


class TestSyntheticData:
    def test_cluster_classification_separable(self):
        data = ClusterClassification(num_classes=5, dim=32, noise=0.1,
                                     samples_per_class=40)
        x, y = data.generate()
        assert x.shape == (200, data.patches, 32)
        # nearest-class-mean classifier should beat chance comfortably
        means = np.stack([x[y == c].mean(axis=0).ravel() for c in range(5)])
        flat = x.reshape(len(y), -1)
        pred = np.argmin(((flat[:, None] - means[None]) ** 2).sum(-1), axis=1)
        assert (pred == y).mean() > 0.9

    def test_sequence_copy_targets_shifted(self):
        d = SequenceCopy(vocab_size=64, seq_len=16, num_families=4,
                         samples_per_family=10)
        toks, targets, fam = d.generate()
        assert np.array_equal(targets[:, :-1], toks[:, 1:])


class TestOptim:
    def test_adamw_descends_quadratic(self):
        opt = AdamW()
        params = {"w": jnp.array([3.0, -2.0])}
        state = opt.init(params)

        @jax.jit
        def step(params, state):    # one compile, 200 cheap iterations
            grads = {"w": 2 * params["w"]}
            return opt.update(grads, state, params, 0.05)

        for _ in range(200):
            params, state = step(params, state)
        assert float(jnp.abs(params["w"]).max()) < 0.05

    def test_none_leaves_passthrough(self):
        opt = AdamW()
        params = {"w": jnp.ones(3), "frozen": None}
        state = opt.init(params)
        grads = {"w": jnp.ones(3), "frozen": None}
        new, _ = opt.update(grads, state, params, 0.1)
        assert new["frozen"] is None
        assert not np.allclose(np.asarray(new["w"]), 1.0)

    def test_linear_decay_schedule(self):
        s = get_schedule("linear", 1.0, 10)
        assert s(0) == 1.0
        assert np.isclose(s(5), 0.5)
        assert s(10) == 0.0


class TestCheckpoint:
    def test_roundtrip_nested(self, tmp_path):
        from repro.checkpointing import load_pytree, save_pytree
        tree = {"a": {"b": jnp.arange(6).reshape(2, 3),
                      "lora": None},
                "c": [jnp.ones(4), jnp.zeros((2, 2))]}
        path = str(tmp_path / "t.npz")
        save_pytree(path, tree, metadata={"round": 3})
        got = load_pytree(path, tree)
        np.testing.assert_array_equal(np.asarray(got["a"]["b"]),
                                      np.asarray(tree["a"]["b"]))
        assert got["a"]["lora"] is None


class TestEnergyMetrics:
    @given(st.lists(st.floats(0.01, 100.0), min_size=2, max_size=64))
    @settings(max_examples=50, deadline=None)
    def test_rho_monotone_in_r(self, sigmas):
        # a bounded sample of r values (ends always included) keeps the
        # monotonicity check while capping the eager-op count -- probing
        # every r at every example dominated tier-1 wall time
        s = jnp.asarray(sorted(sigmas, reverse=True))
        n = len(sigmas)
        rs = sorted(set([1, 2, n - 1, n] + list(range(1, n + 1, max(1, n // 6)))))
        rhos = [float(rho(s, r)) for r in rs]
        assert all(b >= a - 1e-6 for a, b in zip(rhos, rhos[1:]))
        assert np.isclose(rhos[-1], 1.0)

    def test_effective_rank_bounds(self):
        s = jnp.ones(16)
        assert np.isclose(float(effective_rank(s)), 16.0, rtol=1e-4)
        s = jnp.array([1.0] + [0.0] * 15)
        assert float(effective_rank(s)) < 1.01

    def test_breakdown_sums_to_one(self):
        s = jnp.linspace(10, 0.1, 64)
        bd = energy_breakdown(s, [8, 16, 32, 48, 64])
        assert np.isclose(sum(bd.values()), 1.0)

    def test_collapsed_before_any_record(self):
        """Regression: collapsed() used to IndexError on an empty trace."""
        from repro.core.energy import EnergyTrace
        trace = EnergyTrace([8, 16, 32])
        assert trace.collapsed() is False
        trace.record(jnp.concatenate([jnp.ones(8), jnp.full(24, 1e-6)]))
        assert trace.collapsed() is True
        trace.record(jnp.ones(32))
        assert trace.collapsed() is False


class TestShardingSpecs:
    def test_sanitize_drops_nondivisible(self):
        import types
        from jax.sharding import PartitionSpec as P
        from repro.sharding.specs import sanitize_spec
        mesh = types.SimpleNamespace(shape={"data": 16, "model": 4})
        spec = sanitize_spec(P("data", None), (49, 64), mesh, rescue=False)
        assert spec == P(None, None)
        spec = sanitize_spec(P("data", None), (64, 64), mesh)
        assert spec == P("data", None)
        # rescue moves the dropped axis to a big divisible dim
        spec = sanitize_spec(P("data", None), (49, 2048), mesh)
        assert spec == P(None, "data")

    def test_param_specs_cover_tree(self):
        from repro.configs import LoRAConfig, get_config
        from repro.models import build_model
        from repro.sharding import param_specs
        model = build_model(get_config("qwen2-7b").reduced(), LoRAConfig(),
                            dtype=jnp.float32, remat=False)
        specs = param_specs(model)
        shapes = model.param_shapes()
        assert jax.tree_util.tree_structure(specs) == \
            jax.tree_util.tree_structure(shapes)


class TestHLOWalker:
    def test_scan_trip_counts(self):
        from repro.launch.hlo_walker import analyze_hlo

        def scanned(x, w):
            def body(c, _):
                return c @ w, None
            y, _ = jax.lax.scan(body, x, None, length=7)
            return y

        x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
        w = jax.ShapeDtypeStruct((128, 128), jnp.float32)
        c = jax.jit(scanned).lower(x, w).compile()
        st_ = analyze_hlo(c.as_text())
        assert abs(st_.dot_flops - 7 * 2 * 128 ** 3) < 1e-3

    def test_collective_bytes_parse(self):
        from repro.launch.hlo_walker import _bytes_of
        assert _bytes_of("f32[8,16]{1,0}") == 512
        assert _bytes_of("(bf16[4,4], s32[])") == 36
