"""Unit tests for ``analysis/liveness`` (complexity-certifier tentpole):
the peak-live-bytes model on hand-written HLO fixtures -- def-to-last-use
schedule walk, never-read results, fusion virtuality, callee transients
through ``while`` and ``conditional`` branch_computations -- plus a real
compiled dense-vs-factored comparison pinning the property the certifier
gates on (the dense backend's resident set carries a (d, n) buffer, the
factored one never does).
"""
import pytest

from repro.analysis.liveness import analyze_liveness, peak_live_bytes

_STRAIGHT_LINE = """\
HloModule m

ENTRY %main (x: f32[4,8], y: f32[8,4]) -> f32[4,4] {
  %x = f32[4,8]{1,0} parameter(0)
  %y = f32[8,4]{1,0} parameter(1)
  %d = f32[4,4]{1,0} dot(f32[4,8]{1,0} %x, f32[8,4]{1,0} %y), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  ROOT %r = f32[4,4]{1,0} negate(f32[4,4]{1,0} %d)
}
"""


class TestScheduleWalk:
    def test_straight_line_peak_at_last_use(self):
        """x (128B) + y (128B) + d (64B) are simultaneously live at the
        dot; x and y die there, so the root adds only 64B to d's 64B."""
        stats = analyze_liveness(_STRAIGHT_LINE)
        assert stats.peak_live_bytes == 128 + 128 + 64
        assert stats.peak_location == "main/d"

    def test_never_read_result_dies_immediately(self):
        """Two dead 4000B broadcasts never coexist: each dies at its own
        def, so the peak holds ONE of them, not both."""
        text = """\
HloModule m

ENTRY %main (x: f32[4]) -> f32[4] {
  %x = f32[4]{0} parameter(0)
  %dead1 = f32[10,100]{1,0} broadcast(f32[4]{0} %x), dimensions={0}
  %dead2 = f32[10,100]{1,0} broadcast(f32[4]{0} %x), dimensions={0}
  ROOT %r = f32[4]{0} negate(f32[4]{0} %x)
}
"""
        assert peak_live_bytes(text) == 16 + 4000

    def test_fusion_body_is_virtual(self):
        """Only the fusion's result buffer counts -- the 4MB intermediate
        inside the fused computation is never materialized (matches the
        walker's HBM model)."""
        text = """\
HloModule m

%fused (p: f32[4]) -> f32[4] {
  %p = f32[4]{0} parameter(0)
  %huge = f32[1000,1000]{1,0} broadcast(f32[4]{0} %p), dimensions={0}
  ROOT %o = f32[4]{0} slice(f32[1000,1000]{1,0} %huge), slice={[0:4], [0:1]}
}

ENTRY %main (x: f32[4]) -> f32[4] {
  %x = f32[4]{0} parameter(0)
  ROOT %f = f32[4]{0} fusion(f32[4]{0} %x), kind=kLoop, calls=%fused
}
"""
        assert peak_live_bytes(text) == 16 + 16


_CONDITIONAL = """\
HloModule m

%br0 (p: f32[4]) -> f32[4] {
  %p = f32[4]{0} parameter(0)
  %big = f32[64,64]{1,0} broadcast(f32[4]{0} %p), dimensions={0}
  ROOT %r = f32[4]{0} slice(f32[64,64]{1,0} %big), slice={[0:4], [0:1]}
}

%br1 (p: f32[4]) -> f32[4] {
  %p = f32[4]{0} parameter(0)
  ROOT %r = f32[4]{0} negate(f32[4]{0} %p)
}

ENTRY %main (i: s32[], x: f32[4]) -> f32[4] {
  %i = s32[] parameter(0)
  %x = f32[4]{0} parameter(1)
  ROOT %c = f32[4]{0} conditional(s32[] %i, f32[4]{0} %x, f32[4]{0} %x), branch_computations={%br0, %br1}
}
"""


class TestCalleeTransients:
    def test_conditional_adds_max_branch_peak(self):
        """The call site transiently carries the WORST branch's peak on
        top of the caller's live set (branch_computations traversal --
        the walker fix this PR ships; without it the branches would be
        unreachable and contribute nothing)."""
        stats = analyze_liveness(_CONDITIONAL)
        # br0: p (16) + big (16384) live at the broadcast, +r (16) at root
        assert stats.comp_peaks["br0"] == 16 + 16384
        assert stats.comp_peaks["br1"] == 16 + 16
        # entry: i (4) + x (16) + c (16) live at the conditional, plus
        # the max branch transient
        assert stats.peak_live_bytes == 4 + 16 + 16 + (16 + 16384)
        assert stats.peak_location == "main/c"

    def test_while_adds_body_peak(self):
        text = """\
HloModule m

%body (p: f32[8]) -> f32[8] {
  %p = f32[8]{0} parameter(0)
  %sq = f32[32,32]{1,0} broadcast(f32[8]{0} %p), dimensions={0}
  ROOT %r = f32[8]{0} slice(f32[32,32]{1,0} %sq), slice={[0:8], [0:1]}
}

%cond (p: f32[8]) -> pred[] {
  %p = f32[8]{0} parameter(0)
  ROOT %lt = pred[] constant(false)
}

ENTRY %main (x: f32[8]) -> f32[8] {
  %x = f32[8]{0} parameter(0)
  ROOT %w = f32[8]{0} while(f32[8]{0} %x), condition=%cond, body=%body
}
"""
        stats = analyze_liveness(text)
        # body peak: p (32) + sq (4096), entry: x (32) + w (32) + body
        assert stats.comp_peaks["body"] == 32 + 4096
        assert stats.peak_live_bytes == 32 + 32 + (32 + 4096)


class TestRealPrograms:
    @pytest.mark.slow
    def test_dense_carries_dn_buffer_factored_does_not(self):
        """The property the certifier's dn ladder gates: the dense
        backend's peak resident set includes the (d, n) dW, the factored
        backend's stays an order of magnitude below it at d = n = 256."""
        from repro.analysis.lowering import ProgramPoint, lower_program
        pts = {be: ProgramPoint(
            engine="batched", method="raflora", backend=be, d=256, n=256,
            rank_levels=(8,), m_per_group=2, p_bucket=1)
            for be in ("dense", "factored")}
        dense = lower_program(pts["dense"]).liveness.peak_live_bytes
        factored = lower_program(pts["factored"]).liveness.peak_live_bytes
        assert dense >= 4 * 256 * 256            # holds a (d, n) f32
        assert factored < dense / 4
