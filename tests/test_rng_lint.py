"""RNG/determinism lint (ISSUE 8): every rule is tested in BOTH
directions -- clean/waived programs stay silent, broken programs trip --
and the real round-path sources + init functions are certified clean."""
import os

import jax
import jax.numpy as jnp
import pytest

from repro.analysis.rng_lint import (BROKEN_HOST_CLOCK,
                                     BROKEN_HOST_KEY_REUSE,
                                     BROKEN_SEED_COLLISION,
                                     BROKEN_SET_ITERATION, BROKEN_UNSEEDED,
                                     broken_key_reuse, key_flow,
                                     lint_host_source, lint_key_flow)
from repro.models.layers.dense import dense_init, lora_init

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _rules(findings):
    return {f.rule for f in findings}


# ---------------------------------------------------------------------------
# key-provenance dataflow
# ---------------------------------------------------------------------------

def test_clean_split_then_sample_is_silent():
    def clean(key):
        k1, k2 = jax.random.split(key)
        return jax.random.normal(k1, (2,)) + jax.random.uniform(k2, (2,))

    findings, stats = lint_key_flow("clean", clean, jax.random.key(0))
    assert findings == []
    assert stats["consumptions"] == 2 and stats["derivations"] == 1


def test_key_reuse_trips():
    findings, _ = lint_key_flow("broken", broken_key_reuse,
                                jax.random.key(0))
    assert _rules(findings) == {"rng-key-reuse"}


def test_key_reuse_seen_through_old_style_uint32_keys():
    """random_wrap aliasing: the same raw uint32 key wrapped twice is ONE
    key identity, so two samplers on it still count as reuse."""
    def reuse_raw(raw):
        a = jax.random.normal(raw, (2,))
        b = jax.random.uniform(raw, (2,))
        return a + b

    findings, _ = lint_key_flow("raw", reuse_raw, jax.random.PRNGKey(0))
    assert "rng-key-reuse" in _rules(findings)


def test_sample_then_derive_trips():
    def hazard(key):
        x = jax.random.normal(key, (2,))
        child = jax.random.fold_in(key, 1)
        return x + jax.random.normal(child, (2,))

    findings, _ = lint_key_flow("hazard", hazard, jax.random.key(0))
    assert "rng-sample-then-derive" in _rules(findings)


def test_flow_follows_keys_into_pjit_subjaxprs():
    @jax.jit
    def inner(key):
        return jax.random.normal(key, (2,))

    def outer(key):
        return inner(key) + inner(key)   # same outer key, two consumers

    findings, _ = lint_key_flow("nested", outer, jax.random.key(0))
    assert "rng-key-reuse" in _rules(findings)


def test_real_init_functions_are_clean():
    k = jax.random.key(0)
    for name, fn in [
            ("dense", lambda key: dense_init(key, 8, 12)),
            ("lora", lambda key: lora_init(key, 8, 12, 4))]:
        findings, stats = lint_key_flow(name, fn, k)
        assert findings == [], name
        assert stats["eqns"] > 0


def test_key_flow_report_counts_keys():
    rep = key_flow(broken_key_reuse, jax.random.key(0))
    reused = [k for k in rep.keys if len(k.consumers) >= 2]
    assert len(reused) == 1


# ---------------------------------------------------------------------------
# host determinism rules -- broken direction
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("src,rule", [
    (BROKEN_HOST_CLOCK, "rng-host-clock"),
    (BROKEN_UNSEEDED, "rng-unseeded-default-rng"),
    (BROKEN_SEED_COLLISION, "rng-seed-collision"),
    (BROKEN_SET_ITERATION, "rng-order-sensitive-iteration"),
    (BROKEN_HOST_KEY_REUSE, "rng-host-key-reuse")],
    ids=["clock", "unseeded", "collision", "set-iter", "key-reuse"])
def test_broken_host_sources_trip(src, rule):
    findings, stats = lint_host_source("broken.py", src)
    assert rule in _rules(findings)
    assert stats["ast_nodes"] > 0


# ---------------------------------------------------------------------------
# host determinism rules -- clean/waived direction
# ---------------------------------------------------------------------------

def test_seeded_rng_and_sorted_iteration_are_silent():
    clean = (
        "import numpy as np\n"
        "def rngs(seed, clients):\n"
        "    rng = np.random.default_rng(np.random.SeedSequence([seed, 0]))\n"
        "    return [rng.random() for c in sorted(set(clients))]\n"
    )
    findings, _ = lint_host_source("clean.py", clean)
    assert findings == []


def test_same_line_waivers_suppress():
    waived = (
        "import time\n"
        "import numpy as np\n"
        "def f():\n"
        "    t = time.time()  # host-clock: ok (wall-clock engines only)\n"
        "    r = np.random.default_rng()  # rng: ok (throwaway jitter)\n"
        "    return t, r\n"
    )
    findings, _ = lint_host_source("waived.py", waived)
    assert findings == []


def test_waiver_is_tag_specific():
    """A '# rng: ok' waiver does NOT waive the host-clock rule."""
    src = (
        "import time\n"
        "def f():\n"
        "    return time.time()  # rng: ok\n"
    )
    findings, _ = lint_host_source("wrongtag.py", src)
    assert _rules(findings) == {"rng-host-clock"}


def test_distinct_seed_tags_do_not_collide():
    src = (
        "import numpy as np\n"
        "def a(seed, c):\n"
        "    return np.random.SeedSequence([seed, 0, c])\n"
        "def b(seed, c):\n"
        "    return np.random.SeedSequence([seed, 1, c])\n"
    )
    findings, _ = lint_host_source("tagged.py", src)
    assert findings == []


def test_host_key_reuse_split_is_silent():
    """The fixed serve.py pattern -- split, then one consumer per subkey --
    must not trip; passing a key to split/fold_in is not consumption."""
    clean = (
        "import jax\n"
        "def setup(model, seed):\n"
        "    key = jax.random.PRNGKey(seed)\n"
        "    k_init, k_data = jax.random.split(key)\n"
        "    params = model.init(k_init)\n"
        "    prompts = jax.random.randint(k_data, (4, 32), 0, 100)\n"
        "    return params, prompts\n"
    )
    findings, _ = lint_host_source("clean_split.py", clean)
    assert findings == []


def test_host_key_reuse_waiver_suppresses():
    src = BROKEN_HOST_KEY_REUSE.replace(
        "params = model.init(key)",
        "params = model.init(key)  # rng: ok (regression fixture)")
    findings, _ = lint_host_source("waived_reuse.py", src)
    assert findings == []


def test_real_serving_path_sources_are_clean():
    """The serving path (incl. the rewritten serve.py CLI, whose PRNG key
    reuse this rule was written to catch) passes the host lint."""
    rel = ("src/repro/serving/adapter_store.py",
           "src/repro/serving/engine.py",
           "src/repro/serving/scheduler.py",
           "src/repro/launch/serve.py")
    for r in rel:
        with open(os.path.join(_ROOT, r)) as fh:
            findings, _ = lint_host_source(r, fh.read())
        assert findings == [], (r, findings)


def test_real_round_path_sources_are_clean():
    """The shipped round path passes the host lint -- the one intentional
    wall-clock read in server.py carries its waiver."""
    rel = ("src/repro/federation/events.py",
           "src/repro/federation/server.py",
           "src/repro/core/aggregation.py",
           "src/repro/data/traces.py")
    for r in rel:
        with open(os.path.join(_ROOT, r)) as fh:
            findings, _ = lint_host_source(r, fh.read())
        assert findings == [], (r, findings)
