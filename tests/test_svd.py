"""Dense vs factored SVD reallocation: the equivalence core/svd.py claims.

``svd_realloc_factored`` (QR-reduce + small-core SVD, DESIGN.md §4.2) must
reproduce ``svd_realloc_dense`` (materialize + full SVD) up to float
round-off on exactly the stacks the server produces: weighted sums of
heterogeneous-rank client factors, with and without the Eq. 8
fallback-augmented global slices.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_compat import given, settings, strategies as st

from repro.core import pad_stack
from repro.core.partitions import omega_flexlora, omega_raflora
from repro.core.svd import (check_fallback_globals, dense_from_weighted,
                            factored_from_weighted, svd_realloc_dense,
                            svd_realloc_factored, svd_realloc_gram)

LEVELS = [4, 8, 16]
R_MAX = 16
D, N = 24, 40


def make_stack(seed, ranks):
    key = jax.random.PRNGKey(seed)
    factors = []
    for i, r in enumerate(ranks):
        kb, ka = jax.random.split(jax.random.fold_in(key, i))
        factors.append((jax.random.normal(kb, (D, r)),
                        jax.random.normal(ka, (r, N))))
    return pad_stack(factors, R_MAX)


class TestDenseFactoredEquivalence:
    @pytest.mark.parametrize("seed,ranks", [
        (0, [4, 8, 16]),
        (1, [4, 4, 8, 8, 16, 16]),
        (2, [16]),
        (3, [4] * 5),
    ])
    def test_weighted_stacks_agree(self, seed, ranks):
        """Random heterogeneous-rank stacks, FlexLoRA weights."""
        bs, as_ = make_stack(seed, ranks)
        n_k = np.linspace(5, 30, len(ranks))
        omega = jnp.asarray(omega_flexlora(ranks, n_k, R_MAX))
        dw = dense_from_weighted(bs, as_, omega)
        b_d, a_d, s_d = svd_realloc_dense(dw, R_MAX)
        u_c, v_c = factored_from_weighted(bs, as_, omega)
        b_f, a_f, s_f = svd_realloc_factored(u_c, v_c, R_MAX)
        np.testing.assert_allclose(np.asarray(s_d), np.asarray(s_f),
                                   atol=1e-4)
        np.testing.assert_allclose(np.asarray(b_d @ a_d),
                                   np.asarray(b_f @ a_f), atol=1e-4)

    def test_fallback_augmented_stack_agrees(self):
        """raFLoRA's Eq. 8 fallback: the global slice enters both backends
        identically."""
        ranks = [4, 4]                    # partitions (5..8], (9..16] empty
        bs, as_ = make_stack(7, ranks)
        n_k = [3.0, 5.0]
        omega_np, fb_np = omega_raflora(ranks, n_k, LEVELS)
        assert fb_np.any()
        omega, fb = jnp.asarray(omega_np), jnp.asarray(fb_np)
        key = jax.random.PRNGKey(99)
        g_b = jax.random.normal(key, (D, R_MAX))
        g_a = jax.random.normal(jax.random.fold_in(key, 1), (R_MAX, N))
        dw = dense_from_weighted(bs, as_, omega, g_b, g_a, fb)
        b_d, a_d, s_d = svd_realloc_dense(dw, R_MAX)
        u_c, v_c = factored_from_weighted(bs, as_, omega, g_b, g_a, fb)
        b_f, a_f, s_f = svd_realloc_factored(u_c, v_c, R_MAX)
        np.testing.assert_allclose(np.asarray(s_d), np.asarray(s_f),
                                   atol=1e-4)
        np.testing.assert_allclose(np.asarray(b_d @ a_d),
                                   np.asarray(b_f @ a_f), atol=1e-4)

    def test_factored_zero_pads_rank_deficient(self):
        """R < r_max: trailing singular values exactly zero, factors
        zero-padded -- the aggregate has algebraic rank <= R."""
        key = jax.random.PRNGKey(5)
        u_c = jax.random.normal(key, (D, 6))
        v_c = jax.random.normal(jax.random.fold_in(key, 1), (6, N))
        b_f, a_f, s_f = svd_realloc_factored(u_c, v_c, R_MAX)
        assert b_f.shape == (D, R_MAX) and a_f.shape == (R_MAX, N)
        assert np.all(np.asarray(s_f[6:]) == 0)
        assert not np.any(np.asarray(b_f[:, 6:]))
        np.testing.assert_allclose(np.asarray(b_f @ a_f),
                                   np.asarray(u_c @ v_c), atol=1e-4)


class TestGramReallocProperty:
    """``svd_realloc_gram`` (the kernel backend's Gram-core route,
    DESIGN.md §4.3) vs the dense reference, property-tested on random
    heterogeneous-rank stacks with and without the Eq. 8 fallback
    augmentation, f32 and bf16 inputs.

    Tolerance is sqrt(eps)-scaled (looser than the QR route above): the
    Gram cores square the condition number, which is the documented price
    of computing them on-chip with one MXU pass."""

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @settings(max_examples=6, deadline=None)
    @given(seed=st.integers(0, 10_000),
           rank_idx=st.lists(st.integers(0, 2), min_size=1, max_size=6),
           with_fallback=st.sampled_from([False, True]))
    def test_gram_matches_dense(self, dtype, seed, rank_idx, with_fallback):
        ranks = [LEVELS[i % (2 if with_fallback else 3)] for i in rank_idx]
        if not with_fallback:
            ranks = ranks + [R_MAX]      # top partition covered: no fallback
        n_k = np.linspace(2, 20, len(ranks))
        omega_np, fb_np = omega_raflora(ranks, n_k, LEVELS)
        assert bool(fb_np.any()) == with_fallback
        omega = jnp.asarray(omega_np)
        fb = jnp.asarray(fb_np) if with_fallback else None
        key = jax.random.PRNGKey(seed)
        factors = []
        for i, r in enumerate(ranks):
            kb, ka = jax.random.split(jax.random.fold_in(key, i))
            factors.append((jax.random.normal(kb, (D, r)).astype(dtype),
                            jax.random.normal(ka, (r, N)).astype(dtype)))
        bs, as_ = pad_stack(factors, R_MAX)
        g_b = jax.random.normal(jax.random.fold_in(key, 91),
                                (D, R_MAX)).astype(dtype)
        g_a = jax.random.normal(jax.random.fold_in(key, 92),
                                (R_MAX, N)).astype(dtype)
        gb_arg = g_b if with_fallback else None
        ga_arg = g_a if with_fallback else None
        dw = dense_from_weighted(bs, as_, omega, gb_arg, ga_arg, fb)
        b_d, a_d, s_d = svd_realloc_dense(dw, R_MAX)
        u_c, v_c = factored_from_weighted(bs, as_, omega, gb_arg, ga_arg, fb)
        g_u = u_c.T @ u_c
        g_v = v_c @ v_c.T
        b_g, a_g, s_g = svd_realloc_gram(u_c, v_c, g_u, g_v, R_MAX)
        scale = max(1.0, float(np.abs(np.asarray(s_d)).max()))
        np.testing.assert_allclose(np.asarray(s_d), np.asarray(s_g),
                                   atol=1e-3 * scale)
        np.testing.assert_allclose(np.asarray(b_d @ a_d),
                                   np.asarray(b_g @ a_g),
                                   atol=2e-3 * scale)

    def test_gram_zero_pads_rank_deficient(self):
        """R < r_max: trailing singular values exactly zero, factors
        zero-padded -- mirroring ``svd_realloc_factored``'s contract."""
        key = jax.random.PRNGKey(5)
        u_c = jax.random.normal(key, (D, 6))
        v_c = jax.random.normal(jax.random.fold_in(key, 1), (6, N))
        b_g, a_g, s_g = svd_realloc_gram(u_c, v_c, u_c.T @ u_c,
                                         v_c @ v_c.T, R_MAX)
        assert b_g.shape == (D, R_MAX) and a_g.shape == (R_MAX, N)
        assert np.all(np.asarray(s_g[6:]) == 0)
        assert not np.any(np.asarray(b_g[:, 6:]))
        np.testing.assert_allclose(np.asarray(b_g @ a_g),
                                   np.asarray(u_c @ v_c), atol=1e-3)

    def test_gram_ignores_zero_padded_columns(self):
        """Zero client columns (rank padding / ghost clients) must be
        spectrum-inert: the eigensolver sees them as exact-zero eigenpairs
        cut by the rank threshold."""
        key = jax.random.PRNGKey(7)
        u_c = jax.random.normal(key, (D, 6))
        v_c = jax.random.normal(jax.random.fold_in(key, 1), (6, N))
        u_p = jnp.concatenate([u_c, jnp.zeros((D, 10))], axis=1)
        v_p = jnp.concatenate([v_c, jnp.zeros((10, N))], axis=0)
        b1, a1, s1 = svd_realloc_gram(u_c, v_c, u_c.T @ u_c,
                                      v_c @ v_c.T, R_MAX)
        b2, a2, s2 = svd_realloc_gram(u_p, v_p, u_p.T @ u_p,
                                      v_p @ v_p.T, R_MAX)
        np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=1e-4)
        np.testing.assert_allclose(np.asarray(b1 @ a1), np.asarray(b2 @ a2),
                                   atol=1e-3)


class TestFallbackGuard:
    def test_check_requires_globals(self):
        fb = jnp.ones((R_MAX,))
        with pytest.raises(ValueError, match="global_b and global_a"):
            check_fallback_globals(fb, None, None)
        with pytest.raises(ValueError, match="global_a"):
            check_fallback_globals(fb, jnp.zeros((D, R_MAX)), None)
        # no fallback -> globals optional
        check_fallback_globals(None, None, None)
