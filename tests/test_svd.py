"""Dense vs factored SVD reallocation: the equivalence core/svd.py claims.

``svd_realloc_factored`` (QR-reduce + small-core SVD, DESIGN.md §4.2) must
reproduce ``svd_realloc_dense`` (materialize + full SVD) up to float
round-off on exactly the stacks the server produces: weighted sums of
heterogeneous-rank client factors, with and without the Eq. 8
fallback-augmented global slices.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import pad_stack
from repro.core.partitions import omega_flexlora, omega_raflora
from repro.core.svd import (check_fallback_globals, dense_from_weighted,
                            factored_from_weighted, svd_realloc_dense,
                            svd_realloc_factored)

LEVELS = [4, 8, 16]
R_MAX = 16
D, N = 24, 40


def make_stack(seed, ranks):
    key = jax.random.PRNGKey(seed)
    factors = []
    for i, r in enumerate(ranks):
        kb, ka = jax.random.split(jax.random.fold_in(key, i))
        factors.append((jax.random.normal(kb, (D, r)),
                        jax.random.normal(ka, (r, N))))
    return pad_stack(factors, R_MAX)


class TestDenseFactoredEquivalence:
    @pytest.mark.parametrize("seed,ranks", [
        (0, [4, 8, 16]),
        (1, [4, 4, 8, 8, 16, 16]),
        (2, [16]),
        (3, [4] * 5),
    ])
    def test_weighted_stacks_agree(self, seed, ranks):
        """Random heterogeneous-rank stacks, FlexLoRA weights."""
        bs, as_ = make_stack(seed, ranks)
        n_k = np.linspace(5, 30, len(ranks))
        omega = jnp.asarray(omega_flexlora(ranks, n_k, R_MAX))
        dw = dense_from_weighted(bs, as_, omega)
        b_d, a_d, s_d = svd_realloc_dense(dw, R_MAX)
        u_c, v_c = factored_from_weighted(bs, as_, omega)
        b_f, a_f, s_f = svd_realloc_factored(u_c, v_c, R_MAX)
        np.testing.assert_allclose(np.asarray(s_d), np.asarray(s_f),
                                   atol=1e-4)
        np.testing.assert_allclose(np.asarray(b_d @ a_d),
                                   np.asarray(b_f @ a_f), atol=1e-4)

    def test_fallback_augmented_stack_agrees(self):
        """raFLoRA's Eq. 8 fallback: the global slice enters both backends
        identically."""
        ranks = [4, 4]                    # partitions (5..8], (9..16] empty
        bs, as_ = make_stack(7, ranks)
        n_k = [3.0, 5.0]
        omega_np, fb_np = omega_raflora(ranks, n_k, LEVELS)
        assert fb_np.any()
        omega, fb = jnp.asarray(omega_np), jnp.asarray(fb_np)
        key = jax.random.PRNGKey(99)
        g_b = jax.random.normal(key, (D, R_MAX))
        g_a = jax.random.normal(jax.random.fold_in(key, 1), (R_MAX, N))
        dw = dense_from_weighted(bs, as_, omega, g_b, g_a, fb)
        b_d, a_d, s_d = svd_realloc_dense(dw, R_MAX)
        u_c, v_c = factored_from_weighted(bs, as_, omega, g_b, g_a, fb)
        b_f, a_f, s_f = svd_realloc_factored(u_c, v_c, R_MAX)
        np.testing.assert_allclose(np.asarray(s_d), np.asarray(s_f),
                                   atol=1e-4)
        np.testing.assert_allclose(np.asarray(b_d @ a_d),
                                   np.asarray(b_f @ a_f), atol=1e-4)

    def test_factored_zero_pads_rank_deficient(self):
        """R < r_max: trailing singular values exactly zero, factors
        zero-padded -- the aggregate has algebraic rank <= R."""
        key = jax.random.PRNGKey(5)
        u_c = jax.random.normal(key, (D, 6))
        v_c = jax.random.normal(jax.random.fold_in(key, 1), (6, N))
        b_f, a_f, s_f = svd_realloc_factored(u_c, v_c, R_MAX)
        assert b_f.shape == (D, R_MAX) and a_f.shape == (R_MAX, N)
        assert np.all(np.asarray(s_f[6:]) == 0)
        assert not np.any(np.asarray(b_f[:, 6:]))
        np.testing.assert_allclose(np.asarray(b_f @ a_f),
                                   np.asarray(u_c @ v_c), atol=1e-4)


class TestFallbackGuard:
    def test_check_requires_globals(self):
        fb = jnp.ones((R_MAX,))
        with pytest.raises(ValueError, match="global_b and global_a"):
            check_fallback_globals(fb, None, None)
        with pytest.raises(ValueError, match="global_a"):
            check_fallback_globals(fb, jnp.zeros((D, R_MAX)), None)
        # no fallback -> globals optional
        check_fallback_globals(None, None, None)
