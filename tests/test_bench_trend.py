"""Gate logic of ``tools/bench_trend.py`` (ISSUE 6 satellite): event-mode
rows are now gated on ``virtual_time_to_target_energy`` at the same wide
catastrophic-only bar as the absolute ``engine/batched`` reference row,
with ``null`` meaning the target energy was never reached (= infinity).
The tool is not a package; load it by file path."""
import importlib.util
import os

import pytest

_TOOL = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "tools", "bench_trend.py")
_spec = importlib.util.spec_from_file_location("bench_trend", _TOOL)
bench_trend = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(bench_trend)


def _artifact(batched_s=1.0, event_rows=None):
    art = {"median_s": {"batched": batched_s, "sequential": batched_s * 2},
           "per_round_s": {}}
    if event_rows is not None:
        art["event"] = {"rows": event_rows}
    return art


def _event_row(trigger="count", frac=0.25, vt=10.0, aggs=40):
    return {"trigger": trigger, "straggler_frac": frac,
            "virtual_time_to_target_energy": vt, "aggregations": aggs,
            "final_higher_rank_energy": 0.9}


def _compare(baseline, fresh, **kw):
    kw.setdefault("threshold", 1.25)
    kw.setdefault("absolute", False)
    kw.setdefault("ref_threshold", 3.0)
    return bench_trend.compare(baseline, fresh, **kw)


class TestEventRowGate:
    def test_unchanged_event_rows_pass(self):
        b = _artifact(event_rows=[_event_row()])
        f = _artifact(event_rows=[_event_row()])
        assert _compare(b, f) == 0

    def test_mild_drift_stays_under_wide_bar(self):
        b = _artifact(event_rows=[_event_row(vt=10.0)])
        f = _artifact(event_rows=[_event_row(vt=25.0)])   # 2.5x < 3.0x
        assert _compare(b, f) == 0

    def test_catastrophic_slowdown_fails(self):
        b = _artifact(event_rows=[_event_row(vt=10.0)])
        f = _artifact(event_rows=[_event_row(vt=40.0)])   # 4x > 3.0x
        assert _compare(b, f) == 1

    def test_fresh_null_against_finite_baseline_fails(self):
        """None = never reached target energy = infinite virtual time."""
        b = _artifact(event_rows=[_event_row(vt=10.0)])
        f = _artifact(event_rows=[_event_row(vt=None)])
        assert _compare(b, f) == 1

    def test_both_null_passes(self):
        b = _artifact(event_rows=[_event_row(vt=None)])
        f = _artifact(event_rows=[_event_row(vt=None)])
        assert _compare(b, f) == 0

    def test_fresh_finite_against_null_baseline_is_improvement(self):
        b = _artifact(event_rows=[_event_row(vt=None)])
        f = _artifact(event_rows=[_event_row(vt=12.0)])
        assert _compare(b, f) == 0

    def test_new_key_is_not_gated(self):
        b = _artifact(event_rows=[_event_row(trigger="count")])
        f = _artifact(event_rows=[_event_row(trigger="count"),
                                  _event_row(trigger="timeout", vt=99.0)])
        assert _compare(b, f) == 0

    def test_rows_are_append_only_latest_wins(self):
        """An old bad row followed by a fresh good one must gate on the
        LATEST entry per (trigger, straggler_frac) key."""
        b = _artifact(event_rows=[_event_row(vt=10.0)])
        f = _artifact(event_rows=[_event_row(vt=99.0),
                                  _event_row(vt=10.0)])
        assert _compare(b, f) == 0

    def test_keys_are_per_trigger_and_fraction(self):
        b = _artifact(event_rows=[_event_row(trigger="count", vt=10.0),
                                  _event_row(trigger="timeout", vt=10.0)])
        f = _artifact(event_rows=[_event_row(trigger="count", vt=10.0),
                                  _event_row(trigger="timeout", vt=50.0)])
        assert _compare(b, f) == 1


class TestExistingGateStillWorks:
    def test_clean_run_passes(self):
        assert _compare(_artifact(), _artifact()) == 0

    def test_reference_row_catastrophic_regression_fails(self):
        assert _compare(_artifact(batched_s=1.0),
                        _artifact(batched_s=4.0)) == 1

    @pytest.mark.parametrize("ratio,expect", [(1.1, 0), (2.0, 1)])
    def test_normalized_row_threshold(self, ratio, expect):
        b = _artifact()
        f = _artifact()
        f["median_s"]["sequential"] = b["median_s"]["sequential"] * ratio
        assert _compare(b, f) == expect
