"""Checkpoint torn-write protection + path-spelling coverage (ISSUE 3
satellite): ``save_pytree`` must be atomic (temp file + ``os.replace``) so
a crash mid-save -- likely once async checkpointing overlaps training --
leaves either the previous complete checkpoint or the new one, never a
half-written npz that ``restore()`` half-loads. Both ``save("ckpt")`` and
``save("ckpt.npz")`` spellings must interoperate, and ``load_metadata``'s
old dead ``.npz.meta.json`` rewrite branch is replaced by stem
normalization.

ISSUE 5 satellite: the EVENT-DRIVEN engine's in-flight state -- virtual
clock, pending arrival queue, per-plan arrival bookkeeping, per-client
latency rng streams -- must round-trip through ``save()``/``restore()`` so
a mid-buffer resume equals the uninterrupted event-driven run exactly
(``TestEventResume``, mirroring ``TestAsyncResume``)."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpointing.checkpoint import (load_flat, load_metadata,
                                            load_pytree, save_flat,
                                            save_pytree)


def _tree():
    return {"w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": jnp.ones((3,), jnp.float32), "none": None}


class TestPathSpellings:
    """save/load must accept both the bare-stem and the explicit ``.npz``
    spelling, in any combination."""

    @pytest.mark.parametrize("save_as", ["ckpt", "ckpt.npz"])
    @pytest.mark.parametrize("load_as", ["ckpt", "ckpt.npz"])
    def test_pytree_roundtrip_any_spelling(self, tmp_path, save_as, load_as):
        tree = _tree()
        save_pytree(str(tmp_path / save_as), tree, metadata={"round": 7})
        files = sorted(os.listdir(tmp_path))
        assert files == ["ckpt.meta.json", "ckpt.npz"]   # ONE canonical set
        got = load_pytree(str(tmp_path / load_as), tree)
        np.testing.assert_array_equal(np.asarray(got["w"]),
                                      np.asarray(tree["w"]))
        assert got["none"] is None
        meta = load_metadata(str(tmp_path / load_as))
        assert meta == {"round": 7}

    def test_legacy_sidecar_next_to_npz_spelling_still_loads(self, tmp_path):
        """Older code wrote ``<path>.meta.json`` next to an explicit
        ``.npz`` path; the probe order must keep loading it."""
        save_pytree(str(tmp_path / "old"), _tree())
        with open(tmp_path / "old.npz.meta.json", "w") as f:
            json.dump({"legacy": True}, f)
        assert load_metadata(str(tmp_path / "old.npz")) == {"legacy": True}

    def test_flat_roundtrip_both_spellings(self, tmp_path):
        arrays = {"layer0/B_m": np.ones((4, 2), np.float32),
                  "layer0/A_m": np.zeros((2, 3), np.float32)}
        save_flat(str(tmp_path / "mom.npz"), arrays)
        got = load_flat(str(tmp_path / "mom"))
        assert set(got) == set(arrays)
        np.testing.assert_array_equal(got["layer0/B_m"],
                                      arrays["layer0/B_m"])


class TestAtomicity:
    """A failing save must leave the previous checkpoint intact and no
    stray temp files behind."""

    def test_failed_npz_write_preserves_previous_checkpoint(
            self, tmp_path, monkeypatch):
        path = str(tmp_path / "ckpt")
        tree = _tree()
        save_pytree(path, tree, metadata={"round": 1})

        calls = {"n": 0}
        real_savez = np.savez

        def exploding_savez(f, **kw):
            calls["n"] += 1
            # write a prefix then die -- simulates a crash mid-write
            f.write(b"\x00" * 16)
            raise OSError("disk full")

        monkeypatch.setattr(np, "savez", exploding_savez)
        bigger = {"w": jnp.full((2, 3), 9.0), "b": jnp.zeros((3,)),
                  "none": None}
        with pytest.raises(OSError):
            save_pytree(path, bigger, metadata={"round": 2})
        monkeypatch.setattr(np, "savez", real_savez)

        assert calls["n"] == 1
        # previous checkpoint fully intact, metadata untouched
        got = load_pytree(path, tree)
        np.testing.assert_array_equal(np.asarray(got["w"]),
                                      np.asarray(tree["w"]))
        assert load_metadata(path) == {"round": 1}
        # no temp litter
        assert sorted(os.listdir(tmp_path)) == ["ckpt.meta.json", "ckpt.npz"]

    def test_metadata_written_after_arrays(self, tmp_path):
        """Both files land atomically under their canonical names -- a
        reader never observes a .npz without its .meta.json from the SAME
        save (os.replace per file; the npz is replaced first)."""
        path = str(tmp_path / "state")
        save_pytree(path, _tree(), metadata={"v": 1})
        save_pytree(path + ".npz", _tree(), metadata={"v": 2})
        assert load_metadata(path) == {"v": 2}
        assert sorted(os.listdir(tmp_path)) == ["state.meta.json",
                                                "state.npz"]


class TestServerCheckpointMomentum:
    """ISSUE 3 satellite (ROADMAP): FactoredServerMomentum state must
    survive save/restore -- previously a resumed ``server_momentum_beta>0``
    run silently restarted momentum from zero."""

    def test_momentum_state_roundtrip(self, tmp_path):
        from repro.core.server_opt import FactoredServerMomentum
        mom = FactoredServerMomentum(beta=0.9)
        key_a = ("params", "layer0", "q_proj")
        key_b = ("params", "layer0", "v_proj")
        b = jnp.ones((6, 4)) * 0.3
        a = jnp.ones((4, 5)) * 0.2
        mom.apply(key_a, (jnp.zeros((6, 4)), jnp.zeros((4, 5))), (b, a), 4)
        # bucketed entry as well: per-adapter serialization must slice it
        mom.apply_bucket((key_b,), [(jnp.zeros((6, 4)), jnp.zeros((4, 5)))],
                         b[None], a[None], 4)
        arrays = mom.state_arrays()
        assert set(arrays) == {"params/layer0/q_proj/B_m",
                               "params/layer0/q_proj/A_m",
                               "params/layer0/v_proj/B_m",
                               "params/layer0/v_proj/A_m"}
        save_flat(str(tmp_path / "m"), arrays)

        back = FactoredServerMomentum(beta=0.9)
        back.load_state_arrays(load_flat(str(tmp_path / "m")))
        assert set(back.state) == {key_a, key_b}
        np.testing.assert_allclose(np.asarray(back.state[key_a][0]),
                                   np.asarray(mom.state[key_a][0]))
        np.testing.assert_allclose(
            np.asarray(back.state[key_b][0]),
            np.asarray(mom.state[(key_b,)][0][0]))


class TestEventSchedulerStateRoundtrip:
    """The scheduler's ``state_dict`` must survive a JSON round trip (it
    rides checkpoint metadata) and restore clock / queue / rng exactly."""

    def _sched(self):
        from repro.federation.events import (CountTrigger, EventScheduler,
                                             LognormalLatency)
        return EventScheduler(LognormalLatency(median=1.1, sigma=0.4,
                                               seed=3),
                              CountTrigger(5), round_interval=1.0)

    def test_state_json_roundtrip_mid_stream(self):
        sched = self._sched()
        sched.dispatch(0, [0, 1, 2])
        for _ in sched.advance_window():
            sched.take_ready()
        sched.dispatch(1, [3, 4, 0])
        for _ in sched.advance_window():
            sched.take_ready()
        state = json.loads(json.dumps(sched.state_dict()))

        back = self._sched()
        back.load_state_dict(state)
        assert back.clock.now == sched.clock.now
        assert sorted(back._heap) == sorted(sched._heap)
        assert back._book == sched._book
        assert back.fire_log == sched.fire_log
        # the latency rng streams continue IDENTICALLY after restore
        for c in (0, 1, 2, 3, 4):
            assert back.latency.sample(c) == sched.latency.sample(c)

    def test_load_none_resets_pristine(self):
        sched = self._sched()
        sched.dispatch(0, [0, 1, 2])
        sched.load_state_dict(None)
        assert sched.clock.now == 0.0 and not sched._heap
        assert sched.pending_ready_count == 0


@pytest.mark.slow
class TestEventResume:
    """ISSUE 5 satellite: save -> restore -> run equals the uninterrupted
    EVENT-DRIVEN run exactly, with a mid-buffer save (in-flight arrivals in
    the virtual queue, arrived-but-unaggregated updates, momentum state)."""

    def _make(self):
        from repro.federation.events import (CountTrigger, EventScheduler,
                                             LognormalLatency)
        from repro.federation.experiment import build_experiment
        sched = EventScheduler(
            LognormalLatency(median=1.3, sigma=0.5, seed=13),
            CountTrigger(6), round_interval=1.0)
        return build_experiment(
            "raflora",
            fl_overrides={"num_rounds": 8, "num_clients": 8,
                          "participation": 0.5},
            lora_overrides={"rank_levels": (4, 8, 16),
                            "rank_probs": (0.34, 0.33, 0.33)},
            samples_per_class=20, num_classes=4, d_model=32,
            batches_per_round=1, round_engine="async",
            event_scheduler=sched, server_momentum_beta=0.9)

    def test_mid_buffer_event_resume(self, tmp_path):
        full = self._make()
        full.server.run(5)

        part = self._make()
        part.server.run(3)
        sched = part.server.event_scheduler
        assert part.server._pending            # mid-buffer at save time
        assert sched._heap or sched.pending_ready_count  # in-flight events
        path = str(tmp_path / "event_ckpt")
        part.server.save(path)

        resumed = self._make()
        resumed.server.restore(path)
        rs = resumed.server.event_scheduler
        assert rs.clock.now == sched.clock.now
        assert sorted(rs._heap) == sorted(sched._heap)
        assert len(resumed.server._pending) == len(part.server._pending)
        resumed.server.run(2)

        for sf, sr in zip(full.server.history, resumed.server.history):
            assert sf.clients == sr.clients and sf.ranks == sr.ranks
            assert sf.virtual_time == sr.virtual_time
            np.testing.assert_allclose(sf.mean_client_loss,
                                       sr.mean_client_loss, rtol=1e-6)
        np.testing.assert_allclose(full.server.energy.rho_r1,
                                   resumed.server.energy.rho_r1, rtol=1e-6)
        assert full.server.event_scheduler.fire_log == rs.fire_log
        for a, b in zip(jax.tree.leaves(full.server.global_lora),
                        jax.tree.leaves(resumed.server.global_lora)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-5, atol=1e-6)
