"""Per-architecture smoke tests (deliverable f): every assigned arch at a
REDUCED config (2 layers, d<=512, <=4 experts) runs one forward/train step
on CPU with correct output shapes and no NaNs; decoder archs additionally
run prefill + decode and must agree with the full forward."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, LoRAConfig, get_config
from repro.models import build_model

from conftest import small_batch

LORA = LoRAConfig(rank_levels=(4, 8, 16), rank_probs=(0.4, 0.3, 0.3))


def reduced_model(name):
    cfg = get_config(name).reduced()
    return cfg, build_model(cfg, LORA, dtype=jnp.float32, remat=False,
                            block_q=16, block_kv=16)


@pytest.mark.parametrize("name", ASSIGNED_ARCHS)
class TestSmoke:
    def test_reduced_config_limits(self, name):
        cfg = get_config(name).reduced()
        assert cfg.num_layers <= 2
        assert cfg.d_model <= 512
        if cfg.moe is not None:
            assert cfg.moe.num_experts <= 4

    def test_forward_shapes_and_finite(self, name, rng_key):
        cfg, model = reduced_model(name)
        params = model.init(rng_key)
        batch = small_batch(cfg, rng_key, batch=2, seq=32)
        logits, aux, _ = model.forward_seq(params, batch, mode="train",
                                           lora_rank=8)
        assert logits.shape == (2, 32, cfg.vocab_size)
        assert bool(jnp.isfinite(logits).all())
        assert bool(jnp.isfinite(aux))

    def test_train_step_decreases_loss(self, name, rng_key):
        """One AdamW step on LoRA params only must reduce loss on the same
        batch and must NOT touch base params."""
        from repro.core.lora import merge_lora, split_lora
        from repro.launch.steps import build_train_step
        cfg, model = reduced_model(name)
        params = model.init(rng_key)
        base, lora = split_lora(params)
        batch = small_batch(cfg, rng_key, batch=2, seq=32)
        step, opt = build_train_step(model, 8)
        opt_state = opt.init(lora)
        loss0 = None
        for i in range(3):
            lora, opt_state, metrics = step(lora, opt_state, base, batch,
                                            jnp.float32(1e-2))
            if loss0 is None:
                loss0 = float(metrics["loss"])
        assert float(metrics["loss"]) < loss0
        # base unchanged by construction (only lora tree updated)

    def test_decode_matches_forward(self, name, rng_key):
        cfg, model = reduced_model(name)
        if not cfg.supports_decode:
            pytest.skip("encoder-only: no decode step (per DESIGN.md)")
        params = model.init(rng_key)
        B, L = 2, 16
        toks = jax.random.randint(rng_key, (B, L), 0, cfg.vocab_size)
        full_logits, _, _ = model.forward_seq(params, {"tokens": toks},
                                              mode="train", lora_rank=8)
        _, cache = model.prefill(params, {"tokens": toks[:, :L - 1]},
                                 lora_rank=8)

        def grow(x):
            if x.ndim >= 3 and x.shape[2] == L - 1:
                pw = [(0, 0)] * x.ndim
                pw[2] = (0, 1)
                return jnp.pad(x, pw)
            return x

        cache = {"layers": jax.tree.map(grow, cache),
                 "len": jnp.int32(L - 1)}
        dec, _ = model.decode_step(params, {"token": toks[:, L - 1:]},
                                   cache, lora_rank=8)
        np.testing.assert_allclose(np.asarray(full_logits[:, -1]),
                                   np.asarray(dec[:, 0]), atol=2e-4)

    def test_microbatched_grads_match(self, name, rng_key):
        """Grad accumulation must equal the single-batch gradient."""
        from repro.core.lora import split_lora
        from repro.launch.steps import build_train_step
        cfg, model = reduced_model(name)
        params = model.init(rng_key)
        base, lora = split_lora(params)
        batch = small_batch(cfg, rng_key, batch=4, seq=32)
        outs = {}
        for mb in (1, 2):
            step, opt = build_train_step(model, 8, num_microbatches=mb)
            new_lora, _, m = step(lora, opt.init(lora), base, batch,
                                  jnp.float32(1e-3))
            outs[mb] = (new_lora, float(m["loss"]))
        if cfg.moe is not None:
            # MoE aux loss and routing depend on per-microbatch statistics;
            # losses differ slightly by design
            tol = 5e-2
        else:
            tol = 1e-4
        assert abs(outs[1][1] - outs[2][1]) < tol

    def test_lora_rank_truncation_zero_effect_at_init(self, name, rng_key):
        """B=0 init: rank choice must not change the forward at round 0."""
        cfg, model = reduced_model(name)
        params = model.init(rng_key)
        batch = small_batch(cfg, rng_key, batch=2, seq=32)
        l4, _, _ = model.forward_seq(params, batch, lora_rank=4)
        l16, _, _ = model.forward_seq(params, batch, lora_rank=16)
        np.testing.assert_allclose(np.asarray(l4), np.asarray(l16), atol=1e-6)


class TestArchSpecific:
    def test_gqa_head_counts(self, rng_key):
        cfg = get_config("qwen2-7b")
        assert cfg.num_heads == 28 and cfg.num_kv_heads == 4
        assert cfg.qkv_bias

    def test_mla_cache_is_compressed(self, rng_key):
        """deepseek decode cache stores the latent, not per-head K/V."""
        cfg, model = reduced_model("deepseek-v2-236b")
        cache = model.cache_shapes(2, 64)
        entry = cache["layers"]
        assert "ckv" in entry and "k" not in entry
        assert entry["ckv"].shape[-1] == cfg.mla.kv_lora_rank

    def test_mamba2_cache_is_constant_size(self):
        cfg, model = reduced_model("mamba2-1.3b")
        c1 = model.cache_shapes(2, 64)
        c2 = model.cache_shapes(2, 4096)
        assert jax.tree.map(lambda s: s.shape, c1) == \
            jax.tree.map(lambda s: s.shape, c2)   # O(1) in context length

    def test_swa_ring_cache_bounded(self):
        cfg = get_config("qwen2-7b").with_sliding_window(64, global_every=0)
        model = build_model(cfg, LORA, dtype=jnp.float32, remat=False)
        assert model.cache_seq_len(524_288) == 64

    def test_hymba_keeps_global_layers_full_cache(self):
        cfg = get_config("hymba-1.5b").reduced()
        model = build_model(cfg, LORA, dtype=jnp.float32, remat=False)
        # global_attn_every != 0 -> full-length cache
        assert model.cache_seq_len(1000) == 1000

    def test_hubert_is_encoder_only(self):
        cfg = get_config("hubert-xlarge")
        assert cfg.is_encoder_only and not cfg.supports_decode

    def test_llama4_interleaves_moe(self):
        cfg = get_config("llama4-maverick-400b-a17b")
        assert cfg.moe.moe_layer_period == 2
        assert not cfg.moe.is_moe_layer(0) and cfg.moe.is_moe_layer(1)

    def test_mrope_equals_rope_for_text(self, rng_key):
        """M-RoPE with equal position ids must reduce to standard RoPE."""
        from repro.models.layers.rope import apply_mrope, apply_rope
        x = jax.random.normal(rng_key, (2, 8, 4, 32))
        pos = jnp.broadcast_to(jnp.arange(8, dtype=jnp.int32), (2, 8))
        mpos = jnp.broadcast_to(pos, (3, 2, 8))
        sections = (4, 6, 6)
        np.testing.assert_allclose(
            np.asarray(apply_rope(x, pos, 10_000.0)),
            np.asarray(apply_mrope(x, mpos, 10_000.0, sections)), atol=1e-5)

    def test_moe_ep_matches_tp_on_host_mesh(self, rng_key):
        """Expert-parallel shard_map path == plain path (1-device mesh)."""
        from repro.launch.mesh import make_host_mesh
        from repro.models.layers.moe import moe_apply, moe_apply_ep, moe_init
        from repro.configs.base import MoEConfig
        mesh = make_host_mesh()
        cfg = MoEConfig(num_experts=4, top_k=2, expert_d_ff=32)
        params = moe_init(rng_key, 16, cfg, "swiglu", lora_ranks={})
        x = jax.random.normal(jax.random.fold_in(rng_key, 1), (2, 8, 16))
        out_tp, aux_tp = moe_apply(params, x, cfg, "swiglu")
        out_ep, aux_ep = moe_apply_ep(params, x, cfg, "swiglu", mesh,
                                      batch_axes=("data",))
        np.testing.assert_allclose(np.asarray(out_tp), np.asarray(out_ep),
                                   atol=1e-5)
