"""Shared fixtures. NOTE: no XLA_FLAGS here -- smoke tests and benches see
the single real CPU device; only launch/dryrun.py forces 512 devices."""
import jax
import jax.numpy as jnp
import pytest


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.PRNGKey(0)


def small_batch(cfg, key, batch=2, seq=32):
    """A valid training batch for any architecture config."""
    import jax.numpy as jnp
    if cfg.frontend.kind == "audio":
        return {"embeds": jax.random.normal(key, (batch, seq,
                                                  cfg.frontend.embed_dim)),
                "targets": jnp.zeros((batch, seq), jnp.int32)}
    if cfg.frontend.kind == "vision":
        p = cfg.frontend.tokens_per_item
        b = {"embeds": jax.random.normal(key, (batch, p,
                                               cfg.frontend.embed_dim)),
             "tokens": jax.random.randint(key, (batch, seq - p), 0,
                                          cfg.vocab_size),
             "targets": jnp.zeros((batch, seq), jnp.int32)}
        return b
    return {"tokens": jax.random.randint(key, (batch, seq), 0,
                                         cfg.vocab_size),
            "targets": jax.random.randint(jax.random.fold_in(key, 1),
                                          (batch, seq), 0, cfg.vocab_size)}
