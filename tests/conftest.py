"""Shared fixtures. NOTE: no XLA_FLAGS here -- smoke tests and benches see
the single real CPU device; only launch/dryrun.py forces 512 devices (and
``tools/ci.sh shard-smoke`` forces 8 for the sharded round engine).

The persistent XLA compilation cache (ROADMAP "Test wall time") is enabled
for every test run: the federated integration tests dominate tier-1 wall
time and their programs are identical across runs, so warm-cache runs skip
most of the compile cost. Override the location with
``JAX_COMPILATION_CACHE_DIR``; set it empty to disable."""
import os

import jax
import jax.numpy as jnp
import pytest

_CACHE_DIR = os.environ.get(
    "JAX_COMPILATION_CACHE_DIR",
    os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                 ".jax_cache"))
if _CACHE_DIR:
    jax.config.update("jax_compilation_cache_dir", _CACHE_DIR)
    jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.25)
    jax.config.update("jax_persistent_cache_min_entry_size_bytes", 0)


# markers (incl. the ``slow`` tier deselected by ``tools/ci.sh smoke``)
# are registered in pytest.ini under --strict-markers; a typo'd marker is
# a collection error, not a silently-ignored tag.


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.PRNGKey(0)


def small_batch(cfg, key, batch=2, seq=32):
    """A valid training batch for any architecture config."""
    import jax.numpy as jnp
    if cfg.frontend.kind == "audio":
        return {"embeds": jax.random.normal(key, (batch, seq,
                                                  cfg.frontend.embed_dim)),
                "targets": jnp.zeros((batch, seq), jnp.int32)}
    if cfg.frontend.kind == "vision":
        p = cfg.frontend.tokens_per_item
        b = {"embeds": jax.random.normal(key, (batch, p,
                                               cfg.frontend.embed_dim)),
             "tokens": jax.random.randint(key, (batch, seq - p), 0,
                                          cfg.vocab_size),
             "targets": jnp.zeros((batch, seq), jnp.int32)}
        return b
    return {"tokens": jax.random.randint(key, (batch, seq), 0,
                                         cfg.vocab_size),
            "targets": jax.random.randint(jax.random.fold_in(key, 1),
                                          (batch, seq), 0, cfg.vocab_size)}
