"""Unit tests for the program-audit subsystem (ISSUE 6 tentpole):
rule-engine core, the four passes (hlo / jaxpr / pallas / dispatch), and
one slow end-to-end federated dispatch audit. Every rule is exercised in
both directions -- a clean program stays clean AND a deliberately broken
positive control trips -- because a tripwire that cannot fire is
indistinguishable from a passing audit.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import dispatch_audit, hlo_lint, jaxpr_lint, pallas_lint
from repro.analysis.report import AuditReport, ProgramAudit
from repro.analysis.rules import (Finding, ProgramContext, RuleSet,
                                  SEV_ERROR, SEV_WARNING)


class TestRuleEngine:
    def _ruleset(self):
        rs = RuleSet("demo")

        @rs.rule("demo-threshold", "payload above meta['limit']")
        def _check(ctx):
            limit = ctx.meta.get("limit")
            if limit is None:
                return
            if ctx.payload > limit:
                yield f"{ctx.payload} > {limit}", "payload"

        @rs.rule("demo-warn", "always warns", severity=SEV_WARNING)
        def _warn(ctx):
            yield "heads up"

        return rs

    def test_rules_yield_findings_with_severity(self):
        rs = self._ruleset()
        ctx = ProgramContext("p", "demo", payload=5, meta={"limit": 3})
        fs = rs.run(ctx)
        by_rule = {f.rule: f for f in fs}
        assert by_rule["demo-threshold"].severity == SEV_ERROR
        assert by_rule["demo-threshold"].location == "payload"
        assert by_rule["demo-warn"].severity == SEV_WARNING

    def test_unconfigured_rule_yields_nothing(self):
        """Thresholds are opt-in by meta: no meta['limit'] -> no finding,
        never a crash (rules are sweep-wide, programs configure them)."""
        rs = self._ruleset()
        fs = rs.run(ProgramContext("p", "demo", payload=10 ** 9, meta={}))
        assert [f.rule for f in fs] == ["demo-warn"]

    def test_only_filter(self):
        rs = self._ruleset()
        ctx = ProgramContext("p", "demo", payload=5, meta={"limit": 3})
        fs = rs.run(ctx, only=("demo-threshold",))
        assert [f.rule for f in fs] == ["demo-threshold"]

    def test_report_roundtrip_and_control_semantics(self):
        rep = AuditReport(matrix={"demo": True})
        err = Finding("demo-threshold", SEV_ERROR, "p1", "boom")
        rep.add(ProgramAudit("p1", "demo", [err], {}))
        rep.add(ProgramAudit("p0", "demo", [], {"n": 1}))
        rep.add_control("live", "demo-threshold", [err])
        rep.add_control("dead", "demo-threshold", [])
        js = rep.to_json()
        assert [p["program"] for p in js["programs"]] == ["p0", "p1"]
        assert js["controls"]["live"]["tripped"] is True
        assert js["controls"]["dead"]["tripped"] is False
        assert rep.failed_controls == ["dead"]
        assert not rep.ok                       # p1 errored + dead control
        json.dumps(js)                           # artifact-serializable


_HOSTY_HLO = """\
HloModule m

ENTRY %main (x: f32[8]) -> f32[8] {
  %x = f32[8]{0} parameter(0)
  %tok = token[] after-all()
  %o = token[] outfeed(f32[8]{0} %x, token[] %tok)
  %cc = f32[8]{0} custom-call(f32[8]{0} %x), custom_call_target="xla_ffi_python_cpu_callback"
  ROOT %y = f32[8]{0} add(f32[8]{0} %x, f32[8]{0} %cc)
}
"""

_F64_HLO = """\
HloModule m

ENTRY %main (x: f64[4]) -> f64[4] {
  %x = f64[4]{0} parameter(0)
  ROOT %y = f64[4]{0} add(f64[4]{0} %x, f64[4]{0} %x)
}
"""


class TestHLORules:
    def test_host_transfer_rule(self):
        findings, _ = hlo_lint.lint_hlo(_HOSTY_HLO, "hosty")
        rules = sorted({f.rule for f in findings})
        assert rules == ["hlo-host-transfer"]
        assert len(findings) == 2               # outfeed + callback call

    def test_f64_rule_and_waiver(self):
        findings, _ = hlo_lint.lint_hlo(_F64_HLO, "f64")
        assert {f.rule for f in findings} == {"hlo-dtype-upcast"}
        waived, _ = hlo_lint.lint_hlo(_F64_HLO, "f64",
                                      {"allow_f64": True})
        assert waived == []

    def test_materialization_via_compiled_program(self):
        """The real dense-vs-kernel check lives in test_hlo_guard.py; here
        just the rule mechanics on a tiny compiled matmul."""
        text = jax.jit(lambda a, b: a @ b).lower(
            jax.ShapeDtypeStruct((32, 16), jnp.float32),
            jax.ShapeDtypeStruct((16, 24), jnp.float32)).compile().as_text()
        meta = {"forbid_elems": 32 * 24, "forbid_dims": (32, 24)}
        findings, payload = hlo_lint.lint_hlo(text, "mm", meta)
        assert any(f.rule == "hlo-materialization" for f in findings)
        clean, _ = hlo_lint.lint_hlo(text, "mm",
                                     {"forbid_elems": 10 ** 9})
        assert clean == []
        assert payload.stats.total_collective_bytes == 0

    def test_collective_budget_and_parity(self):
        text = _TUPLE = """\
HloModule m

%add (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %s = f32[] add(f32[] %a, f32[] %b)
}

ENTRY %main (x: f32[16]) -> f32[16] {
  %x = f32[16]{0} parameter(0)
  ROOT %ar = f32[16]{0} all-reduce(f32[16]{0} %x), replica_groups={}, to_apply=%add
}
"""
        findings, _ = hlo_lint.lint_hlo(
            text, "coll", {"max_collective_count": 0,
                           "max_collective_bytes": 0})
        assert sorted(f.rule for f in findings) == [
            "hlo-collective-budget", "hlo-collective-budget"]
        ok, _ = hlo_lint.lint_hlo(text, "coll",
                                  {"max_collective_count": 1,
                                   "max_collective_bytes": 64})
        assert ok == []
        assert hlo_lint.collective_parity(text, text, label_a="a",
                                          label_b="b") == []
        doubled = text.replace("f32[16]", "f32[32]")
        diff = hlo_lint.collective_parity(text, doubled, label_a="a",
                                          label_b="b")
        assert {f.rule for f in diff} == {hlo_lint.PARITY_RULE}


class TestJaxprRules:
    def test_clean_program(self):
        jx = jaxpr_lint.trace(lambda x: jnp.tanh(x) @ x,
                              jax.ShapeDtypeStruct((4, 4), jnp.float32))
        assert jaxpr_lint.lint_jaxpr(jx, "clean") == []
        stats = jaxpr_lint.jaxpr_stats(jx)
        assert stats["eqns"] >= 2

    def test_callback_trips_even_inside_scan(self):
        def poisoned(x):
            def body(c, _):
                jax.debug.callback(lambda v: None, c)
                return c * 2.0, None
            out, _ = jax.lax.scan(body, x, None, length=3)
            return out

        jx = jaxpr_lint.trace(poisoned, jnp.float32(1.0))
        fs = jaxpr_lint.lint_jaxpr(jx, "poisoned")
        assert any(f.rule == "jaxpr-callback" for f in fs)
        assert jaxpr_lint.lint_jaxpr(jx, "waived",
                                     {"allow_callbacks": True}) == []

    def test_f64_promotion_trips(self):
        jax.config.update("jax_enable_x64", True)
        try:
            jx = jaxpr_lint.trace(
                lambda x: x.astype(jnp.float64).sum(),
                jax.ShapeDtypeStruct((4,), jnp.float32))
        finally:
            jax.config.update("jax_enable_x64", False)
        fs = jaxpr_lint.lint_jaxpr(jx, "f64")
        assert any(f.rule == "jaxpr-f64" for f in fs)


class TestPallasRules:
    def test_registry_is_clean(self):
        progs = pallas_lint.collect_registry()
        assert progs.records, "registry captured no pallas_call launches"
        assert all(p.ok for p in progs.probes), [
            p.detail for p in progs.probes if not p.ok]
        assert pallas_lint.lint_kernels(progs, "registry") == []

    def test_vmem_estimates_under_budget(self):
        progs = pallas_lint.collect_registry()
        for rec in progs.records:
            assert 0 < pallas_lint.estimate_vmem(rec) \
                <= pallas_lint.VMEM_BUDGET_BYTES

    def test_oversized_control_trips_grid_and_vmem(self):
        fs = pallas_lint.lint_kernels(pallas_lint.oversized_control(),
                                      "oversized")
        rules = {f.rule for f in fs}
        assert "pallas-grid-blockspec" in rules
        assert "pallas-vmem-budget" in rules

    def test_vmem_budget_table(self):
        """Per-target VMEM budgets (satellite: the hard-coded v5e 16 MiB
        became a table): default is v5e, meta selects a target, explicit
        bytes win, unknown targets fail loudly with the known set."""
        assert pallas_lint.vmem_budget() == \
            pallas_lint.VMEM_BUDGETS["v5e"] == 16 * 2 ** 20
        assert pallas_lint.VMEM_BUDGET_BYTES == \
            pallas_lint.VMEM_BUDGETS["v5e"]     # back-compat alias
        for target, budget in pallas_lint.VMEM_BUDGETS.items():
            assert pallas_lint.vmem_budget(
                {"vmem_target": target}) == budget
        assert pallas_lint.VMEM_BUDGETS["v5p"] > \
            pallas_lint.VMEM_BUDGETS["v5e"]
        assert pallas_lint.vmem_budget(
            {"vmem_target": "v4", "vmem_budget_bytes": 123}) == 123
        with pytest.raises(KeyError, match="v5e"):
            pallas_lint.vmem_budget({"vmem_target": "v9"})

    def test_vmem_rule_respects_selected_target(self):
        """The budget rule gates against the SELECTED budget, both
        directions: one byte under the control's footprint trips, one
        byte over clears (every table target is below it, so the control
        keeps tripping v4 through v6e)."""
        ctl = pallas_lint.oversized_control()
        peak = max(pallas_lint.estimate_vmem(r) for r in ctl.records)
        assert peak > max(pallas_lint.VMEM_BUDGETS.values())
        trips = pallas_lint.lint_kernels(
            ctl, "ctl", {"vmem_budget_bytes": peak - 1})
        clear = pallas_lint.lint_kernels(
            ctl, "ctl", {"vmem_budget_bytes": peak + 1})
        assert any(f.rule == "pallas-vmem-budget" for f in trips)
        assert not any(f.rule == "pallas-vmem-budget" for f in clear)


class TestDispatchRules:
    def test_steady_state_clean(self):
        f = jax.jit(lambda x: (x * 2.0).sum())
        mon = dispatch_audit.DispatchMonitor()
        with mon:
            for r in range(4):
                np.asarray(f(jnp.ones((8,))))
                mon.mark(f"round{r}")
        assert dispatch_audit.lint_dispatch(mon, "steady",
                                            {"warmup": 1}) == []

    def test_shape_varying_rounds_trip(self):
        f = jax.jit(lambda x: (x * 2.0).sum())
        mon = dispatch_audit.DispatchMonitor()
        with mon:
            for r in range(4):
                np.asarray(f(jnp.ones((8 + r,))))
                mon.mark(f"round{r}")
        fs = dispatch_audit.lint_dispatch(mon, "vary", {"warmup": 1})
        assert {f.rule for f in fs} == {"dispatch-steady-state-recompile"}
        assert len(fs) == 3                      # rounds 1-3 all retrace

    def test_eager_budget_rule(self):
        mon = dispatch_audit.DispatchMonitor()
        with mon:
            for r in range(3):
                np.asarray(jnp.ones((4,)) * 2.0)   # eager bind on purpose
                mon.mark(f"round{r}")
        fs = dispatch_audit.lint_dispatch(
            mon, "eager", {"warmup": 1, "max_eager_per_phase": 0})
        assert any(f.rule == "dispatch-eager-budget" for f in fs)

    def test_nesting_raises(self):
        mon = dispatch_audit.DispatchMonitor()
        with mon:
            with pytest.raises(RuntimeError):
                with dispatch_audit.DispatchMonitor():
                    pass


@pytest.mark.slow
class TestFederatedDispatchAudit:
    def test_batched_round_engine_is_steady_state(self):
        """End to end: a real multi-round federated run compiles nothing
        after warmup (the gate ``tools/ci.sh lint`` applies per engine)."""
        from repro.federation.experiment import build_experiment
        exp = build_experiment(
            "raflora",
            fl_overrides={"num_rounds": 6, "num_clients": 4,
                          "participation": 1.0},
            lora_overrides={"rank_levels": (4, 8),
                            "rank_probs": (0.5, 0.5)},
            num_classes=4, d_model=32, samples_per_class=20,
            batches_per_round=1, backend="kernel",
            round_engine="batched")
        mon = dispatch_audit.DispatchMonitor()
        with mon:
            for r in range(4):
                exp.server.run_round()
                mon.mark(f"round{r}")
        assert dispatch_audit.lint_dispatch(
            mon, "federated/batched",
            {"warmup": 2, "max_eager_per_phase": 8}) == []
        assert mon.phases[0].traces > 0          # warmup really compiled
