"""Theorem 1 / Appendix A-B: machine-checked theory, incl. property tests."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline: deterministic fixed-grid shim
    from _hypothesis_compat import given, settings, strategies as st

from repro.core import (SampledSim, collapse_bound, contraction_factors,
                        coverage, h_sampling, mean_field_floor,
                        mean_field_step, rho_series, simulate_expected)

LEVELS = [8, 16, 32, 48, 64]


def make_ranks(K=100):
    return np.repeat(LEVELS, K // len(LEVELS))


class TestHSampling:
    def test_endpoints(self):
        # h(1) = 1 (full coverage -> no contraction beyond beta^2)
        assert np.isclose(h_sampling(np.array([1.0]), 100, 10), 1.0)
        assert np.isclose(h_sampling(np.array([0.0]), 100, 10), 0.0)

    @given(p=st.floats(0.01, 0.99), K=st.integers(10, 500),
           frac=st.floats(0.05, 0.9))
    @settings(max_examples=100, deadline=None)
    def test_matches_hypergeometric_moment(self, p, K, frac):
        """h(p) must equal E[(N/M)^2] for N ~ Hypergeo(K, round(pK), M)."""
        M = max(1, int(K * frac))
        kp = round(p * K)
        p_eff = kp / K
        h = h_sampling(np.array([p_eff]), K, M)[0]
        mean = M * p_eff
        var = M * p_eff * (1 - p_eff) * (K - M) / (K - 1)
        second = (var + mean ** 2) / M ** 2
        assert np.isclose(h, second, rtol=1e-9)

    @given(st.lists(st.floats(0.0, 1.0), min_size=2, max_size=10))
    @settings(max_examples=60, deadline=None)
    def test_monotone(self, ps):
        """h is strictly increasing on [0,1] (Step 3 of the proof)."""
        ps = np.sort(np.asarray(ps))
        h = h_sampling(ps, 100, 10)
        assert np.all(np.diff(h) >= -1e-12)


class TestTheorem1:
    def test_geometric_bound_holds(self):
        ranks = make_ranks()
        p = coverage(LEVELS, ranks)
        e0 = np.ones(64)
        E = simulate_expected(e0, p, 100, 10, rounds=200)
        tail = 1 - rho_series(E, 8)
        C, gamma = collapse_bound(e0, p, 100, 10, r1=8)
        bound = C * gamma ** np.arange(201)
        assert 0 < gamma < 1
        assert np.all(tail <= bound + 1e-12)

    def test_collapse_limit(self):
        ranks = make_ranks()
        p = coverage(LEVELS, ranks)
        E = simulate_expected(np.ones(64), p, 100, 10, rounds=500)
        assert 1 - rho_series(E, 8)[-1] < 1e-8   # lim rho -> 1

    @given(seed=st.integers(0, 10_000))
    @settings(max_examples=20, deadline=None)
    def test_contraction_ordering(self, seed):
        """q_1 = ... = q_{r1} > q_{r1+1} >= ... >= q_{rmax} for any client
        rank assignment drawn from the levels."""
        rng = np.random.default_rng(seed)
        ranks = rng.choice(LEVELS, size=100)
        if (ranks >= 16).sum() == 0:
            return
        p = coverage(LEVELS, ranks)
        q = contraction_factors(p, 100, 10)
        r1 = min(LEVELS)
        assert np.allclose(q[:r1], q[0])
        assert np.all(np.diff(q[r1 - 1:]) <= 1e-12)

    def test_full_participation_no_sampling_noise(self):
        """M = K: h(p) = p^2 exactly (variance term vanishes)."""
        p = np.linspace(0.1, 1, 10)
        assert np.allclose(h_sampling(p, 50, 50), p ** 2)


class TestSampledSimulation:
    def test_flexlora_collapses_raflora_does_not(self):
        ranks = make_ranks()
        sim = SampledSim(client_ranks=ranks, M=10, seed=3)
        e_flex = sim.run(np.ones(64), 150, rule="flexlora",
                         rank_levels=LEVELS)
        e_ra = sim.run(np.ones(64), 150, rule="raflora", rank_levels=LEVELS)
        assert 1 - rho_series(e_flex, 8)[-1] < 1e-3     # collapsed
        assert 1 - rho_series(e_ra, 8)[-1] > 0.5        # preserved

    def test_sampled_tracks_expected(self):
        """Monte-Carlo mean energies track the closed-form recursion."""
        ranks = make_ranks()
        p = coverage(LEVELS, ranks)
        runs = [SampledSim(client_ranks=ranks, M=10, seed=s).run(
            np.ones(64), 30, rank_levels=LEVELS) for s in range(40)]
        mc = np.mean(runs, axis=0)
        exact = simulate_expected(np.ones(64), p, 100, 10, 30)
        # compare tail-energy ratio trajectories
        assert np.allclose(1 - rho_series(mc, 8), 1 - rho_series(exact, 8),
                           atol=0.08)


class TestMeanField:
    def test_reduces_to_basic(self):
        p = coverage(LEVELS, make_ranks())
        e = np.ones(64)
        stepped = mean_field_step(e, p, 100, 10)
        q = contraction_factors(p, 100, 10)
        assert np.allclose(stepped, q * e)

    def test_floor_positive_under_noise(self):
        """delta^2 > 0 leaves steady-state floors (no total collapse)."""
        p = coverage(LEVELS, make_ranks())
        floor = mean_field_floor(p, 100, 10, delta2=0.01)
        assert np.all(floor[8:] > 0)

    def test_basis_drift_accelerates(self):
        p = coverage(LEVELS, make_ranks())
        e = np.ones(64)
        drifted = mean_field_step(e, p, 100, 10, kappa=0.8)
        aligned = mean_field_step(e, p, 100, 10, kappa=1.0)
        assert np.all(drifted <= aligned + 1e-12)
