"""Beyond-paper server features: factored momentum + FFA-LoRA baseline."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.server_opt import FactoredServerMomentum


class TestFactoredMomentum:
    def test_matches_dense_fedavgm(self):
        """Factored momentum == dense FedAvgM when everything fits in
        rank r_max (no truncation error)."""
        key = jax.random.PRNGKey(0)
        d, n, r = 24, 20, 16
        opt = FactoredServerMomentum(beta=0.9, eta=1.0)
        # dense reference state
        m_dense = np.zeros((d, n))
        w_dense = None
        rng = np.random.default_rng(0)
        old_b = jnp.zeros((d, r))
        old_a = jnp.zeros((r, n))
        for t in range(4):
            kb, ka = jax.random.split(jax.random.fold_in(key, t))
            # low-rank "aggregated" update (rank 4 so stacks stay <= r)
            nb = jax.random.normal(kb, (d, 4)) * 0.3
            na = jax.random.normal(ka, (4, n)) * 0.3
            new_b = jnp.concatenate([nb, jnp.zeros((d, r - 4))], axis=1)
            new_a = jnp.concatenate([na, jnp.zeros((r - 4, n))], axis=0)
            got_b, got_a = opt.apply("layer0", (old_b, old_a),
                                     (new_b, new_a), r)
            # dense reference
            w_old = np.asarray(old_b @ old_a)
            delta = np.asarray(new_b @ new_a) - w_old
            m_dense = 0.9 * m_dense + delta
            w_dense = w_old + m_dense
            got = np.asarray(got_b @ got_a)
            np.testing.assert_allclose(got, w_dense, atol=2e-3)
            old_b, old_a = got_b, got_a

    def test_accelerates_toward_fixed_target(self):
        """Momentum must make repeated identical updates overshoot the
        plain step (the acceleration property)."""
        d, n, r = 16, 12, 8
        key = jax.random.PRNGKey(1)
        tb = jax.random.normal(key, (d, 4)) * 0.5
        ta = jax.random.normal(jax.random.fold_in(key, 1), (4, n)) * 0.5
        tb_p = jnp.concatenate([tb, jnp.zeros((d, r - 4))], axis=1)
        ta_p = jnp.concatenate([ta, jnp.zeros((r - 4, n))], axis=0)
        opt = FactoredServerMomentum(beta=0.9, eta=1.0)
        b = jnp.zeros((d, r))
        a = jnp.zeros((r, n))
        for _ in range(3):
            b, a = opt.apply("k", (b, a), (tb_p, ta_p), r)
        norm_momentum = float(jnp.linalg.norm(b @ a))
        norm_plain = float(jnp.linalg.norm(tb_p @ ta_p))
        assert norm_momentum > norm_plain  # accumulated past the target

    def test_in_full_federated_loop(self):
        from repro.federation.experiment import build_experiment
        exp = build_experiment(
            "raflora",
            fl_overrides={"num_rounds": 3, "num_clients": 8,
                          "participation": 0.5},
            server_momentum_beta=0.9,
            num_classes=6, d_model=64, samples_per_class=30,
            batches_per_round=1)
        exp.server.run(3)
        assert np.isfinite(exp.server.history[-1].mean_client_loss)
        assert exp.server.server_momentum.state  # momentum accumulated


class TestFFALoRA:
    def test_a_factors_frozen(self):
        """FFA: clients must return UNCHANGED A factors; global A fixed."""
        from repro.federation.experiment import build_experiment
        exp = build_experiment(
            "ffa",
            fl_overrides={"num_rounds": 2, "num_clients": 6,
                          "participation": 0.5},
            num_classes=6, d_model=64, samples_per_class=30,
            batches_per_round=1)
        before = [np.asarray(x) for p, x in
                  jax.tree_util.tree_leaves_with_path(exp.server.global_lora)
                  if str(getattr(p[-1], "key", "")) == "lora_a"]
        exp.server.run(2)
        after = [np.asarray(x) for p, x in
                 jax.tree_util.tree_leaves_with_path(exp.server.global_lora)
                 if str(getattr(p[-1], "key", "")) == "lora_a"]
        for b, a in zip(before, after):
            np.testing.assert_allclose(b, a, atol=1e-6)  # A truly frozen
        # and training still progresses via B
        assert np.isfinite(exp.server.history[-1].mean_client_loss)
