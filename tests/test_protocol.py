"""Protocol verifier (ISSUE 8 tentpole): the bounded-interleaving model
checker holds on the real scheduler, every injected protocol bug trips
its invariant rule (both directions -- a gate whose tripwires are dead
proves nothing), the partial-order/symmetry reduction is sound-shaped,
and the ``tools/verify_protocol.py`` sweep writes a well-formed, green,
control-gated ``AUDIT_protocol.json``."""
import importlib.util
import json
import os

import numpy as np
import pytest

from repro.analysis.protocol import (CancelledDeliveryScheduler,
                                     DoubleConsumeScheduler, Driver,
                                     FixedLatency, Scenario, canonical_combo,
                                     check_scenario, discover_slots,
                                     replay_from, signature_of, table_of)
from repro.federation.events import (ClientLifecycle, CountTrigger,
                                     EventScheduler, LifecycleEvent,
                                     StalenessBoundTrigger, TimeoutTrigger)

_TOOL = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "tools", "verify_protocol.py")
_spec = importlib.util.spec_from_file_location("verify_protocol", _TOOL)
verify_protocol = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(verify_protocol)


def _lc_none():
    return ClientLifecycle()


def _lc_drop():
    return ClientLifecycle([
        LifecycleEvent(time=0.4, kind="dropout", client=2),
        LifecycleEvent(time=1.6, kind="rejoin", client=2)])


def _scenario(trigger_fn, lifecycle_fn=_lc_none, *, name="t", grid=(0.5, 1.5),
              n_k=(3, 1, 2), symmetric=(), staleness_bound=None):
    return Scenario(name=name, num_clients=3, num_plans=2,
                    trigger_fn=trigger_fn, lifecycle_fn=lifecycle_fn,
                    grid=grid, n_k=n_k, ranks=(8, 4, 8),
                    staleness_bound=staleness_bound, symmetric=symmetric)


# ---------------------------------------------------------------------------
# the implementation satisfies the invariants on every interleaving
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("trig,bound", [
    (lambda: CountTrigger(3), None),
    (lambda: TimeoutTrigger(1.5), None),
    (lambda: StalenessBoundTrigger(1), 1)],
    ids=["count", "timeout", "staleness"])
@pytest.mark.parametrize("lc", [_lc_none, _lc_drop], ids=["none", "drop"])
def test_invariants_hold_exhaustively(trig, bound, lc):
    sc = _scenario(trig, lc, staleness_bound=bound)
    findings, stats, _ = check_scenario(sc)
    assert findings == []
    assert stats.unique_schedules > 0
    assert stats.fires > 0
    # every unique schedule was checkpoint-cut at every boundary
    assert stats.replays == stats.boundaries > 0


def test_every_arrival_consumed_or_dropped():
    sc = _scenario(lambda: CountTrigger(3), _lc_drop)
    _, _, records = check_scenario(sc, replay=False, keep_records=True)
    for rec in records:
        slots = {(pr, m) for pr, size in rec.plan_sizes.items()
                 for m in range(size)}
        consumed = set(rec.consume_counts)
        assert consumed | rec.dropped == slots
        assert consumed & rec.dropped == set()
        assert all(c == 1 for c in rec.consume_counts.values())


def test_weights_conserve_with_ghost_at_zero():
    sc = _scenario(lambda: TimeoutTrigger(1.5), _lc_drop)
    _, _, records = check_scenario(sc, replay=False, keep_records=True)
    fires = [f for rec in records for f in rec.fires if f.weights]
    assert fires
    for f in fires:
        assert abs(sum(f.weights) - 1.0) < 1e-9
        assert any(f.ghost), "every cohort carries the padding ghost"
        for w, p, g in zip(f.weights, f.present, f.ghost):
            if g or not p:
                assert w == 0.0


# ---------------------------------------------------------------------------
# injected bugs: every tripwire is live
# ---------------------------------------------------------------------------

def test_double_consume_trips_exactly_once():
    f, _, _ = check_scenario(_scenario(lambda: CountTrigger(3)),
                             replay=False, sched_cls=DoubleConsumeScheduler)
    assert f and {x.rule for x in f} == {"proto-exactly-once"}


def test_cancelled_delivery_trips():
    f, _, _ = check_scenario(_scenario(lambda: CountTrigger(2), _lc_drop),
                             replay=False,
                             sched_cls=CancelledDeliveryScheduler)
    assert "proto-cancelled-consumed" in {x.rule for x in f}


def test_present_mask_leak_trips_ghost_rule():
    f, _, _ = check_scenario(_scenario(lambda: CountTrigger(2), _lc_drop),
                             replay=False, break_present=True)
    assert f and {x.rule for x in f} == {"proto-ghost-weight"}


def test_torn_snapshot_trips_replay_divergence():
    f, _, _ = check_scenario(_scenario(lambda: CountTrigger(3)),
                             corrupt_replay=True)
    assert f and {x.rule for x in f} == {"proto-replay-divergence"}


def test_understated_staleness_bound_trips():
    sc = _scenario(lambda: StalenessBoundTrigger(2), staleness_bound=0)
    f, _, _ = check_scenario(sc, replay=False)
    assert "proto-staleness-bound" in {x.rule for x in f}


# ---------------------------------------------------------------------------
# enumeration machinery
# ---------------------------------------------------------------------------

def test_discover_slots_is_latency_independent():
    sc = _scenario(lambda: CountTrigger(3), _lc_drop)
    slots = discover_slots(sc)
    # plan 0 dispatches all three; client 2 is inactive at plan 1
    assert slots == [(0, 0), (0, 1), (0, 2), (1, 0), (1, 1)]


def test_signature_collapses_to_schedule_multiset():
    sc = _scenario(lambda: CountTrigger(3))
    slots = discover_slots(sc)
    sig = signature_of(sc, slots, (0.5, 1.5, 0.5, 1.5, 0.5, 1.5))
    # plan p dispatches at p * round_interval; arrival = dispatch + draw
    assert sig == tuple(sorted(
        [(0.5, 0, 0), (1.5, 0, 1), (0.5, 0, 2),
         (2.5, 1, 0), (1.5, 1, 1), (2.5, 1, 2)]))


def test_symmetry_reduction_canonicalizes_and_validates():
    sym = _scenario(lambda: CountTrigger(3), n_k=(3, 1, 3),
                    symmetric=((0, 2),))
    slots = discover_slots(sym)
    a = canonical_combo(sym, slots, (2.5, 0.5, 0.5, 1.5, 0.5, 0.5))
    b = canonical_combo(sym, slots, (0.5, 0.5, 2.5, 0.5, 0.5, 1.5))
    assert a == b                       # swapped draws of clients 0/2
    _, stats, _ = check_scenario(sym)
    assert 0 < stats.unique_schedules < stats.assignments

    with pytest.raises(AssertionError, match="mixes"):
        check_scenario(_scenario(lambda: CountTrigger(3),
                                 n_k=(3, 1, 2), symmetric=((0, 2),)))
    with pytest.raises(AssertionError, match="lifecycle"):
        check_scenario(_scenario(lambda: CountTrigger(3), _lc_drop,
                                 n_k=(3, 1, 3), symmetric=((0, 2),)))


def test_table_of_orders_draws_per_client():
    table = table_of([(0, 0), (0, 1), (1, 0)], (0.5, 1.5, 2.5))
    assert table == {0: [0.5, 2.5], 1: [1.5]}


def test_fixed_latency_checkpoint_roundtrip():
    lat = FixedLatency({0: (0.5, 1.5), 1: (2.5,)})
    assert lat.sample(0) == 0.5
    snap = lat.state_dict()
    assert lat.sample(0) == 1.5
    lat.load_state_dict(snap)
    assert lat.sample(0) == 1.5
    assert lat.sample(1) == 2.5
    with pytest.raises(AssertionError, match="exhausted"):
        lat.sample(1)


def test_replay_from_every_boundary_kind():
    sc = _scenario(lambda: TimeoutTrigger(1.5))
    slots = discover_slots(sc)
    table = table_of(slots, (0.5,) * len(slots))
    d = Driver(sc, table)
    bounds = d.run_full(cuts=True)
    kinds = {b.kind for b in bounds}
    assert {"dispatch", "fire", "window"} <= kinds
    for b in bounds:
        assert replay_from(sc, table, b, d.record) == []


def test_mid_run_join_expands_dispatch():
    sc = Scenario(name="join", num_clients=3, num_plans=2,
                  trigger_fn=lambda: CountTrigger(3),
                  lifecycle_fn=lambda: ClientLifecycle([
                      LifecycleEvent(time=0.6, kind="join", client=3,
                                     rank=8, shard=np.arange(2))]),
                  grid=(0.5, 1.5), n_k=(3, 1, 2), ranks=(8, 4, 8))
    slots = discover_slots(sc)
    assert slots == [(0, 0), (0, 1), (0, 2), (1, 0), (1, 1), (1, 2), (1, 3)]
    findings, _, _ = check_scenario(sc)
    assert findings == []


# ---------------------------------------------------------------------------
# the sweep tool
# ---------------------------------------------------------------------------

def test_verify_sweep_fast_green(tmp_path, capsys):
    out = tmp_path / "AUDIT_protocol.json"
    assert verify_protocol.main(["--fast", "--out", str(out)]) == 0
    art = json.loads(out.read_text())
    assert art["schema"] == 1
    assert art["summary"]["ok"] is True
    assert art["summary"]["errors"] == 0
    # >= 3 positive controls incl. the ISSUE-named three, all tripped
    assert {"double-fire", "injected-key-reuse",
            "injected-host-clock"} <= set(art["controls"])
    assert all(c["tripped"] for c in art["controls"].values())
    kinds = {p["kind"] for p in art["programs"]}
    assert kinds == {"protocol", "rng-flow", "rng-host"}
    prot = [p for p in art["programs"] if p["kind"] == "protocol"]
    assert all(p["stats"]["replays"] > 0 for p in prot)


def test_tracked_artifact_matches_full_scope():
    """The tracked artifact at the repo root is the FULL sweep: green, all
    three trigger families x lifecycles, every control live."""
    path = os.path.join(os.path.dirname(_TOOL), os.pardir,
                        "AUDIT_protocol.json")
    art = json.loads(open(path).read())
    assert art["summary"]["ok"] is True
    assert art["matrix"]["scope"] == "full"
    names = {p["program"] for p in art["programs"]}
    for trig in ("count", "timeout", "staleness"):
        for lc in ("none", "droprejoin", "join"):
            assert f"protocol/{trig}/{lc}" in names
    assert len(art["controls"]) >= 3
    assert all(c["tripped"] for c in art["controls"].values())
