"""Serving subsystem tests (DESIGN.md §11): adapter store semantics,
path-aware cache seeding (the SSM ``grow`` regression), end-to-end greedy
prefill+decode equivalence against the full-sequence forward, hot-swap
atomicity at a round landing, scheduler-vs-isolated equality, and the
federation post-aggregation hook (sync and async/drain paths)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import LoRAConfig, get_config
from repro.core.lora import merge_lora, split_lora
from repro.models import build_model
from repro.serving import (AdapterStore, ContinuousBatcher, ServeRequest,
                           ServingEngine, seed_cache)

LORA = LoRAConfig(rank_levels=(4, 8, 16))


def _reduced(name, lora=LORA, **replace):
    cfg = get_config(name).reduced(**replace.pop("reduced_kw", {}))
    if replace:
        cfg = dataclasses.replace(cfg, **replace)
    model = build_model(cfg, lora, dtype=jnp.float32, remat=False,
                        block_q=16, block_kv=16)
    return cfg, model


def _rand_lora(lora_tree, key, scale=0.05):
    """Random nonzero factors (init has B=0, which would test nothing)."""
    leaves = [i for i, _ in enumerate(jax.tree.leaves(
        lora_tree, is_leaf=lambda x: x is None))]
    counter = iter(leaves)

    def rand(x):
        if x is None:
            return None
        k = jax.random.fold_in(key, next(counter))
        return scale * jax.random.normal(k, x.shape, x.dtype)
    return jax.tree.map(rand, lora_tree, is_leaf=lambda x: x is None)


def _mask_rank(lora_tree, rank):
    """Zero factor columns >= rank (the store's omega-style convention)."""
    def mask(path, x):
        if x is None:
            return None
        ax = x.ndim - 2 if path[-1].key == "lora_a" else x.ndim - 1
        col = jnp.arange(x.shape[ax])
        shape = [1] * x.ndim
        shape[ax] = x.shape[ax]
        return x * (col < rank).reshape(shape).astype(x.dtype)
    return jax.tree_util.tree_map_with_path(
        mask, lora_tree, is_leaf=lambda x: x is None)


@pytest.fixture(scope="module")
def attn_setup():
    cfg, model = _reduced("gemma-2b")
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


@pytest.fixture(scope="module")
def ssm_setup():
    cfg, model = _reduced("mamba2-1.3b")
    params = model.init(jax.random.PRNGKey(1))
    return cfg, model, params


@pytest.fixture(scope="module")
def mla_setup():
    cfg, model = _reduced("deepseek-v2-236b")
    params = model.init(jax.random.PRNGKey(5))
    return cfg, model, params


# ---------------------------------------------------------------------------
# AdapterStore
# ---------------------------------------------------------------------------

def _toy_tree(r=16, d_in=8, d_out=6, val=1.0):
    return {"proj": {"lora_a": jnp.full((r, d_in), val),
                     "lora_b": jnp.full((d_out, r), val)}}


class TestAdapterStore:
    def test_bucket_order_and_page_ids(self):
        store = AdapterStore((4, 8, 16))
        store.put("c", _toy_tree(), 16)
        store.put("a", _toy_tree(), 4)
        store.put("b", _toy_tree(), 4)
        snap = store.publish()
        # ascending rank level, insertion order within a bucket
        assert snap.page_of == {"a": 0, "b": 1, "c": 2}
        assert snap.ranks == (4, 4, 16)
        np.testing.assert_array_equal(
            np.asarray(snap.page_ids(["c", "a", "c"])), [2, 0, 2])
        assert snap.pages["proj"]["lora_a"].shape[0] == 3

    def test_monotonic_version(self):
        store = AdapterStore((4, 8, 16))
        store.put("t", _toy_tree(), 8)
        assert store.publish().version == 1
        with pytest.raises(ValueError, match="monotonic"):
            store.publish(1)
        assert store.publish(5).version == 5
        assert store.publish().version == 6

    def test_masking_and_padding(self):
        store = AdapterStore((4, 8, 16))
        store.put("t", _toy_tree(r=8), 4)     # true rank 4, staged at r=8
        snap = store.publish()
        a = np.asarray(snap.pages["proj"]["lora_a"][0])   # (16, 8)
        b = np.asarray(snap.pages["proj"]["lora_b"][0])   # (6, 16)
        assert a.shape == (16, 8) and b.shape == (6, 16)
        assert (a[:4] == 1.0).all() and (a[4:] == 0.0).all()
        assert (b[:, :4] == 1.0).all() and (b[:, 4:] == 0.0).all()

    def test_scale_folded_into_b(self):
        store = AdapterStore((4, 8, 16), scaling_fn=lambda r: 32.0 / r)
        store.put("t", _toy_tree(r=16), 16)
        snap = store.publish()
        assert snap.scales == (2.0,)
        np.testing.assert_allclose(
            np.asarray(snap.pages["proj"]["lora_b"][0]), 2.0)
        np.testing.assert_allclose(
            np.asarray(snap.pages["proj"]["lora_a"][0]), 1.0)

    def test_unknown_rank_and_empty_publish_raise(self):
        store = AdapterStore((4, 8, 16))
        with pytest.raises(ValueError, match="not in levels"):
            store.put("t", _toy_tree(), 5)
        with pytest.raises(ValueError, match="no staged"):
            store.publish()

    def test_dora_magnitudes_rejected(self):
        store = AdapterStore((4, 8, 16))
        tree = _toy_tree()
        tree["proj"]["lora_m"] = jnp.ones((6,))
        store.put("t", tree, 16)
        with pytest.raises(ValueError, match="DoRA"):
            store.publish()


# ---------------------------------------------------------------------------
# seed_cache: path-aware merge (the old `grow` shape-matching regression)
# ---------------------------------------------------------------------------

class TestSeedCache:
    def test_ssm_state_with_coincidental_prompt_len_dim(self):
        """The old serve.py `grow` padded ANY axis-2 dim equal to the
        prompt length -- an SSM conv state of width == prompt_len was
        silently grown (and ssm/conv states never transferred at all).
        seed_cache merges by PATH KEY: states transfer unchanged."""
        lp, s_full, slots = 4, 10, 3
        cache = {"layers": {"conv": jnp.zeros((2, slots, lp, 5)),
                            "ssm": jnp.zeros((2, slots, 7, 5)),
                            "k": jnp.zeros((2, slots, s_full, 2, 2))},
                 "len": jnp.zeros((slots,), jnp.int32)}
        got = {"conv": jnp.ones((2, slots, lp, 5)),
               "ssm": 2.0 * jnp.ones((2, slots, 7, 5)),
               "k": 3.0 * jnp.ones((2, slots, lp, 2, 2))}
        out = seed_cache(cache, got, lp, jnp.array([True, True, True]))
        # conv axis-2 == prompt_len is a coincidence: NOT padded, NOT lost
        np.testing.assert_array_equal(np.asarray(out["layers"]["conv"]), 1.0)
        np.testing.assert_array_equal(np.asarray(out["layers"]["ssm"]), 2.0)
        k = np.asarray(out["layers"]["k"])
        assert (k[:, :, :lp] == 3.0).all() and (k[:, :, lp:] == 0.0).all()
        np.testing.assert_array_equal(np.asarray(out["len"]), lp)

    def test_mask_reseeds_only_selected_slots(self):
        lp, s_full, slots = 2, 6, 3
        cache = {"layers": {"k": jnp.zeros((1, slots, s_full, 2))},
                 "len": jnp.full((slots,), 5, jnp.int32)}
        got = {"k": jnp.ones((1, slots, lp, 2))}
        out = seed_cache(cache, got, lp, jnp.array([False, True, False]))
        k = np.asarray(out["layers"]["k"])
        assert (k[:, 0] == 0.0).all() and (k[:, 2] == 0.0).all()
        assert (k[:, 1, :lp] == 1.0).all()
        np.testing.assert_array_equal(np.asarray(out["len"]), [5, lp, 5])

    def test_unknown_leaf_key_raises(self):
        cache = {"layers": {"mystery": jnp.zeros((1, 2, 3))},
                 "len": jnp.zeros((2,), jnp.int32)}
        with pytest.raises(ValueError, match="unknown cache leaf"):
            seed_cache(cache, {"mystery": jnp.ones((1, 2, 3))}, 3,
                       jnp.array([True, True]))


# ---------------------------------------------------------------------------
# end-to-end greedy equivalence (attention + SSM archs)
# ---------------------------------------------------------------------------

def _greedy_reference(model, params, prompt, n_tokens):
    """Greedy continuation via repeated FULL-sequence forwards."""
    toks = list(np.asarray(prompt))
    out = []
    for _ in range(n_tokens):
        seq = jnp.asarray(toks, jnp.int32)[None, :]
        logits, _, _ = model.forward_seq(params, {"tokens": seq},
                                         mode="train")
        nxt = int(jnp.argmax(logits[0, -1]))
        out.append(nxt)
        toks.append(nxt)
    return out


@pytest.mark.parametrize("setup_name", ["attn_setup", "ssm_setup"])
def test_e2e_greedy_matches_full_forward(setup_name, request):
    """Prefill + token-by-token decode through the serving engine must
    reproduce the full-sequence forward's greedy argmax -- per slot, with
    HETEROGENEOUS per-slot adapter ranks (16 and 4). The SSM arch is the
    regression for the old `grow` bug (conv/ssm states never transferred:
    decode ran from zero state and diverged)."""
    cfg, model, params = request.getfixturevalue(setup_name)
    base, lora_tree = split_lora(params)
    tree_hi = _rand_lora(lora_tree, jax.random.PRNGKey(7))
    tree_lo = _rand_lora(lora_tree, jax.random.PRNGKey(8))

    store = AdapterStore(LORA.rank_levels)
    store.put("hi", tree_hi, 16)
    store.put("lo", tree_lo, 4)
    store.publish()

    lp, n_new = 8, 4
    prompts = jax.random.randint(jax.random.PRNGKey(9), (2, lp), 0,
                                 cfg.vocab_size)
    engine = ServingEngine(model, params, store, max_len=lp + n_new + 1,
                           slots=2)
    first = engine.admit([0, 1], prompts, ["hi", "lo"])
    gen = [np.asarray(first)]
    for _ in range(n_new - 1):
        gen.append(np.asarray(engine.decode(jnp.array([True, True]))))
    gen = np.stack(gen, axis=1)                       # (2, n_new)

    for row, (tree, rank) in enumerate([(tree_hi, 16), (tree_lo, 4)]):
        merged = merge_lora(base, _mask_rank(tree, rank))
        want = _greedy_reference(model, merged, prompts[row], n_new)
        np.testing.assert_array_equal(gen[row], want,
                                      err_msg=f"slot {row} rank {rank}")


def test_e2e_mla_ragged_slots_matches_full_forward(mla_setup):
    """deepseek-style MLA serving with RAGGED per-slot cache lengths: slot 1
    admits mid-stream with a shorter prompt, so ``cache["len"]`` is a
    heterogeneous vector when both slots decode together -- the shape that
    used to raise NotImplementedError in the MLA decode path. Every slot's
    greedy continuation must still match the full-sequence forward."""
    cfg, model, params = mla_setup
    base, lora_tree = split_lora(params)
    tree_hi = _rand_lora(lora_tree, jax.random.PRNGKey(7))
    tree_lo = _rand_lora(lora_tree, jax.random.PRNGKey(8))

    store = AdapterStore(LORA.rank_levels)
    store.put("hi", tree_hi, 16)
    store.put("lo", tree_lo, 4)
    store.publish()

    lp0, lp1 = 8, 5
    key = jax.random.PRNGKey(9)
    prompt0 = jax.random.randint(key, (1, lp0), 0, cfg.vocab_size)
    prompt1 = jax.random.randint(jax.random.fold_in(key, 1), (1, lp1), 0,
                                 cfg.vocab_size)
    engine = ServingEngine(model, params, store, max_len=lp0 + 6, slots=2)
    gen0 = [int(engine.admit([0], prompt0, ["hi"])[0])]
    gen0.append(int(engine.decode(jnp.array([True, False]))[0]))
    gen1 = [int(engine.admit([1], prompt1, ["lo"])[0])]
    lens = np.asarray(engine.slot_len())
    assert lens[0] != lens[1], "slots must be genuinely ragged"
    for _ in range(2):
        toks = engine.decode(jnp.array([True, True]))
        gen0.append(int(toks[0]))
        gen1.append(int(toks[1]))

    for row, (tree, rank, prompt, gen) in enumerate(
            [(tree_hi, 16, prompt0, gen0), (tree_lo, 4, prompt1, gen1)]):
        merged = merge_lora(base, _mask_rank(tree, rank))
        want = _greedy_reference(model, merged, prompt[0], len(gen))
        np.testing.assert_array_equal(
            gen, want, err_msg=f"slot {row} rank {rank} (ragged decode)")


# ---------------------------------------------------------------------------
# hot-swap atomicity at a round landing
# ---------------------------------------------------------------------------

def test_hot_swap_atomic_no_version_mixing():
    """Mid-stream publish: (a) every engine step runs on exactly one
    snapshot version and the version log flips once; (b) post-flip tokens
    are BIT-EQUAL to a fresh engine started on the new adapters that
    teacher-forces the same prefix. Single layer + cache-neutral targets
    (q/o projections feed nothing that is cached), so the cache depends
    only on the token sequence, never the adapter version."""
    cfg, model = _reduced("gemma-2b", lora_targets=("q_proj", "o_proj"),
                          reduced_kw={"num_layers": 1})
    params = model.init(jax.random.PRNGKey(2))
    _, lora_tree = split_lora(params)
    tree_v1 = _rand_lora(lora_tree, jax.random.PRNGKey(3))
    tree_v2 = _rand_lora(lora_tree, jax.random.PRNGKey(4))

    store = AdapterStore(LORA.rank_levels)
    store.put("t", tree_v1, 16)
    store.publish()

    lp, pre_flip, post_flip = 8, 3, 4
    prompts = jax.random.randint(jax.random.PRNGKey(5), (2, lp), 0,
                                 cfg.vocab_size)
    engine = ServingEngine(model, params, store,
                           max_len=lp + pre_flip + post_flip + 2, slots=2)
    seq = [np.asarray(engine.admit([0, 1], prompts, ["t", "t"]))]
    active = jnp.array([True, True])
    for _ in range(pre_flip):
        seq.append(np.asarray(engine.decode(active)))
    # the round landing: in-flight stream, new factors, bumped version
    store.put("t", tree_v2, 16)
    store.publish()
    for _ in range(post_flip):
        seq.append(np.asarray(engine.decode(active)))
    seq = np.stack(seq, axis=1)                 # (2, 1 + pre + post)

    # (a) one version per step, exactly one flip, no interleaving
    log = engine.version_log
    assert log == [1] * (1 + pre_flip) + [2] * post_flip, log

    # (b) fresh engine on v2 only, teacher-forced through the prefix
    store2 = AdapterStore(LORA.rank_levels)
    store2.put("t", tree_v2, 16)
    store2.publish()
    fresh = ServingEngine(model, params, store2,
                          max_len=lp + pre_flip + post_flip + 2, slots=2)
    fresh.admit([0, 1], prompts, ["t", "t"])
    # force the v1-generated prefix (cache is version-independent here)
    replay = []
    for t in range(pre_flip + post_flip):
        fresh.tokens = jnp.asarray(seq[:, t], jnp.int32)
        replay.append(np.asarray(fresh.decode(active)))
    replay = np.stack(replay, axis=1)
    # free-running tail under v2 == original's post-flip tokens, bit-equal
    np.testing.assert_array_equal(replay[:, pre_flip:],
                                  seq[:, 1 + pre_flip:])


# ---------------------------------------------------------------------------
# scheduler
# ---------------------------------------------------------------------------

def test_scheduler_matches_isolated_requests(attn_setup):
    """Continuous batching (slot recycling, interleaved tenants) must not
    change any request's tokens vs running it alone in its own engine."""
    cfg, model, params = attn_setup
    _, lora_tree = split_lora(params)
    store = AdapterStore(LORA.rank_levels)
    store.put("hi", _rand_lora(lora_tree, jax.random.PRNGKey(11)), 16)
    store.put("lo", _rand_lora(lora_tree, jax.random.PRNGKey(12)), 4)
    store.publish()

    lp, n_new, slots = 8, 4, 2
    rng = np.random.default_rng(13)
    reqs = [ServeRequest(rid=i, prompt=rng.integers(0, cfg.vocab_size,
                                                    size=lp),
                         adapter_id=("hi", "lo")[i % 2],
                         max_new_tokens=n_new, arrival=0.01 * i)
            for i in range(5)]

    engine = ServingEngine(model, params, store, max_len=lp + n_new + 1,
                           slots=slots)
    batcher = ContinuousBatcher(engine, step_cost=0.01, prefill_cost=0.05)
    for r in reqs:
        batcher.submit(r)
    batcher.run()
    assert len(batcher.done) == len(reqs)
    stats = batcher.stats()
    assert stats["completed"] == len(reqs)
    assert stats["tokens"] == len(reqs) * n_new
    assert stats["virtual_p95_s"] >= stats["virtual_p50_s"] > 0

    for req in batcher.done:
        iso = ServingEngine(model, params, store, max_len=lp + n_new + 1,
                            slots=slots)
        toks = [int(np.asarray(iso.admit(
            [0], np.asarray(req.prompt)[None], [req.adapter_id]))[0])]
        for _ in range(n_new - 1):
            toks.append(int(np.asarray(
                iso.decode(jnp.array([True, False])))[0]))
        assert req.tokens == toks, req.rid


def test_scheduler_latency_draws_are_deterministic(attn_setup):
    """Same scenario twice -> bit-identical virtual stats (the property
    bench_trend relies on to gate serving rows)."""
    from repro.federation.events import LognormalLatency
    cfg, model, params = attn_setup
    _, lora_tree = split_lora(params)

    def run_once():
        store = AdapterStore(LORA.rank_levels)
        store.put("t", _rand_lora(lora_tree, jax.random.PRNGKey(21)), 8)
        store.publish()
        engine = ServingEngine(model, params, store, max_len=12, slots=2)
        batcher = ContinuousBatcher(
            engine, latency=LognormalLatency(0.02, 0.3, seed=0),
            step_cost=0.01, prefill_cost=0.05)
        rng = np.random.default_rng(22)
        for i in range(4):
            batcher.submit(ServeRequest(
                rid=i, prompt=rng.integers(0, cfg.vocab_size, size=8),
                adapter_id="t", max_new_tokens=3, arrival=0.02 * i))
        batcher.run()
        return batcher.stats()

    assert run_once() == run_once()


# ---------------------------------------------------------------------------
# federation round-landing hook
# ---------------------------------------------------------------------------

def _tiny_experiment(**kw):
    from repro.federation.experiment import build_experiment
    fl = {"num_clients": 4, "participation": 1.0, "num_rounds": 8,
          "local_batch_size": 4}
    fl.update(kw.pop("fl_overrides", {}))
    lora = {"rank_levels": (4, 8), "rank_probs": (0.5, 0.5)}
    lora.update(kw.pop("lora_overrides", {}))
    return build_experiment(
        "raflora", fl_overrides=fl, lora_overrides=lora,
        num_classes=4, d_model=32, samples_per_class=8,
        batches_per_round=1, **kw)


class TestRoundLandingHook:
    def test_sync_engine_fires_hook_every_round(self):
        exp = _tiny_experiment(round_engine="batched")
        seen = []
        exp.server.add_post_aggregate_hook(
            lambda v, tree: seen.append(v))
        store = AdapterStore((4, 8))
        store.bind_server(exp.server)
        exp.server.run(3)
        assert seen == [1, 2, 3]
        assert exp.server.adapter_version == 3
        assert store.version == 3
        snap = store.published
        assert snap.ranks == (8,) and snap.page_of == {"global": 0}

    def test_async_engine_fires_on_buffer_and_drain(self):
        exp = _tiny_experiment(round_engine="async", pipeline_depth=2)
        store = AdapterStore((4, 8))
        store.bind_server(exp.server)
        exp.server.run(3)          # depth 2: not every round aggregates
        mid = store.version
        exp.server.drain_pending()  # mid-buffer leftovers must also land
        assert store.version == exp.server.adapter_version >= mid
        assert store.version >= 1
        log = store.published
        assert log is not None and log.version == store.version

    def test_unservable_adapters_skip_and_warn(self):
        """A DoRA run with a bound AdapterStore: the store rejects DoRA
        magnitudes at publish(), so the post-aggregate hook raises inside
        the round loop. The hook must degrade to skip-and-warn -- training
        continues, the store simply never publishes -- instead of taking
        down the round. (Regression: the hook exception used to propagate
        out of ``_write_factors`` and abort ``run()``.)"""
        exp = _tiny_experiment(round_engine="batched",
                               lora_overrides={"variant": "dora"})
        store = AdapterStore((4, 8))
        store.bind_server(exp.server)
        with pytest.warns(RuntimeWarning, match="post-aggregate hook"):
            exp.server.run(2)
        assert exp.server.adapter_version == 2    # the round loop survived
        assert store.published is None            # nothing ever servable

    def test_served_factors_track_global(self):
        exp = _tiny_experiment(round_engine="batched")
        store = AdapterStore((4, 8))
        store.bind_server(exp.server)
        exp.server.run(2)
        want = {p: np.asarray(l) for p, l in
                jax.tree_util.tree_flatten_with_path(
                    exp.server.global_lora)[0]}
        got_tree = store.published.pages
        for path, leaf in jax.tree_util.tree_flatten_with_path(got_tree)[0]:
            np.testing.assert_allclose(np.asarray(leaf[0]), want[path],
                                       atol=1e-7)


# ---------------------------------------------------------------------------
# engine guards
# ---------------------------------------------------------------------------

def test_engine_rejects_unpublished_store(attn_setup):
    cfg, model, params = attn_setup
    with pytest.raises(ValueError, match="publish"):
        ServingEngine(model, params, AdapterStore(LORA.rank_levels),
                      max_len=8, slots=1)
