"""Peak-memory regression tripwire for the fused kernel backend (ISSUE 4
satellite, now on the ISSUE 6 rule engine): ``backend="kernel"`` must
NEVER materialize a (d, n)-shaped intermediate -- that is the whole point
of the fused factored path (DESIGN.md §4.3). The jitted bucket pipeline is
lowered to optimized HLO and run through ``analysis/hlo_lint``'s
``hlo-materialization`` rule (the declarative generalization of the old
hand-rolled walker loop); at shapes where (d+n) R << d n, ANY array of
d*n elements (or with trailing (d, n) / (n, d) dims) means the dense
update crept back in. The dense backend is lowered too, as a positive
control that the rule actually detects dW.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.analysis.hlo_lint import lint_hlo
from repro.core import aggregation

D, N, M, R_MAX = 192, 320, 3, 16

_META = {"forbid_elems": D * N, "forbid_dims": (D, N)}


def _compiled_text(backend: str, with_fallback: bool = True) -> str:
    """Optimized HLO of ``_stacked_core`` (the batched engine's per-bucket
    dispatch) for one (M, d, r) bucket of the raflora method."""
    bs = jax.ShapeDtypeStruct((M, D, R_MAX), jnp.float32)
    as_ = jax.ShapeDtypeStruct((M, R_MAX, N), jnp.float32)
    om = jax.ShapeDtypeStruct((M, R_MAX), jnp.float32)
    gb = jax.ShapeDtypeStruct((D, R_MAX), jnp.float32)
    ga = jax.ShapeDtypeStruct((R_MAX, N), jnp.float32)
    fb = jax.ShapeDtypeStruct((R_MAX,), jnp.float32) if with_fallback \
        else None
    lowered = aggregation._stacked_core.lower(
        bs, as_, om, gb, ga, fb, r_max=R_MAX, backend=backend,
        method="raflora")
    return lowered.compile().as_text()


def _offending(text: str):
    """Materialization findings -- every computation (while bodies,
    fusions) is inspected through the parsed call graph, not just entry."""
    findings, _ = lint_hlo(text, "test_hlo_guard", _META,
                           only=("hlo-materialization",))
    return findings


class TestKernelPathNeverMaterializesDW:
    def test_guard_detects_dense_dw(self):
        """Positive control: the dense backend DOES materialize (d, n),
        so the tripwire itself is known-live."""
        assert _offending(_compiled_text("dense"))

    @pytest.mark.parametrize("with_fallback", [False, True])
    def test_kernel_path_is_dw_free(self, with_fallback):
        """(d+n)R << dn here ((192+320)*64 vs 192*320): the fused path's
        largest legal intermediates are the (d, R)/(R, n) stacks."""
        bad = _offending(_compiled_text("kernel", with_fallback))
        assert not bad, f"(d, n)-scale intermediates on the kernel path: " \
                        f"{[str(f) for f in bad[:5]]}"

    def test_kernel_bucket_path_is_dw_free(self):
        """The layered (whole-bucket) kernel route stays dW-free too:
        a (P, L) bucket must not materialize (L, d, n) either."""
        bs = jax.ShapeDtypeStruct((M, 2, D, R_MAX), jnp.float32)
        as_ = jax.ShapeDtypeStruct((M, 2, R_MAX, N), jnp.float32)
        om = jax.ShapeDtypeStruct((M, R_MAX), jnp.float32)
        gb = jax.ShapeDtypeStruct((2, D, R_MAX), jnp.float32)
        ga = jax.ShapeDtypeStruct((2, R_MAX, N), jnp.float32)
        fb = jax.ShapeDtypeStruct((R_MAX,), jnp.float32)
        lowered = aggregation._stacked_core.lower(
            bs, as_, om, gb, ga, fb, r_max=R_MAX, backend="kernel",
            method="raflora")
        bad = _offending(lowered.compile().as_text())
        assert not bad, f"(d, n)-scale intermediates in the bucket path: " \
                        f"{[str(f) for f in bad[:5]]}"
