"""Peak-memory regression tripwire for the fused kernel backend (ISSUE 4
satellite): ``backend="kernel"`` must NEVER materialize a (d, n)-shaped
intermediate -- that is the whole point of the fused factored path
(DESIGN.md §4.3). The jitted bucket pipeline is lowered to optimized HLO
and walked with ``launch/hlo_walker.parse_hlo``; at shapes where
(d+n) R << d n, ANY array of d*n elements (or with trailing (d, n) /
(n, d) dims) means the dense update crept back in. The dense backend is
lowered too, as a positive control that the guard actually detects dW.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.core import aggregation
from repro.launch.hlo_walker import _SHAPE, parse_hlo

D, N, M, R_MAX = 192, 320, 3, 16


def _compiled_text(backend: str, with_fallback: bool = True) -> str:
    """Optimized HLO of ``_stacked_core`` (the batched engine's per-bucket
    dispatch) for one (M, d, r) bucket of the raflora method."""
    bs = jax.ShapeDtypeStruct((M, D, R_MAX), jnp.float32)
    as_ = jax.ShapeDtypeStruct((M, R_MAX, N), jnp.float32)
    om = jax.ShapeDtypeStruct((M, R_MAX), jnp.float32)
    gb = jax.ShapeDtypeStruct((D, R_MAX), jnp.float32)
    ga = jax.ShapeDtypeStruct((R_MAX, N), jnp.float32)
    fb = jax.ShapeDtypeStruct((R_MAX,), jnp.float32) if with_fallback \
        else None
    lowered = aggregation._stacked_core.lower(
        bs, as_, om, gb, ga, fb, r_max=R_MAX, backend=backend,
        method="raflora")
    return lowered.compile().as_text()


def _offending_arrays(text: str):
    """All (computation, op, dims) whose result holds >= d*n elements or
    ends in (d, n)/(n, d) -- walked through the parsed call graph so every
    computation (while bodies, fusions) is inspected, not just the entry."""
    bad = []
    comps = parse_hlo(text)
    comps.pop("__entry_name__", None)
    comps.pop("__entry__", None)
    for comp in comps.values():
        for op in comp.ops:
            for m in _SHAPE.finditer(op.result_type):
                dims = [int(x) for x in m.group(2).split(",") if x]
                elems = 1
                for x in dims:
                    elems *= x
                if elems >= D * N or (len(dims) >= 2
                                      and set(dims[-2:]) == {D, N}):
                    bad.append((comp.name, op.name, dims))
    return bad


class TestKernelPathNeverMaterializesDW:
    def test_guard_detects_dense_dw(self):
        """Positive control: the dense backend DOES materialize (d, n),
        so the tripwire itself is known-live."""
        assert _offending_arrays(_compiled_text("dense"))

    @pytest.mark.parametrize("with_fallback", [False, True])
    def test_kernel_path_is_dw_free(self, with_fallback):
        """(d+n)R << dn here ((192+320)*64 vs 192*320): the fused path's
        largest legal intermediates are the (d, R)/(R, n) stacks."""
        bad = _offending_arrays(_compiled_text("kernel", with_fallback))
        assert not bad, f"(d, n)-scale intermediates on the kernel path: " \
                        f"{bad[:5]}"

    def test_kernel_bucket_path_is_dw_free(self):
        """The layered (whole-bucket) kernel route stays dW-free too:
        a (P, L) bucket must not materialize (L, d, n) either."""
        bs = jax.ShapeDtypeStruct((M, 2, D, R_MAX), jnp.float32)
        as_ = jax.ShapeDtypeStruct((M, 2, R_MAX, N), jnp.float32)
        om = jax.ShapeDtypeStruct((M, R_MAX), jnp.float32)
        gb = jax.ShapeDtypeStruct((2, D, R_MAX), jnp.float32)
        ga = jax.ShapeDtypeStruct((2, R_MAX, N), jnp.float32)
        fb = jax.ShapeDtypeStruct((R_MAX,), jnp.float32)
        lowered = aggregation._stacked_core.lower(
            bs, as_, om, gb, ga, fb, r_max=R_MAX, backend="kernel",
            method="raflora")
        bad = _offending_arrays(lowered.compile().as_text())
        assert not bad, f"(d, n)-scale intermediates in the bucket path: " \
                        f"{bad[:5]}"
