"""Aggregation rules: semantics, backend equivalence, collapse behaviour."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (Aggregator, aggregate_flexlora, aggregate_flora,
                        aggregate_hetlora, aggregate_raflora, pad_stack)
from repro.core.svd import (dense_from_weighted, factored_from_weighted,
                            svd_realloc_dense, svd_realloc_factored)

LEVELS = [4, 8, 16]
R_MAX = 16
D, N = 24, 40


def make_factors(key, ranks):
    out = []
    for i, r in enumerate(ranks):
        kb, ka = jax.random.split(jax.random.fold_in(key, i))
        out.append((jax.random.normal(kb, (D, r)),
                    jax.random.normal(ka, (r, N))))
    return out


@pytest.fixture(scope="module")
def setup():
    key = jax.random.PRNGKey(42)
    ranks = [4, 8, 8, 16, 16]
    n_k = [10.0, 20.0, 15.0, 25.0, 30.0]
    return key, ranks, n_k, make_factors(key, ranks)


class TestPadStack:
    def test_shapes_and_zero_padding(self, setup):
        _, ranks, _, factors = setup
        bs, as_ = pad_stack(factors, R_MAX)
        assert bs.shape == (5, D, R_MAX) and as_.shape == (5, R_MAX, N)
        for k, r in enumerate(ranks):
            assert not np.any(np.asarray(bs[k, :, r:]))
            assert not np.any(np.asarray(as_[k, r:, :]))
            # BA product preserved
            ref = factors[k][0] @ factors[k][1]
            assert np.allclose(bs[k] @ as_[k], ref, atol=1e-5)


def svd_truncate(dw, r):
    u, s, vt = np.linalg.svd(np.asarray(dw, dtype=np.float64),
                             full_matrices=False)
    return (u[:, :r] * s[:r]) @ vt[:r]


class TestFlexLoRA:
    def test_matches_explicit_weighted_sum(self, setup):
        """b_g a_g must equal the BEST rank-r_max approximation (Eq. 3-4) of
        the weighted client sum (Eq. 2)."""
        _, ranks, n_k, factors = setup
        bs, as_ = pad_stack(factors, R_MAX)
        res = aggregate_flexlora(bs, as_, ranks, n_k, backend="dense")
        w = np.asarray(n_k) / np.sum(n_k)
        expected = sum(wk * np.asarray(b @ a) for wk, (b, a) in zip(w, factors))
        assert np.allclose(res.b_g @ res.a_g, svd_truncate(expected, R_MAX),
                           atol=1e-3)

    def test_sigma_descending(self, setup):
        _, ranks, n_k, factors = setup
        bs, as_ = pad_stack(factors, R_MAX)
        res = aggregate_flexlora(bs, as_, ranks, n_k)
        s = np.asarray(res.sigma)
        assert np.all(np.diff(s) <= 1e-6)


class TestRaFLoRA:
    def test_matches_eq8_reference(self, setup):
        """Direct per-partition Eq. 8 implementation as oracle."""
        _, ranks, n_k, factors = setup
        bs, as_ = pad_stack(factors, R_MAX)
        g_b = jnp.zeros((D, R_MAX))
        g_a = jnp.zeros((R_MAX, N))
        res = aggregate_raflora(bs, as_, ranks, n_k, rank_levels=LEVELS,
                                global_b=g_b, global_a=g_a, backend="dense")
        # oracle: loop over partitions
        expected = np.zeros((D, N))
        prev = 0
        for h in LEVELS:
            l = prev
            members = [k for k, r in enumerate(ranks) if r >= h]
            n_h = sum(n_k[k] for k in members)
            for k in members:
                b, a = factors[k]
                expected += (n_k[k] / n_h) * (np.asarray(b)[:, l:h]
                                              @ np.asarray(a)[l:h, :])
            prev = h
        assert np.allclose(res.b_g @ res.a_g, svd_truncate(expected, R_MAX),
                           atol=1e-3)

    def test_empty_partition_fallback(self):
        """When no sampled client covers a partition, the global slice is
        kept (Eq. 8 case 2) -- higher-rank info never discarded."""
        key = jax.random.PRNGKey(7)
        ranks = [4, 4]                         # nobody covers (5..16)
        factors = make_factors(key, ranks)
        bs, as_ = pad_stack(factors, R_MAX)
        g_b = jax.random.normal(jax.random.fold_in(key, 100), (D, R_MAX))
        g_a = jax.random.normal(jax.random.fold_in(key, 101), (R_MAX, N))
        res = aggregate_raflora(bs, as_, ranks, [1.0, 1.0],
                                rank_levels=LEVELS, global_b=g_b,
                                global_a=g_a, backend="dense")
        expected = (np.asarray(factors[0][0]) @ np.asarray(factors[0][1])
                    + np.asarray(factors[1][0]) @ np.asarray(factors[1][1])) / 2
        expected = expected + np.asarray(g_b[:, 4:]) @ np.asarray(g_a[4:, :])
        assert np.allclose(res.b_g @ res.a_g, svd_truncate(expected, R_MAX),
                           atol=1e-3)


class TestBackendEquivalence:
    @pytest.mark.parametrize("method", ["flexlora", "raflora"])
    def test_dense_vs_factored_vs_kernel(self, setup, method):
        _, ranks, n_k, factors = setup
        g_b = jnp.zeros((D, R_MAX))
        g_a = jnp.zeros((R_MAX, N))
        results = {}
        for backend in ("dense", "factored", "kernel"):
            agg = Aggregator(method, LEVELS, backend=backend)
            res = agg.aggregate_layer(factors, ranks, n_k, g_b, g_a)
            results[backend] = np.asarray(res.b_g @ res.a_g)
        assert np.allclose(results["dense"], results["factored"], atol=1e-4)
        assert np.allclose(results["dense"], results["kernel"], atol=1e-4)

    def test_factored_svd_identical_spectrum(self):
        key = jax.random.PRNGKey(3)
        u_c = jax.random.normal(key, (D, 12))
        v_c = jax.random.normal(jax.random.fold_in(key, 1), (12, N))
        b_d, a_d, s_d = svd_realloc_dense(u_c @ v_c, R_MAX)
        b_f, a_f, s_f = svd_realloc_factored(u_c, v_c, R_MAX)
        assert np.allclose(s_d, s_f, atol=1e-4)
        assert np.allclose(b_d @ a_d, b_f @ a_f, atol=1e-4)


class TestBaselines:
    def test_hetlora_is_biased(self, setup):
        """Separate averaging of B and A != averaging of BA (the bias the
        paper's Table 1 attributes to HetLoRA)."""
        _, ranks, n_k, factors = setup
        bs, as_ = pad_stack(factors, R_MAX)
        res = aggregate_hetlora(bs, as_, ranks, n_k)
        w = np.asarray(n_k) / np.sum(n_k)
        unbiased = sum(wk * (b @ a) for wk, (b, a) in zip(w, factors))
        assert not np.allclose(res.b_g @ res.a_g, unbiased, atol=1e-3)

    def test_flora_merge_delta_unbiased(self, setup):
        _, ranks, n_k, factors = setup
        bs, as_ = pad_stack(factors, R_MAX)
        res = aggregate_flora(bs, as_, ranks, n_k)
        w = np.asarray(n_k) / np.sum(n_k)
        expected = sum(wk * (b @ a) for wk, (b, a) in zip(w, factors))
        assert np.allclose(res.merge_delta, expected, atol=1e-4)
        # cold start: fresh adapters are zero
        assert not np.any(np.asarray(res.b_g))

    def test_fedavg_requires_homogeneous(self, setup):
        _, ranks, n_k, factors = setup
        bs, as_ = pad_stack(factors, R_MAX)
        from repro.core.aggregation import aggregate_fedavg
        with pytest.raises(AssertionError):
            aggregate_fedavg(bs, as_, ranks, n_k)


class TestFallbackRequired:
    """A non-None Eq. 8 fallback with missing global factors must raise --
    silently dropping it degraded raFLoRA's empty-partition case."""

    def _stack(self):
        key = jax.random.PRNGKey(11)
        ranks = [4, 4]                       # partitions above 4 are empty
        factors = make_factors(key, ranks)
        return pad_stack(factors, R_MAX)

    def test_dense_raises(self):
        bs, as_ = self._stack()
        fb = jnp.ones((R_MAX,))
        om = jnp.zeros((2, R_MAX))
        with pytest.raises(ValueError, match="global"):
            dense_from_weighted(bs, as_, om, None, None, fb)

    def test_factored_raises(self):
        bs, as_ = self._stack()
        fb = jnp.ones((R_MAX,))
        om = jnp.zeros((2, R_MAX))
        with pytest.raises(ValueError, match="global"):
            factored_from_weighted(bs, as_, om, None, None, fb)

    def test_kernel_raises(self):
        from repro.kernels import ops
        bs, as_ = self._stack()
        fb = jnp.ones((R_MAX,))
        om = jnp.zeros((2, R_MAX))
        with pytest.raises(ValueError, match="global"):
            ops.rank_partition_agg(bs, as_, om, None, None, fb)

    def test_raflora_raises_without_globals_when_partition_empty(self):
        bs, as_ = self._stack()
        with pytest.raises(ValueError, match="global"):
            aggregate_raflora(bs, as_, [4, 4], [1.0, 1.0],
                              rank_levels=LEVELS, backend="dense")

    @pytest.mark.parametrize("backend", ["dense", "factored", "kernel"])
    def test_fallback_applied_when_globals_given(self, backend):
        """Positive path: all three backends keep the global higher-rank
        slices when a partition has no contributor."""
        bs, as_ = self._stack()
        key = jax.random.PRNGKey(12)
        g_b = jax.random.normal(key, (D, R_MAX))
        g_a = jax.random.normal(jax.random.fold_in(key, 1), (R_MAX, N))
        res = aggregate_raflora(bs, as_, [4, 4], [1.0, 1.0],
                                rank_levels=LEVELS, global_b=g_b,
                                global_a=g_a, backend=backend)
        # the aggregate must contain the exact global (5..16) slice
        expected_tail = np.asarray(g_b[:, 4:]) @ np.asarray(g_a[4:, :])
        factors_mean = (np.asarray(bs[0] @ as_[0])
                        + np.asarray(bs[1] @ as_[1])) / 2
        got = np.asarray(res.b_g @ res.a_g)
        want = svd_truncate(factors_mean + expected_tail, R_MAX)
        assert np.allclose(got, want, atol=1e-3)


class TestStackedAPI:
    """aggregate_stack / aggregate_grouped: the batched round engine's
    first-class bucketed entry points must match per-adapter calls."""

    @pytest.mark.parametrize("method", ["hetlora", "flexlora", "raflora",
                                        "flora", "ffa"])
    def test_stack_matches_per_adapter(self, setup, method):
        key, ranks, n_k, _ = setup
        P = 3
        per_parent = []
        for j in range(P):
            factors = make_factors(jax.random.fold_in(key, 200 + j), ranks)
            per_parent.append(pad_stack(factors, R_MAX))
        bs = jnp.stack([b for b, _ in per_parent], axis=1)   # (M, P, d, r)
        as_ = jnp.stack([a for _, a in per_parent], axis=1)
        g_b = jax.random.normal(jax.random.fold_in(key, 300), (P, D, R_MAX))
        g_a = jax.random.normal(jax.random.fold_in(key, 301), (P, R_MAX, N))
        agg = Aggregator(method, LEVELS, backend="factored")
        res = agg.aggregate_stack(bs, as_, ranks, n_k, global_b=g_b,
                                  global_a=g_a)
        for j in range(P):
            bs_j, as_j = per_parent[j]
            ref = agg.aggregate_stack(bs_j, as_j, ranks, n_k,
                                      global_b=g_b[j], global_a=g_a[j])
            np.testing.assert_allclose(
                np.asarray(res.b_g[j] @ res.a_g[j]),
                np.asarray(ref.b_g @ ref.a_g), atol=1e-4)
            if res.merge_delta is not None:
                np.testing.assert_allclose(np.asarray(res.merge_delta[j]),
                                           np.asarray(ref.merge_delta),
                                           atol=1e-4)

    def test_stack_matches_aggregate_layer(self, setup):
        key, ranks, n_k, factors = setup
        bs, as_ = pad_stack(factors, R_MAX)
        g_b = jnp.zeros((D, R_MAX))
        g_a = jnp.zeros((R_MAX, N))
        agg = Aggregator("raflora", LEVELS, backend="factored")
        res_stack = agg.aggregate_stack(bs, as_, ranks, n_k, global_b=g_b,
                                        global_a=g_a)
        res_layer = agg.aggregate_layer(factors, ranks, n_k, g_b, g_a)
        np.testing.assert_allclose(np.asarray(res_stack.b_g @ res_stack.a_g),
                                   np.asarray(res_layer.b_g @ res_layer.a_g),
                                   atol=1e-4)
        np.testing.assert_allclose(np.asarray(res_stack.sigma),
                                   np.asarray(res_layer.sigma), atol=1e-4)

    @pytest.mark.parametrize("backend", ["dense", "factored", "kernel"])
    def test_grouped_matches_stack(self, setup, backend):
        """aggregate_grouped (assembly inside jit) == aggregate_stack on the
        equivalent pre-assembled bucket, for every backend."""
        key, ranks, n_k, _ = setup
        P = 2
        # rank-homogeneous groups, as the batched engine produces them
        group_ranks = [[4], [8, 8], [16, 16]]
        group_nk = [[10.0], [20.0, 15.0], [25.0, 30.0]]
        group_bs, group_as, bucket_b, bucket_a = [], [], [], []
        for gi, g_ranks in enumerate(group_ranks):
            bt, at = [], []
            for j in range(P):
                factors = make_factors(
                    jax.random.fold_in(key, 400 + 10 * gi + j), g_ranks)
                b_stack = jnp.stack([b for b, _ in factors])
                a_stack = jnp.stack([a for _, a in factors])
                bt.append(b_stack)
                at.append(a_stack)
            group_bs.append(bt)
            group_as.append(at)
        g_b = jax.random.normal(jax.random.fold_in(key, 500), (P, D, R_MAX))
        g_a = jax.random.normal(jax.random.fold_in(key, 501), (P, R_MAX, N))
        flat_ranks = [r for g in group_ranks for r in g]
        flat_nk = [n for g in group_nk for n in g]
        agg = Aggregator("raflora", LEVELS, backend=backend)
        res = agg.aggregate_grouped(group_bs, group_as, flat_ranks, flat_nk,
                                    global_bs=list(g_b),
                                    global_as=list(g_a))
        # reference: assemble eagerly, then aggregate_stack
        from repro.core.aggregation import _pad_rank
        bs = jnp.concatenate(
            [_pad_rank(jnp.stack(bt, axis=1), R_MAX, -1)
             for bt in group_bs])
        as_ = jnp.concatenate(
            [_pad_rank(jnp.stack(at, axis=1), R_MAX, -2)
             for at in group_as])
        ref = agg.aggregate_stack(bs, as_, flat_ranks, flat_nk,
                                  global_b=g_b, global_a=g_a)
        for j in range(P):
            np.testing.assert_allclose(
                np.asarray(res.b_g[j] @ res.a_g[j]),
                np.asarray(ref.b_g[j] @ ref.a_g[j]), atol=1e-4)


class TestStackedLayers:
    def test_layerwise_vmap_matches_loop(self, setup):
        """(M, L, d, r) stacked aggregation == per-layer loop."""
        key, ranks, n_k, _ = setup
        L = 3
        stacked = []
        per_layer = [[] for _ in range(L)]
        for i, r in enumerate(ranks):
            kb, ka = jax.random.split(jax.random.fold_in(key, 50 + i))
            b = jax.random.normal(kb, (L, D, r))
            a = jax.random.normal(ka, (L, r, N))
            stacked.append((b, a))
            for l in range(L):
                per_layer[l].append((b[l], a[l]))
        agg = Aggregator("raflora", LEVELS, backend="factored")
        g_b = jnp.zeros((L, D, R_MAX))
        g_a = jnp.zeros((L, R_MAX, N))
        res = agg.aggregate_layer(stacked, ranks, n_k, g_b, g_a)
        for l in range(L):
            res_l = agg.aggregate_layer(per_layer[l], ranks, n_k,
                                        g_b[l], g_a[l])
            assert np.allclose(res.b_g[l] @ res.a_g[l],
                               res_l.b_g @ res_l.a_g, atol=1e-4)
