"""Async pipelined round engine (ISSUE 3 tentpole): buffered staleness-
discounted aggregation must reduce EXACTLY to the batched engine at
``pipeline_depth=1``, and the staleness weighting must never silently
down-weight a client set.

Structured like ``tests/test_sharded_engine.py``: per-round equivalence is
asserted for every method in ``METHODS`` from identical initial state, with
adapter PRODUCTS compared (sign-stable, unlike raw SVD factors).
"""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline: deterministic fixed-grid shim
    from _hypothesis_compat import given, settings, strategies as st

from repro.core.aggregation import METHODS, staleness_discount
from repro.federation.experiment import build_experiment


def _one_round(method, engine, *, lora_over=None, **kw):
    lora_over = lora_over or {"rank_levels": (4, 8, 16),
                              "rank_probs": (0.34, 0.33, 0.33)}
    exp = build_experiment(
        method,
        fl_overrides={"num_rounds": 1, "num_clients": 8,
                      "participation": 0.5},
        lora_overrides=lora_over,
        samples_per_class=30, num_classes=6, d_model=32,
        batches_per_round=1, round_engine=engine, **kw)
    hist = exp.server.run(1)
    return exp, hist


def _assert_round_equal(runs, ref="batched", other="async"):
    (e1, h1), (e2, h2) = runs[ref], runs[other]
    for s1, s2 in zip(h1, h2):
        assert s1.clients == s2.clients and s1.ranks == s2.ranks
        np.testing.assert_allclose(s1.mean_client_loss, s2.mean_client_loss,
                                   rtol=1e-4)
        if s1.sigma_probe is not None:
            np.testing.assert_allclose(s1.sigma_probe, s2.sigma_probe,
                                       rtol=1e-4, atol=1e-4)
    r_max = e1.server.lora_cfg.r_max
    f1 = e1.server._extract_factors(e1.server.global_lora, r_max)
    f2 = e2.server._extract_factors(e2.server.global_lora, r_max)
    for parent in f1:
        if isinstance(parent, tuple) and len(parent) == 2 \
                and parent[1] == "m":
            np.testing.assert_allclose(np.asarray(f1[parent]),
                                       np.asarray(f2[parent]),
                                       rtol=1e-4, atol=1e-5)
            continue
        d1 = np.asarray(f1[parent][0] @ f1[parent][1])
        d2 = np.asarray(f2[parent][0] @ f2[parent][1])
        np.testing.assert_allclose(
            d1, d2, atol=1e-4 * max(1.0, np.abs(d1).max()))
    for a, b in zip(jax.tree.leaves(e1.server.base),
                    jax.tree.leaves(e2.server.base)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)


@pytest.mark.slow
class TestAsyncDepthOneEquivalence:
    """``round_engine="async", pipeline_depth=1`` IS the batched engine:
    per-round equivalence for every aggregation method (the async engine
    inherits the batched engine's correctness lattice)."""

    @pytest.mark.parametrize("method", METHODS)
    def test_async_depth1_matches_batched(self, method):
        lora_over = ({"rank_levels": (8,), "rank_probs": (1.0,)}
                     if method == "fedavg"       # fedavg needs equal ranks
                     else None)
        runs = {"batched": _one_round(method, "batched",
                                      lora_over=lora_over),
                "async": _one_round(method, "async", lora_over=lora_over,
                                    pipeline_depth=1)}
        _assert_round_equal(runs)

    def test_async_depth1_matches_sequential(self):
        runs = {"sequential": _one_round("raflora", "sequential"),
                "async": _one_round("raflora", "async", pipeline_depth=1)}
        _assert_round_equal(runs, ref="sequential")


@pytest.mark.slow
class TestBufferedCadence:
    """pipeline_depth > 1: one buffered aggregation per depth rounds, the
    client-sampling stream identical to the synchronous engines, stats
    complete after ``run()``."""

    def _make(self, depth, **kw):
        return build_experiment(
            "raflora",
            fl_overrides={"num_rounds": 8, "num_clients": 8,
                          "participation": 0.5},
            lora_overrides={"rank_levels": (4, 8, 16),
                            "rank_probs": (0.34, 0.33, 0.33)},
            samples_per_class=20, num_classes=4, d_model=32,
            batches_per_round=1, round_engine="async",
            pipeline_depth=depth, **kw)

    def test_sampling_stream_invariant_to_depth(self):
        """The rng is consumed in strict round order at PLAN time, so the
        sampled clients per round are identical across depths (and match
        the batched engine)."""
        batched = build_experiment(
            "raflora",
            fl_overrides={"num_rounds": 6, "num_clients": 8,
                          "participation": 0.5},
            lora_overrides={"rank_levels": (4, 8, 16),
                            "rank_probs": (0.34, 0.33, 0.33)},
            samples_per_class=20, num_classes=4, d_model=32,
            batches_per_round=1, round_engine="batched")
        batched.server.run(6)
        ref = [s.clients for s in batched.server.history]
        for depth in (2, 3):
            exp = self._make(depth)
            exp.server.run(6)
            assert [s.clients for s in exp.server.history] == ref

    def test_aggregation_cadence_and_stats(self):
        exp = self._make(2)
        hist = exp.server.run(6)
        # buffer-fill rounds carry losses but no spectrum; aggregation
        # rounds (every 2nd) carry the buffered aggregate's sigma probe
        assert all(np.isfinite(s.mean_client_loss) for s in hist)
        assert [s.sigma_probe is not None for s in hist] == \
            [False, True, False, True, False, True]
        assert len(exp.server.energy.rho_r1) == 3   # one per aggregation
        assert len(exp.server._pending) == 0

    def test_training_progresses_under_staleness_discount(self):
        exp = self._make(2, staleness_gamma=0.5)
        hist = exp.server.run(8)
        losses = [s.mean_client_loss for s in hist]
        assert np.isfinite(losses).all()
        assert losses[-1] < losses[0]      # still learns

    def test_drain_pending_flushes_partial_buffer(self):
        exp = self._make(3)
        exp.server.run(4)                  # agg at round 2; round 3 pending
        assert len(exp.server._pending) == 1
        before = len(exp.server.energy.rho_r1)
        probe = exp.server.drain_pending()
        assert probe is not None
        assert len(exp.server._pending) == 0
        assert len(exp.server.energy.rho_r1) == before + 1
        assert exp.server.drain_pending() is None   # idempotent when empty

    def test_momentum_one_dispatch_per_bucket_per_aggregation(self):
        exp = self._make(2, server_momentum_beta=0.9)
        exp.server.run(6)
        mom = exp.server.server_momentum
        n_aggs = len(exp.server.energy.rho_r1)
        n_buckets = len(mom.state)
        assert n_aggs == 3 and n_buckets > 0
        assert mom.bucket_calls <= n_aggs * n_buckets


@pytest.mark.slow
class TestAsyncResume:
    """ISSUE 3 acceptance: save -> restore -> run equals the uninterrupted
    run exactly with ``server_momentum_beta > 0``, INCLUDING a non-empty
    pending buffer at save time (the trained-but-unaggregated plans are
    checkpointed and re-consumed, momentum state rides along)."""

    def _make(self):
        return build_experiment(
            "raflora",
            fl_overrides={"num_rounds": 8, "num_clients": 8,
                          "participation": 0.5},
            lora_overrides={"rank_levels": (4, 8, 16),
                            "rank_probs": (0.34, 0.33, 0.33)},
            samples_per_class=20, num_classes=4, d_model=32,
            batches_per_round=1, round_engine="async", pipeline_depth=2,
            server_momentum_beta=0.9)

    def test_resume_with_pending_buffer_and_momentum(self, tmp_path):
        full = self._make()
        full.server.run(5)

        part = self._make()
        part.server.run(3)                 # round 3 trained, unaggregated
        assert len(part.server._pending) == 1
        assert part.server.server_momentum.state
        path = str(tmp_path / "async_ckpt")
        part.server.save(path)

        resumed = self._make()
        resumed.server.restore(path)
        assert resumed.server.round_idx == 3
        assert len(resumed.server._pending) == 1
        assert resumed.server.server_momentum.state
        resumed.server.run(2)

        for sf, sr in zip(full.server.history, resumed.server.history):
            assert sf.clients == sr.clients and sf.ranks == sr.ranks
            np.testing.assert_allclose(sf.mean_client_loss,
                                       sr.mean_client_loss, rtol=1e-6)
            if sf.sigma_probe is not None:
                np.testing.assert_allclose(sf.sigma_probe, sr.sigma_probe,
                                           rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(full.server.energy.rho_r1,
                                   resumed.server.energy.rho_r1, rtol=1e-6)
        for a, b in zip(jax.tree.leaves(full.server.global_lora),
                        jax.tree.leaves(resumed.server.global_lora)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# staleness-discounted weight properties (ISSUE 3 satellite)
# ---------------------------------------------------------------------------

n_k_strategy = st.lists(st.integers(1, 500), min_size=2, max_size=12)
gamma_strategy = st.floats(0.1, 1.0)


def _random_staleness(n, seed):
    return np.random.default_rng(seed).integers(0, 4, size=n)


class TestStalenessDiscountProperties:
    """For ANY interleaving of staleness ages with pipeline_depth > 1:
    the weights of a fixed client set sum to the same total as the
    synchronous round (no silent down-weighting), and gamma=1 reproduces
    the synchronous aggregate on identical factors."""

    @given(n_k=n_k_strategy, gamma=gamma_strategy)
    @settings(max_examples=20, deadline=None)
    def test_fedavg_weights_preserve_total(self, n_k, gamma):
        from repro.core.aggregation import _weights
        stal = _random_staleness(len(n_k), seed=len(n_k))
        w_sync = _weights(np.asarray(n_k, np.float64))
        w_async = _weights(staleness_discount(n_k, stal, gamma))
        assert np.isclose(w_async.sum(), w_sync.sum())   # both total 1
        assert (w_async >= 0).all()

    @given(n_k=n_k_strategy, gamma=gamma_strategy)
    @settings(max_examples=20, deadline=None)
    def test_omega_partition_totals_preserved(self, n_k, gamma):
        """raFLoRA's per-partition omega columns keep the synchronous
        column totals under any staleness interleaving: the discount
        shifts RELATIVE mass, never the per-partition mass itself (and the
        Eq. 8 fallback mask is untouched)."""
        from repro.core.partitions import omega_raflora
        rng = np.random.default_rng(sum(n_k))
        levels = (4, 8, 16)
        ranks = rng.choice(levels, size=len(n_k))
        stal = _random_staleness(len(n_k), seed=sum(n_k))
        om_sync, fb_sync = omega_raflora(ranks, n_k, levels)
        om_async, fb_async = omega_raflora(
            ranks, staleness_discount(n_k, stal, gamma), levels)
        np.testing.assert_allclose(om_async.sum(axis=0), om_sync.sum(axis=0),
                                   atol=1e-12)
        np.testing.assert_array_equal(fb_async, fb_sync)

    @given(n_k=n_k_strategy)
    @settings(max_examples=10, deadline=None)
    def test_gamma_one_and_zero_staleness_are_exact_noops(self, n_k):
        stal = _random_staleness(len(n_k), seed=1)
        out = staleness_discount(n_k, stal, gamma=1.0)
        np.testing.assert_array_equal(out, np.asarray(n_k, np.float64))
        out0 = staleness_discount(n_k, np.zeros(len(n_k), np.int64), 0.5)
        np.testing.assert_array_equal(out0, np.asarray(n_k, np.float64))
        assert staleness_discount(n_k, None, 0.5).dtype == np.float64

    @given(gamma=st.floats(0.1, 0.9))
    @settings(max_examples=10, deadline=None)
    def test_staler_clients_lose_relative_weight(self, gamma):
        from repro.core.aggregation import _weights
        n_k = [100, 100, 100]
        stal = [0, 1, 2]
        w = _weights(staleness_discount(n_k, stal, gamma))
        assert w[0] > w[1] > w[2]
        np.testing.assert_allclose(w[1] / w[0], gamma, rtol=1e-10)

    def test_gamma_one_reproduces_synchronous_aggregate(self):
        """Aggregator.aggregate_grouped with arbitrary mixed staleness and
        gamma=1 returns bit-identical results to the synchronous call on
        identical factor stacks, for the whole SVD family."""
        from repro.core.aggregation import Aggregator
        key = jax.random.PRNGKey(0)
        m, d, n, r = 5, 12, 10, 8
        bs = jax.random.normal(key, (m, 1, d, r))
        as_ = jax.random.normal(jax.random.fold_in(key, 1), (m, 1, r, n))
        gb = jax.random.normal(jax.random.fold_in(key, 2), (1, d, r))
        ga = jax.random.normal(jax.random.fold_in(key, 3), (1, r, n))
        ranks = [4, 8, 4, 8, 8]
        n_k = [10, 20, 30, 40, 50]
        stal = [3, 0, 2, 1, 0]
        for method in ("flexlora", "raflora", "hetlora"):
            agg = Aggregator(method, (4, 8))
            sync = agg.aggregate_grouped(
                [[bs[:, :, :, :]]], [[as_]], ranks, n_k,
                global_bs=[gb], global_as=[ga])
            asyn = agg.aggregate_grouped(
                [[bs]], [[as_]], ranks, n_k,
                global_bs=[gb], global_as=[ga],
                staleness=stal, gamma=1.0)
            np.testing.assert_array_equal(np.asarray(sync.b_g),
                                          np.asarray(asyn.b_g))
            np.testing.assert_array_equal(np.asarray(sync.a_g),
                                          np.asarray(asyn.a_g))

    def test_gamma_below_one_changes_mixed_staleness_aggregate(self):
        """Sanity: with mixed staleness the discount must actually shift
        the aggregate (it is not a hidden no-op)."""
        from repro.core.aggregation import Aggregator
        key = jax.random.PRNGKey(7)
        m, d, n, r = 4, 12, 10, 8
        bs = jax.random.normal(key, (m, 1, d, r))
        as_ = jax.random.normal(jax.random.fold_in(key, 1), (m, 1, r, n))
        agg = Aggregator("flexlora", (4, 8))
        base = agg.aggregate_grouped([[bs]], [[as_]], [8] * m, [10] * m)
        disc = agg.aggregate_grouped([[bs]], [[as_]], [8] * m, [10] * m,
                                     staleness=[0, 1, 2, 3], gamma=0.5)
        assert not np.allclose(np.asarray(base.b_g @ base.a_g),
                               np.asarray(disc.b_g @ disc.a_g), atol=1e-6)
