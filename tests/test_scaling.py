"""Unit tests for ``analysis/complexity`` (the complexity certifier's
contract layer) in both directions: well-behaved cost series pass their
contracts AND injected regressions flip them red -- a scaling gate whose
contracts cannot fire would wave every quadratic blow-up through. Also
covers the report's control-error semantics (a control pass that RAISES
fails the report like one that silently fails to trip) and the shared
lowering cache.
"""
import pytest

from repro.analysis import complexity
from repro.analysis.complexity import (Contract, Measurement, ScalingRow,
                                       dense_control_contracts,
                                       evaluate_row, fit_slope)
from repro.analysis.report import AuditReport


def _row(backend, growth, engine="batched", method="raflora",
         metric="dot_flops", axis="dn", ladder=(128, 256, 512)):
    """Synthetic row whose ``metric`` grows as x**growth along ``axis``."""
    meas = [Measurement(axis, float(x), {metric: float(x) ** growth})
            for x in ladder]
    return ScalingRow(program=f"{engine}/{method}/{backend}",
                      engine=engine, method=method, backend=backend,
                      measurements=meas)


class TestFitSlope:
    def test_exact_powers(self):
        xs = (128, 256, 512)
        assert fit_slope(xs, [x ** 2 for x in xs]) == pytest.approx(2.0)
        assert fit_slope(xs, [7 * x for x in xs]) == pytest.approx(1.0)
        assert fit_slope(xs, [3.0, 3.0, 3.0]) == pytest.approx(0.0)

    def test_all_zero_series_is_constant(self):
        assert fit_slope((2, 4, 8), (0.0, 0.0, 0.0)) == 0.0

    def test_appearing_cost_blows_up_not_under(self):
        """A metric that goes 0 -> positive along the ladder must fit a
        huge positive slope (trips any max contract), never a small one."""
        s = fit_slope((128, 256), (0.0, 1e6))
        assert s > 10.0

    def test_degenerate_inputs_raise(self):
        with pytest.raises(ValueError):
            fit_slope((128,), (1.0,))
        with pytest.raises(ValueError):
            fit_slope((128, 128), (1.0, 2.0))


class TestContracts:
    def test_applies_selectors(self):
        c = Contract("c", "dot_flops", "dn", max_slope=1.0,
                     engines=("batched",), backends=("kernel",))
        assert c.applies("batched", "anything", "kernel")
        assert not c.applies("sharded", "anything", "kernel")
        assert not c.applies("batched", "anything", "dense")
        wide = Contract("w", "dot_flops", "dn", max_slope=1.0)
        assert wide.applies("x", "y", "z")

    def test_linear_low_rank_row_passes(self):
        assert evaluate_row(_row("factored", growth=1.0)) == []
        assert evaluate_row(_row("kernel", growth=1.0)) == []

    def test_injected_regression_flips_kernel_contract_red(self):
        """THE acceptance tripwire: a kernel-path program whose flops go
        quadratic along dn must produce a scaling-contract finding."""
        findings = evaluate_row(_row("kernel", growth=2.0))
        assert findings, "quadratic kernel row slid under the contracts"
        assert all(f.rule == "scaling-contract" for f in findings)
        assert any("agg-flops-linear-dn" in f.message for f in findings)

    def test_min_slope_contract_catches_dead_measurement(self):
        """dense-cert: a dense row that stops looking quadratic means the
        measurement pipeline broke, and must be flagged."""
        flat = _row("dense", growth=0.0)
        findings = evaluate_row(flat)
        assert any("dense-cert-flops" in f.message for f in findings)
        quad = _row("dense", growth=2.0)
        assert not any("dense-cert" in f.message
                       for f in evaluate_row(quad))

    def test_unmeasured_axis_is_not_a_violation(self):
        row = _row("kernel", growth=1.0, axis="dn")
        # no "r"/"m" measurements: their contracts must stay silent
        assert evaluate_row(row) == []

    def test_host_registry_contract_both_directions(self):
        flat = _row("-", growth=0.0, engine="host", method="round",
                    metric="host_loop_iters", axis="registry",
                    ladder=(1000, 10000, 100000))
        assert not any("host-registry-iters" in f.message
                       for f in evaluate_row(flat))
        linear = _row("-", growth=1.0, engine="host", method="round",
                      metric="host_loop_iters", axis="registry",
                      ladder=(1000, 10000, 100000))
        assert any("host-registry-iters" in f.message
                   for f in evaluate_row(linear))


class TestDenseControlContracts:
    def test_retargeted_at_dense_and_trip_on_quadratic(self):
        ctl = dense_control_contracts()
        assert ctl, "no control contracts derived"
        assert all(c.backends == ("dense",) for c in ctl)
        assert all(c.name.endswith("@dense-control") for c in ctl)
        findings = evaluate_row(_row("dense", growth=2.0), ctl)
        assert findings                  # dense quadratic trips them
        # a linear dense row slides under: that is what "dead control"
        # means, and the report layer must then fail the sweep
        assert evaluate_row(_row("dense", growth=1.0), ctl) == []

    def test_report_control_semantics_both_directions(self):
        rep = AuditReport()
        rep.run_control("live", "scaling-contract",
                        lambda: evaluate_row(_row("dense", 2.0),
                                             dense_control_contracts()))
        assert rep.controls["live"].tripped and rep.ok
        rep2 = AuditReport()
        rep2.run_control("dead", "scaling-contract",
                         lambda: evaluate_row(_row("dense", 1.0),
                                              dense_control_contracts()))
        assert not rep2.controls["dead"].tripped and not rep2.ok

    def test_raising_control_fails_report(self):
        """Satellite 3: a control whose pass crashes is recorded with the
        exception and fails the report -- both directions, including the
        artifact field."""
        rep = AuditReport()

        def boom():
            raise RuntimeError("tripwire exploded")

        ctl = rep.run_control("crashy", "scaling-contract", boom)
        assert not ctl.tripped and not rep.ok
        assert "RuntimeError" in ctl.error
        assert rep.to_json()["controls"]["crashy"]["error"]
        ok = rep.to_json()["controls"]  # non-error control omits the key
        rep.run_control("fine", "scaling-contract",
                        lambda: evaluate_row(_row("kernel", 2.0)))
        assert "error" not in rep.to_json()["controls"]["fine"]


@pytest.mark.slow
class TestRealPrograms:
    """Compiled-program direction of the acceptance tripwire."""

    def _real_row(self, backend, label):
        from repro.analysis.lowering import ProgramPoint, lower_program
        meas = []
        for s in (128, 256):
            pt = ProgramPoint(engine="batched", method="raflora",
                              backend=backend, d=s, n=s, rank_levels=(8,),
                              m_per_group=2, p_bucket=1)
            meas.append(Measurement(
                "dn", float(s),
                complexity.device_costs(lower_program(pt))))
        return ScalingRow(program=f"batched/raflora/{label}",
                          engine="batched", method="raflora",
                          backend=label, measurements=meas)

    def test_genuine_kernel_program_passes(self):
        assert evaluate_row(self._real_row("kernel", "kernel")) == []

    def test_dense_program_mislabeled_kernel_flips_red(self):
        """Injected regression on REAL HLO: swap the dense backend into
        the kernel-labeled row (exactly what a bad backend dispatch would
        produce) -- the dn contracts must catch the quadratic programs."""
        findings = evaluate_row(self._real_row("dense", "kernel"))
        assert any("agg-flops-linear-dn" in f.message for f in findings)
        assert any("agg-live-linear-dn" in f.message for f in findings)

    def test_lowering_cache_shares_entries(self):
        from repro.analysis import lowering
        pt = lowering.ProgramPoint(engine="batched", method="raflora",
                                   backend="kernel", d=128, n=128,
                                   rank_levels=(8,), m_per_group=2,
                                   p_bucket=1)
        before = lowering.cache_info()["entries"]
        a = lowering.lower_program(pt)
        after_first = lowering.cache_info()["entries"]
        b = lowering.lower_program(pt.scaled())    # identical point
        assert a is b
        assert lowering.cache_info()["entries"] == after_first
        assert a.payload is b.payload              # parsed once, reused
