"""Engine-matrix equivalence on the KERNEL backend (ISSUE 4 satellite):
``backend="kernel"`` must be engine-complete -- sequential, batched,
sharded and async(pipeline_depth=1) agree per round for every method in
``METHODS``, so the fused Pallas path is a real configuration on every
engine instead of a silent downgrade.

Under plain tier-1 the host exposes a single CPU device (the sharded
engine's collectives are degenerate); ``tools/ci.sh kernel-smoke`` re-runs
this module under a forced 8-virtual-device CPU platform where the
(d+n, R) factor-stack psums are real. Comparisons reuse the sharded-engine
suite's comparator (loss, sigma probe, per-adapter products, DoRA
magnitudes, FLoRA base merge).
"""
import pytest

from repro.core.aggregation import METHODS
from repro.federation.experiment import build_experiment
from test_sharded_engine import _assert_round_equal

ENGINES = ("sequential", "batched", "sharded", "async")


def _run(method, engine, lora_over=None):
    lora_over = lora_over or {"rank_levels": (4, 8, 16),
                              "rank_probs": (0.34, 0.33, 0.33)}
    exp = build_experiment(
        method,
        fl_overrides={"num_rounds": 1, "num_clients": 4,
                      "participation": 1.0},
        lora_overrides=lora_over,
        samples_per_class=20, num_classes=4, d_model=32,
        batches_per_round=1, backend="kernel", round_engine=engine,
        pipeline_depth=1)
    return exp, exp.server.run(1)


class TestKernelEngineMatrix:
    @pytest.mark.parametrize("method", METHODS)
    def test_all_engines_agree(self, method):
        lora_over = ({"rank_levels": (8,), "rank_probs": (1.0,)}
                     if method == "fedavg"       # fedavg needs equal ranks
                     else None)
        runs = {eng: _run(method, eng, lora_over=lora_over)
                for eng in ENGINES}
        for other in ENGINES[1:]:
            _assert_round_equal(runs, ref="sequential", other=other)


class TestKernelFallbackAcrossEngines:
    def test_fallback_active_every_engine(self):
        """rank_probs puts every client at rank <= 8 with rank_levels up to
        16, so the (8, 16] partition is empty EVERY round and the Eq. 8
        fallback augmentation rides through the fused kernels on each
        engine (as the extra sqrt(fallback)-weighted global client)."""
        lora_over = {"rank_levels": (4, 8, 16),
                     "rank_probs": (0.5, 0.5, 0.0)}
        runs = {eng: _run("raflora", eng, lora_over=lora_over)
                for eng in ENGINES}
        srv = runs["sequential"][0].server
        assert max(runs["sequential"][1][0].ranks) <= 8
        assert srv.lora_cfg.r_max == 16
        for other in ENGINES[1:]:
            _assert_round_equal(runs, ref="sequential", other=other)
