"""System-level property tests (hypothesis) on the paper's core invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline: deterministic fixed-grid shim
    from _hypothesis_compat import given, settings, strategies as st

from repro.core import (aggregate_flexlora, aggregate_raflora, coverage,
                        energies, omega_flexlora, omega_raflora, pad_stack,
                        partition_bounds, rho)

LEVELS = [4, 8, 16]
R_MAX = 16
D, N = 24, 32


def rand_factors(seed, ranks):
    key = jax.random.PRNGKey(seed)
    out = []
    for i, r in enumerate(ranks):
        kb, ka = jax.random.split(jax.random.fold_in(key, i))
        out.append((jax.random.normal(kb, (D, r)),
                    jax.random.normal(ka, (r, N))))
    return out


class TestDiagonalFormulationEquivalence:
    """Our unified systems formulation: Eq. 8's partition loop == a single
    weighted-diagonal contraction sum_k B_k diag(omega_k) A_k. This is the
    identity that lets ONE Pallas kernel serve FlexLoRA and raFLoRA."""

    @given(ranks=st.lists(st.sampled_from(LEVELS), min_size=1, max_size=8),
           seed=st.integers(0, 50))
    @settings(max_examples=30, deadline=None)
    def test_raflora_diag_equals_partition_loop(self, ranks, seed):
        rng = np.random.default_rng(seed)
        n_k = rng.integers(1, 50, size=len(ranks)).astype(float)
        factors = rand_factors(seed, ranks)
        bs, as_ = pad_stack(factors, R_MAX)
        omega, fallback = omega_raflora(ranks, n_k, LEVELS)
        diag = np.einsum("mdr,mr,mrn->dn", np.asarray(bs), omega,
                         np.asarray(as_))
        # explicit Eq. 8 partition loop
        loop = np.zeros((D, N))
        prev = 0
        for h in LEVELS:
            members = [k for k, r in enumerate(ranks) if r >= h]
            n_h = sum(n_k[k] for k in members)
            if members:
                for k in members:
                    b, a = factors[k]
                    loop += (n_k[k] / n_h) * (
                        np.asarray(b)[:, prev:h] @ np.asarray(a)[prev:h, :])
            prev = h
        np.testing.assert_allclose(diag, loop, atol=1e-4)


class TestEnergyPreservation:
    """NOTE: "raFLoRA tail >= FlexLoRA tail" is NOT a per-step inequality
    for arbitrary factors (SVD mixes directions); the paper's claim is about
    the expected dynamics under Assumptions 1-2. The orthogonal
    direction-preserving cases below verify the mechanism exactly."""

    @given(seed=st.integers(0, 200))
    @settings(max_examples=20, deadline=None)
    def test_raflora_tail_energy_geq_flexlora_orthogonal(self, seed):
        """Direction-preserving updates: raFLoRA retains at least FlexLoRA's
        higher-rank energy (per-step form of Theorem 1's comparison)."""
        rng = np.random.default_rng(seed)
        ranks = list(rng.choice(LEVELS, size=6))
        if max(ranks) < R_MAX:
            return
        n_k = [1.0] * 6
        q, _ = np.linalg.qr(rng.normal(size=(D, R_MAX)))
        qn, _ = np.linalg.qr(rng.normal(size=(N, R_MAX)))
        sigma = np.sort(rng.uniform(0.5, 4.0, size=R_MAX))[::-1]
        factors = [(jnp.asarray(q[:, :r] * sigma[:r]),
                    jnp.asarray(qn[:, :r].T)) for r in ranks]
        bs, as_ = pad_stack(factors, R_MAX)
        res_fl = aggregate_flexlora(bs, as_, ranks, n_k, backend="dense")
        res_ra = aggregate_raflora(
            bs, as_, ranks, n_k, rank_levels=LEVELS,
            global_b=jnp.zeros((D, R_MAX)), global_a=jnp.zeros((R_MAX, N)),
            backend="dense")
        r1 = min(LEVELS)
        tail_fl = 1.0 - float(rho(res_fl.sigma, r1))
        tail_ra = 1.0 - float(rho(res_ra.sigma, r1))
        assert tail_ra >= tail_fl - 1e-6

    def test_orthogonal_directions_exact_contraction(self):
        """With orthogonal direction-preserving updates (Assumption 1-2),
        one FlexLoRA step scales sigma_i by exactly the sample coverage of
        direction i -- Eq. 7 verbatim."""
        m = 4
        ranks = [4, 8, 16, 16]
        n_k = [1.0] * m
        q, _ = np.linalg.qr(np.random.default_rng(0).normal(size=(D, R_MAX)))
        qn, _ = np.linalg.qr(np.random.default_rng(1).normal(size=(N, R_MAX)))
        sigma = np.linspace(4.0, 1.0, R_MAX)
        factors = []
        for r in ranks:
            b = q[:, :r] * sigma[:r]
            a = qn[:, :r].T
            factors.append((jnp.asarray(b), jnp.asarray(a)))
        bs, as_ = pad_stack(factors, R_MAX)
        res = aggregate_flexlora(bs, as_, ranks, n_k, backend="dense")
        got = np.sort(np.asarray(res.sigma))[::-1]
        cover = np.array([(np.asarray(ranks) >= i + 1).mean()
                          for i in range(R_MAX)])
        want = np.sort(sigma * cover)[::-1]
        np.testing.assert_allclose(got, want, atol=1e-4)

    def test_raflora_orthogonal_no_dilution(self):
        """Same setup: raFLoRA restores sigma exactly (no p_i factor)."""
        ranks = [4, 8, 16, 16]
        n_k = [1.0] * 4
        q, _ = np.linalg.qr(np.random.default_rng(0).normal(size=(D, R_MAX)))
        qn, _ = np.linalg.qr(np.random.default_rng(1).normal(size=(N, R_MAX)))
        sigma = np.linspace(4.0, 1.0, R_MAX)
        factors = []
        for r in ranks:
            factors.append((jnp.asarray(q[:, :r] * sigma[:r]),
                            jnp.asarray(qn[:, :r].T)))
        bs, as_ = pad_stack(factors, R_MAX)
        res = aggregate_raflora(
            bs, as_, ranks, n_k, rank_levels=[4, 8, 16],
            global_b=jnp.zeros((D, R_MAX)), global_a=jnp.zeros((R_MAX, N)),
            backend="dense")
        got = np.sort(np.asarray(res.sigma))[::-1]
        np.testing.assert_allclose(got, np.sort(sigma)[::-1], atol=1e-4)


@pytest.mark.slow
class TestStragglerRankCollapse:
    """ISSUE 5 satellite: the paper's core claim in the EVENT-DRIVEN
    straggler scenario. When the HIGH-RANK clients are the stragglers,
    their updates arrive late and staleness discounting (gamma < 1) pushes
    aggregation weight toward the fresh low-rank cohort -- the worst case
    for higher-rank energy. Rank-agnostic aggregation (FlexLoRA) collapses;
    raFLoRA's rank-partitioned weights keep the higher-rank energy alive.
    """

    def _run(self, method, transport=None):
        from repro.federation.events import (EventScheduler,
                                             StragglerTailLatency,
                                             TimeoutTrigger)
        from repro.federation.experiment import build_experiment
        exp = build_experiment(
            method,
            fl_overrides={"num_rounds": 12, "num_clients": 12,
                          "participation": 0.5},
            samples_per_class=60, num_classes=12, d_model=96,
            batches_per_round=1, round_engine="async",
            staleness_gamma=0.6, transport=transport)
        # stragglers = every client above the minimum rank level: the
        # high-rank updates always arrive one-to-several windows late
        high = np.flatnonzero(
            exp.registry.ranks > min(exp.server.lora_cfg.rank_levels))
        assert high.size > 0
        sched = EventScheduler(
            StragglerTailLatency(median=0.8, sigma=0.15, tail_scale=2.5,
                                 straggler_clients=high, seed=5),
            TimeoutTrigger(2.0), round_interval=1.0)
        exp.server.set_event_scheduler(sched)
        exp.server.run(12)
        exp.server.drain_pending()
        return exp.server.energy

    def test_high_rank_stragglers_collapse_flexlora_not_raflora(self):
        ratios = {m: self._run(m).higher_rank_ratio
                  for m in ("flexlora", "raflora")}
        # FlexLoRA: higher-rank energy decays markedly even though the
        # high-rank updates DO arrive (late, discounted); raFLoRA holds it
        assert ratios["flexlora"][-1] < 0.5 * ratios["flexlora"][0]
        assert ratios["raflora"][-1] > 0.8 * ratios["raflora"][0]
        assert ratios["raflora"][-1] > 2.0 * ratios["flexlora"][-1]

    def test_collapse_contrast_survives_int8_error_feedback(self):
        """The paper's straggler contrast must SURVIVE the compressed
        update transport (DESIGN.md §12): with int8 quantization + error
        feedback on every upload, staleness discounting acting on
        DEQUANTIZED contributions, raFLoRA still holds the higher-rank
        energy (absolute floor 0.4) while FlexLoRA still collapses."""
        from repro.federation.transport import TransportConfig
        tx = TransportConfig(mode="int8", error_feedback=True)
        ratios = {m: self._run(m, transport=tx).higher_rank_ratio
                  for m in ("flexlora", "raflora")}
        assert ratios["raflora"][-1] >= 0.4, ratios["raflora"]
        assert ratios["flexlora"][-1] < 0.5 * ratios["flexlora"][0], \
            ratios["flexlora"]
        assert ratios["raflora"][-1] > 2.0 * ratios["flexlora"][-1]


class TestServingInvariants:
    def test_multi_step_decode_matches_forward(self, rng_key):
        """Greedy decode token-by-token == teacher-forced forward argmax at
        every position (dense arch, 12 steps)."""
        from repro.configs import LoRAConfig, get_config
        from repro.models import build_model
        cfg = get_config("granite-3-8b").reduced()
        model = build_model(cfg, LoRAConfig(), dtype=jnp.float32,
                            remat=False, block_q=8, block_kv=8)
        params = model.init(rng_key)
        B, L = 1, 12
        toks = jax.random.randint(rng_key, (B, L), 0, cfg.vocab_size)
        full, _, _ = model.forward_seq(params, {"tokens": toks},
                                       mode="train", lora_rank=8)
        cache = model.init_cache(B, L)
        outs = []
        for t in range(L):
            logits, cache = model.decode_step(
                params, {"token": toks[:, t:t + 1]}, cache, lora_rank=8)
            outs.append(logits[:, 0])
        dec = jnp.stack(outs, axis=1)
        np.testing.assert_allclose(np.asarray(full), np.asarray(dec),
                                   atol=3e-4)
