"""Rank-partition machinery: Eq. 8 invariants as property tests."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline: deterministic fixed-grid shim
    from _hypothesis_compat import given, settings, strategies as st

from repro.core import (boundaries, boundary_of_index, coverage,
                        omega_flexlora, omega_raflora, partition_bounds,
                        prev_boundary)

LEVELS = [8, 16, 32, 48, 64]

ranks_strategy = st.lists(st.sampled_from(LEVELS), min_size=1, max_size=20)
samples_strategy = st.lists(st.integers(1, 500), min_size=1, max_size=20)


class TestPartitionStructure:
    def test_partition_bounds_cover_exactly(self):
        bounds = partition_bounds(LEVELS)
        assert bounds == [(1, 8), (9, 16), (17, 32), (33, 48), (49, 64)]
        covered = sorted(i for (l, h) in bounds for i in range(l, h + 1))
        assert covered == list(range(1, 65))     # non-overlapping, complete

    def test_prev_boundary(self):
        assert prev_boundary(8, LEVELS) == 0     # paper: prev(r_1) = 0
        assert prev_boundary(16, LEVELS) == 8
        assert prev_boundary(64, LEVELS) == 48

    def test_boundary_of_index(self):
        h = boundary_of_index(LEVELS)
        assert h[0] == 8 and h[7] == 8
        assert h[8] == 16 and h[31] == 32 and h[63] == 64

    def test_coverage_eq1(self):
        """p_1 = ... = p_{r1} = 1 > p_{r1+1} >= ... >= p_rmax > 0 (Eq. 1)."""
        ranks = np.repeat(LEVELS, 20)
        p = coverage(LEVELS, ranks)
        assert np.all(p[:8] == 1.0)
        assert np.all(np.diff(p) <= 0)
        assert p[-1] > 0


class TestOmegaWeights:
    @given(ranks=ranks_strategy, seed=st.integers(0, 99))
    @settings(max_examples=60, deadline=None)
    def test_raflora_weights_partition_normalized(self, ranks, seed):
        """Within every covered partition the weights over clients sum to 1
        (effective-contributor normalization, Eq. 8)."""
        rng = np.random.default_rng(seed)
        n = rng.integers(1, 100, size=len(ranks)).astype(float)
        omega, fallback = omega_raflora(ranks, n, LEVELS)
        col = omega.sum(axis=0)
        covered = fallback == 0
        assert np.allclose(col[covered], 1.0)
        assert np.allclose(col[~covered], 0.0)
        # fallback indices take exactly the global slice
        assert np.allclose(fallback[~covered], 1.0)

    @given(ranks=ranks_strategy, seed=st.integers(0, 99))
    @settings(max_examples=60, deadline=None)
    def test_flexlora_weights_dilute(self, ranks, seed):
        """FlexLoRA columns sum to p-hat_i <= 1: the dilution of Theorem 1 --
        column sums equal the SAMPLE-weighted coverage of index i."""
        rng = np.random.default_rng(seed)
        n = rng.integers(1, 100, size=len(ranks)).astype(float)
        omega = omega_flexlora(ranks, n, max(LEVELS))
        w = n / n.sum()
        ranks_arr = np.asarray(ranks)
        for i in range(max(LEVELS)):
            expected = w[ranks_arr >= i + 1].sum()
            assert np.isclose(omega[:, i].sum(), expected)

    @given(ranks=ranks_strategy)
    @settings(max_examples=40, deadline=None)
    def test_support_respected(self, ranks):
        """No client ever receives weight beyond its own rank."""
        n = np.ones(len(ranks))
        om_ra, _ = omega_raflora(ranks, n, LEVELS)
        om_fl = omega_flexlora(ranks, n, max(LEVELS))
        for k, r in enumerate(ranks):
            assert np.all(om_ra[k, r:] == 0)
            assert np.all(om_fl[k, r:] == 0)

    def test_equal_when_all_max_rank(self):
        """With homogeneous max-rank clients, raFLoRA == FlexLoRA (no
        mismatch to correct)."""
        ranks = [64] * 6
        n = [10.0] * 6
        om_ra, fb = omega_raflora(ranks, n, LEVELS)
        om_fl = omega_flexlora(ranks, n, 64)
        assert np.allclose(om_ra, om_fl)
        assert not fb.any()

    def test_single_client_reduces_to_flexlora(self):
        """Paper Sec 6.5: with one participant there is no dilution."""
        om_ra, _ = omega_raflora([64], [5.0], LEVELS)
        om_fl = omega_flexlora([64], [5.0], 64)
        assert np.allclose(om_ra, om_fl)
