"""Blockwise attention: equivalence with naive softmax attention across
masking modes, plus decode-path invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline: deterministic fixed-grid shim
    from _hypothesis_compat import given, settings, strategies as st

from repro.models.layers.attention import (blockwise_attention,
                                           decode_attention)


def naive_attention(q, k, v, causal, window=0):
    b, lq, h, d = q.shape
    _, lkv, kvh, _ = k.shape
    g = h // kvh
    qg = q.reshape(b, lq, kvh, g, d)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * d ** -0.5
    qpos = jnp.arange(lq)
    kpos = jnp.arange(lkv)
    mask = jnp.ones((lq, lkv), bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window:
        mask &= kpos[None, :] > qpos[:, None] - window
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return o.reshape(b, lq, h, d).astype(q.dtype)


def make_qkv(key, b=2, l=48, h=4, kvh=2, d=16):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, l, h, d))
    k = jax.random.normal(ks[1], (b, l, kvh, d))
    v = jax.random.normal(ks[2], (b, l, kvh, d))
    return q, k, v


class TestBlockwise:
    @pytest.mark.parametrize("causal", [True, False])
    @pytest.mark.parametrize("bq,bkv", [(16, 16), (48, 48), (8, 24)])
    def test_matches_naive(self, causal, bq, bkv):
        q, k, v = make_qkv(jax.random.PRNGKey(0))
        got = blockwise_attention(q, k, v, causal=causal, block_q=bq,
                                  block_kv=bkv)
        want = naive_attention(q, k, v, causal)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-5)

    @pytest.mark.parametrize("window", [1, 8, 17, 48])
    def test_sliding_window(self, window):
        q, k, v = make_qkv(jax.random.PRNGKey(1))
        got = blockwise_attention(q, k, v, causal=True,
                                  sliding_window=window, block_q=16,
                                  block_kv=16)
        want = naive_attention(q, k, v, True, window=window)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-5)

    def test_traced_window(self):
        """Window as a traced scalar (hymba per-layer global selection)."""
        q, k, v = make_qkv(jax.random.PRNGKey(2))

        @jax.jit
        def f(q, k, v, w):
            return blockwise_attention(q, k, v, causal=True,
                                       sliding_window=w, block_q=16,
                                       block_kv=16)

        got = f(q, k, v, jnp.int32(8))
        want = naive_attention(q, k, v, True, window=8)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-5)

    @given(l=st.sampled_from([3, 7, 15, 16, 17, 31, 33, 47, 50]),
           seed=st.integers(0, 100))
    @settings(max_examples=25, deadline=None)
    def test_ragged_lengths(self, l, seed):
        """Non-block-multiple sequence lengths pad correctly.

        Lengths are drawn from a fixed set spanning below/at/above block
        boundaries: every DISTINCT length compiles a fresh attention
        program, so a free-range integer strategy made this the single
        slowest cold-run test while adding no extra padding coverage."""
        key = jax.random.PRNGKey(seed)
        q, k, v = make_qkv(key, l=l)
        got = blockwise_attention(q, k, v, causal=True, block_q=16,
                                  block_kv=16)
        want = naive_attention(q, k, v, True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-4)

    def test_mqa_grouping(self):
        q, k, v = make_qkv(jax.random.PRNGKey(3), h=8, kvh=1)
        got = blockwise_attention(q, k, v, causal=True, block_q=16,
                                  block_kv=16)
        want = naive_attention(q, k, v, True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-5)

    def test_softcap(self):
        q, k, v = make_qkv(jax.random.PRNGKey(4))
        got = blockwise_attention(q, k, v, causal=True, softcap=5.0,
                                  block_q=16, block_kv=16)
        assert bool(jnp.isfinite(got).all())


class TestDecode:
    def test_matches_last_row_of_full(self):
        key = jax.random.PRNGKey(5)
        q, k, v = make_qkv(key, l=20)
        full = naive_attention(q, k, v, True)
        got = decode_attention(q[:, -1:], k, v, cache_len=20)
        np.testing.assert_allclose(np.asarray(got[:, 0]),
                                   np.asarray(full[:, -1]), atol=1e-5)

    def test_cache_len_masks_tail(self):
        """Entries beyond cache_len must not influence the output."""
        key = jax.random.PRNGKey(6)
        q, k, v = make_qkv(key, l=32)
        out1 = decode_attention(q[:, -1:], k, v, cache_len=16)
        k_garbage = k.at[:, 16:].set(99.0)
        v_garbage = v.at[:, 16:].set(-99.0)
        out2 = decode_attention(q[:, -1:], k_garbage, v_garbage, cache_len=16)
        np.testing.assert_allclose(np.asarray(out1), np.asarray(out2),
                                   atol=1e-6)
