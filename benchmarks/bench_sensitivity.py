"""Figures 2c/2d/5a/6a-6d + Table 4: sensitivity & robustness sweeps.

Each sub-benchmark mirrors one paper figure at CPU scale:
  noniid          -- Fig 2c/6a: Dirichlet alpha sweep
  participation   -- Fig 6b: clients per round
  rank_configs    -- Fig 2d/6c: conf-1..conf-5 (varying r_1 / r_max)
  rank_dists      -- Fig 6d: uniform / low-skew / high-skew / bimodal
  partial         -- Fig 5a: raFLoRA-a/b/c partial variants
  noisy           -- Table 4: Gaussian noise on low-rank clients
"""
import numpy as np

from benchmarks.common import emit, quick_fl

ROUNDS = 8


def bench_noniid():
    for alpha in (1.0, 0.1):
        for method in ("flexlora", "raflora"):
            exp, wall = quick_fl(
                method, rounds=ROUNDS,
                fl_overrides={"partition": "dirichlet",
                              "dirichlet_alpha": alpha})
            emit(f"fig6a_noniid/alpha{alpha}/{method}", wall * 1e6,
                 f"{exp.eval_accuracy():.4f}")


def bench_participation():
    for part in (0.25, 0.5):
        for method in ("flexlora", "raflora"):
            exp, wall = quick_fl(method, rounds=ROUNDS,
                                 participation=part)
            emit(f"fig6b_participation/{part}/{method}", wall * 1e6,
                 f"{exp.eval_accuracy():.4f}")


RANK_CONFS = {
    "conf1": (1, 8, 32),
    "conf3": (4, 8, 32),
    "conf5": (4, 8, 48),
}


def bench_rank_configs():
    for name, levels in RANK_CONFS.items():
        probs = tuple([1 / len(levels)] * len(levels))
        for method in ("flexlora", "raflora"):
            exp, wall = quick_fl(
                method, rounds=ROUNDS,
                lora_overrides={"rank_levels": levels,
                                "rank_probs": tuple([1 / len(levels)]
                                                    * len(levels))})
            emit(f"fig6c_rankconf/{name}/{method}", wall * 1e6,
                 f"{exp.eval_accuracy():.4f}")


RANK_DISTS = {
    "uniform": (0.34, 0.33, 0.33),
    "low_skew": (0.8, 0.1, 0.1),
    "high_skew": (0.1, 0.1, 0.8),
}


def bench_rank_dists():
    for name, probs in RANK_DISTS.items():
        for method in ("flexlora", "raflora"):
            exp, wall = quick_fl(
                method, rounds=ROUNDS,
                lora_overrides={"rank_levels": (4, 8, 32),
                                "rank_probs": probs})
            emit(f"fig6d_rankdist/{name}/{method}", wall * 1e6,
                 f"{exp.eval_accuracy():.4f}")


def bench_partial_variants():
    """raFLoRA-a/b/c: rank-aware weighting up to partition k only."""
    levels = (4, 8, 16, 32)
    for name, cut in (("raflora-a", 8), ("raflora-b", 16),
                      ("raflora-full", None)):
        exp, wall = quick_fl(
            "raflora", rounds=ROUNDS, partial_up_to=cut,
            lora_overrides={"rank_levels": levels,
                            "rank_probs": (0.25,) * 4})
        hr = exp.server.energy.higher_rank_ratio[-1]
        emit(f"fig5a_partial/{name}", wall * 1e6,
             f"{exp.eval_accuracy():.4f}", higher_rank=f"{hr:.4f}")


def bench_noisy_clients():
    for nu in (0.0, 0.5):
        for method in ("flexlora", "raflora"):
            exp, wall = quick_fl(method, rounds=ROUNDS,
                                 noisy_low_rank_std=nu)
            emit(f"table4_noisy/nu{nu}/{method}", wall * 1e6,
                 f"{exp.eval_accuracy():.4f}")


def run():
    bench_noniid()
    bench_participation()
    bench_rank_configs()
    bench_rank_dists()
    bench_partial_variants()
    bench_noisy_clients()
    return True


if __name__ == "__main__":
    run()
