"""Figure 2a/2b: energy breakdown of the global update over rounds.

FlexLoRA concentrates energy in the shared-rank partition (rank collapse);
raFLoRA reshapes the energy structure and preserves higher partitions.
"""
from benchmarks.common import emit, quick_fl


def run(rounds: int = 12):
    for method in ("flexlora", "raflora"):
        exp, wall = quick_fl(method, rounds=rounds, seed=1)
        hr = exp.server.energy.higher_rank_ratio
        breakdown = exp.server.energy.breakdown[-1]
        emit(f"fig2_energy/{method}/higher_rank_final",
             wall / rounds * 1e6, f"{hr[-1]:.4f}",
             round0=f"{hr[0]:.4f}",
             breakdown={k: round(v, 4) for k, v in breakdown.items()})
    return True


if __name__ == "__main__":
    run()
