"""Table 3: communication cost per client per round + aggregation compute.

Communication is exact (bytes of the factors each method moves, from the
real adapter shapes of the model); computation is the measured wall time of
one server aggregation over M=10 uploads, for the dense (paper-faithful),
factored (beyond-paper QR-SVD) and Pallas-kernel backends.
"""
import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timed
from repro.core import Aggregator
from repro.core.lora import adapter_paths
from repro.configs import LoRAConfig, get_config


def comm_bytes_per_client(cfg, lora: LoRAConfig, method: str, m: int,
                          rank: int, dtype_bytes: int = 4) -> int:
    """Upload + download volume per client per round (Table 1 column)."""
    from repro.models import build_model
    model = build_model(cfg, lora, dtype=jnp.float32, remat=False)
    shapes = model.param_shapes()
    per_rank_elems = 0  # elements per unit rank across all adapters
    for ab in adapter_paths(shapes).values():
        r_max, d_in = ab["a"].shape[-2:]
        d_out = ab["b"].shape[-2]
        layers = int(np.prod(ab["a"].shape[:-2])) or 1
        per_rank_elems += layers * (d_in + d_out)
    up = per_rank_elems * rank * dtype_bytes
    if method == "flora":
        # stacked matrices of ALL selected clients are broadcast down
        down = per_rank_elems * rank * m * dtype_bytes
    else:
        down = per_rank_elems * rank * dtype_bytes
    return up + down


def run():
    lora = LoRAConfig()  # paper ranks {8..64}
    m = 10
    avg_rank = int(np.mean(lora.rank_levels))
    for arch in ("vit-base", "llama3.1-8b"):
        cfg = get_config(arch)
        for method in ("hetlora", "flora", "flexlora", "raflora"):
            comm = comm_bytes_per_client(cfg, lora, method, m, avg_rank)
            emit(f"table3_comm/{arch}/{method}", 0.0,
                 f"{comm / 1e6:.1f}MB")

    # aggregation compute: one layer of vit-base scale (768x768), M=10
    key = jax.random.PRNGKey(0)
    d = n = 768
    ranks = list(np.random.default_rng(0).choice(lora.rank_levels, size=m))
    factors = []
    for i, r in enumerate(ranks):
        kb, ka = jax.random.split(jax.random.fold_in(key, i))
        factors.append((jax.random.normal(kb, (d, int(r))),
                        jax.random.normal(ka, (int(r), n))))
    n_k = [100.0] * m
    gb = jnp.zeros((d, lora.r_max))
    ga = jnp.zeros((lora.r_max, n))
    for backend in ("dense", "factored", "kernel"):
        agg = Aggregator("raflora", lora.rank_levels, backend=backend)

        def call():
            res = agg.aggregate_layer(factors, ranks, n_k, gb, ga)
            jax.block_until_ready(res.b_g)
            return res

        _, us = timed(call)
        emit(f"table3_comp/aggregate_layer_768/{backend}", us,
             f"{us / 1e3:.2f}ms")
    return True


if __name__ == "__main__":
    run()
