"""Shared benchmark infrastructure.

Every benchmark prints ``name,us_per_call,derived`` CSV rows (us_per_call =
wall time of the measured unit; derived = the paper-relevant metric, e.g.
accuracy or energy ratio) and returns its rows for run.py aggregation.
"""
from __future__ import annotations

import time
from typing import Callable, List, Optional

ROWS: List[dict] = []


def emit(name: str, us_per_call: float, derived, **extra) -> dict:
    row = {"name": name, "us_per_call": us_per_call, "derived": derived,
           **extra}
    ROWS.append(row)
    print(f"{name},{us_per_call:.1f},{derived}")
    return row


def timed(fn: Callable, *args, repeats: int = 3, **kw):
    """(result, us_per_call) with a warmup call."""
    fn(*args, **kw)
    t0 = time.perf_counter()
    for _ in range(repeats):
        out = fn(*args, **kw)
    us = (time.perf_counter() - t0) / repeats * 1e6
    return out, us


def quick_fl(method: str, *, rounds: int = 10, clients: int = 16,
             participation: float = 0.25, seed: int = 0, **kw):
    """Small-but-meaningful FL experiment used across the benchmarks."""
    from repro.federation.experiment import build_experiment
    over = {"num_rounds": rounds, "num_clients": clients,
            "participation": participation, "seed": seed}
    over.update(kw.pop("fl_overrides", {}))
    kw.setdefault("lora_overrides", {"rank_levels": (4, 8, 16),
                                     "rank_probs": (0.34, 0.33, 0.33)})
    exp = build_experiment(method, fl_overrides=over,
                           num_classes=kw.pop("num_classes", 10),
                           d_model=kw.pop("d_model", 64),
                           samples_per_class=kw.pop("samples_per_class", 50),
                           batches_per_round=kw.pop("batches_per_round", 1),
                           **kw)
    t0 = time.perf_counter()
    exp.server.run(rounds)
    wall = time.perf_counter() - t0
    return exp, wall
