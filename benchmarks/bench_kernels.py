"""Kernel micro-benchmarks: Pallas (interpret) vs jnp oracle wall time and
-- the meaningful number on CPU -- allclose validation at realistic shapes.
On-TPU timing is what block sizes were chosen for; interpret-mode wall time
only proves correctness, so `derived` reports max |err| against the oracle.
"""
import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timed
from repro.kernels import ops, ref


def run():
    key = jax.random.PRNGKey(0)

    # fused LoRA apply at a qwen2-ish projection shape (scaled for CPU)
    m, k, n, r = 512, 512, 512, 64
    x = jax.random.normal(key, (m, k), jnp.float32)
    w = jax.random.normal(jax.random.fold_in(key, 1), (k, n)) * 0.05
    a = jax.random.normal(jax.random.fold_in(key, 2), (r, k)) * 0.1
    b = jax.random.normal(jax.random.fold_in(key, 3), (n, r)) * 0.1
    got, us = timed(lambda: jax.block_until_ready(
        ops.lora_apply(x, w, a, b, 1.0)), repeats=2)
    want = ref.lora_apply_ref(x, w, a, b, 1.0)
    err = float(jnp.abs(got - want).max())
    emit("kernel/lora_apply_512", us, f"err={err:.2e}")

    # rank-partition aggregation at vit-base layer scale
    M, d, rm = 10, 768, 64
    bs = jax.random.normal(key, (M, d, rm))
    as_ = jax.random.normal(jax.random.fold_in(key, 4), (M, rm, d))
    om = jax.random.uniform(jax.random.fold_in(key, 5), (M, rm))
    got, us = timed(lambda: jax.block_until_ready(
        ops.rank_partition_agg(bs, as_, om)), repeats=2)
    err = float(jnp.abs(got - ref.rank_partition_agg_ref(bs, as_, om)).max())
    emit("kernel/rank_partition_agg_768", us, f"err={err:.2e}")

    # SSD scan at reduced mamba2 shapes
    B, L, H, P, G, N = 2, 256, 8, 32, 1, 32
    ks = jax.random.split(key, 6)
    xs = jax.random.normal(ks[0], (B, L, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, L, H)))
    alog = jax.random.normal(ks[2], (H,)) * 0.5
    bb = jax.random.normal(ks[3], (B, L, G, N)) * 0.3
    cc = jax.random.normal(ks[4], (B, L, G, N)) * 0.3
    dd = jax.random.normal(ks[5], (H,))
    (y, s), us = timed(lambda: jax.block_until_ready(
        ops.ssd_scan(xs, dt, alog, bb, cc, dd, chunk=64)), repeats=2)
    y_r, s_r = ref.ssd_scan_sequential_ref(xs, dt, alog, bb, cc, dd)
    err = float(jnp.abs(y - y_r).max())
    emit("kernel/ssd_scan_256", us, f"err={err:.2e}")

    # factored vs dense SVD reallocation (the beyond-paper optimization)
    from repro.core.svd import svd_realloc_dense, svd_realloc_factored
    d_big, n_big, R = 2048, 2048, 128
    u_c = jax.random.normal(key, (d_big, R))
    v_c = jax.random.normal(jax.random.fold_in(key, 9), (R, n_big))
    dw = u_c @ v_c
    _, us_d = timed(lambda: jax.block_until_ready(
        svd_realloc_dense(dw, 64)[2]), repeats=2)
    _, us_f = timed(lambda: jax.block_until_ready(
        svd_realloc_factored(u_c, v_c, 64)[2]), repeats=2)
    emit("svd/dense_2048", us_d, f"{us_d/1e3:.1f}ms")
    emit("svd/factored_2048", us_f,
         f"{us_f/1e3:.1f}ms ({us_d/us_f:.1f}x speedup)")
    return True


if __name__ == "__main__":
    run()
