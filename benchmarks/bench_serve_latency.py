"""Multi-tenant serving latency study (DESIGN.md §11).

Runs the continuous-batching serving stack -- ``AdapterStore`` (paged,
rank-bucketed, versioned) + ``ServingEngine`` (fixed slots, leaf-
substituted per-request adapters) + ``ContinuousBatcher`` (admit/evict on
the federation stack's ``VirtualClock``) -- over a grid of

    batch (slots)      x  adapter count (tenants, cycled rank levels)
                       x  swap rate (hot-swap a new adapter version every
                          N scheduler steps; 0 = never)

and records DETERMINISTIC virtual-time serving metrics per cell: token
throughput, request-latency p50/p95, and time-to-first-token p50. Virtual
timing replays bit-identically for a fixed scenario (seeded per-tenant
latency streams, fixed arrivals), so ``tools/bench_trend.py`` gates these
rows exactly like the event-engine rows -- only a structural scheduler or
engine regression can move them. Wall-clock per cell is recorded as
CONTEXT only (shared-CPU noise; never gated).

Hot-swap cells exercise the round-landing path mid-stream: every
``swap_every`` steps a perturbed adapter set is published under a bumped
version while requests are in flight, so the engine's snapshot-per-step
discipline (no version mixing within a step) is on the measured path.

Artifacts: benchmarks/artifacts/serve_latency.json, mirrored to the
tracked ``BENCH_serve_latency.json`` at the repo root
(``tools/ci.sh bench-check`` gates it).
"""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit

ARTIFACT = os.path.join(os.path.dirname(__file__), "artifacts",
                        "serve_latency.json")
ROOT_ARTIFACT = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_serve_latency.json")

ARCH = "gemma-2b"
PROMPT_LEN = 8
MAX_NEW = 6
RANK_LEVELS = (4, 8, 16)


def _merge_artifact(update: dict) -> dict:
    """Read-modify-write the artifact and its tracked repo-root mirror
    (same discipline as bench_round_latency)."""
    result = {}
    for path in (ROOT_ARTIFACT, ARTIFACT):   # local artifact wins if both
        if os.path.exists(path):
            with open(path) as f:
                result = json.load(f)
    result.update(update)
    os.makedirs(os.path.dirname(ARTIFACT), exist_ok=True)
    for path in (ARTIFACT, ROOT_ARTIFACT):
        with open(path, "w") as f:
            json.dump(result, f, indent=2)
    return result


def _build_model():
    from repro.configs import LoRAConfig, get_config
    from repro.models import build_model
    cfg = get_config(ARCH).reduced()
    lora = LoRAConfig(rank_levels=RANK_LEVELS)
    model = build_model(cfg, lora, dtype=jnp.float32, remat=False,
                        block_q=16, block_kv=16)
    return cfg, lora, model


def _stage(store, lora_tree, n_adapters: int, *, version_salt: int = 0):
    """(Re)stage ``n_adapters`` tenants, one rank level each (cycled),
    deterministically perturbed by tenant index and ``version_salt``."""
    levels = sorted(RANK_LEVELS, reverse=True)
    for t in range(n_adapters):
        perturb = jax.tree.map(
            lambda x, _t=t: None if x is None
            else x + 0.01 * (_t + 1) + 0.001 * version_salt,
            lora_tree, is_leaf=lambda x: x is None)
        store.put(f"tenant{t}", perturb, levels[t % len(levels)])


def _run_cell(model, params, lora_tree, *, batch: int, n_adapters: int,
              swap_every: int, vocab: int) -> dict:
    from repro.federation.events import LognormalLatency
    from repro.serving import AdapterStore, ContinuousBatcher, ServeRequest, \
        ServingEngine

    store = AdapterStore(RANK_LEVELS)
    _stage(store, lora_tree, n_adapters)
    store.publish()
    engine = ServingEngine(model, params, store,
                           max_len=PROMPT_LEN + MAX_NEW + 2, slots=batch)
    batcher = ContinuousBatcher(
        engine, latency=LognormalLatency(0.02, 0.25, seed=0),
        step_cost=0.01, prefill_cost=0.05)
    rng = np.random.default_rng(0)          # scenario fixture, fixed seed
    n_requests = 2 * batch
    for i in range(n_requests):
        batcher.submit(ServeRequest(
            rid=i, prompt=rng.integers(0, vocab, size=PROMPT_LEN),
            adapter_id=f"tenant{i % n_adapters}",
            max_new_tokens=MAX_NEW, arrival=0.02 * i))

    t0 = time.perf_counter()
    swaps = 0
    for _ in range(10_000):
        if not batcher.queue and all(r is None for r in batcher.slots):
            break
        if batcher.queue and not any(batcher.slots) \
                and batcher.queue[0].arrival > batcher.clock.now:
            batcher.clock.advance(batcher.queue[0].arrival)
        if swap_every and batcher.steps and batcher.steps % swap_every == 0:
            swaps += 1                       # hot-swap mid-stream
            _stage(store, lora_tree, n_adapters, version_salt=swaps)
            store.publish()
        batcher.step()
    else:
        raise RuntimeError("serve cell did not drain")
    wall = time.perf_counter() - t0

    stats = batcher.stats()
    assert stats["completed"] == n_requests, stats
    versions = sorted(set(engine.version_log))
    return {"batch": batch, "adapters": n_adapters,
            "swap_every": swap_every, "requests": n_requests,
            "swaps": swaps, "versions_seen": versions,
            **stats, "wall_s_context_only": wall}


def run(batches=(2, 4), adapter_counts=(1, 4), swap_rates=(0, 4)) -> dict:
    cfg, lora, model = _build_model()
    from repro.core.lora import split_lora
    key = jax.random.PRNGKey(0)
    params = model.init(key)     # rng: ok (single consumer; prompts use numpy)
    _, lora_tree = split_lora(params)

    rows = []
    for batch in batches:
        for n_adapters in adapter_counts:
            for swap_every in swap_rates:
                row = _run_cell(model, params, lora_tree, batch=batch,
                                n_adapters=n_adapters, swap_every=swap_every,
                                vocab=cfg.vocab_size)
                rows.append(row)
                name = (f"serve_latency/b{batch}_a{n_adapters}"
                        f"_sw{swap_every}")
                emit(name, row["wall_s_context_only"] * 1e6,
                     f"vp95={row['virtual_p95_s']:.3f}s "
                     f"vtp={row['virtual_throughput_tok_per_s']:.1f}tok/s")

    result = {
        "config": {"arch": ARCH, "prompt_len": PROMPT_LEN,
                   "max_new_tokens": MAX_NEW,
                   "rank_levels": list(RANK_LEVELS),
                   "latency": "lognormal(0.02, 0.25) seeded per tenant",
                   "step_cost_s": 0.01, "prefill_cost_s": 0.05,
                   "note": "virtual rows gated by bench_trend; wall is "
                           "context only"},
        "rows": rows,
    }
    _merge_artifact(result)
    print(f"# artifact: {ARTIFACT}")
    return result


if __name__ == "__main__":
    run()
