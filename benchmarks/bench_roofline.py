"""Roofline table (deliverable g): reads the dry-run artifacts and prints
the three-term roofline per (arch x shape x mesh) -- the §Roofline source.
"""
import json
import os

from benchmarks.common import emit

FILES = {
    "16x16": "dryrun_single_pod.json",
    "2x16x16": "dryrun_multi_pod.json",
}


def run(root: str = None):
    root = root or os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    found = False
    for mesh, fname in FILES.items():
        path = os.path.join(root, fname)
        if not os.path.exists(path):
            print(f"# {fname} missing -- run repro.launch.dryrun first")
            continue
        found = True
        rows = json.load(open(path))
        for r in rows:
            if r.get("status") != "OK":
                emit(f"roofline/{mesh}/{r['arch']}/{r['shape']}", 0.0,
                     r["status"], reason=r.get("reason", r.get("error", "")))
                continue
            dom = r["bottleneck"]
            emit(f"roofline/{mesh}/{r['arch']}/{r['shape']}",
                 r.get("compile_s", 0) * 1e6,
                 f"{dom}",
                 t_compute_ms=round(r["t_compute_s"] * 1e3, 3),
                 t_memory_ms=round(r["t_memory_s"] * 1e3, 3),
                 t_collective_ms=round(r["t_collective_s"] * 1e3, 3),
                 useful_ratio=round(r.get("useful_ratio", 0), 4))
    return found


if __name__ == "__main__":
    run()
