"""Table 5: extension to PEFT variants (QLoRA / DoRA).

The paper's finding: FlexLoRA-DoRA degrades sharply because magnitude
reweighting cannot recover directions attenuated by rank collapse, while
raFLoRA avoids the issue. QLoRA (quantized frozen base) is robustness to a
degraded base; AdaLoRA's budget reallocation is out of scope (its rank
schedule conflicts with fixed heterogeneous client ranks).
"""
from benchmarks.common import emit, quick_fl

ROUNDS = 8


def run():
    for variant in ("lora", "qlora", "dora"):
        for method in ("flexlora", "raflora"):
            exp, wall = quick_fl(
                method, rounds=ROUNDS,
                lora_overrides={"variant": variant, "quant_bits": 4,
                                "rank_levels": (4, 8, 32),
                                "rank_probs": (0.34, 0.33, 0.33)})
            hr = (exp.server.energy.higher_rank_ratio[-1]
                  if exp.server.energy.rho_r1 else float("nan"))
            emit(f"table5_variants/{variant}/{method}", wall * 1e6,
                 f"{exp.eval_accuracy():.4f}", higher_rank=f"{hr:.4f}")
    return True


if __name__ == "__main__":
    run()
