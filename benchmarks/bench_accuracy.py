"""Table 2: accuracy comparison across methods (synthetic non-IID proxy).

Validates the paper's ORDERING claims (raflora >= flexlora > hetlora/flora
under heterogeneous ranks + non-IID data), not absolute numbers -- the
container has no CIFAR100/GSM8K or pretrained checkpoints (DESIGN.md §0).
"""
from benchmarks.common import emit, quick_fl


def run(rounds: int = 12, seeds=(0, 1)):
    import numpy as np
    results = {}
    for method in ("hetlora", "flora", "flexlora", "raflora"):
        accs, walls = [], []
        for seed in seeds:
            exp, wall = quick_fl(method, rounds=rounds, seed=seed)
            accs.append(exp.eval_accuracy())
            walls.append(wall)
        results[method] = float(np.mean(accs))
        emit(f"table2_accuracy/{method}",
             float(np.mean(walls)) * 1e6,
             f"{np.mean(accs):.4f}", std=f"{np.std(accs):.4f}")
    return results


if __name__ == "__main__":
    run()
