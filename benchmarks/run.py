"""Benchmark harness entry point: one benchmark per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run            # full sweep (~10 min)
  PYTHONPATH=src python -m benchmarks.run --quick    # core subset (~3 min)
  PYTHONPATH=src python -m benchmarks.run --only accuracy,kernels

Prints ``name,us_per_call,derived`` CSV (benchmarks/common.emit).
"""
import argparse
import sys
import time

BENCHES = {
    "kernels": "benchmarks.bench_kernels",          # kernel validation/cost
    "cost": "benchmarks.bench_cost",                # Table 3
    "energy": "benchmarks.bench_energy_dynamics",   # Fig 2a/2b
    "accuracy": "benchmarks.bench_accuracy",        # Table 2
    "sensitivity": "benchmarks.bench_sensitivity",  # Fig 2c/2d/5a/6a-d, Tbl 4
    "variants": "benchmarks.bench_lora_variants",   # Table 5 (QLoRA/DoRA)
    "roofline": "benchmarks.bench_roofline",        # §Roofline table
    "round_latency": "benchmarks.bench_round_latency",  # batched vs seq engine
}

QUICK = ("kernels", "cost", "energy", "roofline", "round_latency")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated benchmark keys")
    args = ap.parse_args(argv)

    if args.only:
        names = args.only.split(",")
    elif args.quick:
        names = list(QUICK)
    else:
        names = list(BENCHES)

    print("name,us_per_call,derived")
    t0 = time.time()
    failed = []
    for name in names:
        mod_name = BENCHES[name]
        print(f"# --- {name} ({mod_name}) ---", flush=True)
        try:
            import importlib
            mod = importlib.import_module(mod_name)
            mod.run()
        except Exception as e:  # pragma: no cover
            failed.append(name)
            print(f"# FAILED {name}: {type(e).__name__}: {e}", flush=True)
    print(f"# total {time.time() - t0:.1f}s; failed={failed or 'none'}")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
