"""Round-engine latency studies: sequential vs batched vs sharded vs async.

Measures the `FederatedLoRA.run_round` hot path at ``clients_per_round=8``
(full participation of 8 heterogeneous-rank clients, so every round has the
same rank-group composition and only round 1 pays jit compilation). Warmup
rounds are excluded; engines are timed INTERLEAVED, block by block, so
drifting background load on shared-CPU machines biases all engines equally;
the reported number is the median over the timed blocks.

Studies (all merged into one artifact):

* default (``run``): the ISSUE 1 sequential-vs-batched comparison.
* ``--engine sharded`` (ISSUE 2): the SHARDED engine swept over shard
  counts (1, 2, 4, ... up to the visible device count).
* ``--engine async`` (ISSUE 3): the ASYNC buffered-aggregation engine swept
  over ``pipeline_depth`` (1, 2, 4) against the batched engine. Depth d
  trains every round but runs ONE staleness-discounted buffered aggregation
  per d rounds, amortizing the aggregation + SVD realloc + momentum +
  global write-back -- so per-round wall time drops even on a serial host,
  and on parallel hosts the non-blocking dispatches additionally overlap.
  The sweep also runs a momentum-equipped experiment and asserts server
  momentum cost <= ONE jitted dispatch per bucket per aggregation
  (``FactoredServerMomentum.bucket_calls`` -- the ISSUE 3 satellite).
* ``--backend kernel`` (ISSUE 4): the FUSED KERNEL aggregation backend
  (Pallas weighted-stack + Gram-core grids feeding the Gram-core SVD
  realloc, DESIGN.md §4.3) on the batched AND sharded engines, against the
  factored jnp baseline. On CPU the kernels run interpret-mode -- the
  sweep tracks the configuration's latency, not MXU throughput (that is
  ``bench_kernels`` on hardware).
* ``--engine event`` (ISSUE 5): the EVENT-DRIVEN async engine on the
  virtual clock -- buffer trigger type x straggler fraction, measuring
  SIMULATED VIRTUAL TIME to a target higher-rank energy (plus per-fire
  consumption stats). Unlike the wall-clock studies this sweep
  characterizes scheduling outcomes: how quickly each trigger policy
  accumulates aggregated energy when a straggler fraction delays updates.
  Rows are APPENDED to the artifact's ``event.rows`` (never rewritten), so
  the tracked file accumulates a history across PRs;
  ``tools/bench_trend.py`` gates only the wall-clock engine rows.
* ``--engine all``: every study, one process (``tools/ci.sh bench``).

The sharded/async sweeps are STANDALONE-ONLY (``python -m
benchmarks.bench_round_latency --engine ...``): they must force an
8-virtual-device CPU host platform BEFORE jax initializes, which
run.py -- whose `run()` entry stays the sequential-vs-batched study --
cannot do after importing other benches.

Artifacts: the raw per-round times, medians, and speedups are written to
benchmarks/artifacts/round_latency.json AND mirrored to
``BENCH_round_latency.json`` at the repo root -- the tracked perf artifact
successive PRs compare against (``tools/ci.sh bench``).
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from benchmarks.common import emit

ARTIFACT = os.path.join(os.path.dirname(__file__), "artifacts",
                        "round_latency.json")
ROOT_ARTIFACT = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_round_latency.json")


def _merge_artifact(update: dict) -> dict:
    """Read-modify-write the shared JSON artifact (and its tracked repo-root
    mirror) so the engine studies never clobber each other. On a fresh
    checkout the local artifact is absent but the tracked mirror may hold
    committed results from earlier PRs -- seed from whichever exists so a
    partial rerun never drops committed sections."""
    result = {}
    for path in (ROOT_ARTIFACT, ARTIFACT):   # local artifact wins if both
        if os.path.exists(path):
            with open(path) as f:
                result = json.load(f)
    result.update(update)
    os.makedirs(os.path.dirname(ARTIFACT), exist_ok=True)
    for path in (ARTIFACT, ROOT_ARTIFACT):
        with open(path, "w") as f:
            json.dump(result, f, indent=2)
    return result


def _make(engine: str, *, rounds: int, d_model: int, batches_per_round: int,
          local_batch_size: int, mesh=None, pipeline_depth: int = 1,
          server_momentum_beta: float = 0.0, backend: str = "factored",
          transport=None):
    from repro.federation.experiment import build_experiment
    return build_experiment(
        "raflora",
        fl_overrides={"num_rounds": rounds, "num_clients": 8,
                      "participation": 1.0,            # clients_per_round=8
                      "local_batch_size": local_batch_size},
        lora_overrides={"rank_levels": (4, 8, 16),
                        "rank_probs": (0.34, 0.33, 0.33)},
        samples_per_class=40, num_classes=8, d_model=d_model,
        batches_per_round=batches_per_round, round_engine=engine, mesh=mesh,
        pipeline_depth=pipeline_depth, backend=backend,
        server_momentum_beta=server_momentum_beta, transport=transport)


def _time_blocks(servers: dict, *, blocks: int, rounds_per_block: int,
                 warmup: int) -> dict:
    """Median seconds-per-round per server, timed in interleaved blocks.

    Each timed block ends with the server's own ``flush_stats()`` so
    engines that defer work (the async engine's lazy stat materialization
    and in-flight dispatches) are charged for it INSIDE their own block --
    otherwise their device-queue tail would spill into the next engine's
    timing and bias the comparison both ways."""
    for _ in range(warmup):                 # jit/compile time excluded
        for srv in servers.values():
            for _ in range(rounds_per_block):
                srv.run_round()
            srv.flush_stats()
    times = {k: [] for k in servers}
    for _ in range(blocks):
        for key, srv in servers.items():    # interleaved: shared load drift
            t0 = time.perf_counter()
            for _ in range(rounds_per_block):
                srv.run_round()
            srv.flush_stats()
            times[key].append((time.perf_counter() - t0) / rounds_per_block)
    return times


def run(rounds: int = 12, warmup: int = 2, d_model: int = 64,
        batches_per_round: int = 1, local_batch_size: int = 16) -> dict:
    total = rounds + warmup
    servers = {eng: _make(eng, rounds=total, d_model=d_model,
                          batches_per_round=batches_per_round,
                          local_batch_size=local_batch_size).server
               for eng in ("sequential", "batched")}
    times = _time_blocks(servers, blocks=rounds, rounds_per_block=1,
                         warmup=warmup)

    medians = {eng: float(np.median(ts)) for eng, ts in times.items()}
    speedup = medians["sequential"] / medians["batched"]
    result = _merge_artifact({
        "config": {"clients_per_round": 8, "rounds_timed": rounds,
                   "warmup_rounds": warmup, "d_model": d_model,
                   "batches_per_round": batches_per_round,
                   "local_batch_size": local_batch_size,
                   "rank_levels": [4, 8, 16], "method": "raflora"},
        "per_round_s": {eng: ts for eng, ts in times.items()},
        "median_s": medians,
        "speedup_batched_over_sequential": speedup,
    })

    for eng in servers:
        emit(f"round_latency/{eng}", medians[eng] * 1e6,
             f"median_round_ms={medians[eng] * 1e3:.1f}")
    emit("round_latency/speedup", 0.0, f"{speedup:.2f}x")
    print(f"# artifact: {ARTIFACT}")
    return result


def run_sharded(rounds: int = 8, warmup: int = 2, d_model: int = 64,
                batches_per_round: int = 1,
                local_batch_size: int = 16) -> dict:
    """Sharded-engine latency vs shard count (ISSUE 2 acceptance artifact).

    One experiment per power-of-two shard count that fits the visible
    devices, all timed the same way as ``run``; results merge into the
    existing artifact so the engine studies live side by side.
    """
    import jax
    from repro.launch.mesh import make_fl_mesh
    shard_counts = [s for s in (1, 2, 4, 8, 16)
                    if s <= jax.device_count()]
    total = rounds + warmup
    servers = {s: _make("sharded", rounds=total, d_model=d_model,
                        batches_per_round=batches_per_round,
                        local_batch_size=local_batch_size,
                        mesh=make_fl_mesh(s)).server
               for s in shard_counts}
    times = _time_blocks(servers, blocks=rounds, rounds_per_block=1,
                         warmup=warmup)

    medians = {s: float(np.median(ts)) for s, ts in times.items()}
    sharded = {
        "config": {"clients_per_round": 8, "rounds_timed": rounds,
                   "warmup_rounds": warmup, "d_model": d_model,
                   "batches_per_round": batches_per_round,
                   "local_batch_size": local_batch_size,
                   "rank_levels": [4, 8, 16], "method": "raflora",
                   "device_count": jax.device_count()},
        "shard_counts": shard_counts,
        "per_round_s": {str(s): ts for s, ts in times.items()},
        "median_s": {str(s): m for s, m in medians.items()},
    }
    _merge_artifact({"sharded": sharded})

    for s in shard_counts:
        emit(f"round_latency/sharded_{s}", medians[s] * 1e6,
             f"median_round_ms={medians[s] * 1e3:.1f}")
    print(f"# artifact: {ARTIFACT}")
    return sharded


def _momentum_dispatch_audit(*, d_model: int, local_batch_size: int) -> dict:
    """ISSUE 3 satellite check: bucketed server momentum must add at most
    ONE jitted dispatch per shape bucket per aggregation (the old
    ``_record_result`` ran an unjitted per-ADAPTER stacked-QR-SVD loop on
    the host, defeating the one-dispatch-per-bucket engine design)."""
    exp = _make("async", rounds=8, d_model=d_model, batches_per_round=1,
                local_batch_size=local_batch_size, pipeline_depth=2,
                server_momentum_beta=0.9)
    exp.server.run(6)
    mom = exp.server.server_momentum
    n_aggs = len(exp.server.energy.rho_r1)
    n_buckets = len(mom.state)              # one stacked entry per bucket
    assert n_aggs > 0 and n_buckets > 0, (n_aggs, n_buckets)
    assert mom.bucket_calls <= n_aggs * n_buckets, \
        (mom.bucket_calls, n_aggs, n_buckets)
    return {"bucket_calls": mom.bucket_calls, "aggregations": n_aggs,
            "buckets": n_buckets,
            "dispatches_per_bucket_per_agg":
                mom.bucket_calls / (n_aggs * n_buckets)}


def run_async(rounds: int = 8, warmup: int = 4, d_model: int = 128,
              batches_per_round: int = 1, local_batch_size: int = 4,
              depths=(1, 2, 4), rounds_per_block: int = 4,
              backend: str = "dense") -> dict:
    """Async-engine latency vs pipeline depth (ISSUE 3 acceptance artifact).

    Depth d runs one buffered aggregation per d training rounds, so blocks
    of ``rounds_per_block`` rounds are timed (a multiple of every swept
    depth) and per-round wall time is block time / block rounds. The
    acceptance bar -- async at depth 2 at least 1.3x faster per round than
    batched -- is recorded as ``speedup_async2_over_batched``.

    The study runs the DENSE (paper-faithful) aggregation backend at an
    aggregation-heavy shape (d_model=128, local batch 4): the dense SVD
    realloc cost is independent of the merged client count, so buffered
    aggregation amortizes it fully (depth d = 1/d as many SVD + write-back
    server steps). The factored backend's QR core grows with the merged
    stack width R = M*r_max, so buffering pays less there -- the tradeoff
    is recorded in the artifact config.
    """
    import jax
    total = (rounds + warmup) * rounds_per_block
    servers = {"batched": _make("batched", rounds=total, d_model=d_model,
                                batches_per_round=batches_per_round,
                                local_batch_size=local_batch_size,
                                backend=backend).server}
    for d in depths:
        servers[f"async{d}"] = _make(
            "async", rounds=total, d_model=d_model,
            batches_per_round=batches_per_round,
            local_batch_size=local_batch_size, pipeline_depth=d,
            backend=backend).server
    times = _time_blocks(servers, blocks=rounds,
                         rounds_per_block=rounds_per_block, warmup=warmup)

    medians = {k: float(np.median(ts)) for k, ts in times.items()}
    speedups = {f"speedup_async{d}_over_batched":
                medians["batched"] / medians[f"async{d}"] for d in depths}
    audit = _momentum_dispatch_audit(d_model=d_model,
                                     local_batch_size=local_batch_size)
    async_result = {
        "config": {"clients_per_round": 8, "blocks_timed": rounds,
                   "rounds_per_block": rounds_per_block,
                   "warmup_blocks": warmup, "d_model": d_model,
                   "batches_per_round": batches_per_round,
                   "local_batch_size": local_batch_size,
                   "rank_levels": [4, 8, 16], "method": "raflora",
                   "backend": backend,
                   "device_count": jax.device_count()},
        "pipeline_depths": list(depths),
        "per_round_s": {k: ts for k, ts in times.items()},
        "median_s": medians,
        "momentum_dispatch_audit": audit,
        **speedups,
    }
    _merge_artifact({"async": async_result})

    for k in servers:
        emit(f"round_latency/{k}", medians[k] * 1e6,
             f"median_round_ms={medians[k] * 1e3:.1f}")
    for d in depths:
        emit(f"round_latency/speedup_async{d}", 0.0,
             f"{speedups[f'speedup_async{d}_over_batched']:.2f}x")
    print(f"# artifact: {ARTIFACT}")
    return async_result


def run_kernel_backend(rounds: int = 8, warmup: int = 2, d_model: int = 64,
                       batches_per_round: int = 1,
                       local_batch_size: int = 16) -> dict:
    """Kernel-backend latency sweep (ISSUE 4 acceptance artifact): the
    fused Pallas aggregation on the batched and sharded engines against
    the factored jnp baseline, interleaved-block-timed like every other
    study. The sharded run uses every visible device, so under the forced
    8-device platform its per-bucket (d+n, R) psums are real."""
    import jax
    from repro.launch.mesh import make_fl_mesh
    total = rounds + warmup
    servers = {
        "batched_factored": _make("batched", rounds=total, d_model=d_model,
                                  batches_per_round=batches_per_round,
                                  local_batch_size=local_batch_size,
                                  backend="factored").server,
        "batched_kernel": _make("batched", rounds=total, d_model=d_model,
                                batches_per_round=batches_per_round,
                                local_batch_size=local_batch_size,
                                backend="kernel").server,
        "sharded_kernel": _make("sharded", rounds=total, d_model=d_model,
                                batches_per_round=batches_per_round,
                                local_batch_size=local_batch_size,
                                backend="kernel",
                                mesh=make_fl_mesh()).server,
    }
    times = _time_blocks(servers, blocks=rounds, rounds_per_block=1,
                         warmup=warmup)

    medians = {k: float(np.median(ts)) for k, ts in times.items()}
    result = {
        "config": {"clients_per_round": 8, "rounds_timed": rounds,
                   "warmup_rounds": warmup, "d_model": d_model,
                   "batches_per_round": batches_per_round,
                   "local_batch_size": local_batch_size,
                   "rank_levels": [4, 8, 16], "method": "raflora",
                   "device_count": jax.device_count(),
                   "note": "Pallas kernels run interpret-mode on CPU"},
        "per_round_s": {k: ts for k, ts in times.items()},
        "median_s": medians,
        "kernel_over_factored_batched":
            medians["batched_factored"] / medians["batched_kernel"],
    }
    _merge_artifact({"kernel_backend": result})

    for k in servers:
        emit(f"round_latency/{k}", medians[k] * 1e6,
             f"median_round_ms={medians[k] * 1e3:.1f}")
    print(f"# artifact: {ARTIFACT}")
    return result


def _upload_bytes_per_round(server, mode) -> int:
    """Analytic client->server upload bytes for one full-participation
    round: per participating client, per LoRA adapter, the factor pair at
    the client's rank level. f32 ships raw (d*r + r*n)*4; the transport
    modes ship the QuantFactor payload + f32 per-column scales."""
    from repro.federation.transport import TransportConfig, UpdateTransport
    tr = None if mode == "f32" else UpdateTransport(TransportConfig(mode))
    shapes = []                               # (d, n) per adapter
    for parent, (b, a) in _adapter_shapes(server):
        shapes.append((b, a))
    total = 0
    for rank in server.registry.ranks:
        rank = int(rank)                       # np.int64 is not JSON-able
        for d, n in shapes:
            if tr is None:
                total += (d * rank + rank * n) * 4
            else:
                total += tr.payload_bytes(d, n, rank)
    return int(total)


def _adapter_shapes(server):
    import jax
    flat = jax.tree_util.tree_flatten_with_path(server.global_lora)[0]
    got = {}
    for path, leaf in flat:
        key = tuple(str(getattr(p, "key", p)) for p in path)
        if key[-1] == "lora_b":
            got.setdefault(key[:-1], [0, 0])[0] = leaf.shape[-2]
        elif key[-1] == "lora_a":
            got.setdefault(key[:-1], [0, 0])[1] = leaf.shape[-1]
    return sorted(got.items())


def run_transport(rounds: int = 8, warmup: int = 2, d_model: int = 64,
                  batches_per_round: int = 1,
                  local_batch_size: int = 16) -> dict:
    """Compressed-transport study (DESIGN.md §12): the batched engine with
    f32 uploads vs int8 and bf16 quantized transport (error feedback on),
    interleaved-block-timed like every other study, PLUS the analytic
    upload bytes per round for each mode -- the ``bytes_per_round`` column
    the tracked artifact carries for successive PRs. Latency rows are gated
    by ``tools/bench_trend.py`` at the standard bar; the bytes column is
    exact (shape arithmetic, nothing to drift)."""
    from repro.federation.transport import TransportConfig
    total = rounds + warmup
    servers = {
        "batched_f32": _make("batched", rounds=total, d_model=d_model,
                             batches_per_round=batches_per_round,
                             local_batch_size=local_batch_size).server,
        "batched_int8": _make("batched", rounds=total, d_model=d_model,
                              batches_per_round=batches_per_round,
                              local_batch_size=local_batch_size,
                              transport=TransportConfig(mode="int8")).server,
        "batched_bf16": _make("batched", rounds=total, d_model=d_model,
                              batches_per_round=batches_per_round,
                              local_batch_size=local_batch_size,
                              transport=TransportConfig(mode="bf16")).server,
    }
    times = _time_blocks(servers, blocks=rounds, rounds_per_block=1,
                         warmup=warmup)

    medians = {k: float(np.median(ts)) for k, ts in times.items()}
    byts = {k: _upload_bytes_per_round(servers[k], k.split("_")[1])
            for k in servers}
    result = {
        "config": {"clients_per_round": 8, "rounds_timed": rounds,
                   "warmup_rounds": warmup, "d_model": d_model,
                   "batches_per_round": batches_per_round,
                   "local_batch_size": local_batch_size,
                   "rank_levels": [4, 8, 16], "method": "raflora",
                   "error_feedback": True},
        "per_round_s": {k: ts for k, ts in times.items()},
        "median_s": medians,
        "bytes_per_round": byts,
        "bytes_reduction_int8":
            byts["batched_f32"] / byts["batched_int8"],
        "bytes_reduction_bf16":
            byts["batched_f32"] / byts["batched_bf16"],
    }
    _merge_artifact({"transport": result})

    for k in servers:
        emit(f"round_latency/{k}", medians[k] * 1e6,
             f"median_round_ms={medians[k] * 1e3:.1f} "
             f"upload_MB={byts[k] / 1e6:.2f}")
    emit("round_latency/bytes_reduction_int8", 0.0,
         f"{result['bytes_reduction_int8']:.2f}x")
    print(f"# artifact: {ARTIFACT}")
    return result


def run_event(rounds: int = 10, d_model: int = 32,
              local_batch_size: int = 8,
              straggler_fracs=(0.0, 0.5),
              target_energy: float = 0.25) -> dict:
    """Event-driven scheduler sweep (ISSUE 5 acceptance artifact): buffer
    trigger type x straggler fraction -> simulated-virtual-time-to-target-
    energy for raFLoRA.

    Per config the event engine runs ``rounds`` rounds + a drain on the
    virtual clock; the recorded metric is the virtual time of the first
    aggregation whose higher-rank energy ratio reaches ``target_energy``
    (energy-trace entries map 1:1 to trigger firings), plus per-fire
    consumption stats. Stragglers are drawn with the same seed across
    trigger types, so rows are comparable within a sweep. Rows APPEND to
    the tracked artifact -- reruns accumulate instead of rewriting, and
    ``tools/bench_trend.py`` never gates them (virtual time is exactly
    reproducible, so there is nothing to drift)."""
    from repro.federation.events import (EventScheduler, standard_trigger,
                                         standard_straggler_latency)
    rows = []
    for trig_name in ("count", "timeout", "staleness"):
        for frac in straggler_fracs:
            exp = _make("async", rounds=rounds, d_model=d_model,
                        batches_per_round=1,
                        local_batch_size=local_batch_size)
            m = exp.server.fl.clients_per_round
            trigger = standard_trigger(trig_name, m)
            sched = EventScheduler(standard_straggler_latency(frac),
                                   trigger, round_interval=1.0)
            exp.server.set_event_scheduler(sched)
            exp.server.run(rounds)
            exp.server.drain_pending()
            energy = exp.server.energy.higher_rank_ratio
            fires = sched.fire_log
            assert len(energy) == len(fires), (len(energy), len(fires))
            vt = next((f.time for f, e in zip(fires, energy)
                       if e >= target_energy), None)
            rows.append({
                "trigger": trigger.describe(),
                "straggler_frac": frac,
                "virtual_time_to_target_energy": vt,
                "target_energy": target_energy,
                "final_higher_rank_energy": float(energy[-1]),
                "virtual_time_total": sched.clock.now,
                "aggregations": len(fires),
                "updates_aggregated": int(sum(f.consumed for f in fires)),
                "max_staleness": int(max(f.max_staleness for f in fires)),
                "rounds": rounds,
            })
            if vt is not None:
                emit(f"round_latency/event_{trig_name}_s{frac}", vt * 1e6,
                     f"vt_to_E>={target_energy}={vt:.1f} aggs={len(fires)}")
            else:
                # target never reached: no metric row (a 0.0 sentinel would
                # read as the BEST outcome in the shared ROWS stream); the
                # JSON row records null + the final energy
                print(f"# event_{trig_name}_s{frac}: target energy "
                      f"{target_energy} not reached in {rounds} rounds "
                      f"(final {float(energy[-1]):.3f})")
    # APPEND (never rewrite): the tracked artifact accumulates event rows.
    # Histories are append-only, so whichever copy holds MORE rows is the
    # superset -- seeding from it means a stale local artifact (or a
    # pre-event one) can never truncate the tracked history.
    existing = {}
    for path in (ROOT_ARTIFACT, ARTIFACT):
        if os.path.exists(path):
            with open(path) as f:
                section = json.load(f).get("event") or {}
            if len(section.get("rows", [])) > len(existing.get("rows", [])):
                existing = section
    result = {
        "config": {"clients_per_round": 8, "d_model": d_model,
                   "local_batch_size": local_batch_size,
                   "rank_levels": [4, 8, 16], "method": "raflora",
                   "round_interval": 1.0,
                   "latency": "straggler-tail lognormal(0.9, 0.2) x6"},
        "rows": list(existing.get("rows", [])) + rows,
    }
    _merge_artifact({"event": result})
    print(f"# artifact: {ARTIFACT}")
    return result


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--engine", choices=("batched", "sharded", "async",
                                         "event", "transport", "all"),
                    default="batched")
    ap.add_argument("--backend", choices=("factored", "kernel"),
                    default="factored",
                    help="'kernel' runs the fused-Pallas backend sweep "
                         "instead of the engine studies")
    args = ap.parse_args()
    if args.engine != "batched" or args.backend == "kernel":
        # must precede the first jax initialization: standalone sweeps get
        # an 8-virtual-device CPU host platform
        os.environ.setdefault(
            "XLA_FLAGS", "--xla_force_host_platform_device_count=8")
    if args.backend == "kernel":
        run_kernel_backend()
    elif args.engine == "sharded":
        run_sharded()
    elif args.engine == "async":
        run_async()
    elif args.engine == "event":
        run_event()
    elif args.engine == "transport":
        run_transport()
    elif args.engine == "all":
        run()
        run_sharded()
        run_async()
        run_kernel_backend()
        run_event()
        run_transport()
    else:
        run()
