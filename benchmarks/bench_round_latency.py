"""Sequential vs batched round engine: per-round wall time (ISSUE 1 tentpole).

Measures the `FederatedLoRA.run_round` hot path at ``clients_per_round=8``
(full participation of 8 heterogeneous-rank clients, so every round has the
same rank-group composition and only round 1 pays jit compilation). Warmup
rounds are excluded; the two engines are timed INTERLEAVED, round by round,
so drifting background load on shared-CPU machines biases both equally; the
reported number is the median over the timed rounds.

Writes a JSON artifact (benchmarks/artifacts/round_latency.json) with the
raw per-round times, the medians, and the speedup, and emits the usual CSV
rows for run.py.
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from benchmarks.common import emit

ARTIFACT = os.path.join(os.path.dirname(__file__), "artifacts",
                        "round_latency.json")


def _make(engine: str, *, rounds: int, d_model: int, batches_per_round: int,
          local_batch_size: int):
    from repro.federation.experiment import build_experiment
    return build_experiment(
        "raflora",
        fl_overrides={"num_rounds": rounds, "num_clients": 8,
                      "participation": 1.0,            # clients_per_round=8
                      "local_batch_size": local_batch_size},
        lora_overrides={"rank_levels": (4, 8, 16),
                        "rank_probs": (0.34, 0.33, 0.33)},
        samples_per_class=40, num_classes=8, d_model=d_model,
        batches_per_round=batches_per_round, round_engine=engine)


def run(rounds: int = 12, warmup: int = 2, d_model: int = 64,
        batches_per_round: int = 1, local_batch_size: int = 16) -> dict:
    total = rounds + warmup
    servers = {eng: _make(eng, rounds=total, d_model=d_model,
                          batches_per_round=batches_per_round,
                          local_batch_size=local_batch_size).server
               for eng in ("sequential", "batched")}
    times = {eng: [] for eng in servers}
    for _ in range(warmup):                 # jit/compile time excluded
        for srv in servers.values():
            srv.run_round()
    for _ in range(rounds):
        for eng, srv in servers.items():    # interleaved: shared load drift
            t0 = time.perf_counter()
            srv.run_round()
            times[eng].append(time.perf_counter() - t0)

    medians = {eng: float(np.median(ts)) for eng, ts in times.items()}
    speedup = medians["sequential"] / medians["batched"]
    result = {
        "config": {"clients_per_round": 8, "rounds_timed": rounds,
                   "warmup_rounds": warmup, "d_model": d_model,
                   "batches_per_round": batches_per_round,
                   "local_batch_size": local_batch_size,
                   "rank_levels": [4, 8, 16], "method": "raflora"},
        "per_round_s": {eng: ts for eng, ts in times.items()},
        "median_s": medians,
        "speedup_batched_over_sequential": speedup,
    }
    os.makedirs(os.path.dirname(ARTIFACT), exist_ok=True)
    with open(ARTIFACT, "w") as f:
        json.dump(result, f, indent=2)

    for eng in servers:
        emit(f"round_latency/{eng}", medians[eng] * 1e6,
             f"median_round_ms={medians[eng] * 1e3:.1f}")
    emit("round_latency/speedup", 0.0, f"{speedup:.2f}x")
    print(f"# artifact: {ARTIFACT}")
    return result


if __name__ == "__main__":
    run()
