"""Sequential vs batched round engine: per-round wall time (ISSUE 1 tentpole).

Measures the `FederatedLoRA.run_round` hot path at ``clients_per_round=8``
(full participation of 8 heterogeneous-rank clients, so every round has the
same rank-group composition and only round 1 pays jit compilation). Warmup
rounds are excluded; the two engines are timed INTERLEAVED, round by round,
so drifting background load on shared-CPU machines biases both equally; the
reported number is the median over the timed rounds.

``--engine sharded`` (ISSUE 2) instead sweeps the SHARDED engine over shard
counts (1, 2, 4, ... up to the visible device count): one experiment per
``("data",)`` mesh size, recording per-round medians vs shard count into the
same JSON artifact under ``"sharded"``. The sweep is STANDALONE-ONLY
(``python -m benchmarks.bench_round_latency --engine sharded``): it must
force an 8-virtual-device CPU host platform BEFORE jax initializes, which
run.py/``tools/ci.sh bench`` -- whose `run()` entry stays the
sequential-vs-batched study -- cannot do after importing other benches.

Writes a JSON artifact (benchmarks/artifacts/round_latency.json) with the
raw per-round times, the medians, and the speedup, and emits the usual CSV
rows for run.py.
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from benchmarks.common import emit

ARTIFACT = os.path.join(os.path.dirname(__file__), "artifacts",
                        "round_latency.json")


def _merge_artifact(update: dict) -> dict:
    """Read-modify-write the shared JSON artifact so the batched-vs-seq
    study and the sharded shard-count sweep never clobber each other."""
    result = {}
    if os.path.exists(ARTIFACT):
        with open(ARTIFACT) as f:
            result = json.load(f)
    result.update(update)
    os.makedirs(os.path.dirname(ARTIFACT), exist_ok=True)
    with open(ARTIFACT, "w") as f:
        json.dump(result, f, indent=2)
    return result


def _make(engine: str, *, rounds: int, d_model: int, batches_per_round: int,
          local_batch_size: int, mesh=None):
    from repro.federation.experiment import build_experiment
    return build_experiment(
        "raflora",
        fl_overrides={"num_rounds": rounds, "num_clients": 8,
                      "participation": 1.0,            # clients_per_round=8
                      "local_batch_size": local_batch_size},
        lora_overrides={"rank_levels": (4, 8, 16),
                        "rank_probs": (0.34, 0.33, 0.33)},
        samples_per_class=40, num_classes=8, d_model=d_model,
        batches_per_round=batches_per_round, round_engine=engine, mesh=mesh)


def run(rounds: int = 12, warmup: int = 2, d_model: int = 64,
        batches_per_round: int = 1, local_batch_size: int = 16) -> dict:
    total = rounds + warmup
    servers = {eng: _make(eng, rounds=total, d_model=d_model,
                          batches_per_round=batches_per_round,
                          local_batch_size=local_batch_size).server
               for eng in ("sequential", "batched")}
    times = {eng: [] for eng in servers}
    for _ in range(warmup):                 # jit/compile time excluded
        for srv in servers.values():
            srv.run_round()
    for _ in range(rounds):
        for eng, srv in servers.items():    # interleaved: shared load drift
            t0 = time.perf_counter()
            srv.run_round()
            times[eng].append(time.perf_counter() - t0)

    medians = {eng: float(np.median(ts)) for eng, ts in times.items()}
    speedup = medians["sequential"] / medians["batched"]
    result = _merge_artifact({
        "config": {"clients_per_round": 8, "rounds_timed": rounds,
                   "warmup_rounds": warmup, "d_model": d_model,
                   "batches_per_round": batches_per_round,
                   "local_batch_size": local_batch_size,
                   "rank_levels": [4, 8, 16], "method": "raflora"},
        "per_round_s": {eng: ts for eng, ts in times.items()},
        "median_s": medians,
        "speedup_batched_over_sequential": speedup,
    })

    for eng in servers:
        emit(f"round_latency/{eng}", medians[eng] * 1e6,
             f"median_round_ms={medians[eng] * 1e3:.1f}")
    emit("round_latency/speedup", 0.0, f"{speedup:.2f}x")
    print(f"# artifact: {ARTIFACT}")
    return result


def run_sharded(rounds: int = 8, warmup: int = 2, d_model: int = 64,
                batches_per_round: int = 1,
                local_batch_size: int = 16) -> dict:
    """Sharded-engine latency vs shard count (ISSUE 2 acceptance artifact).

    One experiment per power-of-two shard count that fits the visible
    devices, all timed the same way as ``run``; results merge into the
    existing artifact so the two engine studies live side by side.
    """
    import jax
    from repro.launch.mesh import make_fl_mesh
    shard_counts = [s for s in (1, 2, 4, 8, 16)
                    if s <= jax.device_count()]
    total = rounds + warmup
    servers = {s: _make("sharded", rounds=total, d_model=d_model,
                        batches_per_round=batches_per_round,
                        local_batch_size=local_batch_size,
                        mesh=make_fl_mesh(s)).server
               for s in shard_counts}
    times = {s: [] for s in servers}
    for _ in range(warmup):                 # jit/compile time excluded
        for srv in servers.values():
            srv.run_round()
    for _ in range(rounds):
        for s, srv in servers.items():      # interleaved: shared load drift
            t0 = time.perf_counter()
            srv.run_round()
            times[s].append(time.perf_counter() - t0)

    medians = {s: float(np.median(ts)) for s, ts in times.items()}
    sharded = {
        "config": {"clients_per_round": 8, "rounds_timed": rounds,
                   "warmup_rounds": warmup, "d_model": d_model,
                   "batches_per_round": batches_per_round,
                   "local_batch_size": local_batch_size,
                   "rank_levels": [4, 8, 16], "method": "raflora",
                   "device_count": jax.device_count()},
        "shard_counts": shard_counts,
        "per_round_s": {str(s): ts for s, ts in times.items()},
        "median_s": {str(s): m for s, m in medians.items()},
    }
    _merge_artifact({"sharded": sharded})

    for s in shard_counts:
        emit(f"round_latency/sharded_{s}", medians[s] * 1e6,
             f"median_round_ms={medians[s] * 1e3:.1f}")
    print(f"# artifact: {ARTIFACT}")
    return sharded


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--engine", choices=("batched", "sharded"),
                    default="batched")
    args = ap.parse_args()
    if args.engine == "sharded":
        # must precede the first jax initialization: standalone sharded
        # sweeps get an 8-virtual-device CPU host platform
        os.environ.setdefault(
            "XLA_FLAGS", "--xla_force_host_platform_device_count=8")
        run_sharded()
    else:
        run()
