"""Quickstart: federated heterogeneous-rank LoRA with raFLoRA in ~40 lines.

Runs 8 federated rounds on the synthetic non-IID classification task and
prints the higher-rank energy ratio each round -- the quantity whose decay
is "rank collapse" (Definition 1) and whose preservation is the paper's
contribution.

  PYTHONPATH=src python examples/quickstart.py
"""
from repro.federation.experiment import build_experiment


def main():
    for method in ("flexlora", "raflora"):
        exp = build_experiment(
            method,
            fl_overrides={"num_rounds": 8, "num_clients": 16,
                          "participation": 0.5},
            num_classes=10, d_model=64, samples_per_class=50,
            batches_per_round=1)
        print(f"\n=== {method} ===")
        acc0 = exp.eval_accuracy()
        for r in range(8):
            stats = exp.server.run_round()
            hr = exp.server.energy.higher_rank_ratio[-1]
            print(f"round {r}: client loss {stats.mean_client_loss:.3f}  "
                  f"higher-rank energy (1-rho_r1) = {hr:.3f}")
        print(f"test accuracy: {acc0:.3f} -> {exp.eval_accuracy():.3f}")


if __name__ == "__main__":
    main()
