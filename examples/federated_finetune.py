"""End-to-end driver (deliverable b): federated fine-tuning of a ~100M-class
model for a few hundred client steps, comparing all four heterogeneous-rank
aggregation methods, with energy traces and a final accuracy table.

20 rounds x 5 clients/round x 2 batches = 200 client optimization steps per
method. The model is the reduced ViT-family encoder with LoRA on all six
projection types (the paper's "all linear layers" setting).

  PYTHONPATH=src python examples/federated_finetune.py [--rounds 20]
"""
import argparse

import numpy as np

from repro.federation.experiment import build_experiment


def run(method: str, rounds: int, seed: int = 0):
    exp = build_experiment(
        method,
        fl_overrides={"num_rounds": rounds, "num_clients": 20,
                      "participation": 0.25, "seed": seed},
        num_classes=20, d_model=128, samples_per_class=100,
        batches_per_round=2)
    exp.server.run(rounds)
    return {
        "accuracy": exp.eval_accuracy(),
        "final_loss": exp.server.history[-1].mean_client_loss,
        "higher_rank_energy": (float(exp.server.energy.higher_rank_ratio[-1])
                               if exp.server.energy.rho_r1 else float("nan")),
        "collapsed": (exp.server.energy.collapsed()
                      if exp.server.energy.rho_r1 else None),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=20)
    ap.add_argument("--methods", default="hetlora,flora,flexlora,raflora")
    args = ap.parse_args()

    print(f"{'method':10s} {'accuracy':>9s} {'loss':>8s} "
          f"{'1-rho_r1':>9s} {'collapsed':>10s}")
    for method in args.methods.split(","):
        r = run(method, args.rounds)
        print(f"{method:10s} {r['accuracy']:9.3f} {r['final_loss']:8.3f} "
              f"{r['higher_rank_energy']:9.3f} {str(r['collapsed']):>10s}")


if __name__ == "__main__":
    main()
