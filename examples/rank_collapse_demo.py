"""Theory demo: Theorem 1's geometric rank collapse, exactly as proved.

Simulates the closed-form expected-energy recursion AND the Monte-Carlo
client-sampling model, prints the (C, gamma) bound, and shows raFLoRA's
corrected recursion staying flat -- no training required, pure theory.

  PYTHONPATH=src python examples/rank_collapse_demo.py
"""
import numpy as np

from repro.core import (SampledSim, collapse_bound, coverage, rho_series,
                        simulate_expected)

LEVELS = [8, 16, 32, 48, 64]
K, M, ROUNDS = 100, 10, 60


def bar(x, width=40):
    return "#" * int(x * width)


def main():
    ranks = np.repeat(LEVELS, K // len(LEVELS))
    p = coverage(LEVELS, ranks)
    e0 = np.ones(64)

    C, gamma = collapse_bound(e0, p, K, M, r1=8)
    print(f"Theorem 1 constants: C={C:.2f}, gamma={gamma:.4f} "
          f"(higher-rank energy <= C*gamma^t)\n")

    exact = simulate_expected(e0, p, K, M, ROUNDS)
    flex = SampledSim(ranks, M, seed=0).run(np.ones(64), ROUNDS,
                                            rule="flexlora",
                                            rank_levels=LEVELS)
    ra = SampledSim(ranks, M, seed=0).run(np.ones(64), ROUNDS,
                                          rule="raflora", rank_levels=LEVELS)
    tail_exact = 1 - rho_series(exact, 8)
    tail_flex = 1 - rho_series(flex, 8)
    tail_ra = 1 - rho_series(ra, 8)

    print(f"{'t':>3s} {'bound':>8s} {'E[flex]':>8s} {'flex-MC':>8s} "
          f"{'raFLoRA':>8s}  higher-rank energy")
    for t in range(0, ROUNDS + 1, 6):
        print(f"{t:3d} {min(C * gamma ** t, 1):8.4f} {tail_exact[t]:8.4f} "
              f"{tail_flex[t]:8.4f} {tail_ra[t]:8.4f}  "
              f"|{bar(tail_flex[t]):40s}|")
    print("\nFlexLoRA's higher-rank energy decays geometrically (rank "
          "collapse); raFLoRA's stays flat.")


if __name__ == "__main__":
    main()
