"""Serving example (deliverable b): batched greedy decoding for a decoder
arch from the assigned pool, exercising prefill -> KV-cache -> serve_step.

  PYTHONPATH=src python examples/serve_finetuned.py --arch mamba2-1.3b
"""
import sys

from repro.launch.serve import main

if __name__ == "__main__":
    sys.exit(main())
