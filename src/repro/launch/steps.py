"""Step builders: client train step (LoRA-only AdamW, grad accumulation),
prefill step, and single-token serve step. Shared by the real trainer, the
examples, and the multi-pod dry-run."""
from __future__ import annotations

import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core.lora import merge_lora
from repro.models.transformer import Model
from repro.optim import AdamW


def _split_microbatches(batch: dict, num: int) -> dict:
    """Reshape the batch dim into (num, B/num). M-RoPE positions (3, B, L)
    split on axis 1."""
    def split(key, x):
        if key == "positions" and x.ndim == 3 and x.shape[0] == 3:
            return x.reshape((3, num, -1) + x.shape[2:]).transpose(1, 0, 2, 3)
        return x.reshape((num, -1) + x.shape[1:])
    return {k: split(k, v) for k, v in batch.items()}


def build_train_step(model: Model, lora_rank: int, *,
                     num_microbatches: int = 1,
                     weight_decay: float = 0.0) -> Callable:
    """(lora, opt_state, base, batch, lr) -> (lora, opt_state, metrics).

    Gradients flow ONLY to the LoRA factors (the paper's client step); grad
    accumulation over microbatches bounds activation memory at 340B scale.
    """
    opt = AdamW(weight_decay=weight_decay)
    scale = model.lora.scaling(lora_rank) if model.lora is not None else 1.0

    def loss_fn(lora, base, mb):
        params = merge_lora(base, lora)
        loss, metrics = model.train_loss(params, mb, lora_rank=lora_rank,
                                         lora_scale=scale)
        return loss, metrics["loss"]

    def train_step(lora, opt_state, base, batch, lr):
        if num_microbatches == 1:
            (loss, _), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(lora, base, batch)
        else:
            mbs = _split_microbatches(batch, num_microbatches)
            g0 = jax.tree.map(
                lambda p: None if p is None else jnp.zeros(p.shape, jnp.float32),
                lora, is_leaf=lambda x: x is None)

            def acc(carry, mb):
                g_acc, l_acc = carry
                (l, _), g = jax.value_and_grad(
                    loss_fn, has_aux=True)(lora, base, mb)
                g_acc = jax.tree.map(
                    lambda a, b: None if a is None else a + b.astype(jnp.float32),
                    g_acc, g, is_leaf=lambda x: x is None)
                return (g_acc, l_acc + l), None

            (grads, loss), _ = jax.lax.scan(acc, (g0, 0.0), mbs)
            inv = 1.0 / num_microbatches
            grads = jax.tree.map(
                lambda g: None if g is None else g * inv, grads,
                is_leaf=lambda x: x is None)
            loss = loss * inv
        new_lora, new_opt = opt.update(grads, opt_state, lora, lr)
        return new_lora, new_opt, {"loss": loss}

    return train_step, opt


def build_prefill_step(model: Model, lora_rank: int) -> Callable:
    def prefill_step(params, batch):
        logits, cache = model.prefill(params, batch, lora_rank=lora_rank)
        return logits, cache
    return prefill_step


def build_serve_step(model: Model, lora_rank: int) -> Callable:
    """One decode step; greedy next-token included so the step is closed."""
    def serve_step(params, batch, cache):
        logits, new_cache = model.decode_step(params, batch, cache,
                                              lora_rank=lora_rank)
        next_tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        return next_tok, new_cache
    return serve_step
