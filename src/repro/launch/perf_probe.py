import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""§Perf profiling probe: top collectives + top dots for one (arch, shape),
with optional iteration overrides. This is the dry-run profile equivalent.

  PYTHONPATH=src python -m repro.launch.perf_probe qwen2-7b train_4k
  PYTHONPATH=src python -m repro.launch.perf_probe deepseek-v2-236b train_4k \
      --moe-capacity 1.25
"""
import argparse
import collections
import sys

from repro.launch import hlo_walker as hw


def top_collectives(txt, n=12):
    comps = hw.parse_hlo(txt)
    entry = comps.pop("__entry_name__")
    comps.pop("__entry__")
    mult = collections.defaultdict(float)

    def visit(name, m):
        comp = comps.get(name)
        if comp is None:
            return
        mult[name] += m
        for callee, kind, trip in comp.calls:
            visit(callee, m * (trip if kind == "while" else 1))

    visit(entry, 1.0)
    rows = []
    for name, comp in comps.items():
        m = mult.get(name, 0)
        if not m:
            continue
        for op in comp.ops:
            base = op.opcode.replace("-start", "")
            if base in hw.COLLECTIVE_OPS and not op.opcode.endswith("-done"):
                rows.append((m * hw._bytes_of(op.result_type), m, base,
                             op.result_type[:64]))
    rows.sort(reverse=True)
    return rows[:n]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("arch")
    ap.add_argument("shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--strategy", default="2d")
    ap.add_argument("--residual-mode", default="feature")
    ap.add_argument("--moe-capacity", type=float, default=0.0)
    ap.add_argument("--microbatches", type=int, default=0)
    ap.add_argument("--repeat-kv", action="store_true")
    args = ap.parse_args(argv)

    from repro.launch import dryrun
    if args.microbatches:
        dryrun.MICROBATCHES[args.arch] = args.microbatches
    overrides = {}
    if args.strategy != "2d":
        overrides["strategy"] = args.strategy
    if args.residual_mode != "feature":
        overrides["residual_mode"] = args.residual_mode
    if args.moe_capacity:
        overrides["moe_capacity_factor"] = args.moe_capacity
    if args.repeat_kv:
        overrides["attn_repeat_kv"] = True
    lowered, compiled, meta = dryrun.lower_pair(
        args.arch, args.shape, multi_pod=args.multi_pod,
        model_overrides=overrides or None)
    txt = compiled.as_text()
    st = hw.analyze_hlo(txt)
    print(f"== {args.arch} x {args.shape} {meta.get('mesh')} "
          f"(overrides={overrides}, mb={meta.get('microbatches')}) ==")
    print(f"dot flops/dev: {st.dot_flops/1e12:.2f} TF   "
          f"hbm bytes/dev: {st.hbm_bytes/1e9:.1f} GB")
    for k, v in sorted(st.collective_bytes.items()):
        print(f"  {k:20s} {v/1e9:10.2f} GB/dev  x{st.collective_counts[k]:.0f}")
    print("-- top collectives (bytes x trips) --")
    for r in top_collectives(txt):
        print(f"  {r[0]/1e9:8.2f}GB x{r[1]:6.0f} {r[2]:18s} {r[3]}")
    print("-- top dots --")
    for r in hw.top_dots(txt, 8):
        print(f"  {r[0]/1e12:8.1f}TF x{r[1]:6.0f} {r[2]}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
