"""Roofline-term extraction from compiled dry-run artifacts.

  compute term    = HLO_FLOPs / (chips * peak_FLOPs)
  memory term     = HLO_bytes / (chips * HBM_bw)
  collective term = collective_bytes / (chips * link_bw)

FLOPs/bytes come from ``compiled.cost_analysis()``; collective bytes are NOT
reported there, so ``hlo_walker.analyze_hlo`` (the single source of truth
for HLO shape/collective accounting -- also behind ``analysis/hlo_lint``)
parses the optimized HLO text and sums the result-buffer sizes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
(ring algorithms move ~(n-1)/n of that on the wire; we report the buffer
total and note the approximation).

Hardware constants: TPU v5e -- 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
ICI (per the assignment).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link


@dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float
    hlo_bytes: float
    coll_bytes: float
    coll_breakdown: Dict[str, int]
    model_flops: float = 0.0
    per_device_mem: Optional[dict] = None
    xla_cost: Optional[dict] = None   # raw cost_analysis (while-bodies-once)

    @property
    def t_compute(self) -> float:
        return self.hlo_flops / (self.chips * PEAK_FLOPS)

    @property
    def t_memory(self) -> float:
        return self.hlo_bytes / (self.chips * HBM_BW)

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / (self.chips * ICI_BW)

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        return self.model_flops / self.hlo_flops if self.hlo_flops else 0.0

    def row(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "t_compute_s": self.t_compute, "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "model_flops": self.model_flops, "hlo_flops": self.hlo_flops,
            "useful_ratio": self.useful_flops_ratio,
            "coll_bytes": self.coll_bytes,
        }


def analyze_compiled(lowered, compiled, *, arch: str, shape: str, mesh_name: str,
                     chips: int, model_flops: float = 0.0) -> RooflineReport:
    from repro.launch.hlo_walker import analyze_hlo
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0] if cost else {}
    cost = cost or {}
    hlo = compiled.as_text()
    # trip-count-aware walker: XLA cost_analysis counts while bodies once
    # (scan under-reporting), so the roofline terms come from the walker.
    stats = analyze_hlo(hlo)
    # HLO is the per-device SPMD program -> totals = per-device * chips
    flops = stats.dot_flops * chips
    byts = stats.hbm_bytes * chips
    coll = {k: float(v) * chips for k, v in stats.collective_bytes.items()}
    counts = {k: float(v) for k, v in stats.collective_counts.items()}
    # TPU-corrected: CPU's bf16-matmul emulation inflates f32 collective
    # shares 2x (see hlo_walker.HLOStats) -- report the corrected total
    total_coll = float(stats.collective_bytes_tpu) * chips
    raw_cost = {"xla_flops": float(cost.get("flops", 0.0)),
                "xla_bytes": float(cost.get("bytes accessed", 0.0))}
    mem = None
    try:
        ma = compiled.memory_analysis()
        if ma is not None:
            mem = {
                "args_bytes": getattr(ma, "argument_size_in_bytes", None),
                "out_bytes": getattr(ma, "output_size_in_bytes", None),
                "temp_bytes": getattr(ma, "temp_size_in_bytes", None),
            }
    except Exception:
        pass
    return RooflineReport(arch=arch, shape=shape, mesh=mesh_name, chips=chips,
                          hlo_flops=flops, hlo_bytes=byts,
                          coll_bytes=total_coll,
                          coll_breakdown={**coll, "counts": counts},
                          model_flops=model_flops, per_device_mem=mem,
                          xla_cost=raw_cost)


def model_flops_estimate(cfg, shape) -> float:
    """MODEL_FLOPS = 6*N*D for training (fwd+bwd), 2*N*D for inference,
    with N = active params (MoE: routed top-k + shared only)."""
    n_active = active_params(cfg)
    if shape.mode == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.mode == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch


def active_params(cfg) -> float:
    """Active (per-token) parameter count: MoE experts count top_k/E."""
    total = cfg.num_params()
    if cfg.moe is None:
        return float(total)
    mo = cfg.moe
    from repro.configs.base import ACT_GEGLU, ACT_SWIGLU
    gated = cfg.activation in (ACT_GEGLU, ACT_SWIGLU)
    e_ff = mo.expert_d_ff or cfg.d_ff
    per_expert = cfg.d_model * e_ff * (3 if gated else 2)
    n_moe_layers = sum(1 for i in range(cfg.num_layers) if mo.is_moe_layer(i))
    inactive = (mo.num_experts - mo.top_k) * per_expert * n_moe_layers
    return float(total - inactive)
