"""Serving driver: batched greedy decoding with the fine-tuned adapters.

Demonstrates the inference side of the system -- prefill fills the KV/SSM
cache, then serve_step decodes token-by-token for a batch of requests.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-7b --tokens 32
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-7b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    from repro.configs import LoRAConfig, get_config
    from repro.launch.steps import build_prefill_step, build_serve_step
    from repro.models import build_model

    cfg = get_config(args.arch).reduced()
    if not cfg.supports_decode:
        print(f"{args.arch} is encoder-only; no decode path")
        return 1
    lora = LoRAConfig(rank_levels=(4, 8, 16))
    model = build_model(cfg, lora, dtype=jnp.float32, remat=False,
                        block_q=32, block_kv=32)
    key = jax.random.PRNGKey(args.seed)
    params = model.init(key)

    b, lp = args.batch, args.prompt_len
    prompts = jax.random.randint(key, (b, lp), 0, cfg.vocab_size)
    prefill = jax.jit(build_prefill_step(model, 16))
    serve = jax.jit(build_serve_step(model, 16))

    t0 = time.time()
    logits, layer_caches = prefill(params, {"tokens": prompts})
    max_len = lp + args.tokens

    def grow(x):
        if x.ndim >= 3 and x.shape[2] == lp:
            pw = [(0, 0)] * x.ndim
            pw[2] = (0, max_len - lp)
            return jnp.pad(x, pw)
        return x

    cache = {"layers": jax.tree.map(grow, layer_caches),
             "len": jnp.int32(lp)}
    tok = jnp.argmax(logits[:, -1:, :], axis=-1).astype(jnp.int32)
    t_prefill = time.time() - t0

    generated = [tok]
    t0 = time.time()
    for _ in range(args.tokens - 1):
        nxt, cache = serve(params, {"token": tok}, cache)
        tok = nxt[:, None]
        generated.append(tok)
    seqs = jnp.concatenate(generated, axis=1)
    t_decode = time.time() - t0
    print(f"arch={cfg.name} batch={b} prefill({lp} toks)={t_prefill:.2f}s "
          f"decode({args.tokens} toks)={t_decode:.2f}s "
          f"[{args.tokens * b / max(t_decode, 1e-9):.1f} tok/s]")
    for i in range(min(b, 2)):
        print(f"  req{i}: {seqs[i].tolist()}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
