"""Serving driver: multi-tenant batched greedy decoding with the
fine-tuned adapters (DESIGN.md §11).

Runs the serving subsystem end to end -- adapters are staged in an
``AdapterStore`` (paged, rank-bucketed, versioned) and a ``ServingEngine``
prefills the KV/SSM cache up front at full ``max_len`` via
``Model.init_cache`` (path-aware seeding; SSM ``conv``/``ssm`` states
transfer correctly), then decodes token-by-token.

The serving rank is DERIVED from the LoRA config (``r_max``) -- never
hardcoded -- so train-side rank-level changes cannot desync serving.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-7b --tokens 32
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-7b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--tenants", type=int, default=2,
                    help="number of adapter pages to serve across the batch")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    from repro.configs import LoRAConfig, get_config
    from repro.core.lora import split_lora
    from repro.models import build_model
    from repro.serving import AdapterStore, ServingEngine

    cfg = get_config(args.arch).reduced()
    if not cfg.supports_decode:
        print(f"{args.arch} is encoder-only; no decode path")
        return 1
    lora = LoRAConfig(rank_levels=(4, 8, 16))
    model = build_model(cfg, lora, dtype=jnp.float32, remat=False,
                        block_q=32, block_kv=32)
    # independent streams: params and prompts must never share a key
    key = jax.random.PRNGKey(args.seed)
    k_init, k_prompts, k_perturb = jax.random.split(key, 3)
    params = model.init(k_init)
    _, lora_tree = split_lora(params)

    # stage one tenant per rank level (cycled), highest level = the config's
    # serving rank r_max -- derived, never hardcoded
    store = AdapterStore(lora.rank_levels, scaling_fn=lora.scaling)
    levels = sorted(lora.rank_levels, reverse=True)
    for t in range(max(1, args.tenants)):
        perturb = jax.tree.map(
            lambda x: None if x is None
            else x + 0.01 * t * jnp.ones_like(x), lora_tree,
            is_leaf=lambda x: x is None)
        store.put(f"tenant{t}", perturb, levels[t % len(levels)])
    store.publish()

    b, lp = args.batch, args.prompt_len
    prompts = jax.random.randint(k_prompts, (b, lp), 0, cfg.vocab_size)
    engine = ServingEngine(model, params, store,
                           max_len=lp + args.tokens, slots=b)
    tenant_of = [f"tenant{i % max(1, args.tenants)}" for i in range(b)]

    t0 = time.time()   # host-clock: ok (CLI wall phase timing, off the round path)
    first = engine.admit(range(b), prompts, tenant_of)
    t_prefill = time.time() - t0   # host-clock: ok (CLI wall phase timing)

    generated = [np.asarray(first)]
    active = jnp.ones((b,), bool)
    t0 = time.time()   # host-clock: ok (CLI wall phase timing)
    for _ in range(args.tokens - 1):
        generated.append(np.asarray(engine.decode(active)))
    seqs = np.stack(generated, axis=1)
    t_decode = time.time() - t0   # host-clock: ok (CLI wall phase timing)
    print(f"arch={cfg.name} batch={b} tenants={store.published.num_pages} "
          f"ranks={store.published.ranks} adapter_v{store.published.version} "
          f"prefill({lp} toks)={t_prefill:.2f}s "
          f"decode({args.tokens} toks)={t_decode:.2f}s "
          f"[{args.tokens * b / max(t_decode, 1e-9):.1f} tok/s]")
    for i in range(min(b, 2)):
        print(f"  req{i} [{tenant_of[i]}]: {seqs[i].tolist()}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
