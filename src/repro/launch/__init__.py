"""Launchers: mesh construction, multi-pod dry-run, train/serve drivers.

NOTE: repro.launch.dryrun and repro.launch.fl_dryrun set
XLA_FLAGS=--xla_force_host_platform_device_count=512 at import time (before
jax initializes); import them only in dedicated processes.
"""
from repro.launch.mesh import make_host_mesh, make_production_mesh

__all__ = ["make_host_mesh", "make_production_mesh"]
