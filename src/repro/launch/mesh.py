"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state -- required because the dry-run must set
XLA_FLAGS before the first jax initialization.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (16, 16) over ("data", "model") = 256 chips (TPU v5e pod
    slice). Multi-pod: (2, 16, 16) over ("pod", "data", "model") = 512 chips,
    the pod axis crossing the DCN/ICI boundary."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_axis: int = 1):
    """Degenerate mesh over however many (CPU) devices exist -- lets the
    distributed code paths run in tests without the 512-device dry-run env."""
    n = jax.device_count()
    data = n // model_axis
    return jax.make_mesh((data, model_axis), ("data", "model"))


def make_fl_mesh(shards: int = 0):
    """1-D ``("data",)`` mesh for the sharded federated round engine.

    ``shards`` = 0 uses every visible device; a positive count takes the
    first ``shards`` devices, which lets benchmarks sweep shard counts under
    one forced ``--xla_force_host_platform_device_count`` process (tests use
    host-count=1 CPU meshes the same way). Built with ``jax.sharding.Mesh``
    directly because ``jax.make_mesh`` insists on consuming all devices.
    """
    import numpy as np
    from jax.sharding import Mesh
    devices = jax.devices()
    n = shards or len(devices)
    assert 1 <= n <= len(devices), (n, len(devices))
    return Mesh(np.asarray(devices[:n]), ("data",))
