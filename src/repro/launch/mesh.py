"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state -- required because the dry-run must set
XLA_FLAGS before the first jax initialization.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (16, 16) over ("data", "model") = 256 chips (TPU v5e pod
    slice). Multi-pod: (2, 16, 16) over ("pod", "data", "model") = 512 chips,
    the pod axis crossing the DCN/ICI boundary."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_axis: int = 1):
    """Degenerate mesh over however many (CPU) devices exist -- lets the
    distributed code paths run in tests without the 512-device dry-run env."""
    n = jax.device_count()
    data = n // model_axis
    return jax.make_mesh((data, model_axis), ("data", "model"))
