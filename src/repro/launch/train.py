"""Federated fine-tuning driver (the end-to-end trainer).

Runs heterogeneous-rank FedLoRA on the synthetic non-IID task with any of
the five aggregation methods over any architecture family (reduced configs
on CPU; the same code path scales to the production mesh via the sharding
hooks in Model).

  PYTHONPATH=src python -m repro.launch.train --method raflora --rounds 20
  PYTHONPATH=src python -m repro.launch.train --method flexlora --rounds 20 \
      --noniid dirichlet --alpha 0.1
"""
from __future__ import annotations

import argparse
import json
import sys
import time


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--method", default="raflora",
                    choices=["fedavg", "hetlora", "flora", "flexlora",
                             "raflora"])
    ap.add_argument("--rounds", type=int, default=20)
    ap.add_argument("--clients", type=int, default=20)
    ap.add_argument("--participation", type=float, default=0.25)
    ap.add_argument("--noniid", default="pathological",
                    choices=["iid", "dirichlet", "pathological"])
    ap.add_argument("--alpha", type=float, default=1.0)
    ap.add_argument("--rank-levels", default="4,8,16,24,32")
    ap.add_argument("--backend", default="factored",
                    choices=["dense", "factored", "kernel"])
    ap.add_argument("--eval-every", type=int, default=5)
    ap.add_argument("--checkpoint", default=None)
    ap.add_argument("--out", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    from repro.federation.experiment import build_experiment
    levels = tuple(int(r) for r in args.rank_levels.split(","))
    exp = build_experiment(
        args.method,
        fl_overrides={"num_rounds": args.rounds, "num_clients": args.clients,
                      "participation": args.participation,
                      "partition": args.noniid,
                      "dirichlet_alpha": args.alpha, "seed": args.seed},
        lora_overrides={"rank_levels": levels,
                        "rank_probs": tuple([1 / len(levels)] * len(levels))},
        backend=args.backend)

    log = []
    t0 = time.time()
    for r in range(args.rounds):
        stats = exp.server.run_round()
        row = {"round": r, "loss": stats.mean_client_loss,
               "higher_rank_energy": float(
                   exp.server.energy.higher_rank_ratio[-1]),
               "lr": stats.lr, "wall_s": stats.wall_time_s}
        if (r + 1) % args.eval_every == 0 or r == args.rounds - 1:
            row["test_accuracy"] = exp.eval_accuracy()
        log.append(row)
        msg = (f"round {r:3d} loss={row['loss']:.4f} "
               f"1-rho={row['higher_rank_energy']:.3f}")
        if "test_accuracy" in row:
            msg += f" acc={row['test_accuracy']:.3f}"
        print(msg, flush=True)
    print(f"done in {time.time() - t0:.1f}s; "
          f"final acc={log[-1].get('test_accuracy'):.3f}")
    if args.checkpoint:
        exp.server.save(args.checkpoint)
        print(f"checkpoint -> {args.checkpoint}")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(log, f, indent=1)
    return 0


if __name__ == "__main__":
    sys.exit(main())
