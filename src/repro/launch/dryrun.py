import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: prove every (architecture x input-shape x mesh)
combination lowers, compiles, and fits -- with no real hardware.

For each pair this driver builds the appropriate step (client train step for
train_4k, prefill for prefill_32k, serve for decode shapes), attaches
NamedShardings to ShapeDtypeStruct stand-ins, runs .lower().compile() on the
16x16 production mesh (and the 2x16x16 multi-pod mesh with --multi-pod), and
extracts memory_analysis / cost_analysis + the HLO collective schedule for
EXPERIMENTS.md §Dry-run and §Roofline.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma-2b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] --out results.json
"""
import argparse
import json
import sys
import time
import traceback
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs import (ASSIGNED_ARCHS, INPUT_SHAPES, LoRAConfig,
                           get_config)
from repro.core.lora import split_lora
from repro.launch.hlo_analysis import (analyze_compiled, model_flops_estimate)
from repro.launch.inputs import input_specs
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import (build_prefill_step, build_serve_step,
                                build_train_step)
from repro.models.transformer import Model
from repro.optim import AdamW
from repro.sharding import (batch_axes, batch_specs, cache_specs, param_specs,
                            residual_spec)

LORA = LoRAConfig()  # paper defaults: ranks {8..64}

# Per-(arch) dry-run tuning: microbatch counts keep saved activations within
# v5e HBM; values derived from the napkin math in EXPERIMENTS.md §Dry-run.
MICROBATCHES = {
    "nemotron-4-340b": 16,
    "deepseek-v2-236b": 8,
    "llama4-maverick-400b-a17b": 8,
    "qwen2-vl-7b": 4,
    "qwen2-7b": 4,
    "granite-3-8b": 4,
    "hubert-xlarge": 2,
    "gemma-2b": 2,
    "hymba-1.5b": 2,
    "mamba2-1.3b": 2,
}

# long_500k needs sub-quadratic decode: SSM/hybrid run natively; attention
# archs run their sliding-window variant (window 8192, ring KV cache).
LONG_CTX_WINDOW = 8192


def plan(arch: str, shape_name: str):
    """Resolve (config, skip_reason) for a pair."""
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    if shape.mode == "decode" and not cfg.supports_decode:
        return None, f"{arch} is encoder-only: no decode step exists"
    if shape_name == "long_500k" and cfg.kind not in ("ssm", "hybrid"):
        # attention archs: sliding-window variant (noted in DESIGN.md)
        cfg = cfg.with_sliding_window(LONG_CTX_WINDOW, global_every=0)
    return (cfg, shape), None


def build_model_for(cfg, mesh, use_kernels: bool = False,
                    shard_residuals: bool = True, mode: str = "train",
                    global_batch: int = 0, strategy: str = "2d",
                    residual_mode: str = "feature",
                    moe_capacity_factor: float = 0.0,
                    attn_repeat_kv: bool = False,
                    bf16_scores: bool = False) -> Model:
    """strategy: "2d" = FSDP x TP baseline; "dp" = DP-dominant (small
    models, §Perf iteration C). residual_mode: "feature"|"sequence" (§Perf B).
    moe_capacity_factor > 0: capacity-grouped EP dispatch (§Perf A)."""
    baxes = batch_axes(mesh)
    if strategy == "dp":
        baxes = baxes + ("model",)
    res_shard = None
    if shard_residuals and strategy != "dp":
        res_shard = NamedSharding(mesh, residual_spec(mesh, residual_mode))
    # vocab-sharded logits only when the vocab divides the axis (constraint
    # on a padded dim trips an XLA SPMD dynamic-slice verifier bug)
    logit_shard = None
    if strategy != "dp" and cfg.vocab_size % mesh.shape["model"] == 0:
        if residual_mode == "sequence":
            logit_shard = NamedSharding(mesh, P(baxes, "model", None))
        else:
            logit_shard = NamedSharding(mesh, P(baxes, None, "model"))
    q_shard = None
    if strategy != "dp":
        q_shard = NamedSharding(mesh, P(baxes, None, "model"))
    # expert-parallel shard_map needs the batch to split over the data axes;
    # decode batches (<= 128, or 1 at long_500k) fall back to the GSPMD path
    batch_div = 1
    for a in baxes:
        batch_div *= mesh.shape[a]
    use_ep = (cfg.moe is not None and mode in ("train", "prefill")
              and strategy != "dp"
              and (global_batch == 0 or global_batch % batch_div == 0))
    return Model(
        cfg, LORA, dtype=jnp.bfloat16, remat=True, use_kernels=use_kernels,
        block_q=512, block_kv=1024,
        moe_impl="ep" if use_ep else "tp",
        mesh=mesh, batch_axes=baxes,
        residual_sharding=res_shard, logits_sharding=logit_shard,
        attn_q_sharding=q_shard, moe_capacity_factor=moe_capacity_factor,
        attn_repeat_kv=attn_repeat_kv, bf16_scores=bf16_scores)


def _with_sharding(shapes_tree, specs_tree, mesh):
    return jax.tree.map(
        lambda s, spec: jax.ShapeDtypeStruct(
            s.shape, s.dtype, sharding=NamedSharding(mesh, spec)),
        shapes_tree, specs_tree)


def lower_pair(arch: str, shape_name: str, *, multi_pod: bool = False,
               model_overrides: Optional[dict] = None,
               donate: bool = True):
    """Lower + compile one pair; returns (lowered, compiled, meta)."""
    planned, skip = plan(arch, shape_name)
    if skip:
        return None, None, {"skipped": skip}
    cfg, shape = planned
    mesh = make_production_mesh(multi_pod=multi_pod)
    overrides = dict(model_overrides or {})
    strategy = overrides.get("strategy", "2d")
    model = build_model_for(cfg, mesh, mode=shape.mode,
                            global_batch=shape.global_batch, **overrides)
    if strategy == "dp":
        from repro.sharding.specs import dp_param_specs
        pspecs = dp_param_specs(model, mesh)
    else:
        pspecs = param_specs(model, mesh)
    pshapes = model.param_shapes()
    params_sds = _with_sharding(pshapes, pspecs, mesh)
    binputs = input_specs(cfg, shape, dtype=jnp.bfloat16)
    bspecs = batch_specs(model, binputs, mesh)
    batch_sds = {k: jax.ShapeDtypeStruct(
        v.shape, v.dtype, sharding=NamedSharding(mesh, bspecs[k]))
        for k, v in binputs.items()}
    rank = LORA.r_max

    if shape.mode == "train":
        mb = MICROBATCHES.get(arch, 1)
        step, opt = build_train_step(model, rank, num_microbatches=mb)
        base_sds, lora_sds = split_lora(params_sds)
        mu_sds = jax.tree.map(
            lambda s: None if s is None else jax.ShapeDtypeStruct(
                s.shape, jnp.float32, sharding=s.sharding),
            lora_sds, is_leaf=lambda x: x is None)
        opt_sds = type(opt.init(jnp.zeros(0)))(
            jax.ShapeDtypeStruct((), jnp.int32), mu_sds, mu_sds) \
            if False else None
        # AdamWState is a NamedTuple; construct directly
        from repro.optim.adamw import AdamWState
        opt_sds = AdamWState(jax.ShapeDtypeStruct((), jnp.int32), mu_sds,
                             mu_sds)
        lr_sds = jax.ShapeDtypeStruct((), jnp.float32)
        fn = jax.jit(step, donate_argnums=(0, 1) if donate else ())
        lowered = fn.lower(lora_sds, opt_sds, base_sds, batch_sds, lr_sds)
        meta = {"step": "train_step", "microbatches": mb}
    elif shape.mode == "prefill":
        step = build_prefill_step(model, rank)
        fn = jax.jit(step)
        lowered = fn.lower(params_sds, batch_sds)
        meta = {"step": "prefill_step"}
    else:  # decode
        step = build_serve_step(model, rank)
        cshapes = model.cache_shapes(shape.global_batch, shape.seq_len)
        cspecs = cache_specs(model, cshapes, mesh)
        cache_sds = _with_sharding(cshapes, cspecs, mesh)
        fn = jax.jit(step, donate_argnums=(2,) if donate else ())
        lowered = fn.lower(params_sds, batch_sds, cache_sds)
        meta = {"step": "serve_step",
                "cache_seq": model.cache_seq_len(shape.seq_len)}

    t0 = time.time()
    compiled = lowered.compile()
    meta.update(compile_s=time.time() - t0, cfg_name=cfg.name,
                mesh="2x16x16" if multi_pod else "16x16",
                chips=512 if multi_pod else 256)
    return lowered, compiled, meta


def run_pair(arch: str, shape_name: str, *, multi_pod: bool = False,
             model_overrides: Optional[dict] = None) -> dict:
    try:
        lowered, compiled, meta = lower_pair(arch, shape_name,
                                             multi_pod=multi_pod,
                                             model_overrides=model_overrides)
    except Exception as e:  # a failure here is a bug in the system
        return {"arch": arch, "shape": shape_name,
                "mesh": "2x16x16" if multi_pod else "16x16",
                "status": "FAIL", "error": f"{type(e).__name__}: {e}",
                "trace": traceback.format_exc()[-2000:]}
    if lowered is None:
        return {"arch": arch, "shape": shape_name,
                "mesh": "2x16x16" if multi_pod else "16x16",
                "status": "SKIP", "reason": meta["skipped"]}
    planned, _ = plan(arch, shape_name)
    cfg, shape = planned
    report = analyze_compiled(
        lowered, compiled, arch=arch, shape=shape_name,
        mesh_name=meta["mesh"], chips=meta["chips"],
        model_flops=model_flops_estimate(cfg, shape))
    row = {"arch": arch, "shape": shape_name, "status": "OK", **meta,
           **report.row(),
           "coll_breakdown": {k: v for k, v in
                              report.coll_breakdown.items()},
           "per_device_mem": report.per_device_mem}
    return row


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true",
                    help="use the 2x16x16 512-chip mesh")
    ap.add_argument("--out", default=None)
    # §Perf iteration knobs
    ap.add_argument("--strategy", default="2d", choices=["2d", "dp"])
    ap.add_argument("--residual-mode", default="feature",
                    choices=["feature", "sequence"])
    ap.add_argument("--moe-capacity", type=float, default=0.0)
    ap.add_argument("--repeat-kv", action="store_true")
    ap.add_argument("--microbatches", type=int, default=0,
                    help="override MICROBATCHES for the selected arch(s)")
    args = ap.parse_args(argv)
    if args.microbatches:
        for a in list(MICROBATCHES):
            MICROBATCHES[a] = args.microbatches
        if args.arch:
            MICROBATCHES[args.arch] = args.microbatches
    overrides = {}
    if args.repeat_kv:
        overrides["attn_repeat_kv"] = True
    if args.strategy != "2d":
        overrides["strategy"] = args.strategy
    if args.residual_mode != "feature":
        overrides["residual_mode"] = args.residual_mode
    if args.moe_capacity:
        overrides["moe_capacity_factor"] = args.moe_capacity

    pairs = []
    archs = list(ASSIGNED_ARCHS) if (args.all or args.arch is None) \
        else [args.arch]
    shapes = list(INPUT_SHAPES) if (args.all or args.shape is None) \
        else [args.shape]
    for a in archs:
        for s in shapes:
            pairs.append((a, s))

    rows = []
    failures = 0
    for a, s in pairs:
        t0 = time.time()
        row = run_pair(a, s, multi_pod=args.multi_pod,
                       model_overrides=overrides or None)
        rows.append(row)
        status = row["status"]
        extra = ""
        if status == "OK":
            extra = (f"compile={row['compile_s']:.1f}s "
                     f"bottleneck={row['bottleneck']} "
                     f"tc={row['t_compute_s']*1e3:.2f}ms "
                     f"tm={row['t_memory_s']*1e3:.2f}ms "
                     f"tx={row['t_collective_s']*1e3:.2f}ms")
        elif status == "SKIP":
            extra = row["reason"]
        else:
            failures += 1
            extra = row["error"]
        print(f"[{status}] {a} x {s} ({row['mesh']}) {extra}", flush=True)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(rows, f, indent=1, default=str)
        print(f"wrote {args.out}")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
