"""ShapeDtypeStruct stand-ins for every model input -- the dry-run feeds
these to jit(...).lower() so nothing is ever allocated."""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig


def input_specs(cfg: ModelConfig, shape: ShapeConfig, *,
                dtype=jnp.bfloat16) -> Dict[str, jax.ShapeDtypeStruct]:
    """Inputs for one (architecture, input-shape) pair.

    train/prefill: full-sequence batch; decode: one token (the KV cache is
    produced separately by Model.cache_shapes). Frontend archs receive
    precomputed embeddings per the assignment's modality-stub carve-out.
    """
    b, l = shape.global_batch, shape.seq_len
    i32 = jnp.int32

    if shape.mode == "decode":
        return {"token": jax.ShapeDtypeStruct((b, 1), i32)}

    batch: Dict[str, jax.ShapeDtypeStruct] = {}
    if cfg.frontend.kind == "audio":
        # the conv codec is stubbed: frames arrive as embeddings
        batch["embeds"] = jax.ShapeDtypeStruct((b, l, cfg.frontend.embed_dim),
                                               dtype)
        if shape.mode == "train":
            batch["targets"] = jax.ShapeDtypeStruct((b, l), i32)
        return batch
    if cfg.frontend.kind == "vision":
        p = cfg.frontend.tokens_per_item
        batch["embeds"] = jax.ShapeDtypeStruct((b, p, cfg.frontend.embed_dim),
                                               dtype)
        batch["tokens"] = jax.ShapeDtypeStruct((b, l - p), i32)
        if cfg.rope_type == "mrope":
            batch["positions"] = jax.ShapeDtypeStruct((3, b, l), i32)
        if shape.mode == "train":
            batch["targets"] = jax.ShapeDtypeStruct((b, l), i32)
            batch["loss_mask"] = jax.ShapeDtypeStruct((b, l), jnp.float32)
        return batch

    batch["tokens"] = jax.ShapeDtypeStruct((b, l), i32)
    if shape.mode == "train":
        batch["targets"] = jax.ShapeDtypeStruct((b, l), i32)
    return batch
