"""Trip-count-aware HLO analysis.

XLA's built-in cost_analysis counts each ``while`` body ONCE, so scanned
layer stacks / microbatch loops / chunk scans under-report flops and
collective bytes by their trip counts (verified: scan-of-10-matmuls reports
1/10 of the unrolled flops). This walker parses the optimized HLO text,
builds the computation call graph (while bodies x known_trip_count, fusions,
calls), and accumulates

  * dot flops         (2 * result_elems * contracted_elems)
  * collective bytes  (result-buffer bytes of all-gather / all-reduce /
                       reduce-scatter / all-to-all / collective-permute)
  * hbm bytes proxy   (result bytes of non-fusion-internal ops, x2 for
                       write+read; fusion bodies are virtual and excluded)

each multiplied by the product of enclosing loop trip counts.
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")

_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->\s*\S.*\{$")
_OP_LINE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.+)$")
_SHAPE = re.compile(r"(\w+)\[([\d,]*)\]")
_TRIP = re.compile(r'known_trip_count[^\d]*(\d+)')
_CALLSITE = re.compile(
    r"(?:body|condition|to_apply|calls|true_computation|"
    r"false_computation)=%?([\w\.\-]+)")
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")
_BRANCH_NAME = re.compile(r"%?([\w\.\-]+)")


def callee_names(rest: str) -> List[str]:
    """Every computation referenced by an op line's attributes: scalar
    callsites (``body=`` / ``to_apply=`` / ...) plus ``conditional``
    branch lists (``branch_computations={%a, %b}``, which single-name
    regexes miss -- the bug that hid Pallas grid-loop dots from the flop
    count at large shapes)."""
    names = [m.group(1) for m in _CALLSITE.finditer(rest)]
    for bl in _BRANCHES.finditer(rest):
        names.extend(m.group(1) for m in _BRANCH_NAME.finditer(bl.group(1)))
    return names


def _shape_list(type_str: str) -> List[Tuple[str, int]]:
    """All (dtype, elems) arrays in a type string (handles tuples)."""
    out = []
    for m in _SHAPE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        out.append((dt, n))
    return out


def _bytes_of(type_str: str) -> int:
    return sum(_DTYPE_BYTES[dt] * n for dt, n in _shape_list(type_str))


@dataclass
class OpInfo:
    name: str
    result_type: str
    opcode: str
    rest: str


@dataclass
class Computation:
    name: str
    ops: List[OpInfo] = field(default_factory=list)
    # (callee, kind, trip) -- trip applies to while bodies/conds
    calls: List[Tuple[str, str, int]] = field(default_factory=list)
    symbol_types: Dict[str, str] = field(default_factory=dict)


_KNOWN_OPCODES = None


def parse_hlo(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    current: Optional[Computation] = None
    entry_name = None
    for raw in text.splitlines():
        line = raw.rstrip()
        stripped = line.strip()
        if not stripped:
            continue
        hdr = _COMP_HDR.match(stripped)
        if hdr and stripped.endswith("{"):
            current = Computation(name=hdr.group(2))
            comps[current.name] = current
            if hdr.group(1):
                entry_name = current.name
            continue
        if stripped == "}" or current is None:
            continue
        m = _OP_LINE.match(stripped)
        if not m:
            continue
        name, rhs = m.group(1), m.group(2)
        # result type = prefix of rhs up to the opcode token
        # find opcode: first bare word followed by '(' after the type
        om = re.search(r"([a-z][\w\-]*)\(", rhs)
        if not om:
            continue
        opcode = om.group(1)
        result_type = rhs[:om.start()].strip()
        rest = rhs[om.start():]
        op = OpInfo(name=name, result_type=result_type, opcode=opcode,
                    rest=rest)
        current.ops.append(op)
        current.symbol_types[name] = result_type
        if opcode == "while":
            trip = 1
            tm = _TRIP.search(rhs)
            if tm:
                trip = int(tm.group(1))
            for callee in callee_names(rhs):
                current.calls.append((callee, "while", trip))
        elif opcode in ("fusion", "call", "conditional", "custom-call",
                        "reduce", "sort", "map", "scatter", "select-and-scatter",
                        "reduce-window"):
            for callee in callee_names(rhs):
                current.calls.append((callee, opcode, 1))
    comps["__entry__"] = comps.get(entry_name, Computation("none"))
    comps["__entry_name__"] = entry_name  # type: ignore
    return comps


@dataclass
class HLOStats:
    dot_flops: float = 0.0
    collective_bytes: Dict[str, float] = field(
        default_factory=lambda: defaultdict(float))
    collective_counts: Dict[str, float] = field(
        default_factory=lambda: defaultdict(float))
    # f32-dtype share of collective bytes. XLA:CPU emulates bf16 matmuls as
    # convert->f32 dot->convert, and SPMD often reshards the f32 side, so a
    # bf16 model's activation collectives appear at 2x TPU bytes. The
    # "tpu-corrected" total halves the f32 share (real TPUs move bf16).
    collective_bytes_f32: float = 0.0
    hbm_bytes: float = 0.0
    while_trips: Dict[str, int] = field(default_factory=dict)

    @property
    def total_collective_bytes(self) -> float:
        return float(sum(self.collective_bytes.values()))

    @property
    def collective_bytes_tpu(self) -> float:
        """TPU estimate: f32 collective traffic of a bf16 program halves."""
        total = self.total_collective_bytes
        return total - 0.5 * self.collective_bytes_f32


def _dot_flops(op: OpInfo, comp: Computation) -> float:
    """2 * result_elems * prod(contracting dims of lhs)."""
    shapes = _shape_list(op.result_type)
    if not shapes:
        return 0.0
    result_elems = sum(n for _, n in shapes)
    cm = re.search(r"lhs_contracting_dims={([\d,]*)}", op.rest)
    k = 1
    if cm:
        paren = op.rest[op.rest.find("(") + 1:op.rest.find(")")]
        # lhs shape: newer XLA prints operand types inline
        # (``dot(f32[m,k]{1,0} %x, ...)``); older prints only names, which
        # we resolve through the computation's symbol table.
        lhs_shape = _SHAPE.search(paren)
        if lhs_shape is None:
            nm = re.match(r"\s*%?([\w\.\-]+)", paren)
            if nm:
                lhs_shape = _SHAPE.search(
                    comp.symbol_types.get(nm.group(1), ""))
        if lhs_shape:
            dims = [int(d) for d in lhs_shape.group(2).split(",") if d]
            for ci in cm.group(1).split(","):
                if ci and int(ci) < len(dims):
                    k *= dims[int(ci)]
    return 2.0 * result_elems * k


def analyze_hlo(text: str) -> HLOStats:
    comps = parse_hlo(text)
    entry = comps.pop("__entry_name__", None)
    comps.pop("__entry__", None)
    stats = HLOStats()

    # computations reached ONLY via fusion are virtual (no HBM traffic of
    # their internal ops); track reachable multipliers
    mult: Dict[str, float] = defaultdict(float)
    fusion_only: Dict[str, bool] = {}

    def visit(name: str, m: float, via_fusion: bool):
        comp = comps.get(name)
        if comp is None:
            return
        mult[name] += m
        if name in fusion_only:
            fusion_only[name] = fusion_only[name] and via_fusion
        else:
            fusion_only[name] = via_fusion
        for callee, kind, trip in comp.calls:
            child_m = m * (trip if kind == "while" else 1)
            visit(callee, child_m, via_fusion or kind == "fusion")

    if entry:
        visit(entry, 1.0, False)

    for name, comp in comps.items():
        m = mult.get(name, 0.0)
        if m == 0.0:
            continue
        is_virtual = fusion_only.get(name, False)
        for op in comp.ops:
            if op.opcode == "dot":
                stats.dot_flops += m * _dot_flops(op, comp)
            if op.opcode.startswith(COLLECTIVE_OPS) or any(
                    op.opcode == c or op.opcode == c + "-start"
                    for c in COLLECTIVE_OPS):
                base = op.opcode.replace("-start", "")
                if base in COLLECTIVE_OPS and not op.opcode.endswith("-done"):
                    b = _bytes_of(op.result_type)
                    stats.collective_bytes[base] += m * b
                    stats.collective_counts[base] += m
                    f32b = sum(_DTYPE_BYTES[dt] * n for dt, n in
                               _shape_list(op.result_type) if dt == "f32")
                    stats.collective_bytes_f32 += m * f32b
            # HBM proxy, TPU-fusion-aware: on TPU, elementwise chains fuse
            # into the producing dot/collective, so we count only ops that
            # necessarily touch HBM: dots (read lhs+rhs, write out), data
            # movement (gather/scatter/DUS/copy/transpose/reshape of big
            # buffers), and collectives (counted via collective_bytes).
            if op.opcode == "dot":  # dots touch HBM even when fused
                operands = re.findall(
                    r"\(?%([\w\.\-]+)", op.rest[:op.rest.find(")")])
                op_bytes = sum(_bytes_of(comp.symbol_types.get(o, ""))
                               for o in operands[:2])
                stats.hbm_bytes += m * (op_bytes + _bytes_of(op.result_type))
            elif not is_virtual and op.opcode in (
                    "gather", "scatter", "dynamic-slice",
                    "dynamic-update-slice", "copy", "transpose", "reshape",
                    "concatenate", "pad", "slice"):
                stats.hbm_bytes += 2.0 * m * _bytes_of(op.result_type)
        for op in comp.ops:
            if op.opcode == "while":
                tm = _TRIP.search(op.rest)
                if tm:
                    stats.while_trips[op.name] = int(tm.group(1))
    return stats


def top_dots(text: str, n: int = 15):
    """The n most expensive dot ops (flops x trip multiplier) -- the
    profile-equivalent view for §Perf iteration on the dry-run."""
    comps = parse_hlo(text)
    entry = comps.pop("__entry_name__", None)
    comps.pop("__entry__", None)
    mult: Dict[str, float] = defaultdict(float)

    def visit(name, m):
        comp = comps.get(name)
        if comp is None:
            return
        mult[name] += m
        for callee, kind, trip in comp.calls:
            visit(callee, m * (trip if kind == "while" else 1))

    if entry:
        visit(entry, 1.0)
    rows = []
    for name, comp in comps.items():
        m = mult.get(name, 0.0)
        if not m:
            continue
        for op in comp.ops:
            if op.opcode == "dot":
                f = _dot_flops(op, comp)
                rows.append((m * f, m, op.result_type[:48],
                             op.rest[:100]))
    rows.sort(reverse=True)
    return rows[:n]
