import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Dry-run of the PAPER'S OWN step: rank-partitioned aggregation as a
distributed program on the production mesh.

Client factor stacks are sharded over the data axes (each data shard holds
its resident clients' uploads); the weighted-diagonal contraction
sum_k B_k diag(omega_k) A_k lowers to per-shard partial matmuls + one
all-reduce -- i.e. Algorithm 1 lines 6-10 become ICI collectives instead of
a parameter-server gather. Both the dense (paper-faithful) and factored
QR-SVD (beyond-paper) reallocation paths are lowered and compared; this is
the roofline evidence for the §Perf "never materialize dW" iteration.

  PYTHONPATH=src python -m repro.launch.fl_dryrun [--multi-pod] \
      [--d 4096] [--n 4096] [--clients 64]
"""
import argparse
import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.core.svd import (dense_from_weighted, factored_from_weighted,
                            svd_realloc_dense, svd_realloc_factored)
from repro.launch.hlo_analysis import analyze_compiled
from repro.launch.mesh import make_production_mesh
from repro.sharding.specs import batch_axes


def aggregate_dense(bs, as_, omega, r_max):
    dw = dense_from_weighted(bs, as_, omega)
    return svd_realloc_dense(dw, r_max)


def aggregate_factored(bs, as_, omega, r_max):
    u_c, v_c = factored_from_weighted(bs, as_, omega)
    return svd_realloc_factored(u_c, v_c, r_max)


def lower_aggregation(*, d: int, n: int, clients: int, r_max: int,
                      multi_pod: bool, backend: str):
    mesh = make_production_mesh(multi_pod=multi_pod)
    baxes = batch_axes(mesh)
    from repro.sharding.specs import sanitize_spec
    sh = lambda spec, shape: NamedSharding(
        mesh, sanitize_spec(spec, shape, mesh, rescue=False))
    bs = jax.ShapeDtypeStruct(
        (clients, d, r_max), jnp.float32,
        sharding=sh(P(baxes, None, None), (clients, d, r_max)))
    as_ = jax.ShapeDtypeStruct(
        (clients, r_max, n), jnp.float32,
        sharding=sh(P(baxes, None, None), (clients, r_max, n)))
    omega = jax.ShapeDtypeStruct(
        (clients, r_max), jnp.float32,
        sharding=sh(P(baxes, None), (clients, r_max)))
    fn = aggregate_dense if backend == "dense" else aggregate_factored
    lowered = jax.jit(fn, static_argnums=(3,)).lower(bs, as_, omega, r_max)
    return lowered, lowered.compile(), mesh


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--d", type=int, default=4096)
    ap.add_argument("--n", type=int, default=4096)
    ap.add_argument("--clients", type=int, default=64)
    ap.add_argument("--r-max", type=int, default=64)
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args(argv)

    chips = 512 if args.multi_pod else 256
    for backend in ("dense", "factored"):
        lowered, compiled, mesh = lower_aggregation(
            d=args.d, n=args.n, clients=args.clients, r_max=args.r_max,
            multi_pod=args.multi_pod, backend=backend)
        rep = analyze_compiled(
            lowered, compiled, arch=f"fl-agg-{backend}",
            shape=f"d{args.d}xn{args.n}xM{args.clients}",
            mesh_name="2x16x16" if args.multi_pod else "16x16", chips=chips)
        print(f"[OK] fl-aggregation backend={backend:9s} "
              f"tc={rep.t_compute*1e6:9.2f}us tm={rep.t_memory*1e6:9.2f}us "
              f"tx={rep.t_collective*1e6:9.2f}us "
              f"coll={rep.coll_bytes/1e6:8.1f}MB flops={rep.hlo_flops/1e9:9.2f}GF")
    return 0


if __name__ == "__main__":
    sys.exit(main())
