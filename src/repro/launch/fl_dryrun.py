import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Dry-run of the LIVE sharded round engine's aggregation program on the
production mesh.

This used to lower a standalone demo of the rank-partitioned contraction;
it now lowers ``core/aggregation.py::sharded_grouped_fn`` -- the exact
jitted shard_map program the ``round_engine="sharded"`` server executes per
bucket per round -- so the roofline numbers describe the shipping code
path. Client factor stacks are sharded over the ``data`` axis (each shard
holds its round-robin resident clients' uploads); the weighted-diagonal
contraction sum_k B_k diag(omega_k) A_k lowers to per-shard partial
matmuls + one ``jax.lax.psum`` -- i.e. Algorithm 1 lines 6-10 become ICI
collectives instead of a parameter-server gather. Both the dense
(paper-faithful (d, n) all-reduce) and factored ((d+n, R) stack all-reduce)
paths are lowered and compared; this is the roofline evidence for the
§Perf "never materialize dW" iteration.

  PYTHONPATH=src python -m repro.launch.fl_dryrun [--multi-pod] \
      [--d 4096] [--n 4096] [--clients 64] [--pipeline-depth 1]

``--pipeline-depth D`` lowers the ASYNC engine's buffered aggregation
instead: one aggregation consuming D buffered rounds is the SAME
``sharded_grouped_fn`` program with a D-times-larger client axis (the
staleness discounts are omega DATA, not program structure), so the dry run
shows exactly how the collective bytes and FLOPs of a buffered step scale
with depth -- dense stays a (d, n) all-reduce regardless of D; the factored
stack widens to R = D*M*r_max.

``--trigger {count,timeout,staleness}`` lowers the EVENT-DRIVEN engine's
buffered step instead (DESIGN.md §7): the event scheduler is SIMULATED on
the host (virtual clock + straggler-tail latency, ``--straggler-fraction``)
to obtain the trigger's actual fire-time cohort sizes, and the same
``sharded_grouped_fn`` program is lowered at the p50 and p95 cohort
(padded to the mesh's data-axis multiple, exactly like the live engine's
ghost clients) -- i.e. the program the production mesh would run at a
typical and at a tail firing. Staleness discounts and the ``present`` mask
are omega DATA, so trigger choice changes the CLIENT-AXIS SIZE
distribution, which is what the tx/coll columns quantify.
"""
import argparse
import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.analysis.hlo_lint import collective_parity
from repro.core.aggregation import sharded_grouped_fn
from repro.launch.hlo_analysis import analyze_compiled
from repro.launch.mesh import make_production_mesh
from repro.sharding.specs import batch_axes, client_spec


def check_kernel_parity(texts: dict, tag: str) -> int:
    """kernel == factored collective parity via the analysis rule (one
    source of truth: ``analysis/hlo_lint.collective_parity``). The fused
    Pallas path changes per-shard compute, never the collective -- any
    divergence is a lowering regression. Returns the number of findings."""
    findings = collective_parity(
        texts["factored"], texts["kernel"], label_a="factored",
        label_b="kernel", program=f"fl_dryrun/{tag}")
    for f in findings:
        print(f"[PARITY FAIL] {f}")
    if not findings:
        print(f"[OK] fl-parity {tag}: kernel == factored collective "
              "bytes/counts")
    return len(findings)


def lower_aggregation(*, d: int, n: int, clients: int, r_max: int,
                      multi_pod: bool, backend: str,
                      transport: str = "none"):
    """Lower the live sharded-bucket pipeline for one single-adapter bucket
    (one client group, no Eq. 8 fallback active this round). Clients shard
    over ALL batch axes -- ("pod", "data") in multi-pod -- so the pod axis
    shares the reduction instead of replicating it.

    ``transport`` != "none" lowers the QUANTIZED collective (DESIGN.md
    §12): client uploads arrive as transport ``QuantFactor`` payloads
    (int8/bf16 + f32 per-column scales) and the program all-reduces the
    compressed bytes, dequantizing once after the psum."""
    mesh = make_production_mesh(multi_pod=multi_pod)
    baxes = batch_axes(mesh)
    cl = NamedSharding(mesh, client_spec(baxes))
    if transport != "none":
        from repro.federation.transport import QuantFactor
        pay = jnp.int8 if transport == "int8" else jnp.bfloat16
        bs = QuantFactor(
            jax.ShapeDtypeStruct((clients, d, r_max), pay, sharding=cl),
            jax.ShapeDtypeStruct((clients, 1, r_max), jnp.float32,
                                 sharding=cl))
        as_ = QuantFactor(
            jax.ShapeDtypeStruct((clients, r_max, n), pay, sharding=cl),
            jax.ShapeDtypeStruct((clients, r_max, 1), jnp.float32,
                                 sharding=cl))
    else:
        bs = jax.ShapeDtypeStruct((clients, d, r_max), jnp.float32,
                                  sharding=cl)
        as_ = jax.ShapeDtypeStruct((clients, r_max, n), jnp.float32,
                                   sharding=cl)
    omega = jax.ShapeDtypeStruct((clients, r_max), jnp.float32, sharding=cl)
    fn = sharded_grouped_fn(mesh, r_max, backend, "raflora", axes=baxes)
    lowered = fn.lower(((bs,),), ((as_,),), (omega,), None, None, None)
    return lowered, lowered.compile(), mesh


def simulate_trigger_cohorts(trigger: str, *, clients_per_round: int,
                             rounds: int = 40,
                             straggler_fraction: float = 0.25,
                             seed: int = 0) -> list:
    """Host-only event-scheduler simulation (no jax): the per-fire cohort
    sizes the chosen trigger actually produces under a straggler-tail
    latency model. These sizes parameterize the lowered program's client
    axis -- the event-driven engine's ONLY program-structure effect."""
    from repro.federation.events import (EventScheduler, standard_trigger,
                                         standard_straggler_latency)
    sched = EventScheduler(
        standard_straggler_latency(straggler_fraction, seed=seed),
        standard_trigger(trigger, clients_per_round), round_interval=1.0)
    counts = []
    for r in range(rounds):
        sched.dispatch(r, list(range(clients_per_round)))
        for _ in sched.advance_window():
            ready = sched.take_ready()
            counts.append(sum(len(rd) for rd in ready.values()))
    for _ in sched.drain():
        ready = sched.take_ready()
        counts.append(sum(len(rd) for rd in ready.values()))
    return counts


def transport_gate(args, chips: int) -> int:
    """Lower the quantized collective next to the f32 factored program and
    GATE: the compressed program's collective bytes must be STRICTLY below
    the f32 factored baseline, else exit 1. At int8 the payload is 1/4 the
    f32 stack plus a tiny f32 per-column scale*sqrt(omega) vector, so the
    ratio lands near 4x (bf16 near 2x); a ratio <= 1 means the quantized
    staging regressed into shipping full-precision bytes."""
    from repro.launch.hlo_walker import analyze_hlo
    merged = args.clients * args.pipeline_depth
    mesh_name = "2x16x16" if args.multi_pod else "16x16"
    tag = f"d{args.d}xn{args.n}xM{merged}"
    # Byte accounting is asymmetric ON PURPOSE. The f32 factored baseline
    # moves real f32 stacks on TPU too, so it gates on RAW HLO collective
    # bytes. The quantized rows gate on the tpu-corrected figure
    # (``collective_bytes_tpu`` halves the f32 share): XLA:CPU upcasts the
    # bf16 payload psum to f32 (emulation artifact -- a TPU moves bf16),
    # and the int8 payload stays s8 either way, so the correction touches
    # exactly the emulated bytes plus the negligible f32 scale vectors.
    lowered, compiled, _ = lower_aggregation(
        d=args.d, n=args.n, clients=merged, r_max=args.r_max,
        multi_pod=args.multi_pod, backend="factored")
    base = analyze_compiled(lowered, compiled, arch="fl-agg-factored",
                            shape=tag, mesh_name=mesh_name, chips=chips)
    base_raw = analyze_hlo(compiled.as_text()).total_collective_bytes * chips
    print(f"[OK] fl-transport baseline  f32/factored   "
          f"tx={base.t_collective*1e6:9.2f}us "
          f"coll={base_raw/1e6:8.1f}MB")
    texts = {}
    raws = {}
    for backend in ("factored", "kernel"):
        lowered, compiled, _ = lower_aggregation(
            d=args.d, n=args.n, clients=merged, r_max=args.r_max,
            multi_pod=args.multi_pod, backend=backend,
            transport=args.transport)
        texts[backend] = compiled.as_text()
        rep = analyze_compiled(
            lowered, compiled, arch=f"fl-agg-tx-{backend}",
            shape=f"{tag}{args.transport}", mesh_name=mesh_name,
            chips=chips)
        raw = analyze_hlo(texts[backend]).collective_bytes_tpu * chips
        raws[backend] = raw
        print(f"[OK] fl-transport {args.transport}/{backend:9s} "
              f"tx={rep.t_collective*1e6:9.2f}us "
              f"coll={raw/1e6:8.1f}MB "
              f"reduction={base_raw/max(raw, 1):5.2f}x")
    findings = check_kernel_parity(texts, f"{tag}{args.transport}")
    worst = max(raws.values())
    if worst >= base_raw:
        print(f"[GATE FAIL] quantized collective moves {worst/1e6:.1f}MB, "
              f"not strictly below the f32 factored "
              f"{base_raw/1e6:.1f}MB")
        return 1
    print(f"[OK] fl-transport gate: {args.transport} collective "
          f"{worst/1e6:.1f}MB < f32 factored {base_raw/1e6:.1f}MB "
          f"({base_raw/max(worst, 1):.2f}x reduction)")
    return 1 if findings else 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--d", type=int, default=4096)
    ap.add_argument("--n", type=int, default=4096)
    ap.add_argument("--clients", type=int, default=64)
    ap.add_argument("--r-max", type=int, default=64)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--pipeline-depth", type=int, default=1,
                    help="lower the async engine's buffered aggregation: "
                         "one step consuming this many rounds' clients")
    ap.add_argument("--trigger",
                    choices=("count", "timeout", "staleness"),
                    help="lower the EVENT-DRIVEN buffered step at the "
                         "simulated trigger's p50/p95 cohort sizes")
    ap.add_argument("--straggler-fraction", type=float, default=0.25)
    ap.add_argument("--transport", choices=("none", "int8", "bf16"),
                    default="none",
                    help="lower the COMPRESSED update collective "
                         "(DESIGN.md §12) and gate its bytes against the "
                         "f32 factored program")
    args = ap.parse_args(argv)

    chips = 512 if args.multi_pod else 256

    if args.transport != "none":
        return transport_gate(args, chips)

    if args.trigger is not None:
        counts = simulate_trigger_cohorts(
            args.trigger, clients_per_round=args.clients,
            straggler_fraction=args.straggler_fraction)
        data_mult = 32 if args.multi_pod else 16   # pad like ghost clients
        cohorts, seen = [], set()
        for pct in (50, 95):
            c = int(np.percentile(counts, pct))
            merged = max(data_mult, -(-c // data_mult) * data_mult)
            if merged not in seen:     # p50 == p95 happens (count trigger)
                seen.add(merged)
                cohorts.append((pct, merged))
        print(f"[event] trigger={args.trigger} "
              f"straggler_frac={args.straggler_fraction} fires={len(counts)} "
              "cohorts "
              + "/".join(f"p{pct}={m}" for pct, m in cohorts)
              + f" (raw {int(np.percentile(counts, 50))}/"
              f"{int(np.percentile(counts, 95))}, padded to x{data_mult})")
        parity_findings = 0
        for pct, merged in cohorts:
            tag = f"d{args.d}xn{args.n}xM{merged}p{pct}{args.trigger}"
            texts = {}
            for backend in ("dense", "factored", "kernel"):
                lowered, compiled, mesh = lower_aggregation(
                    d=args.d, n=args.n, clients=merged, r_max=args.r_max,
                    multi_pod=args.multi_pod, backend=backend)
                texts[backend] = compiled.as_text()
                rep = analyze_compiled(
                    lowered, compiled, arch=f"fl-agg-evt-{backend}",
                    shape=tag,
                    mesh_name="2x16x16" if args.multi_pod else "16x16",
                    chips=chips)
                print(f"[OK] fl-event p{pct} backend={backend:9s} "
                      f"M={merged:4d} "
                      f"tx={rep.t_collective*1e6:9.2f}us "
                      f"coll={rep.coll_bytes/1e6:8.1f}MB "
                      f"flops={rep.hlo_flops/1e9:9.2f}GF")
            parity_findings += check_kernel_parity(texts, tag)
        return 1 if parity_findings else 0

    merged_clients = args.clients * args.pipeline_depth
    tag = (f"d{args.d}xn{args.n}xM{args.clients}"
           + (f"x{args.pipeline_depth}buf" if args.pipeline_depth > 1
              else ""))
    # "kernel" lowers the fused Pallas path (DESIGN.md §4.3): per-shard
    # stack grids + the same (d+n, R) all-reduce as "factored", then the
    # Gram-core realloc -- dW never appears in the program. Off-TPU the
    # Pallas grids lower in INTERPRET mode (a while-loop emulation), so the
    # kernel row's tc/tm columns are emulation artifacts; the tx/coll
    # columns are the real datum -- identical to factored's, showing the
    # fused path changes per-shard compute, not the collective.
    texts = {}
    for backend in ("dense", "factored", "kernel"):
        lowered, compiled, mesh = lower_aggregation(
            d=args.d, n=args.n, clients=merged_clients, r_max=args.r_max,
            multi_pod=args.multi_pod, backend=backend)
        texts[backend] = compiled.as_text()
        rep = analyze_compiled(
            lowered, compiled, arch=f"fl-agg-{backend}", shape=tag,
            mesh_name="2x16x16" if args.multi_pod else "16x16", chips=chips)
        print(f"[OK] fl-aggregation backend={backend:9s} "
              f"tc={rep.t_compute*1e6:9.2f}us tm={rep.t_memory*1e6:9.2f}us "
              f"tx={rep.t_collective*1e6:9.2f}us "
              f"coll={rep.coll_bytes/1e6:8.1f}MB flops={rep.hlo_flops/1e9:9.2f}GF")
    return 1 if check_kernel_parity(texts, tag) else 0


if __name__ == "__main__":
    sys.exit(main())
