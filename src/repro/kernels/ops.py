"""jit'd public wrappers for the Pallas kernels.

Each op pads to hardware-friendly shapes, dispatches to the kernel (interpret
mode on CPU -- the kernel body runs in Python for correctness validation;
compiled Mosaic on real TPU), and slices back. Oracles in ``ref.py``.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.svd import check_fallback_globals
from repro.kernels.lora_apply import (batched_lora_apply_pallas,
                                      lora_apply_pallas)
from repro.kernels.rank_partition_agg import (gram_left_layered_pallas,
                                              gram_right_layered_pallas,
                                              rank_partition_agg_layered_pallas,
                                              rank_partition_agg_pallas,
                                              weighted_stack_a_layered_pallas,
                                              weighted_stack_b_layered_pallas)
from repro.kernels.ssd_scan import ssd_scan_pallas

_ON_TPU = jax.default_backend() == "tpu"
_INTERPRET = not _ON_TPU


# pad-to-multiple: the ONE zero-pad helper, shared with the kernel grids
from repro.kernels.rank_partition_agg import _pad_axis as _pad_to


def _tile_block(padded: int, preferred: int = 256, lane: int = 128) -> int:
    """Largest tile <= preferred that divides the (lane-padded) dim --
    e.g. a 384-padded dim tiles at 128, not the non-divisor 256."""
    return preferred if padded % preferred == 0 else lane


@functools.partial(jax.jit, static_argnames=("scale",))
def lora_apply(x: jnp.ndarray, w: jnp.ndarray, a: jnp.ndarray,
               b: jnp.ndarray, scale: float = 1.0) -> jnp.ndarray:
    """Fused y = x @ w + scale * (x @ a.T) @ b.T; x (..., K)."""
    lead = x.shape[:-1]
    k = x.shape[-1]
    n = w.shape[-1]
    x2 = x.reshape(-1, k)
    m = x2.shape[0]
    # pad every dim to the kernel's tiling granularity
    bm = 256 if m >= 256 else max(8, m)
    x2 = _pad_to(x2, 0, bm)
    xp = _pad_to(x2, 1, 128)
    wp = _pad_to(_pad_to(w, 0, 128), 1, 128)
    ap = _pad_to(_pad_to(a, 0, 8), 1, 128)
    bp = _pad_to(_pad_to(b, 0, 128), 1, 8)
    y = lora_apply_pallas(xp, wp, ap, bp, scale,
                          block_m=min(256, xp.shape[0]),
                          block_n=min(512, wp.shape[1]),
                          block_k=min(512, xp.shape[1]),
                          interpret=_INTERPRET)
    return y[:m, :n].reshape(lead + (n,)).astype(x.dtype)


@jax.jit
def batched_lora_apply(x: jnp.ndarray, w: jnp.ndarray,
                       a_pages: jnp.ndarray, b_pages: jnp.ndarray,
                       scales: jnp.ndarray, ids: jnp.ndarray) -> jnp.ndarray:
    """Multi-adapter fused apply: row t of x (..., K) uses adapter page
    ``ids[t]`` from a_pages (P, r, K) / b_pages (P, N, r) / scales (P,).

    SGMV-style grouping (DESIGN.md §11): rows are sorted by page id and
    each group is padded to the ``bm`` row-block boundary, so every kernel
    row block is single-adapter and the paged kernel gathers its (A, B,
    scale) once per tile via scalar-prefetched block->page indices. All
    shapes stay static under jit: the padded row count is bounded by
    ceil(M/bm) + P blocks, zero filler rows are inert, and the scatter
    back drops them.
    """
    lead = x.shape[:-1]
    k = x.shape[-1]
    n = w.shape[-1]
    x2 = x.reshape(-1, k)
    idf = ids.reshape(-1).astype(jnp.int32)
    m = x2.shape[0]
    p = a_pages.shape[0]
    bm = 8
    # group rows by page: sorted order, per-page extents, block-aligned
    # destination offsets (group g starts at a bm multiple)
    order = jnp.argsort(idf, stable=True)
    ids_sorted = idf[order]
    counts = jnp.bincount(idf, length=p)
    blocks_per = (counts + bm - 1) // bm
    padded = blocks_per * bm
    group_start = jnp.cumsum(padded) - padded
    cum_before = jnp.cumsum(counts) - counts
    dest = group_start[ids_sorted] + (jnp.arange(m) - cum_before[ids_sorted])
    m_pad = ((m + bm - 1) // bm + p) * bm           # static worst case
    x_g = jnp.zeros((m_pad, k), x.dtype).at[dest].set(x2[order])
    # page of each row block: invert the block-aligned group layout
    # (trailing unused blocks clip to page P-1; their rows are zero)
    bounds = jnp.cumsum(blocks_per)
    block_page = jnp.minimum(
        jnp.searchsorted(bounds, jnp.arange(m_pad // bm), side="right"),
        p - 1).astype(jnp.int32)
    # pad every dim to the kernel's tiling granularity (as in lora_apply)
    xp = _pad_to(x_g, 1, 128)
    wp = _pad_to(_pad_to(w, 0, 128), 1, 128)
    ap = _pad_to(_pad_to(a_pages, 1, 8), 2, 128)
    bp = _pad_to(_pad_to(b_pages, 1, 128), 2, 8)
    y_g = batched_lora_apply_pallas(
        xp, wp, ap, bp, scales, block_page,
        block_m=bm, block_n=min(512, wp.shape[1]),
        block_k=min(512, xp.shape[1]), interpret=_INTERPRET)
    y2 = jnp.zeros((m, n), x.dtype).at[order].set(y_g[dest, :n])
    return y2.reshape(lead + (n,))


@jax.jit
def rank_partition_agg(bs: jnp.ndarray, as_: jnp.ndarray, omega: jnp.ndarray,
                       global_b: Optional[jnp.ndarray] = None,
                       global_a: Optional[jnp.ndarray] = None,
                       fallback: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """dW = sum_m B_m diag(omega_m) A_m (+ fallback global slices).

    bs (M, d, r); as_ (M, r, n); omega (M, r); optional global factors enter
    as one extra "client" carrying the empty-partition fallback (Eq. 8).
    """
    bs, as_, omega = _append_fallback_client(bs, as_, omega, global_b,
                                             global_a, fallback,
                                             layer_axes=0)
    # only r needs padding (to the 8-sublane tile); the kernel pads and
    # re-slices non-divisible d / n extents itself
    bsp = _pad_to(bs, 2, 8)
    asp = _pad_to(as_, 1, 8)
    omp = _pad_to(omega, 1, 8)
    return rank_partition_agg_pallas(
        bsp, asp, omp,
        block_d=_tile_block(bsp.shape[1]), block_n=_tile_block(asp.shape[2]),
        interpret=_INTERPRET)


@jax.jit
def rank_partition_agg_layered(bs: jnp.ndarray, as_: jnp.ndarray,
                               omega: jnp.ndarray,
                               global_b: Optional[jnp.ndarray] = None,
                               global_a: Optional[jnp.ndarray] = None,
                               fallback: Optional[jnp.ndarray] = None
                               ) -> jnp.ndarray:
    """Layer-batched dW: one kernel launch for a whole adapter bucket.

    bs (L, M, d, r); as_ (L, M, r, n); omega (M, r) shared across layers;
    optional global factors (L, d, r)/(L, r, n) enter as one extra "client"
    per layer carrying the empty-partition fallback (Eq. 8).
    Returns dW (L, d, n) f32.
    """
    bs, as_, omega = _append_fallback_client(bs, as_, omega, global_b,
                                             global_a, fallback,
                                             layer_axes=1)
    # only r needs padding (to the 8-sublane tile); the kernel pads and
    # re-slices non-divisible d / n extents itself
    bsp = _pad_to(bs, 3, 8)
    asp = _pad_to(as_, 2, 8)
    omp = _pad_to(omega, 1, 8)
    return rank_partition_agg_layered_pallas(
        bsp, asp, omp,
        block_d=_tile_block(bsp.shape[2]), block_n=_tile_block(asp.shape[3]),
        interpret=_INTERPRET)


# -- fused factored aggregation (DESIGN.md §4.3): O((d+n)R) memory ----------
#
# The kernel backend's hot path: build the sqrt(omega)-weighted column
# stacks U_c / V_c and their (R x R) Gram cores with Pallas kernels, then
# SVD-realloc via core/svd.svd_realloc_gram -- dW (d, n) is NEVER formed.
# The Eq. 8 empty-partition fallback enters as one extra "client" whose
# omega row is the fallback indicator, exactly as on the dense kernel path.
# These helpers are plain traced functions (no own jit) so the aggregation
# pipelines can call them inside their jitted / shard_map'd bodies.

def _dequant(x):
    """Accept the compressed-transport layout (QuantFactor: int8/bf16
    payload + f32 per-column scales, DESIGN.md §12) at every factor-stack
    entry point. Duck-typed so kernels/ never imports repro.federation;
    plain f32 stacks pass through untouched. The payload->f32 multiply is
    elementwise staging the Pallas grids consume directly -- the grids
    themselves stay layout-agnostic."""
    if hasattr(x, "q") and hasattr(x, "scale"):
        return x.q.astype(jnp.float32) * x.scale
    return x


def _append_fallback_client(bs, as_, omega, global_b, global_a, fallback,
                            *, layer_axes: int):
    """Concatenate the global factors as client M+1 carrying ``fallback``.

    ``layer_axes`` leading axes precede the client axis (0 for (M, d, r),
    1 for (L, M, d, r)); the global factors carry those axes without the
    client axis."""
    check_fallback_globals(fallback, global_b, global_a)
    if fallback is None:
        return bs, as_, omega
    ax = layer_axes
    bs = jnp.concatenate(
        [bs, jnp.expand_dims(global_b, ax).astype(bs.dtype)], axis=ax)
    as_ = jnp.concatenate(
        [as_, jnp.expand_dims(global_a, ax).astype(as_.dtype)], axis=ax)
    omega = jnp.concatenate([omega, fallback[None].astype(omega.dtype)],
                            axis=0)
    return bs, as_, omega


def factored_stack_layered(bs: jnp.ndarray, as_: jnp.ndarray,
                           omega: jnp.ndarray) -> Tuple[jnp.ndarray,
                                                        jnp.ndarray]:
    """bs (L, M, d, r); as_ (L, M, r, n); omega (M, r) ->
    U_c (L, d, M*r8), V_c (L, M*r8, n) f32 (r zero-padded to a multiple of
    8 -- zero columns are spectrum-inert and keep the R width tile-able;
    the stack grids pad and re-slice d / n themselves)."""
    bsp = _pad_to(bs, 3, 8)
    asp = _pad_to(as_, 2, 8)
    omp = _pad_to(omega, 1, 8)
    u_c = weighted_stack_b_layered_pallas(
        bsp, omp, block_d=_tile_block(bsp.shape[2]), interpret=_INTERPRET)
    v_c = weighted_stack_a_layered_pallas(
        asp, omp, block_n=_tile_block(asp.shape[3]), interpret=_INTERPRET)
    return u_c, v_c


def factored_gram_layered(u_c: jnp.ndarray, v_c: jnp.ndarray
                          ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """u_c (L, d, R); v_c (L, R, n) -> Gram cores (L, R, R) x2. R is padded
    to 8 so the core tiles; callers slice back to the incoming width."""
    rr = u_c.shape[-1]
    up = _pad_to(u_c, 2, 8)
    vp = _pad_to(v_c, 1, 8)
    g_u = gram_left_layered_pallas(up, block_d=_tile_block(up.shape[1]),
                                   interpret=_INTERPRET)
    g_v = gram_right_layered_pallas(vp, block_n=_tile_block(vp.shape[2]),
                                    interpret=_INTERPRET)
    return g_u[:, :rr, :rr], g_v[:, :rr, :rr]


def factored_stack_lead(bs: jnp.ndarray, as_: jnp.ndarray,
                        omega: jnp.ndarray) -> Tuple[jnp.ndarray,
                                                     jnp.ndarray]:
    """``svd.factored_stack_batched`` on the Pallas kernels, for factor
    stacks with ANY batch axes between the client and matrix axes.

    bs (M, *B, d, r); as_ (M, *B, r, n); omega (M, r). Returns
    u_c (*B, d, M*r8), v_c (*B, M*r8, n) -- the layout the sharded round
    engine zero-scatters and psums (DESIGN.md §5), built on-chip."""
    bs, as_ = _dequant(bs), _dequant(as_)
    m, r = bs.shape[0], bs.shape[-1]
    d, n = bs.shape[-2], as_.shape[-1]
    lead = bs.shape[1:-2]
    layers = 1
    for s in lead:
        layers *= s
    bs_l = jnp.moveaxis(bs.reshape(m, layers, d, r), 0, 1)
    as_l = jnp.moveaxis(as_.reshape(m, layers, r, n), 0, 1)
    u_c, v_c = factored_stack_layered(bs_l, as_l, omega)
    width = u_c.shape[-1]
    return (u_c.reshape(lead + (d, width)),
            v_c.reshape(lead + (width, n)))


def factored_gram_lead(u_c: jnp.ndarray, v_c: jnp.ndarray
                       ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """``factored_gram_layered`` over ANY leading batch axes (or none)."""
    lead = u_c.shape[:-2]
    d, rr = u_c.shape[-2:]
    n = v_c.shape[-1]
    layers = 1
    for s in lead:
        layers *= s
    g_u, g_v = factored_gram_layered(u_c.reshape(layers, d, rr),
                                     v_c.reshape(layers, rr, n))
    return g_u.reshape(lead + (rr, rr)), g_v.reshape(lead + (rr, rr))


@jax.jit
def factored_stack_gram(bs: jnp.ndarray, as_: jnp.ndarray,
                        omega: jnp.ndarray,
                        global_b: Optional[jnp.ndarray] = None,
                        global_a: Optional[jnp.ndarray] = None,
                        fallback: Optional[jnp.ndarray] = None
                        ) -> Tuple[jnp.ndarray, jnp.ndarray,
                                   jnp.ndarray, jnp.ndarray]:
    """The whole fused factored front half for ONE adapter: (u_c, v_c,
    g_u, g_v) for svd_realloc_gram.

    bs (M, d, r); as_ (M, r, n); omega (M, r); optional global factors
    enter as one extra "client" carrying the Eq. 8 fallback indicator.
    """
    bs, as_ = _dequant(bs), _dequant(as_)
    bs, as_, omega = _append_fallback_client(bs, as_, omega, global_b,
                                             global_a, fallback,
                                             layer_axes=0)
    u_c, v_c = factored_stack_layered(bs[None], as_[None], omega)
    g_u, g_v = factored_gram_layered(u_c, v_c)
    return u_c[0], v_c[0], g_u[0], g_v[0]


@jax.jit
def factored_stack_gram_layered(bs: jnp.ndarray, as_: jnp.ndarray,
                                omega: jnp.ndarray,
                                global_b: Optional[jnp.ndarray] = None,
                                global_a: Optional[jnp.ndarray] = None,
                                fallback: Optional[jnp.ndarray] = None
                                ) -> Tuple[jnp.ndarray, jnp.ndarray,
                                           jnp.ndarray, jnp.ndarray]:
    """Layer-batched ``factored_stack_gram``: one kernel launch per shape
    bucket. bs (L, M, d, r); as_ (L, M, r, n); omega (M, r) shared across
    layers; global factors (L, d, r)/(L, r, n)."""
    bs, as_ = _dequant(bs), _dequant(as_)
    bs, as_, omega = _append_fallback_client(bs, as_, omega, global_b,
                                             global_a, fallback,
                                             layer_axes=1)
    u_c, v_c = factored_stack_layered(bs, as_, omega)
    g_u, g_v = factored_gram_layered(u_c, v_c)
    return u_c, v_c, g_u, g_v


@functools.partial(jax.jit, static_argnames=("chunk",))
def ssd_scan(x: jnp.ndarray, dt: jnp.ndarray, a_log: jnp.ndarray,
             b: jnp.ndarray, c: jnp.ndarray, d_skip: jnp.ndarray,
             chunk: int, init_state: Optional[jnp.ndarray] = None
             ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Chunked SSD scan. Shapes as in models/layers/ssd.ssd_scan_chunked."""
    B_, L, H, P = x.shape
    G, N = b.shape[-2:]
    chunk = min(chunk, L)
    assert L % chunk == 0
    nc = L // chunk
    reps = H // G
    bh = jnp.repeat(b, reps, axis=2).reshape(B_, nc, chunk, H, N)
    ch = jnp.repeat(c, reps, axis=2).reshape(B_, nc, chunk, H, N)
    xr = x.reshape(B_, nc, chunk, H, P)
    dtr = dt.reshape(B_, nc, chunk, H)
    init = (jnp.zeros((B_, H, P, N), jnp.float32)
            if init_state is None else init_state.astype(jnp.float32))
    block_heads = 8 if H % 8 == 0 else (4 if H % 4 == 0 else 1)
    y, final = ssd_scan_pallas(xr, dtr, a_log.astype(jnp.float32), bh, ch,
                               d_skip.astype(jnp.float32), init,
                               block_heads=block_heads,
                               interpret=_INTERPRET)
    return y.reshape(B_, L, H, P), final


@functools.partial(jax.jit, static_argnames=("causal", "window"))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                    causal: bool = True, window: int = 0) -> jnp.ndarray:
    """Fused flash attention; pads sequence lengths to block multiples."""
    from repro.kernels.flash_attention import flash_attention_pallas
    b, lq, h, d = q.shape
    lkv = k.shape[1]
    bq = min(128, max(8, lq))
    bk = min(128, max(8, lkv))
    qp = _pad_to(q, 1, bq)
    kp = _pad_to(k, 1, bk)
    vp = _pad_to(v, 1, bk)
    out = flash_attention_pallas(qp, kp, vp, causal=causal, window=window,
                                 block_q=bq, block_kv=bk,
                                 interpret=_INTERPRET)
    return out[:, :lq]
