"""jit'd public wrappers for the Pallas kernels.

Each op pads to hardware-friendly shapes, dispatches to the kernel (interpret
mode on CPU -- the kernel body runs in Python for correctness validation;
compiled Mosaic on real TPU), and slices back. Oracles in ``ref.py``.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.svd import check_fallback_globals
from repro.kernels.lora_apply import lora_apply_pallas
from repro.kernels.rank_partition_agg import (rank_partition_agg_layered_pallas,
                                              rank_partition_agg_pallas)
from repro.kernels.ssd_scan import ssd_scan_pallas

_ON_TPU = jax.default_backend() == "tpu"
_INTERPRET = not _ON_TPU


def _pad_to(x: jnp.ndarray, axis: int, mult: int) -> jnp.ndarray:
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _tile_block(padded: int, preferred: int = 256, lane: int = 128) -> int:
    """Largest tile <= preferred that divides the (lane-padded) dim --
    e.g. a 384-padded dim tiles at 128, not the non-divisor 256."""
    return preferred if padded % preferred == 0 else lane


@functools.partial(jax.jit, static_argnames=("scale",))
def lora_apply(x: jnp.ndarray, w: jnp.ndarray, a: jnp.ndarray,
               b: jnp.ndarray, scale: float = 1.0) -> jnp.ndarray:
    """Fused y = x @ w + scale * (x @ a.T) @ b.T; x (..., K)."""
    lead = x.shape[:-1]
    k = x.shape[-1]
    n = w.shape[-1]
    x2 = x.reshape(-1, k)
    m = x2.shape[0]
    # pad every dim to the kernel's tiling granularity
    bm = 256 if m >= 256 else max(8, m)
    x2 = _pad_to(x2, 0, bm)
    xp = _pad_to(x2, 1, 128)
    wp = _pad_to(_pad_to(w, 0, 128), 1, 128)
    ap = _pad_to(_pad_to(a, 0, 8), 1, 128)
    bp = _pad_to(_pad_to(b, 0, 128), 1, 8)
    y = lora_apply_pallas(xp, wp, ap, bp, scale,
                          block_m=min(256, xp.shape[0]),
                          block_n=min(512, wp.shape[1]),
                          block_k=min(512, xp.shape[1]),
                          interpret=_INTERPRET)
    return y[:m, :n].reshape(lead + (n,)).astype(x.dtype)


@jax.jit
def rank_partition_agg(bs: jnp.ndarray, as_: jnp.ndarray, omega: jnp.ndarray,
                       global_b: Optional[jnp.ndarray] = None,
                       global_a: Optional[jnp.ndarray] = None,
                       fallback: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """dW = sum_m B_m diag(omega_m) A_m (+ fallback global slices).

    bs (M, d, r); as_ (M, r, n); omega (M, r); optional global factors enter
    as one extra "client" carrying the empty-partition fallback (Eq. 8).
    """
    check_fallback_globals(fallback, global_b, global_a)
    if fallback is not None:
        bs = jnp.concatenate([bs, global_b[None].astype(bs.dtype)], axis=0)
        as_ = jnp.concatenate([as_, global_a[None].astype(as_.dtype)], axis=0)
        omega = jnp.concatenate(
            [omega, fallback[None].astype(omega.dtype)], axis=0)
    d, r = bs.shape[1], bs.shape[2]
    n = as_.shape[-1]
    bsp = _pad_to(_pad_to(bs, 1, 128), 2, 8)
    asp = _pad_to(_pad_to(as_, 1, 8), 2, 128)
    omp = _pad_to(omega, 1, 8)
    dw = rank_partition_agg_pallas(
        bsp, asp, omp,
        block_d=_tile_block(bsp.shape[1]), block_n=_tile_block(asp.shape[2]),
        interpret=_INTERPRET)
    return dw[:d, :n]


@jax.jit
def rank_partition_agg_layered(bs: jnp.ndarray, as_: jnp.ndarray,
                               omega: jnp.ndarray,
                               global_b: Optional[jnp.ndarray] = None,
                               global_a: Optional[jnp.ndarray] = None,
                               fallback: Optional[jnp.ndarray] = None
                               ) -> jnp.ndarray:
    """Layer-batched dW: one kernel launch for a whole adapter bucket.

    bs (L, M, d, r); as_ (L, M, r, n); omega (M, r) shared across layers;
    optional global factors (L, d, r)/(L, r, n) enter as one extra "client"
    per layer carrying the empty-partition fallback (Eq. 8).
    Returns dW (L, d, n) f32.
    """
    check_fallback_globals(fallback, global_b, global_a)
    if fallback is not None:
        bs = jnp.concatenate([bs, global_b[:, None].astype(bs.dtype)], axis=1)
        as_ = jnp.concatenate([as_, global_a[:, None].astype(as_.dtype)],
                              axis=1)
        omega = jnp.concatenate(
            [omega, fallback[None].astype(omega.dtype)], axis=0)
    d, r = bs.shape[2], bs.shape[3]
    n = as_.shape[-1]
    bsp = _pad_to(_pad_to(bs, 2, 128), 3, 8)
    asp = _pad_to(_pad_to(as_, 2, 8), 3, 128)
    omp = _pad_to(omega, 1, 8)
    dw = rank_partition_agg_layered_pallas(
        bsp, asp, omp,
        block_d=_tile_block(bsp.shape[2]), block_n=_tile_block(asp.shape[3]),
        interpret=_INTERPRET)
    return dw[:, :d, :n]


@functools.partial(jax.jit, static_argnames=("chunk",))
def ssd_scan(x: jnp.ndarray, dt: jnp.ndarray, a_log: jnp.ndarray,
             b: jnp.ndarray, c: jnp.ndarray, d_skip: jnp.ndarray,
             chunk: int, init_state: Optional[jnp.ndarray] = None
             ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Chunked SSD scan. Shapes as in models/layers/ssd.ssd_scan_chunked."""
    B_, L, H, P = x.shape
    G, N = b.shape[-2:]
    chunk = min(chunk, L)
    assert L % chunk == 0
    nc = L // chunk
    reps = H // G
    bh = jnp.repeat(b, reps, axis=2).reshape(B_, nc, chunk, H, N)
    ch = jnp.repeat(c, reps, axis=2).reshape(B_, nc, chunk, H, N)
    xr = x.reshape(B_, nc, chunk, H, P)
    dtr = dt.reshape(B_, nc, chunk, H)
    init = (jnp.zeros((B_, H, P, N), jnp.float32)
            if init_state is None else init_state.astype(jnp.float32))
    block_heads = 8 if H % 8 == 0 else (4 if H % 4 == 0 else 1)
    y, final = ssd_scan_pallas(xr, dtr, a_log.astype(jnp.float32), bh, ch,
                               d_skip.astype(jnp.float32), init,
                               block_heads=block_heads,
                               interpret=_INTERPRET)
    return y.reshape(B_, L, H, P), final


@functools.partial(jax.jit, static_argnames=("causal", "window"))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                    causal: bool = True, window: int = 0) -> jnp.ndarray:
    """Fused flash attention; pads sequence lengths to block multiples."""
    from repro.kernels.flash_attention import flash_attention_pallas
    b, lq, h, d = q.shape
    lkv = k.shape[1]
    bq = min(128, max(8, lq))
    bk = min(128, max(8, lkv))
    qp = _pad_to(q, 1, bq)
    kp = _pad_to(k, 1, bk)
    vp = _pad_to(v, 1, bk)
    out = flash_attention_pallas(qp, kp, vp, causal=causal, window=window,
                                 block_q=bq, block_kv=bk,
                                 interpret=_INTERPRET)
    return out[:, :lq]
