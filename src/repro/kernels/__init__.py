"""Pallas TPU kernels for the perf-critical compute of the system.

  lora_apply           -- fused dense + LoRA adapter matmul
  rank_partition_agg   -- the paper's Eq. 8 aggregation as one contraction
  factored_stack_gram  -- Eq. 8 WITHOUT materializing dW: sqrt-weighted
                          U_c/V_c stacks + (R, R) Gram cores feeding the
                          Gram-core SVD realloc (DESIGN.md §4.3)
  ssd_scan             -- Mamba-2 chunked SSD (dual form)

Each kernel ships with a pure-jnp oracle in ref.py and a jit'd public
wrapper in ops.py; kernels run under interpret=True on CPU and compile via
Mosaic on TPU.
"""
from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
