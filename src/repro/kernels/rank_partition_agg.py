"""Rank-partitioned aggregation Pallas kernel (the paper's Eq. 8 / Alg. 1
lines 6-10 as a single TPU contraction).

Computes   dW = sum_m  B_m  diag(omega_m)  A_m   over M clients, where
``omega`` encodes EITHER FlexLoRA's rank-agnostic weights or raFLoRA's
rank-partitioned effective-contributor weights (see core/partitions.py) --
the aggregation-rule difference is data, not code.

TPU rationale: the per-client diagonal scaling is folded into the B tile
while it is VMEM-resident, so each (d-tile, n-tile) output block is an
M-step accumulation of (bd x r) @ (r x bn) MXU matmuls with zero extra HBM
traffic for the weighting. With r = r_max <= 256 the factor tiles are
small; arithmetic intensity per output tile is ~r ops/byte.

Grid: (d/bd, n/bn, M), client loop innermost ("arbitrary"), f32 accumulator
in VMEM scratch. The empty-partition fallback slice (Eq. 8 case 2) enters
as client M+1 with omega = the fallback indicator (handled by ops.py).

``rank_partition_agg_layered_pallas`` is the batched-round-engine variant:
the server stacks every same-shape adapter of the model into one
(L, M, d, r) bucket and the whole bucket lowers through a single grid with
the layer axis outermost -- one kernel launch per round per shape bucket
instead of one per adapter. omega is shared across layers (the aggregation
weights depend only on the round's client ranks/sample counts, not on the
adapter), so the weight tile stays resident across the layer loop.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu
    _VMEM = pltpu.VMEM
except Exception:  # pragma: no cover
    pltpu = None
    _VMEM = None


def _kernel(bs_ref, as_ref, om_ref, o_ref, acc_ref, *, m_steps: int):
    m = pl.program_id(2)

    @pl.when(m == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    b = bs_ref[0].astype(jnp.float32)            # (bd, r)
    a = as_ref[0].astype(jnp.float32)            # (r, bn)
    om = om_ref[0].astype(jnp.float32)           # (r,)
    acc_ref[...] += jax.lax.dot(b * om[None, :], a,
                                precision=jax.lax.Precision.HIGHEST)

    @pl.when(m == m_steps - 1)
    def _finalize():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def rank_partition_agg_pallas(bs: jnp.ndarray, as_: jnp.ndarray,
                              omega: jnp.ndarray, *,
                              block_d: int = 256, block_n: int = 256,
                              interpret: bool = True) -> jnp.ndarray:
    """bs (M, d, r); as_ (M, r, n); omega (M, r) -> dW (d, n) f32."""
    m, d, r = bs.shape
    n = as_.shape[-1]
    bd, bn = min(block_d, d), min(block_n, n)
    assert d % bd == 0 and n % bn == 0, (d, n, bd, bn)
    grid = (d // bd, n // bn, m)

    scratch = [_VMEM((bd, bn), jnp.float32)] if _VMEM is not None else \
        [jax.ShapeDtypeStruct((bd, bn), jnp.float32)]

    kernel = functools.partial(_kernel, m_steps=m)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bd, r), lambda i, j, mm: (mm, i, 0)),
            pl.BlockSpec((1, r, bn), lambda i, j, mm: (mm, 0, j)),
            pl.BlockSpec((1, r), lambda i, j, mm: (mm, 0)),
        ],
        out_specs=pl.BlockSpec((bd, bn), lambda i, j, mm: (i, j)),
        out_shape=jax.ShapeDtypeStruct((d, n), jnp.float32),
        scratch_shapes=scratch,
        interpret=interpret,
    )(bs, as_, omega)


def _layered_kernel(bs_ref, as_ref, om_ref, o_ref, acc_ref, *, m_steps: int):
    m = pl.program_id(3)

    @pl.when(m == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    b = bs_ref[0, 0].astype(jnp.float32)         # (bd, r)
    a = as_ref[0, 0].astype(jnp.float32)         # (r, bn)
    om = om_ref[0].astype(jnp.float32)           # (r,)
    acc_ref[...] += jax.lax.dot(b * om[None, :], a,
                                precision=jax.lax.Precision.HIGHEST)

    @pl.when(m == m_steps - 1)
    def _finalize():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


def rank_partition_agg_layered_pallas(bs: jnp.ndarray, as_: jnp.ndarray,
                                      omega: jnp.ndarray, *,
                                      block_d: int = 256, block_n: int = 256,
                                      interpret: bool = True) -> jnp.ndarray:
    """bs (L, M, d, r); as_ (L, M, r, n); omega (M, r) -> dW (L, d, n) f32.

    Layer axis outermost in the grid so each layer's accumulator lives its
    full client loop before the next layer starts (same scratch reuse
    pattern as the single-layer kernel)."""
    l, m, d, r = bs.shape
    n = as_.shape[-1]
    bd, bn = min(block_d, d), min(block_n, n)
    assert d % bd == 0 and n % bn == 0, (d, n, bd, bn)
    grid = (l, d // bd, n // bn, m)

    scratch = [_VMEM((bd, bn), jnp.float32)] if _VMEM is not None else \
        [jax.ShapeDtypeStruct((bd, bn), jnp.float32)]

    kernel = functools.partial(_layered_kernel, m_steps=m)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bd, r), lambda ll, i, j, mm: (ll, mm, i, 0)),
            pl.BlockSpec((1, 1, r, bn), lambda ll, i, j, mm: (ll, mm, 0, j)),
            pl.BlockSpec((1, r), lambda ll, i, j, mm: (mm, 0)),
        ],
        out_specs=pl.BlockSpec((1, bd, bn), lambda ll, i, j, mm: (ll, i, j)),
        out_shape=jax.ShapeDtypeStruct((l, d, n), jnp.float32),
        scratch_shapes=scratch,
        interpret=interpret,
    )(bs, as_, omega)
