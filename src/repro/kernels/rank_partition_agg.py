"""Rank-partitioned aggregation Pallas kernels (the paper's Eq. 8 / Alg. 1
lines 6-10 as TPU contractions) -- dense-output AND fused-factored variants.

``rank_partition_agg_pallas`` computes dW = sum_m B_m diag(omega_m) A_m over
M clients, where ``omega`` encodes EITHER FlexLoRA's rank-agnostic weights
or raFLoRA's rank-partitioned effective-contributor weights (see
core/partitions.py) -- the aggregation-rule difference is data, not code.
The per-client diagonal scaling is folded into the B tile while it is
VMEM-resident, so each (d-tile, n-tile) output block is an M-step
accumulation of (bd x r) @ (r x bn) MXU matmuls with zero extra HBM traffic
for the weighting. Grid (d/bd, n/bn, M), client loop innermost
("arbitrary"), f32 accumulator in VMEM scratch. The empty-partition
fallback slice (Eq. 8 case 2) enters as client M+1 with omega = the
fallback indicator (handled by ops.py).

``rank_partition_agg_layered_pallas`` is the batched-round-engine variant:
the server stacks every same-shape adapter of the model into one
(L, M, d, r) bucket and the whole bucket lowers through a single grid with
the layer axis outermost -- one kernel launch per round per shape bucket
instead of one per adapter. omega is shared across layers (the aggregation
weights depend only on the round's client ranks/sample counts, not on the
adapter), so the weight tile stays resident across the layer loop.

The FUSED FACTORED path (DESIGN.md §4.3) never materializes dW at all.
The aggregate is always U_c @ V_c with U_c (d, M r) the sqrt(omega)-weighted
client B columns and V_c (M r, n) the matching A rows (DESIGN.md §4.2), so
the kernels below emit only O((d+n) R) HBM bytes:

* ``weighted_stack_{b,a}_layered_pallas`` build the sqrt-weighted column
  stacks U_c / V_c on-chip (grid (L, M, tiles): one weighted copy per
  client tile -- the omega diagonal is applied while the factor tile is
  VMEM-resident, exactly as in the dense kernel).
* ``gram_left_layered_pallas`` / ``gram_right_layered_pallas`` compute the
  (R x R) Gram cores G_u = U_c^T U_c and G_v = V_c V_c^T as d-/n-step MXU
  accumulations (grid (L, R/br, R/br, tiles), f32 scratch accumulator) --
  the O((d+n) R^2) heavy lifting of the factored SVD realloc, on the MXU,
  with the (R x R) eigen/SVD core left to ``core/svd.svd_realloc_gram``.

All kernels pad non-tile-divisible d / n extents to the block size with
zeros (zero rows/columns contribute nothing to any contraction; callers
slice the valid extent back), so odd adapter shapes (e.g. d=300, n=520)
lower instead of tripping divisibility asserts.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu
    _VMEM = pltpu.VMEM
except Exception:  # pragma: no cover
    pltpu = None
    _VMEM = None

_HI = jax.lax.Precision.HIGHEST


def _pad_axis(x: jnp.ndarray, axis: int, mult: int) -> jnp.ndarray:
    """Zero-pad ``axis`` up to a multiple of ``mult`` (pad-to-tile)."""
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _acc_scratch(shape):
    return [_VMEM(shape, jnp.float32)] if _VMEM is not None else \
        [jax.ShapeDtypeStruct(shape, jnp.float32)]


def _block_div(dim: int, preferred: int) -> int:
    """Largest tile <= preferred that divides ``dim`` (dims the callers
    guarantee tile-able, e.g. the 8-padded R width)."""
    b = min(preferred, dim)
    while dim % b:
        b -= 1
    return b


# ---------------------------------------------------------------------------
# dense-output kernels (materialize dW -- the paper-faithful contraction)
# ---------------------------------------------------------------------------

def _kernel(bs_ref, as_ref, om_ref, o_ref, acc_ref, *, m_steps: int):
    m = pl.program_id(2)

    @pl.when(m == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    b = bs_ref[0].astype(jnp.float32)            # (bd, r)
    a = as_ref[0].astype(jnp.float32)            # (r, bn)
    om = om_ref[0].astype(jnp.float32)           # (r,)
    acc_ref[...] += jax.lax.dot(b * om[None, :], a, precision=_HI)

    @pl.when(m == m_steps - 1)
    def _finalize():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def rank_partition_agg_pallas(bs: jnp.ndarray, as_: jnp.ndarray,
                              omega: jnp.ndarray, *,
                              block_d: int = 256, block_n: int = 256,
                              interpret: bool = True) -> jnp.ndarray:
    """bs (M, d, r); as_ (M, r, n); omega (M, r) -> dW (d, n) f32."""
    m, d, r = bs.shape
    n = as_.shape[-1]
    bd, bn = min(block_d, d), min(block_n, n)
    bs = _pad_axis(bs, 1, bd)
    as_ = _pad_axis(as_, 2, bn)
    dp, np_ = bs.shape[1], as_.shape[2]
    grid = (dp // bd, np_ // bn, m)

    kernel = functools.partial(_kernel, m_steps=m)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bd, r), lambda i, j, mm: (mm, i, 0)),
            pl.BlockSpec((1, r, bn), lambda i, j, mm: (mm, 0, j)),
            pl.BlockSpec((1, r), lambda i, j, mm: (mm, 0)),
        ],
        out_specs=pl.BlockSpec((bd, bn), lambda i, j, mm: (i, j)),
        out_shape=jax.ShapeDtypeStruct((dp, np_), jnp.float32),
        scratch_shapes=_acc_scratch((bd, bn)),
        interpret=interpret,
    )(bs, as_, omega)
    return out[:d, :n]


def _layered_kernel(bs_ref, as_ref, om_ref, o_ref, acc_ref, *, m_steps: int):
    m = pl.program_id(3)

    @pl.when(m == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    b = bs_ref[0, 0].astype(jnp.float32)         # (bd, r)
    a = as_ref[0, 0].astype(jnp.float32)         # (r, bn)
    om = om_ref[0].astype(jnp.float32)           # (r,)
    acc_ref[...] += jax.lax.dot(b * om[None, :], a, precision=_HI)

    @pl.when(m == m_steps - 1)
    def _finalize():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


def rank_partition_agg_layered_pallas(bs: jnp.ndarray, as_: jnp.ndarray,
                                      omega: jnp.ndarray, *,
                                      block_d: int = 256, block_n: int = 256,
                                      interpret: bool = True) -> jnp.ndarray:
    """bs (L, M, d, r); as_ (L, M, r, n); omega (M, r) -> dW (L, d, n) f32.

    Layer axis outermost in the grid so each layer's accumulator lives its
    full client loop before the next layer starts (same scratch reuse
    pattern as the single-layer kernel)."""
    l, m, d, r = bs.shape
    n = as_.shape[-1]
    bd, bn = min(block_d, d), min(block_n, n)
    bs = _pad_axis(bs, 2, bd)
    as_ = _pad_axis(as_, 3, bn)
    dp, np_ = bs.shape[2], as_.shape[3]
    grid = (l, dp // bd, np_ // bn, m)

    kernel = functools.partial(_layered_kernel, m_steps=m)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bd, r), lambda ll, i, j, mm: (ll, mm, i, 0)),
            pl.BlockSpec((1, 1, r, bn), lambda ll, i, j, mm: (ll, mm, 0, j)),
            pl.BlockSpec((1, r), lambda ll, i, j, mm: (mm, 0)),
        ],
        out_specs=pl.BlockSpec((1, bd, bn), lambda ll, i, j, mm: (ll, i, j)),
        out_shape=jax.ShapeDtypeStruct((l, dp, np_), jnp.float32),
        scratch_shapes=_acc_scratch((bd, bn)),
        interpret=interpret,
    )(bs, as_, omega)
    return out[:, :d, :n]


# ---------------------------------------------------------------------------
# fused factored kernels: sqrt-weighted stacks + (R x R) Gram cores
# ---------------------------------------------------------------------------

def _stack_b_kernel(bs_ref, om_ref, u_ref):
    b = bs_ref[0, 0].astype(jnp.float32)                        # (bd, r)
    sq = jnp.sqrt(jnp.maximum(om_ref[0].astype(jnp.float32), 0.0))
    u_ref[0] = (b * sq[None, :]).astype(u_ref.dtype)


def weighted_stack_b_layered_pallas(bs: jnp.ndarray, omega: jnp.ndarray, *,
                                    block_d: int = 256,
                                    interpret: bool = True) -> jnp.ndarray:
    """bs (L, M, d, r); omega (M, r) -> U_c (L, d, M*r) f32.

    Client m's weighted columns B_m diag(sqrt(omega_m)) land in column
    block m -- the left factor of DESIGN.md §4.2's U_c V_c form, built
    on-chip so dW is never needed."""
    l, m, d, r = bs.shape
    bd = min(block_d, d)
    bs = _pad_axis(bs, 2, bd)
    dp = bs.shape[2]
    grid = (l, m, dp // bd)
    out = pl.pallas_call(
        _stack_b_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bd, r), lambda ll, mm, t: (ll, mm, t, 0)),
            pl.BlockSpec((1, r), lambda ll, mm, t: (mm, 0)),
        ],
        out_specs=pl.BlockSpec((1, bd, r), lambda ll, mm, t: (ll, t, mm)),
        out_shape=jax.ShapeDtypeStruct((l, dp, m * r), jnp.float32),
        interpret=interpret,
    )(bs, omega)
    return out[:, :d]


def _stack_a_kernel(as_ref, om_ref, v_ref):
    a = as_ref[0, 0].astype(jnp.float32)                        # (r, bn)
    sq = jnp.sqrt(jnp.maximum(om_ref[0].astype(jnp.float32), 0.0))
    v_ref[0] = (a * sq[:, None]).astype(v_ref.dtype)


def weighted_stack_a_layered_pallas(as_: jnp.ndarray, omega: jnp.ndarray, *,
                                    block_n: int = 256,
                                    interpret: bool = True) -> jnp.ndarray:
    """as_ (L, M, r, n); omega (M, r) -> V_c (L, M*r, n) f32."""
    l, m, r, n = as_.shape
    bn = min(block_n, n)
    as_ = _pad_axis(as_, 3, bn)
    np_ = as_.shape[3]
    grid = (l, m, np_ // bn)
    out = pl.pallas_call(
        _stack_a_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, r, bn), lambda ll, mm, t: (ll, mm, 0, t)),
            pl.BlockSpec((1, r), lambda ll, mm, t: (mm, 0)),
        ],
        out_specs=pl.BlockSpec((1, r, bn), lambda ll, mm, t: (ll, mm, t)),
        out_shape=jax.ShapeDtypeStruct((l, m * r, np_), jnp.float32),
        interpret=interpret,
    )(as_, omega)
    return out[..., :n]


def _gram_kernel(xi_ref, xj_ref, g_ref, acc_ref, *, t_steps: int,
                 contract_axis: int):
    i, j, t = pl.program_id(1), pl.program_id(2), pl.program_id(3)

    @pl.when(t == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # the Gram matrix is symmetric: accumulate only the upper-triangle
    # blocks (j >= i); the strictly-lower blocks finalize as zeros and the
    # wrapper mirrors them with one elementwise select
    @pl.when(j >= i)
    def _accumulate():
        xi = xi_ref[0].astype(jnp.float32)
        xj = xj_ref[0].astype(jnp.float32)
        dims = (((contract_axis,), (contract_axis,)), ((), ()))
        acc_ref[...] += jax.lax.dot_general(xi, xj, dims, precision=_HI)

    @pl.when(t == t_steps - 1)
    def _finalize():
        g_ref[0] = acc_ref[...].astype(g_ref.dtype)


def _mirror_lower(g: jnp.ndarray, br: int) -> jnp.ndarray:
    """Fill the zero strictly-lower-triangle BLOCKS of a block-upper Gram
    output with the transposed upper triangle (diagonal blocks were
    computed whole, so only whole blocks below the diagonal mirror)."""
    rr = g.shape[-1]
    rb = jnp.arange(rr) // br
    lower = rb[:, None] > rb[None, :]
    return jnp.where(lower, jnp.swapaxes(g, -1, -2), g)


def gram_left_layered_pallas(u_c: jnp.ndarray, *, block_d: int = 256,
                             block_r: int = 128,
                             interpret: bool = True) -> jnp.ndarray:
    """u_c (L, d, R) -> G_u = U_c^T U_c (L, R, R) f32.

    Grid (L, R/br, R/br, d/bd): each (br x br) core block accumulates a
    d-step sum of (bd x br)^T @ (bd x br) MXU products in f32 scratch --
    upper-triangle blocks only (the Gram matrix is symmetric; the lower
    half is mirrored with one elementwise select, halving the MXU work).
    R must tile by 8 (ops.py pads client ranks to 8)."""
    l, d, rr = u_c.shape
    bd = min(block_d, d)
    br = _block_div(rr, block_r)
    u_c = _pad_axis(u_c, 1, bd)
    dp = u_c.shape[1]
    grid = (l, rr // br, rr // br, dp // bd)
    kernel = functools.partial(_gram_kernel, t_steps=dp // bd,
                               contract_axis=0)
    g = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bd, br), lambda ll, i, j, t: (ll, t, i)),
            pl.BlockSpec((1, bd, br), lambda ll, i, j, t: (ll, t, j)),
        ],
        out_specs=pl.BlockSpec((1, br, br), lambda ll, i, j, t: (ll, i, j)),
        out_shape=jax.ShapeDtypeStruct((l, rr, rr), jnp.float32),
        scratch_shapes=_acc_scratch((br, br)),
        interpret=interpret,
    )(u_c, u_c)
    return _mirror_lower(g, br)


def gram_right_layered_pallas(v_c: jnp.ndarray, *, block_n: int = 256,
                              block_r: int = 128,
                              interpret: bool = True) -> jnp.ndarray:
    """v_c (L, R, n) -> G_v = V_c V_c^T (L, R, R) f32."""
    l, rr, n = v_c.shape
    bn = min(block_n, n)
    br = _block_div(rr, block_r)
    v_c = _pad_axis(v_c, 2, bn)
    np_ = v_c.shape[2]
    grid = (l, rr // br, rr // br, np_ // bn)
    kernel = functools.partial(_gram_kernel, t_steps=np_ // bn,
                               contract_axis=1)
    g = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, br, bn), lambda ll, i, j, t: (ll, i, t)),
            pl.BlockSpec((1, br, bn), lambda ll, i, j, t: (ll, j, t)),
        ],
        out_specs=pl.BlockSpec((1, br, br), lambda ll, i, j, t: (ll, i, j)),
        out_shape=jax.ShapeDtypeStruct((l, rr, rr), jnp.float32),
        scratch_shapes=_acc_scratch((br, br)),
        interpret=interpret,
    )(v_c, v_c)
    return _mirror_lower(g, br)
