"""Mamba-2 SSD chunked-scan Pallas kernel.

TPU rationale (DESIGN.md §4.3): the SSD *dual form* turns the selective-scan
recurrence into per-chunk matmuls -- exactly what the MXU wants -- plus a
tiny sequential inter-chunk state update. A GPU implementation leans on
warp-level associative scans; on TPU the right decomposition is:

  grid = (batch, head-blocks, chunks), chunk axis innermost & sequential;
  per step:   cb   = C_q B_q^T             (Q x Q matmul, MXU)
              y    = (cb * Lmat) X + (C decay) . state   (MXU)
              state = chunk_decay * state + (B^T weighted X)  (MXU)

The (P x N) state for the head-block lives in VMEM scratch across the chunk
loop; nothing recurrent ever round-trips HBM. Q (chunk) and N are 128-ish;
P=64 (mamba2) -> tiles are MXU-aligned or padded by ops.py.

Layout expected by the kernel (pre-reshaped by ops.py):
  x   (B, nc, Q, H, P)        dt (B, nc, Q, H)
  b,c (B, nc, Q, H, N)        -- groups already expanded to heads
  a_log (H,), d_skip (H,)     init_state (B, H, P, N)
Outputs: y (B, nc, Q, H, P); final_state (B, H, P, N).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu
    _VMEM = pltpu.VMEM
except Exception:  # pragma: no cover
    pltpu = None
    _VMEM = None


def _kernel(x_ref, dt_ref, alog_ref, b_ref, c_ref, dskip_ref, init_ref,
            y_ref, final_ref, state_ref, *, nc: int, q: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = init_ref[0].astype(jnp.float32)    # (bh, P, N)

    x = x_ref[0, 0].astype(jnp.float32)       # (Q, bh, P)
    dt = dt_ref[0, 0].astype(jnp.float32)     # (Q, bh)
    bq = b_ref[0, 0].astype(jnp.float32)      # (Q, bh, N)
    cq = c_ref[0, 0].astype(jnp.float32)      # (Q, bh, N)
    alog = alog_ref[...].astype(jnp.float32)  # (bh,)
    a_neg = -jnp.exp(alog)                    # (bh,) < 0

    a_inc = dt * a_neg[None, :]               # (Q, bh)
    cum = jnp.cumsum(a_inc, axis=0)           # inclusive, (Q, bh)
    dtx = x * dt[:, :, None]                  # (Q, bh, P)

    # intra-chunk: Lmat_ij = exp(cum_i - cum_j), i >= j (mask before exp --
    # see models/layers/ssd.py for the where-NaN rationale)
    diff = cum[:, None, :] - cum[None, :, :]  # (Q, Q, bh)
    idx = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    jdx = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    causal = (idx >= jdx)[:, :, None]
    lmat = jnp.exp(jnp.where(causal, diff, -1e30))          # (Q, Q, bh)
    cb = jnp.einsum("ihn,jhn->ijh", cq, bq)                 # (Q, Q, bh)
    y_intra = jnp.einsum("ijh,jhp->ihp", cb * lmat, dtx)    # (Q, bh, P)

    # inter-chunk: contribution of carried state
    state = state_ref[...]                                  # (bh, P, N)
    decay_in = jnp.exp(cum)                                 # (Q, bh)
    y_inter = jnp.einsum("qhn,hpn,qh->qhp", cq, state, decay_in)

    # state update
    decay_out = jnp.exp(cum[-1:, :] - cum)                  # (Q, bh)
    new_contrib = jnp.einsum("qhn,qhp,qh->hpn", bq, dtx, decay_out)
    chunk_decay = jnp.exp(cum[-1, :])                       # (bh,)
    state_ref[...] = state * chunk_decay[:, None, None] + new_contrib

    dskip = dskip_ref[...].astype(jnp.float32)              # (bh,)
    y = y_intra + y_inter + x * dskip[None, :, None]
    y_ref[0, 0] = y.astype(y_ref.dtype)

    @pl.when(ci == nc - 1)
    def _final():
        final_ref[0] = state_ref[...]


def ssd_scan_pallas(x, dt, a_log, b, c, d_skip, init_state, *,
                    block_heads: int = 8,
                    interpret: bool = True):
    """Inputs pre-chunked & group-expanded (see module docstring)."""
    B_, nc, q, h, p = x.shape
    n = b.shape[-1]
    bh = min(block_heads, h)
    assert h % bh == 0, (h, bh)
    grid = (B_, h // bh, nc)

    scratch = [_VMEM((bh, p, n), jnp.float32)] if _VMEM is not None else \
        [jax.ShapeDtypeStruct((bh, p, n), jnp.float32)]

    kernel = functools.partial(_kernel, nc=nc, q=q)
    y, final = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, q, bh, p), lambda bi, hi, ci: (bi, ci, 0, hi, 0)),
            pl.BlockSpec((1, 1, q, bh), lambda bi, hi, ci: (bi, ci, 0, hi)),
            pl.BlockSpec((bh,), lambda bi, hi, ci: (hi,)),
            pl.BlockSpec((1, 1, q, bh, n), lambda bi, hi, ci: (bi, ci, 0, hi, 0)),
            pl.BlockSpec((1, 1, q, bh, n), lambda bi, hi, ci: (bi, ci, 0, hi, 0)),
            pl.BlockSpec((bh,), lambda bi, hi, ci: (hi,)),
            pl.BlockSpec((1, bh, p, n), lambda bi, hi, ci: (bi, hi, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, q, bh, p), lambda bi, hi, ci: (bi, ci, 0, hi, 0)),
            pl.BlockSpec((1, bh, p, n), lambda bi, hi, ci: (bi, hi, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B_, nc, q, h, p), x.dtype),
            jax.ShapeDtypeStruct((B_, h, p, n), jnp.float32),
        ],
        scratch_shapes=scratch,
        interpret=interpret,
    )(x, dt, a_log, b, c, d_skip, init_state)
    return y, final
