"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def lora_apply_ref(x: jnp.ndarray, w: jnp.ndarray, a: jnp.ndarray,
                   b: jnp.ndarray, scale: float = 1.0) -> jnp.ndarray:
    """y = x @ w + scale * (x @ a.T) @ b.T.

    x (M, K); w (K, N); a (r, K); b (N, r).
    """
    y = x.astype(jnp.float32) @ w.astype(jnp.float32)
    z = x.astype(jnp.float32) @ a.astype(jnp.float32).T
    return (y + scale * (z @ b.astype(jnp.float32).T)).astype(x.dtype)


def batched_lora_apply_ref(x: jnp.ndarray, w: jnp.ndarray,
                           a_pages: jnp.ndarray, b_pages: jnp.ndarray,
                           scales: jnp.ndarray,
                           ids: jnp.ndarray) -> jnp.ndarray:
    """Per-row paged LoRA apply: row t uses adapter page ``ids[t]``.

    x (..., K); ids (...) int32; a_pages (P, r, K); b_pages (P, N, r);
    scales (P,) f32.  y[t] = x[t] @ w + s_p * (x[t] @ A_p^T) @ B_p^T.
    """
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1]).astype(jnp.float32)
    idf = ids.reshape(-1)
    a = a_pages.astype(jnp.float32)[idf]            # (M, r, K)
    b = b_pages.astype(jnp.float32)[idf]            # (M, N, r)
    s = scales.astype(jnp.float32)[idf]
    y = x2 @ w.astype(jnp.float32)
    z = jnp.einsum("mk,mrk->mr", x2, a)
    y = y + s[:, None] * jnp.einsum("mr,mnr->mn", z, b)
    return y.reshape(lead + (w.shape[-1],)).astype(x.dtype)


def rank_partition_agg_ref(bs: jnp.ndarray, as_: jnp.ndarray,
                           omega: jnp.ndarray) -> jnp.ndarray:
    """dW = sum_m B_m diag(omega_m) A_m.

    bs (M, d, r); as_ (M, r, n); omega (M, r). Returns (d, n) f32.
    """
    return jnp.einsum("mdr,mr,mrn->dn", bs.astype(jnp.float32),
                      omega.astype(jnp.float32), as_.astype(jnp.float32))


def factored_stack_ref(bs: jnp.ndarray, as_: jnp.ndarray,
                       omega: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Sqrt-weighted column stacks U_c (d, M*r) / V_c (M*r, n) of
    dW = U_c V_c (DESIGN.md §4.2 layout, client-major column blocks).

    bs (M, d, r); as_ (M, r, n); omega (M, r).
    """
    m, d, r = bs.shape
    n = as_.shape[-1]
    sq = jnp.sqrt(jnp.maximum(omega.astype(jnp.float32), 0.0))
    u = bs.astype(jnp.float32) * sq[:, None, :]
    v = as_.astype(jnp.float32) * sq[:, :, None]
    return (jnp.moveaxis(u, 0, 1).reshape(d, m * r), v.reshape(m * r, n))


def gram_cores_ref(u_c: jnp.ndarray, v_c: jnp.ndarray
                   ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(G_u, G_v) = (U_c^T U_c, V_c V_c^T) -- the (R, R) cores the fused
    kernel accumulates on-chip."""
    u = u_c.astype(jnp.float32)
    v = v_c.astype(jnp.float32)
    return u.T @ u, v @ v.T


def ssd_scan_ref(x: jnp.ndarray, dt: jnp.ndarray, a_log: jnp.ndarray,
                 b: jnp.ndarray, c: jnp.ndarray, d_skip: jnp.ndarray,
                 chunk: int, init_state: Optional[jnp.ndarray] = None
                 ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Oracle = the model's chunked jnp implementation (itself validated
    against a token-by-token recurrence in tests/test_ssd.py)."""
    from repro.models.layers.ssd import ssd_scan_chunked
    return ssd_scan_chunked(x, dt, a_log, b, c, d_skip, chunk,
                            init_state=init_state)


def ssd_scan_sequential_ref(x, dt, a_log, b, c, d_skip,
                            init_state=None):
    """Token-by-token recurrence: the slowest, most obviously-correct form.

    Used to validate BOTH the chunked jnp path and the Pallas kernel.
    """
    B_, L, H, P = x.shape
    G, N = b.shape[-2:]
    A = -jnp.exp(a_log.astype(jnp.float32))
    reps = H // G
    bh = jnp.repeat(b.astype(jnp.float32), reps, axis=2)   # (B,L,H,N)
    ch = jnp.repeat(c.astype(jnp.float32), reps, axis=2)
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    state = (jnp.zeros((B_, H, P, N), jnp.float32)
             if init_state is None else init_state.astype(jnp.float32))

    def step(state, inp):
        xt, dtt, bt, ct = inp                              # (B,H,P),(B,H),...
        decay = jnp.exp(dtt * A[None, :])
        state = state * decay[..., None, None] + jnp.einsum(
            "bhn,bhp,bh->bhpn", bt, xt, dtt)
        y = jnp.einsum("bhn,bhpn->bhp", ct, state)
        return state, y

    inputs = (xf.transpose(1, 0, 2, 3), dtf.transpose(1, 0, 2),
              bh.transpose(1, 0, 2, 3), ch.transpose(1, 0, 2, 3))
    state, ys = jax.lax.scan(step, state, inputs)
    y = ys.transpose(1, 0, 2, 3)
    y = y + xf * d_skip.astype(jnp.float32)[None, None, :, None]
    return y.astype(x.dtype), state


def flash_attention_ref(q, k, v, *, causal=True, window=0):
    """Naive softmax attention oracle for the flash kernel.

    q (B, Lq, H, D); k, v (B, Lkv, KVH, D).
    """
    b, lq, h, d = q.shape
    _, lkv, kvh, _ = k.shape
    g = h // kvh
    qg = q.reshape(b, lq, kvh, g, d)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * d ** -0.5
    qpos = jnp.arange(lq)
    kpos = jnp.arange(lkv)
    mask = jnp.ones((lq, lkv), bool)
    if causal:
        mask = mask & (kpos[None, :] <= qpos[:, None])
    if window > 0:
        mask = mask & (kpos[None, :] > qpos[:, None] - window)
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return o.reshape(b, lq, h, d).astype(q.dtype)
