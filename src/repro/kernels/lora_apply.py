"""Fused LoRA-dense matmul Pallas kernels.

Single-adapter (training-side):  y = x @ W + s * (x @ A^T) @ B^T.
Multi-adapter  (serving-side):   y[m] = x[m] @ W
                                        + s_p * (x[m] @ A_p^T) @ B_p^T,
                                 p = page_of_block(m) -- each request row
                                 gathers its own (A, B, scale) from a paged
                                 adapter cache via scalar-prefetched page
                                 indices (DESIGN.md §11).

TPU rationale (DESIGN.md §4.3): the naive three-matmul composition streams
``x`` from HBM twice and materializes ``z = x A^T`` in HBM. Fusing lets one
pass over x feed both the MXU main matmul and the (tall-skinny) adapter
matmul; the rank-r bottleneck z lives entirely in a VMEM scratch
(bm x r <= 512 x 256 floats), and the adapter correction is applied to the
output tile while it is still resident. Block sizes default to MXU-aligned
(512, 512, 512).

Both wrappers follow the PR-4 pad-to-tile-and-slice convention: non-tile
extents are zero-padded up to the block grid and the result is sliced back,
so callers never need divisible shapes (zero rows/columns are inert in
every product). Grid: (M/bm, N/bn, K/bk), K innermost ("arbitrary"
semantics) so the f32 accumulator and z scratch carry across the K loop.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.rank_partition_agg import _pad_axis

try:  # TPU-specific memory spaces; fall back gracefully off-TPU
    from jax.experimental.pallas import tpu as pltpu
    _VMEM = pltpu.VMEM
except Exception:  # pragma: no cover
    pltpu = None
    _VMEM = None

_HI = jax.lax.Precision.HIGHEST


def _kernel(x_ref, w_ref, a_ref, b_ref, o_ref, acc_ref, z_ref, *,
            scale: float, k_steps: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        z_ref[...] = jnp.zeros_like(z_ref)

    x = x_ref[...].astype(jnp.float32)          # (bm, bk)
    w = w_ref[...].astype(jnp.float32)          # (bk, bn)
    a = a_ref[...].astype(jnp.float32)          # (r, bk)
    acc_ref[...] += jax.lax.dot(x, w, precision=_HI)
    z_ref[...] += jax.lax.dot(x, a.T, precision=_HI)

    @pl.when(k == k_steps - 1)
    def _finalize():
        b = b_ref[...].astype(jnp.float32)      # (bn, r)
        out = acc_ref[...] + scale * jax.lax.dot(
            z_ref[...], b.T, precision=_HI)
        o_ref[...] = out.astype(o_ref.dtype)


def lora_apply_pallas(x: jnp.ndarray, w: jnp.ndarray, a: jnp.ndarray,
                      b: jnp.ndarray, scale: float = 1.0, *,
                      block_m: int = 512, block_n: int = 512,
                      block_k: int = 512,
                      interpret: bool = True) -> jnp.ndarray:
    """x (M, K); w (K, N); a (r, K); b (N, r). Returns (M, N) in x.dtype.

    Extents need NOT divide the block sizes: the wrapper zero-pads every
    dim (m/n/k to its tile, r to the 8-sublane tile) and slices the
    result back -- zero rows of x contribute nothing, zero columns of
    a/b are spectrum-inert (the omega-style padding convention).
    """
    m, k = x.shape
    _, n = w.shape
    bm, bn, bk = min(block_m, m), min(block_n, n), min(block_k, k)
    xp = _pad_axis(_pad_axis(x, 0, bm), 1, bk)
    wp = _pad_axis(_pad_axis(w, 0, bk), 1, bn)
    ap = _pad_axis(_pad_axis(a, 0, 8), 1, bk)
    bp = _pad_axis(_pad_axis(b, 0, bn), 1, 8)
    mp, kp = xp.shape
    np_ = wp.shape[1]
    r = ap.shape[0]
    k_steps = kp // bk
    grid = (mp // bm, np_ // bn, k_steps)

    if _VMEM is not None:
        scratch_shapes = [_VMEM((bm, bn), jnp.float32),
                          _VMEM((bm, r), jnp.float32)]
    else:  # pragma: no cover
        scratch_shapes = [jax.ShapeDtypeStruct((bm, bn), jnp.float32),
                          jax.ShapeDtypeStruct((bm, r), jnp.float32)]

    kernel = functools.partial(_kernel, scale=scale, k_steps=k_steps)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((r, bk), lambda i, j, kk: (0, kk)),
            pl.BlockSpec((bn, r), lambda i, j, kk: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), x.dtype),
        scratch_shapes=scratch_shapes,
        compiler_params=dict(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ) if _VMEM is not None and not interpret else None,
        interpret=interpret,
    )(xp, wp, ap, bp)
    return out[:m, :n]


# ---------------------------------------------------------------------------
# batched multi-adapter kernel (serving path, DESIGN.md §11)
# ---------------------------------------------------------------------------

def _batched_kernel(pages_ref, x_ref, w_ref, a_ref, b_ref, s_ref, o_ref,
                    acc_ref, z_ref, *, k_steps: int):
    """One (row-block, n-block) output tile whose rows all share the page
    selected by the scalar-prefetched ``pages_ref`` -- the A/B BlockSpec
    index maps gather that page's factors straight from the cache, so the
    rank-r bottleneck z stays VMEM-resident per tile exactly as in the
    single-adapter kernel."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        z_ref[...] = jnp.zeros_like(z_ref)

    x = x_ref[...].astype(jnp.float32)          # (bm, bk)
    w = w_ref[...].astype(jnp.float32)          # (bk, bn)
    a = a_ref[0].astype(jnp.float32)            # (r, bk): this block's page
    acc_ref[...] += jax.lax.dot(x, w, precision=_HI)
    z_ref[...] += jax.lax.dot(x, a.T, precision=_HI)

    @pl.when(k == k_steps - 1)
    def _finalize():
        b = b_ref[0].astype(jnp.float32)        # (bn, r)
        out = acc_ref[...] + s_ref[0] * jax.lax.dot(
            z_ref[...], b.T, precision=_HI)
        o_ref[...] = out.astype(o_ref.dtype)


def batched_lora_apply_pallas(x: jnp.ndarray, w: jnp.ndarray,
                              a_pages: jnp.ndarray, b_pages: jnp.ndarray,
                              scales: jnp.ndarray,
                              block_pages: jnp.ndarray, *,
                              block_m: int = 8, block_n: int = 512,
                              block_k: int = 512,
                              interpret: bool = True) -> jnp.ndarray:
    """Paged multi-adapter fused apply.

    x (M, K) with M a multiple of ``block_m`` and every ``block_m`` row
    block single-adapter by construction (the ops wrapper's SGMV grouping
    guarantees this); w (K, N); a_pages (P, r, K); b_pages (P, N, r);
    scales (P,) f32; block_pages (M / block_m,) int32 page index per row
    block. Returns (M, N) in x.dtype.

    n / k / r are padded to tiles here (pad-to-tile-and-slice); padded
    rank columns are zero (omega-style) and therefore inert.
    """
    m, k = x.shape
    _, n = w.shape
    p = a_pages.shape[0]
    bm = block_m
    assert m % bm == 0 and block_pages.shape == (m // bm,), \
        (m, bm, block_pages.shape)
    bn, bk = min(block_n, n), min(block_k, k)
    xp = _pad_axis(x, 1, bk)
    wp = _pad_axis(_pad_axis(w, 0, bk), 1, bn)
    ap = _pad_axis(_pad_axis(a_pages, 1, 8), 2, bk)
    bp = _pad_axis(_pad_axis(b_pages, 1, bn), 2, 8)
    kp = xp.shape[1]
    np_ = wp.shape[1]
    r = ap.shape[1]
    k_steps = kp // bk
    grid = (m // bm, np_ // bn, k_steps)

    if _VMEM is not None:
        scratch_shapes = [_VMEM((bm, bn), jnp.float32),
                          _VMEM((bm, r), jnp.float32)]
    else:  # pragma: no cover
        scratch_shapes = [jax.ShapeDtypeStruct((bm, bn), jnp.float32),
                          jax.ShapeDtypeStruct((bm, r), jnp.float32)]

    kernel = functools.partial(_batched_kernel, k_steps=k_steps)
    if pltpu is None:  # pragma: no cover - non-TPU builds lack prefetch
        raise NotImplementedError("batched lora kernel needs pallas-tpu")
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk, pg: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk, pg: (kk, j)),
            pl.BlockSpec((1, r, bk), lambda i, j, kk, pg: (pg[i], 0, kk)),
            pl.BlockSpec((1, bn, r), lambda i, j, kk, pg: (pg[i], j, 0)),
            pl.BlockSpec((1,), lambda i, j, kk, pg: (pg[i],)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk, pg: (i, j)),
        scratch_shapes=scratch_shapes,
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((m, np_), x.dtype),
        compiler_params=dict(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ) if not interpret else None,
        interpret=interpret,
    )(block_pages.astype(jnp.int32), xp, wp, ap, bp,
      scales.astype(jnp.float32))
    return out[:, :n]
