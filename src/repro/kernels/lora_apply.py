"""Fused LoRA-dense matmul Pallas kernel: y = x @ W + s * (x @ A^T) @ B^T.

TPU rationale (DESIGN.md §4.3): the naive three-matmul composition streams
``x`` from HBM twice and materializes ``z = x A^T`` in HBM. Fusing lets one
pass over x feed both the MXU main matmul and the (tall-skinny) adapter
matmul; the rank-r bottleneck z lives entirely in a VMEM scratch
(bm x r <= 512 x 256 floats), and the adapter correction is applied to the
output tile while it is still resident. Block sizes default to MXU-aligned
(512, 512, 512); r is padded to a multiple of 128 by the ops wrapper.

Grid: (M/bm, N/bn, K/bk), K innermost ("arbitrary" semantics) so the
f32 accumulator and z scratch carry across the K loop.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:  # TPU-specific memory spaces; fall back gracefully off-TPU
    from jax.experimental.pallas import tpu as pltpu
    _VMEM = pltpu.VMEM
except Exception:  # pragma: no cover
    pltpu = None
    _VMEM = None


def _kernel(x_ref, w_ref, a_ref, b_ref, o_ref, acc_ref, z_ref, *,
            scale: float, k_steps: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        z_ref[...] = jnp.zeros_like(z_ref)

    x = x_ref[...].astype(jnp.float32)          # (bm, bk)
    w = w_ref[...].astype(jnp.float32)          # (bk, bn)
    a = a_ref[...].astype(jnp.float32)          # (r, bk)
    acc_ref[...] += jax.lax.dot(x, w, precision=jax.lax.Precision.HIGHEST)
    z_ref[...] += jax.lax.dot(x, a.T, precision=jax.lax.Precision.HIGHEST)

    @pl.when(k == k_steps - 1)
    def _finalize():
        b = b_ref[...].astype(jnp.float32)      # (bn, r)
        out = acc_ref[...] + scale * jax.lax.dot(
            z_ref[...], b.T, precision=jax.lax.Precision.HIGHEST)
        o_ref[...] = out.astype(o_ref.dtype)


def lora_apply_pallas(x: jnp.ndarray, w: jnp.ndarray, a: jnp.ndarray,
                      b: jnp.ndarray, scale: float = 1.0, *,
                      block_m: int = 512, block_n: int = 512,
                      block_k: int = 512,
                      interpret: bool = True) -> jnp.ndarray:
    """x (M, K); w (K, N); a (r, K); b (N, r). Returns (M, N) in x.dtype."""
    m, k = x.shape
    _, n = w.shape
    r = a.shape[0]
    bm, bn, bk = min(block_m, m), min(block_n, n), min(block_k, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (m, n, k, bm, bn, bk)
    k_steps = k // bk
    grid = (m // bm, n // bn, k_steps)

    scratch_shapes = []
    if _VMEM is not None:
        scratch_shapes = [_VMEM((bm, bn), jnp.float32),
                          _VMEM((bm, r), jnp.float32)]
    else:  # pragma: no cover
        scratch_shapes = [jax.ShapeDtypeStruct((bm, bn), jnp.float32),
                          jax.ShapeDtypeStruct((bm, r), jnp.float32)]

    kernel = functools.partial(_kernel, scale=scale, k_steps=k_steps)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((r, bk), lambda i, j, kk: (0, kk)),
            pl.BlockSpec((bn, r), lambda i, j, kk: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        scratch_shapes=scratch_shapes,
        compiler_params=dict(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ) if _VMEM is not None and not interpret else None,
        interpret=interpret,
    )(x, w, a, b)
