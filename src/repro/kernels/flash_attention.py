"""Flash-style causal attention Pallas kernel (TPU).

The fourth perf-critical hot-spot: every assigned architecture except
mamba2 spends most of its prefill/train flops here. The pure-XLA blockwise
path (models/layers/attention.py) streams KV blocks through lax.scan with
f32 online-softmax state in HLO; on TPU each scan step round-trips its
block through HBM and (under TP) the f32 boundary values inflate collective
traffic (measured in EXPERIMENTS.md §Perf C). The kernel keeps the running
max / denominator / accumulator strictly in VMEM scratch.

Layout: grid (batch*kv_heads, q_blocks, kv_blocks); kv innermost
("arbitrary") so the online-softmax state carries in scratch; q/k/v blocks
are MXU-aligned; GQA handled by folding the group dim into the q rows
(q block (G*bq, D) vs kv block (bk, D)).

Masking supports full-causal and sliding-window (static window) -- the
same modes the model uses. Oracle: ref.flash_attention_ref.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu
    _VMEM = pltpu.VMEM
except Exception:  # pragma: no cover
    pltpu = None
    _VMEM = None

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            causal: bool, window: int, block_q: int, block_kv: int,
            n_kv: int, kv_len: int, groups: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32)       # (G*bq, D)
    k = k_ref[0, 0].astype(jnp.float32)       # (bk, D)
    v = v_ref[0, 0].astype(jnp.float32)       # (bk, D)
    d = q.shape[-1]
    s = jax.lax.dot(q, k.T, precision=jax.lax.Precision.HIGHEST)
    s = s * (d ** -0.5)                        # (G*bq, bk)

    # absolute positions: q rows are G groups x bq positions
    row = jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    q_pos = qi * block_q + row % block_q
    kv_pos = ki * block_kv + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = kv_pos < kv_len
    if causal:
        mask &= kv_pos <= q_pos
    if window > 0:
        mask &= kv_pos > q_pos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    p = jnp.exp(s - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot(
        p, v, precision=jax.lax.Precision.HIGHEST)
    m_ref[...] = m_new

    @pl.when(ki == n_kv - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)


def flash_attention_pallas(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                           *, causal: bool = True, window: int = 0,
                           block_q: int = 128, block_kv: int = 128,
                           interpret: bool = True) -> jnp.ndarray:
    """q (B, Lq, H, D); k, v (B, Lkv, KVH, D) -> (B, Lq, H, D).

    Lq/Lkv padded to block multiples by the ops wrapper; H = G * KVH.
    """
    b, lq, h, d = q.shape
    _, lkv, kvh, _ = k.shape
    g = h // kvh
    assert lq % block_q == 0 and lkv % block_kv == 0
    n_q = lq // block_q
    n_kv = lkv // block_kv

    # (B, Lq, KVH, G, D) -> (B*KVH, n_q, G*bq, D)
    qg = q.reshape(b, n_q, block_q, kvh, g, d)
    qg = qg.transpose(0, 3, 1, 4, 2, 5).reshape(b * kvh, n_q,
                                                g * block_q, d)
    kb = k.reshape(b, n_kv, block_kv, kvh, d).transpose(0, 3, 1, 2, 4)
    kb = kb.reshape(b * kvh, n_kv, block_kv, d)
    vb = v.reshape(b, n_kv, block_kv, kvh, d).transpose(0, 3, 1, 2, 4)
    vb = vb.reshape(b * kvh, n_kv, block_kv, d)

    grid = (b * kvh, n_q, n_kv)
    rows = g * block_q
    scratch = ([_VMEM((rows, d), jnp.float32), _VMEM((rows,), jnp.float32),
                _VMEM((rows,), jnp.float32)] if _VMEM is not None else
               [jax.ShapeDtypeStruct((rows, d), jnp.float32),
                jax.ShapeDtypeStruct((rows,), jnp.float32),
                jax.ShapeDtypeStruct((rows,), jnp.float32)])
    kernel = functools.partial(
        _kernel, causal=causal, window=window, block_q=block_q,
        block_kv=block_kv, n_kv=n_kv, kv_len=lkv, groups=g)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, rows, d), lambda bh, qi, ki: (bh, qi, 0, 0)),
            pl.BlockSpec((1, 1, block_kv, d),
                         lambda bh, qi, ki: (bh, ki, 0, 0)),
            pl.BlockSpec((1, 1, block_kv, d),
                         lambda bh, qi, ki: (bh, ki, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, rows, d),
                               lambda bh, qi, ki: (bh, qi, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b * kvh, n_q, rows, d), q.dtype),
        scratch_shapes=scratch,
        interpret=interpret,
    )(qg, kb, vb)
    # (B*KVH, n_q, G*bq, D) -> (B, Lq, H, D)
    out = out.reshape(b, kvh, n_q, g, block_q, d)
    out = out.transpose(0, 2, 4, 1, 3, 5).reshape(b, lq, h, d)
    return out
