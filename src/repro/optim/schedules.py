"""Learning-rate schedules (paper: linear decay per round)."""
from __future__ import annotations


def linear_decay(base_lr: float, num_rounds: int):
    """Paper setting: lr decays linearly over communication rounds."""
    def schedule(round_idx: int) -> float:
        frac = 1.0 - round_idx / max(num_rounds, 1)
        return base_lr * max(frac, 0.0)
    return schedule


def constant(base_lr: float):
    def schedule(round_idx: int) -> float:
        return base_lr
    return schedule


def get_schedule(name: str, base_lr: float, num_rounds: int):
    if name == "linear":
        return linear_decay(base_lr, num_rounds)
    if name == "constant":
        return constant(base_lr)
    raise ValueError(f"unknown schedule {name!r}")
