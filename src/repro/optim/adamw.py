"""AdamW on pytrees (supports None leaves -- lora-only trees)."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    mu: Any
    nu: Any


@dataclass(frozen=True)
class AdamW:
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0

    def init(self, params) -> AdamWState:
        zeros = jax.tree.map(
            lambda p: None if p is None else jnp.zeros_like(p, jnp.float32),
            params, is_leaf=lambda x: x is None)
        return AdamWState(jnp.zeros((), jnp.int32), zeros, zeros)

    def update(self, grads, state: AdamWState, params, lr) -> tuple:
        step = state.step + 1
        b1, b2 = self.b1, self.b2

        def upd(g, m, v, p):
            if g is None:
                return None, None, None
            g32 = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g32
            v = b2 * v + (1 - b2) * jnp.square(g32)
            mhat = m / (1 - b1 ** step)
            vhat = v / (1 - b2 ** step)
            delta = mhat / (jnp.sqrt(vhat) + self.eps)
            if self.weight_decay:
                delta = delta + self.weight_decay * p.astype(jnp.float32)
            new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
            return new_p, m, v

        flat = jax.tree.map(upd, grads, state.mu, state.nu, params,
                            is_leaf=lambda x: x is None)
        is3 = lambda x: isinstance(x, tuple) and len(x) == 3
        new_params = jax.tree.map(lambda t: t[0], flat, is_leaf=is3)
        mu = jax.tree.map(lambda t: t[1], flat, is_leaf=is3)
        nu = jax.tree.map(lambda t: t[2], flat, is_leaf=is3)
        return new_params, AdamWState(step, mu, nu)
