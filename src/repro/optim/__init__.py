from repro.optim.adamw import AdamW, AdamWState
from repro.optim.schedules import constant, get_schedule, linear_decay

__all__ = ["AdamW", "AdamWState", "constant", "get_schedule", "linear_decay"]
