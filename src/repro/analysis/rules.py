"""Rule-engine core for the program-audit subsystem (DESIGN.md §8).

A *rule* is a named, documented predicate over one analyzed program; a
*pass* (hlo_lint / jaxpr_lint / pallas_lint / dispatch_audit) is a
``RuleSet`` of rules sharing one payload type. Rules are declarative: each
one receives a ``ProgramContext`` -- the parsed program plus per-program
``meta`` thresholds -- and yields ``(message, location)`` pairs for every
violation; the engine wraps them into ``Finding`` records tagged with the
rule id, severity and program name. A rule that needs a threshold the
caller did not provide in ``meta`` must yield nothing (rules are
opt-in-by-configuration, so one RuleSet serves every program in the
engine x backend x METHODS matrix without per-program rule lists).

Adding a rule::

    @MY_RULES.rule("pass-short-name", "one-line description")
    def _check_short_name(ctx):
        limit = ctx.meta.get("my_limit")
        if limit is None:
            return
        for thing in ctx.payload.things:
            if thing.size > limit:
                yield f"{thing.size} > {limit}", thing.name
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, Iterable, Iterator, List, Optional, Tuple

SEV_ERROR = "error"
SEV_WARNING = "warning"


@dataclass(frozen=True)
class Finding:
    """One rule violation in one program."""
    rule: str
    severity: str
    program: str
    message: str
    location: str = ""

    def to_json(self) -> dict:
        return {"rule": self.rule, "severity": self.severity,
                "message": self.message, "location": self.location}

    def __str__(self) -> str:
        loc = f" @ {self.location}" if self.location else ""
        return f"[{self.severity}] {self.program}: {self.rule}{loc}: " \
               f"{self.message}"


@dataclass(frozen=True)
class Rule:
    id: str
    description: str
    check: Callable[["ProgramContext"], Optional[Iterable]]
    severity: str = SEV_ERROR


@dataclass
class ProgramContext:
    """One analyzed program handed to every rule of a RuleSet.

    ``payload`` is pass-specific (parsed HLO, a jaxpr, kernel launch
    records, dispatch counters); ``meta`` carries the per-program
    thresholds that arm the opt-in rules.
    """
    program: str                      # e.g. "batched/raflora/kernel"
    kind: str                         # "hlo" | "jaxpr" | "pallas" | "dispatch"
    payload: object
    meta: Dict = field(default_factory=dict)


class RuleSet:
    """An ordered, id-unique collection of rules for one program kind."""

    def __init__(self, kind: str):
        self.kind = kind
        self._rules: Dict[str, Rule] = {}

    def rule(self, rule_id: str, description: str,
             severity: str = SEV_ERROR):
        """Decorator registering ``fn(ctx) -> iterable of (msg, loc)|msg``."""
        def deco(fn):
            self.register(Rule(rule_id, description, fn, severity))
            return fn
        return deco

    def register(self, rule: Rule) -> None:
        if rule.id in self._rules:
            raise ValueError(f"duplicate rule id {rule.id!r}")
        self._rules[rule.id] = rule

    @property
    def rules(self) -> Tuple[Rule, ...]:
        return tuple(self._rules.values())

    def run(self, ctx: ProgramContext,
            only: Optional[Iterable[str]] = None) -> List[Finding]:
        """All findings of (optionally a subset of) this set's rules."""
        wanted = set(only) if only is not None else None
        findings: List[Finding] = []
        for rule in self._rules.values():
            if wanted is not None and rule.id not in wanted:
                continue
            for hit in rule.check(ctx) or ():
                if isinstance(hit, Finding):
                    findings.append(hit)
                    continue
                if isinstance(hit, str):
                    msg, loc = hit, ""
                else:
                    msg, loc = hit
                findings.append(Finding(rule.id, rule.severity, ctx.program,
                                        msg, loc))
        return findings


def iter_catalog(*rulesets: RuleSet) -> Iterator[Tuple[str, Rule]]:
    """(pass-kind, rule) pairs -- the DESIGN.md §8 rule-catalog view."""
    for rs in rulesets:
        for rule in rs.rules:
            yield rs.kind, rule
