"""dispatch_audit: count jit cache misses, XLA compiles and eager
dispatches across a multi-round run; assert steady-state rounds compile
NOTHING new.

PR 3 fixed, by hand, a class of regressions where the async round path
retraced jitted programs every round (shape-varying arguments) or leaked
eager ops into the host loop (each one a device sync serializing against
in-flight work). This pass turns that discipline into a gate:

  * jit cache misses  -- ``jax.monitoring`` duration events: every miss of
    the pjit cache fires ``/jax/core/compile/jaxpr_trace_duration``; every
    actual XLA compile fires ``.../backend_compile_duration``. With the
    persistent compilation cache warm, a retrace still fires the trace
    event -- exactly the signal we gate (retraces cost host time and
    indicate shape instability even when XLA's binary is cached).
  * eager binds -- ``core.EvalTrace.process_primitive`` is patched while
    the monitor is active; classic eager op dispatches (the ones that
    synchronize the host) route through it. jit-backed jnp calls do not.

Usage::

    mon = DispatchMonitor()
    with mon:
        for r in range(rounds):
            run_round(r)
            mon.mark(f"round{r}")
    findings = lint_dispatch(mon, "audit/steady", meta={"warmup": 2})

Rules:

  dispatch-steady-state-recompile  any phase after meta['warmup'] with a
                                   jit trace or an XLA compile
  dispatch-eager-budget            eager binds per steady phase above
                                   meta['max_eager_per_phase'] (opt-in)

The monitoring listener is registered once per process and gated by the
active monitor (jax.monitoring has no unregister API).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterable, List, Optional

from repro.analysis.rules import ProgramContext, RuleSet

_TRACE_EVENT = "/jax/core/compile/jaxpr_trace_duration"
_COMPILE_EVENT = "/jax/core/compile/backend_compile_duration"


@dataclass
class PhaseCounters:
    label: str
    traces: int = 0
    compiles: int = 0
    eager_binds: int = 0

    def to_json(self) -> dict:
        return {"label": self.label, "traces": self.traces,
                "compiles": self.compiles, "eager_binds": self.eager_binds}


_ACTIVE_MONITOR: Optional["DispatchMonitor"] = None
_LISTENER_INSTALLED = False


def _duration_listener(event: str, duration: float, **kwargs) -> None:
    mon = _ACTIVE_MONITOR
    if mon is None:
        return
    if event == _TRACE_EVENT:
        mon._traces += 1
    elif event == _COMPILE_EVENT:
        mon._compiles += 1


def _install_listener() -> None:
    global _LISTENER_INSTALLED
    if _LISTENER_INSTALLED:
        return
    import jax
    jax.monitoring.register_event_duration_secs_listener(_duration_listener)
    _LISTENER_INSTALLED = True


class DispatchMonitor:
    """Context manager accumulating per-phase dispatch counters."""

    def __init__(self):
        self._traces = 0
        self._compiles = 0
        self._eager = 0
        self._last = (0, 0, 0)
        self.phases: List[PhaseCounters] = []
        self._orig_process = None

    def __enter__(self):
        global _ACTIVE_MONITOR
        if _ACTIVE_MONITOR is not None:
            raise RuntimeError("nested DispatchMonitor")
        _install_listener()
        _ACTIVE_MONITOR = self
        from jax._src import core as jcore
        self._orig_process = jcore.EvalTrace.process_primitive
        mon = self

        def counting_process(trace_self, primitive, tracers, params):
            mon._eager += 1
            return mon._orig_process(trace_self, primitive, tracers,
                                     params)

        jcore.EvalTrace.process_primitive = counting_process
        self._last = (0, 0, 0)
        return self

    def __exit__(self, *exc):
        global _ACTIVE_MONITOR
        _ACTIVE_MONITOR = None
        from jax._src import core as jcore
        if self._orig_process is not None:
            jcore.EvalTrace.process_primitive = self._orig_process
        return False

    def mark(self, label: str) -> PhaseCounters:
        """Close the current phase: counters since the previous mark."""
        now = (self._traces, self._compiles, self._eager)
        ph = PhaseCounters(label, traces=now[0] - self._last[0],
                           compiles=now[1] - self._last[1],
                           eager_binds=now[2] - self._last[2])
        self._last = now
        self.phases.append(ph)
        return ph

    def stats(self) -> dict:
        return {
            "phases": [p.to_json() for p in self.phases],
            "total_traces": self._traces,
            "total_compiles": self._compiles,
            "total_eager_binds": self._eager,
        }


DISPATCH_RULES = RuleSet("dispatch")


@DISPATCH_RULES.rule(
    "dispatch-steady-state-recompile",
    "after the first meta['warmup'] phases (default 1), no phase may jit-"
    "trace or XLA-compile anything: steady-state rounds reuse compiled "
    "programs bit-for-bit (shape-stable arguments, warm jit caches)")
def _check_steady_state(ctx: ProgramContext):
    warmup = ctx.meta.get("warmup", 1)
    for ph in ctx.payload.phases[warmup:]:
        if ph.traces or ph.compiles:
            yield (f"{ph.traces} jit trace(s) + {ph.compiles} XLA "
                   f"compile(s) in steady-state phase", ph.label)


@DISPATCH_RULES.rule(
    "dispatch-eager-budget",
    "eager primitive binds per steady-state phase within "
    "meta['max_eager_per_phase'] (each eager op is a host->device "
    "round-trip; opt-in threshold)")
def _check_eager_budget(ctx: ProgramContext):
    budget = ctx.meta.get("max_eager_per_phase")
    if budget is None:
        return
    warmup = ctx.meta.get("warmup", 1)
    for ph in ctx.payload.phases[warmup:]:
        if ph.eager_binds > budget:
            yield (f"{ph.eager_binds} eager binds > budget {budget}",
                   ph.label)


def lint_dispatch(monitor: DispatchMonitor, program: str,
                  meta: Optional[dict] = None,
                  only: Optional[Iterable[str]] = None):
    ctx = ProgramContext(program=program, kind="dispatch", payload=monitor,
                         meta=dict(meta or {}))
    return DISPATCH_RULES.run(ctx, only=only)
