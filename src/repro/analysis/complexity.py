"""Complexity certifier: scaling-law contracts over measured cost vectors.

The PR-6 lint rules check each program at ONE shape; this module checks
the *exponents*. A sweep (``tools/certify_scaling.py``) lowers every
engine x backend x method program at a geometric ladder of problem sizes
along each axis and extracts a cost vector per point:

  device metrics (from ``launch/hlo_walker`` + ``analysis/liveness``)
      dot_flops, hbm_bytes, collective_bytes, collective_count,
      peak_live_bytes
  host metrics (from ``analysis/host_cost`` over real tiny rounds)
      host_loop_iters, host_alloc_bytes

Per (axis, metric) we fit a log-log least-squares slope -- the empirical
scaling exponent -- and gate it against the declared CONTRACTS catalog:
e.g. factored/kernel aggregation flops and peak-live bytes must stay
~linear along the joint d=n axis (the O((d+n)R) claim), sharded
collective bytes must track the factor perimeter rather than d*n, the
per-bucket psum count must not grow with shard count, and per-round host
cost must be independent of registry size (the ROADMAP million-client
tripwire). The dense backend carries *min*-slope contracts: it MUST
certify O(d*n) -- if the dense positive control stops looking quadratic,
the measurement pipeline itself is broken.

Joint-axis design note: a single-axis d ladder cannot separate O(d*n)
from O((d+n)R) -- both are linear in d alone. The distinguishing axis is
"dn" (d = n = s scaled together): dense slope ~2, factored/kernel ~1.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field, replace
from typing import Dict, List, Optional, Sequence, Tuple

from repro.analysis.rules import Finding, ProgramContext, RuleSet

METRICS = ("dot_flops", "hbm_bytes", "collective_bytes",
           "collective_count", "peak_live_bytes", "host_loop_iters",
           "host_alloc_bytes")

_EPS = 1e-9


def fit_slope(xs: Sequence[float], ys: Sequence[float]) -> float:
    """Log-log least-squares slope of ``ys`` against ``xs``.

    An all-zero series fits as slope 0 (a metric that never appears
    scales as O(1)); isolated zeros are clamped to a tiny epsilon, so a
    cost that *appears* along the ladder (0 -> positive) yields a huge
    positive slope and trips any max-slope contract -- the conservative
    reading.
    """
    if len(xs) != len(ys) or len(xs) < 2:
        raise ValueError("need >= 2 aligned points")
    if all(y <= 0 for y in ys):
        return 0.0
    lx = [math.log(float(x)) for x in xs]
    ly = [math.log(max(float(y), _EPS)) for y in ys]
    n = float(len(lx))
    mx, my = sum(lx) / n, sum(ly) / n
    den = sum((a - mx) ** 2 for a in lx)
    if den == 0.0:
        raise ValueError("degenerate ladder: all x equal")
    return sum((a - mx) * (b - my) for a, b in zip(lx, ly)) / den


@dataclass(frozen=True)
class Measurement:
    """One ladder point: the cost vector at coordinate ``x`` of ``axis``."""
    axis: str
    x: float
    costs: Dict[str, float]


@dataclass
class ScalingRow:
    """All measurements for one program (or the host round path)."""

    program: str                      # e.g. "batched/raflora/kernel"
    engine: str
    method: str
    backend: str
    measurements: List[Measurement] = field(default_factory=list)

    def axes(self) -> List[str]:
        seen = []
        for m in self.measurements:
            if m.axis not in seen:
                seen.append(m.axis)
        return seen

    def slopes(self) -> Dict[Tuple[str, str], float]:
        """{(axis, metric): fitted exponent} over every measured axis."""
        out: Dict[Tuple[str, str], float] = {}
        for axis in self.axes():
            pts = sorted((m for m in self.measurements if m.axis == axis),
                         key=lambda m: m.x)
            if len(pts) < 2:
                continue
            xs = [p.x for p in pts]
            metrics = sorted({k for p in pts for k in p.costs})
            for met in metrics:
                ys = [p.costs.get(met, 0.0) for p in pts]
                out[(axis, met)] = fit_slope(xs, ys)
        return out

    def stats(self) -> dict:
        """JSON view for the audit artifact (slopes rounded for diff
        stability; contracts are evaluated on the unrounded values)."""
        ladder = {}
        for axis in self.axes():
            ladder[axis] = sorted(
                {m.x for m in self.measurements if m.axis == axis})
        return {
            "slopes": {f"{axis}/{met}": round(v, 3) + 0.0  # kill -0.0
                       for (axis, met), v in sorted(self.slopes().items())},
            "ladder": ladder,
        }


@dataclass(frozen=True)
class Contract:
    """A declared bound on one (axis, metric) exponent for a slice of the
    program matrix. ``None`` selectors match anything."""

    name: str
    metric: str
    axis: str
    max_slope: Optional[float] = None
    min_slope: Optional[float] = None
    engines: Optional[Tuple[str, ...]] = None
    methods: Optional[Tuple[str, ...]] = None
    backends: Optional[Tuple[str, ...]] = None
    note: str = ""

    def applies(self, engine: str, method: str, backend: str) -> bool:
        return ((self.engines is None or engine in self.engines)
                and (self.methods is None or method in self.methods)
                and (self.backends is None or backend in self.backends))


_SVD = ("flexlora", "raflora")
_LOWRANK = ("factored", "kernel")

# -- the contract catalog ---------------------------------------------------
# max_slope headroom: a pure O(s) series fits 1.0 exactly; constant-plus-
# linear terms and lane padding bend small-ladder fits by ~0.2, so linear
# claims gate at 1.35 and quadratic certifications at >= 1.6.
CONTRACTS: Tuple[Contract, ...] = (
    # O((d+n)R) aggregation: flops / resident set / HBM traffic of the
    # low-rank backends stay ~linear when d and n scale TOGETHER
    Contract("agg-flops-linear-dn", "dot_flops", "dn", max_slope=1.35,
             methods=_SVD, backends=_LOWRANK,
             note="SVD-family low-rank aggregation flops ~ O((d+n)R M)"),
    Contract("agg-live-linear-dn", "peak_live_bytes", "dn", max_slope=1.35,
             methods=_SVD, backends=("factored",),
             note="no (d, n)-scale resident intermediate on the low-rank "
                  "path"),
    Contract("agg-live-linear-dn-kernel", "peak_live_bytes", "dn",
             max_slope=1.35, methods=_SVD, backends=("kernel",),
             engines=("sequential", "batched", "async", "event"),
             note="kernel-backend resident set stays linear on the "
                  "single-device engines; sharded rows are excluded -- "
                  "their CPU interpret-mode grid lowers to a while loop "
                  "whose carried tuple holds whole padded stack buffers "
                  "(liveness sees the carry, an interpreter artifact; the "
                  "sharded kernel path is gated via flops, collectives "
                  "and the shards axis instead)"),
    Contract("agg-hbm-linear-dn", "hbm_bytes", "dn", max_slope=1.35,
             methods=_SVD, backends=("factored",),
             note="HBM traffic tracks the factor perimeter, not the "
                  "product (factored only: the kernel backend's CPU "
                  "interpret-mode grid loop carries whole-buffer copies "
                  "per step, an artifact gated via flops + live instead)"),
    Contract("avg-live-linear-dn", "peak_live_bytes", "dn", max_slope=1.35,
             methods=("fedavg", "hetlora", "ffa"),
             note="averaging-family aggregation never forms B@A (flora's "
                  "dense merge_delta is by design and excluded)"),
    # communication: sharded collective bytes follow the factors; the
    # per-bucket psum count is independent of the shard count
    Contract("collective-linear-dn", "collective_bytes", "dn",
             max_slope=1.35, engines=("sharded",), methods=_SVD,
             backends=_LOWRANK,
             note="collective bytes ~ d*W + W*n beyond the factor term, "
                  "never d*n"),
    Contract("collective-count-shards", "collective_count", "shards",
             max_slope=0.2, engines=("sharded",),
             note="one psum per bucket regardless of mesh size"),
    # cohort / rank axes: Gram-style cores are quadratic in the stacked
    # width M*R (that IS the O((d+n) R^2 M^2) SVD-realloc budget) but must
    # not go cubic
    Contract("agg-flops-cohort", "dot_flops", "m", max_slope=2.4,
             methods=_SVD, backends=_LOWRANK,
             note="stacked-width Gram/QR cost <= quadratic in cohort"),
    Contract("agg-flops-rank", "dot_flops", "r", max_slope=2.5,
             methods=_SVD, backends=_LOWRANK,
             note="stacked-width Gram/QR cost <= quadratic in r_max"),
    Contract("avg-flops-cohort", "dot_flops", "m", max_slope=1.4,
             methods=("fedavg", "hetlora", "ffa", "flora"),
             note="weighted averaging is linear in cohort size"),
    # positive-control contracts: the dense backend MUST look quadratic
    # along dn -- if it stops certifying O(d*n) the ladder, the walker or
    # the liveness pass is broken, not the backend
    Contract("dense-cert-flops", "dot_flops", "dn", min_slope=1.6,
             methods=_SVD, backends=("dense",),
             note="dense backend certifies O(d*n) flops (measurement "
                  "positive control)"),
    Contract("dense-cert-live", "peak_live_bytes", "dn", min_slope=1.6,
             methods=_SVD, backends=("dense",),
             note="dense backend certifies an O(d*n) resident buffer"),
    # host round path: per-round cost tracks the cohort, NEVER the
    # registry (ROADMAP million-client tripwire)
    Contract("host-registry-iters", "host_loop_iters", "registry",
             max_slope=0.15, engines=("host",),
             note="per-round host loop iterations independent of "
                  "registered-client count"),
    Contract("host-registry-alloc", "host_alloc_bytes", "registry",
             max_slope=0.15, engines=("host",),
             note="per-round host ndarray bytes independent of "
                  "registered-client count"),
    Contract("host-cohort-iters", "host_loop_iters", "m", min_slope=0.3,
             max_slope=1.5, engines=("host",),
             note="per-round host work scales with the sampled cohort "
                  "(sublinear would mean the counters went dead)"),
)


def contracts_catalog() -> Tuple[Contract, ...]:
    return CONTRACTS


SCALING_RULES = RuleSet("scaling")


@SCALING_RULES.rule(
    "scaling-contract",
    "every fitted (axis, metric) exponent of the program stays inside the "
    "declared complexity contract bounds (meta['contracts'])")
def _check_contracts(ctx: ProgramContext):
    contracts = ctx.meta.get("contracts")
    if not contracts:
        return
    row = ctx.payload
    slopes = row.slopes()
    for c in contracts:
        if not c.applies(row.engine, row.method, row.backend):
            continue
        s = slopes.get((c.axis, c.metric))
        if s is None:
            continue                  # axis not measured for this row
        if c.max_slope is not None and s > c.max_slope:
            yield (f"{c.name}: {c.metric} ~ {c.axis}^{s:.2f} exceeds "
                   f"max exponent {c.max_slope} ({c.note})",
                   f"{c.axis}/{c.metric}")
        if c.min_slope is not None and s < c.min_slope:
            yield (f"{c.name}: {c.metric} ~ {c.axis}^{s:.2f} below "
                   f"min exponent {c.min_slope} ({c.note})",
                   f"{c.axis}/{c.metric}")


def evaluate_row(row: ScalingRow,
                 contracts: Sequence[Contract] = CONTRACTS
                 ) -> List[Finding]:
    """Findings for every contract the row's fitted exponents violate."""
    ctx = ProgramContext(program=row.program, kind="scaling", payload=row,
                         meta={"contracts": tuple(contracts)})
    return SCALING_RULES.run(ctx)


def dense_control_contracts() -> Tuple[Contract, ...]:
    """The linear (low-rank path) contracts re-targeted at the dense
    backend: evaluating a dense row against THESE must produce findings.
    A dense row sliding under them means the tripwire is dead."""
    out = []
    for c in CONTRACTS:
        if (c.max_slope is not None and c.backends
                and set(c.backends) <= set(_LOWRANK)):
            out.append(replace(c, backends=("dense",),
                               name=c.name + "@dense-control"))
    return tuple(out)


def device_costs(lowered) -> Dict[str, float]:
    """Cost vector of one lowered program (``lowering.LoweredProgram``)."""
    stats = lowered.payload.stats
    return {
        "dot_flops": float(stats.dot_flops),
        "hbm_bytes": float(stats.hbm_bytes),
        "collective_bytes": float(stats.total_collective_bytes),
        "collective_count": float(sum(stats.collective_counts.values())),
        "peak_live_bytes": float(lowered.liveness.peak_live_bytes),
    }
