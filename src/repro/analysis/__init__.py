"""Program-audit subsystem (DESIGN.md §8): declarative lint rules over the
three program representations this repo ships -- optimized HLO, jaxprs and
Pallas kernel launch parameters -- plus a runtime dispatch/recompile
auditor. Four passes share one rule-engine core:

  hlo_lint        rules over parsed optimized HLO (``launch/hlo_walker``):
                  (d, n)-materialization scale, collective count/byte
                  budgets, host-transfer ops, dtype upcasts
  jaxpr_lint      rules over traced round-path jaxprs: host callbacks,
                  host-sync primitives, f64 promotions
  pallas_lint     static validation of every registered Pallas kernel:
                  BlockSpec/grid consistency, pad-to-tile coverage,
                  per-grid-step VMEM footprint vs budget
  dispatch_audit  counts jit cache misses / XLA compiles / eager binds
                  across a multi-round run; steady-state rounds must
                  compile nothing new

``tools/lint_programs.py`` sweeps the engine x backend x METHODS matrix
through all four and writes the tracked ``AUDIT_program_lint.json``;
``tools/ci.sh lint`` gates it.

Two further passes verify the federated protocol itself (DESIGN.md §10):

  protocol        exhaustive bounded-interleaving model checking of the
                  event round path against the REAL scheduler/aggregation
                  objects: exactly-once consumption, the ghost/present-
                  mask weight rule, bounded staleness, cancellation, and
                  checkpoint-cut replay at every reachable boundary
  rng_lint        PRNG key-provenance dataflow over round-path jaxprs
                  (key reuse, sample-then-derive) + host-determinism AST
                  rules (unseeded default_rng, host-clock reads, seed
                  collisions, set-order iteration)

``tools/verify_protocol.py`` sweeps both and writes the tracked
``AUDIT_protocol.json``; ``tools/ci.sh verify`` gates it in tier-1.
"""
from repro.analysis.rules import (Finding, ProgramContext, Rule, RuleSet,
                                  SEV_ERROR, SEV_WARNING)
from repro.analysis.report import AuditReport, ProgramAudit

__all__ = [
    "Finding", "ProgramContext", "Rule", "RuleSet", "SEV_ERROR",
    "SEV_WARNING", "AuditReport", "ProgramAudit",
]
