"""Peak-live-bytes estimate from a liveness pass over the parsed HLO op
schedule.

``hlo_walker.analyze_hlo`` accumulates *traffic* (flops / collective /
HBM bytes); it says nothing about the largest *resident* working set. A
program can keep its dot flops at O((d+n)R) while still materializing a
(d, n) temporary -- the exact failure mode the dense backend exhibits by
design and the factored / kernel backends must never regress into. This
pass walks each computation's op list in printed schedule order (XLA's
textual order IS a valid schedule: operands are defined before use) and
tracks the sum of live buffer bytes:

  * an op's result buffer goes live at its definition and dies after its
    last textual use inside the computation (the root result stays live
    to the end);
  * parameters are live from the top (they are the caller's buffers, but
    counting them keeps the estimate comparable across call boundaries);
  * a call site (``while`` / ``call`` / ``conditional`` / ``reduce``...)
    transiently adds the callee's own peak on top of the caller's live
    set -- a consistent over-estimate (real buffer assignment may alias
    loop carries) that preserves scaling exponents;
  * ``fusion`` bodies are virtual: only the fusion's result buffer
    counts, matching the walker's HBM model.

The absolute number over-counts versus XLA's buffer assignment (no
aliasing, tuples double-count their elements); what the complexity
certifier consumes is the *slope* of this estimate along a size ladder,
for which a consistent over-estimate is exactly as good as the truth.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.launch.hlo_walker import (_bytes_of, callee_names, Computation,
                                     OpInfo, parse_hlo)

_OPERAND_NAME = re.compile(r"%?([\w\.\-]+)\s*$")


@dataclass
class LivenessStats:
    """Result of :func:`analyze_liveness`."""

    peak_live_bytes: float = 0.0
    peak_location: str = ""           # "computation/op" at the peak
    comp_peaks: Dict[str, float] = field(default_factory=dict)


def _operand_names(op: OpInfo, comp: Computation) -> List[str]:
    """Operand symbols of ``op`` that name values of this computation.

    Parses the first parenthesized group of the op tail; each comma-
    separated piece ends in the operand symbol (possibly preceded by an
    inline type like ``f32[128,8]{1,0} %stack.3``). Attribute references
    (``body=%region_0``) live outside the parens and computation names
    are filtered out via the symbol table.
    """
    lp = op.rest.find("(")
    if lp < 0:
        return []
    depth, rp = 0, -1
    for i in range(lp, len(op.rest)):
        c = op.rest[i]
        if c == "(":
            depth += 1
        elif c == ")":
            depth -= 1
            if depth == 0:
                rp = i
                break
    if rp < 0:
        return []
    inner = op.rest[lp + 1:rp]
    names = []
    for piece in inner.split(","):
        m = _OPERAND_NAME.search(piece.strip())
        if m and m.group(1) in comp.symbol_types:
            names.append(m.group(1))
    return names


def _schedule_liveness(comp: Computation, peak_of) -> Tuple[float, str]:
    """Peak live bytes of one computation, callee peaks via ``peak_of``."""
    last_use: Dict[str, int] = {}
    operands_per_op: List[List[str]] = []
    for i, op in enumerate(comp.ops):
        names = _operand_names(op, comp)
        operands_per_op.append(names)
        for nm in names:
            last_use[nm] = i
    if not comp.ops:
        return 0.0, ""
    root = comp.ops[-1].name

    alive: Dict[str, float] = {}
    live = 0.0
    peak, loc = 0.0, ""
    for i, op in enumerate(comp.ops):
        b = float(_bytes_of(op.result_type))
        alive[op.name] = b
        live += b
        # transient callee peak at this op (fusion bodies are virtual)
        transient = 0.0
        if op.opcode != "fusion":
            for callee in callee_names(op.rest):
                transient = max(transient, peak_of(callee))
        if live + transient > peak:
            peak, loc = live + transient, f"{comp.name}/{op.name}"
        # free operands whose last use is this op
        for nm in set(operands_per_op[i]):
            if last_use.get(nm) == i and nm in alive and nm != root:
                live -= alive.pop(nm)
        # a result that is never read dies immediately (except the root)
        if op.name not in last_use and op.name != root:
            live -= alive.pop(op.name)
    return peak, loc


def analyze_liveness(text: str) -> LivenessStats:
    """Peak-live-bytes estimate of an optimized HLO module (see module
    docstring for the model)."""
    comps = parse_hlo(text)
    entry: Optional[str] = comps.pop("__entry_name__", None)  # type: ignore
    comps.pop("__entry__", None)

    memo: Dict[str, float] = {}
    locs: Dict[str, str] = {}

    def peak_of(name: str) -> float:
        if name in memo:
            return memo[name]
        memo[name] = 0.0            # cycle guard (HLO call graphs are DAGs)
        comp = comps.get(name)
        if comp is None:
            return 0.0
        p, loc = _schedule_liveness(comp, peak_of)
        memo[name], locs[name] = p, loc
        return p

    stats = LivenessStats()
    if entry is not None and entry in comps:
        stats.peak_live_bytes = peak_of(entry)
        stats.peak_location = locs.get(entry, "")
    else:                           # headerless fragment: largest comp wins
        for name in comps:
            p = peak_of(name)
            if p > stats.peak_live_bytes:
                stats.peak_live_bytes = p
                stats.peak_location = locs.get(name, "")
    stats.comp_peaks = dict(memo)
    return stats


def peak_live_bytes(text: str) -> float:
    """Convenience: just the entry peak."""
    return analyze_liveness(text).peak_live_bytes
