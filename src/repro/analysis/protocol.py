"""Protocol verifier: exhaustive bounded-interleaving model checking of the
event-driven federated round path (DESIGN.md §10).

The event scheduler's promises -- exactly-once consumption, the ghost /
present-mask weight rule, bounded staleness, cancelled arrivals never
aggregated, checkpoint-cut replay equivalence -- were until now backed by
example-based tests. This module checks them over ALL bounded
interleavings of a small federation (~3 clients x 2-3 plans x every
trigger family), the way PRs 6-7 made program shape and asymptotic cost
machine-checked.

The split of responsibilities is the point of the design:

* the MODEL supplies only the event order: each (plan, client) dispatch
  is assigned a latency from a small grid (``Scenario.grid``), and the
  sweep enumerates every assignment;
* the IMPLEMENTATION supplies every transition: runs drive a REAL
  ``events.EventScheduler`` (or a deliberately sabotaged subclass, for
  the positive controls) through the exact consumption protocol
  ``FederatedLoRA`` uses -- ``dispatch`` / ``advance_window`` /
  ``take_ready`` / ``completed_plans`` / ``forget_plan`` / ``drain`` --
  and cohort weights come from the same ``flatten_cohort`` +
  ``cohort_weights`` code the aggregation consumes.

A violation is therefore a finding against the implementation, never a
modeling artifact.

Partial-order reduction: the model's choices frequently commute, and the
sweep runs one representative per commuting class (``CheckStats`` records
the reduction). Two mechanisms:

* schedule-signature dedupe: assignments realizing the SAME arrival
  schedule (identical multiset of ``(arrival_time, plan, member)``) are
  one class -- the sorted multiset canonicalizes the pop order of
  simultaneous arrivals, which cannot change what any fire consumes
  (a fire takes the whole arrived set) or any weight (weights key on
  ``(plan, member)``, not pop order);
* symmetry reduction: a scenario may declare clients INTERCHANGEABLE
  (``Scenario.symmetric``) when they have identical ``(rank, n_k)`` and
  no lifecycle event names them (validated at sweep time). Permuting
  the latencies of interchangeable clients within one plan permutes
  member labels in every observable, and every protocol invariant is
  label-permutation-invariant, so the sweep canonicalizes each plan's
  draws over a symmetric group to sorted order.

Checkpoint cuts: the uninterrupted run snapshots ``state_dict()`` at
every reachable event boundary -- after each dispatch, after each trigger
firing (mid-window AND mid-drain), and at each window end -- and each
snapshot is restored into a FRESH scheduler that replays the remainder.
Replays must reproduce the uninterrupted run's remaining fires (times,
delivered members, arrival times, staleness, present masks, weights) and
its final ``state_dict`` EXACTLY; this generalizes the single mid-buffer
resume test of PR 5 into a checked invariant over every path.
"""
from __future__ import annotations

import copy
import itertools
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from repro.analysis.rules import Finding, ProgramContext, RuleSet
from repro.core.aggregation import cohort_weights
from repro.federation.events import (BufferTrigger, ClientLifecycle,
                                     EventScheduler, LatencyModel)
from repro.federation.server import flatten_cohort

WEIGHT_TOL = 1e-9


# ---------------------------------------------------------------------------
# the model's only degree of freedom: which latency each dispatch draws
# ---------------------------------------------------------------------------

class FixedLatency(LatencyModel):
    """Replay a per-client latency table: client ``c``'s i-th dispatch
    draws ``table[c][i]``. This is how the model checker injects one
    enumerated interleaving into the REAL scheduler -- everything else
    (arrival order, trigger decisions, cancellation, staleness) is the
    implementation's own behavior. Checkpointable like every other
    ``LatencyModel`` (per-client draw cursors)."""

    def __init__(self, table: Dict[int, Sequence[float]]):
        super().__init__(seed=0)
        self.table = {int(c): tuple(float(l) for l in ls)
                      for c, ls in table.items()}
        self.pos: Dict[int, int] = {}

    def sample(self, client: int) -> float:
        c = int(client)
        i = self.pos.get(c, 0)
        draws = self.table[c]
        assert i < len(draws), f"latency table exhausted for client {c}"
        self.pos[c] = i + 1
        return draws[i]

    def state_dict(self) -> dict:
        return {"pos": {str(c): self.pos[c] for c in sorted(self.pos)}}

    def load_state_dict(self, state: Optional[dict]) -> None:
        self.pos = ({} if not state else
                    {int(c): int(p) for c, p in state["pos"].items()})


# ---------------------------------------------------------------------------
# scenarios
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Scenario:
    """One model-checked configuration: a fixed federation shape, trigger
    family and lifecycle script; the sweep enumerates every latency
    assignment from ``grid`` over the slots the scenario actually
    dispatches."""

    name: str
    num_clients: int
    num_plans: int
    trigger_fn: Callable[[], BufferTrigger]
    lifecycle_fn: Callable[[], ClientLifecycle]
    grid: Tuple[float, ...]
    n_k: Tuple[int, ...]            # per base client (joined clients: 1)
    ranks: Tuple[int, ...]
    round_interval: float = 1.0
    gamma: float = 0.6
    # armed for the staleness-bound trigger family: consumed staleness may
    # never exceed it (rule proto-staleness-bound)
    staleness_bound: Optional[int] = None
    r_min: int = 4
    join_rank: int = 8
    # groups of interchangeable client ids (symmetry reduction): each
    # group's members must share (rank, n_k) and appear in no lifecycle
    # event -- validated by check_scenario
    symmetric: Tuple[Tuple[int, ...], ...] = ()

    def client_rank(self, c: int) -> int:
        return (int(self.ranks[c]) if c < len(self.ranks)
                else self.join_rank)

    def client_n_k(self, c: int) -> int:
        return int(self.n_k[c]) if c < len(self.n_k) else 1


# ---------------------------------------------------------------------------
# run records (what the rules inspect)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Fire:
    """One trigger firing as the server-protocol driver consumed it."""

    time: float
    phase: str                                   # "w{p}" | "drain"
    delivered: Tuple[Tuple[int, int, float], ...]  # (plan, member, arrival)
    staleness: Tuple[int, ...]                   # flattened cohort order
    present: Tuple[bool, ...]
    ghost: Tuple[bool, ...]
    weights: Tuple[float, ...]

    def key(self):
        return (self.time, self.phase, self.delivered, self.staleness,
                self.present, self.ghost, self.weights)


@dataclass
class RunRecord:
    """Everything one interleaving produced; the payload the protocol
    rules run over."""

    scenario: str
    signature: Tuple = ()
    dispatch_slots: List[Tuple[int, int]] = field(default_factory=list)
    plan_sizes: Dict[int, int] = field(default_factory=dict)
    fires: List[Fire] = field(default_factory=list)
    consume_counts: Dict[Tuple[int, int], int] = field(default_factory=dict)
    dropped: Set[Tuple[int, int]] = field(default_factory=set)
    final_state: Optional[dict] = None
    boundaries: int = 0
    replays: int = 0
    replay_mismatches: List[str] = field(default_factory=list)
    drain_horizon: Optional[float] = None


@dataclass(frozen=True)
class _Boundary:
    """A reachable event boundary of the uninterrupted run: the snapshot
    taken there plus the driver context a resume needs."""

    kind: str                       # "dispatch" | "fire" | "window" | "drain-fire"
    plan: int
    window_end: Optional[float]
    snapshot: dict
    fires_done: int
    pending: Tuple[int, ...]
    plan_clients: Dict[int, Tuple[int, ...]]
    horizon: Optional[float]


class _Registry:
    """Registry surrogate for "join" lifecycle events -- mirrors
    ``FederatedLoRA._apply_join``'s append-only, idempotent id rule."""

    def __init__(self, base: int):
        self.num = int(base)

    def apply_join(self, ev) -> None:
        if ev.client < self.num:
            return                   # already applied (restore replay)
        assert ev.client == self.num, (ev.client, self.num)
        self.num += 1


# ---------------------------------------------------------------------------
# the server-protocol driver
# ---------------------------------------------------------------------------

class Driver:
    """Drives a real ``EventScheduler`` through the exact protocol the
    server uses, recording every transition into a ``RunRecord``.

    ``break_present=True`` is the injected ghost-rule bug (positive
    control): cohort weights are computed IGNORING the present mask, the
    way a naive aggregation would -- ``proto-ghost-weight`` must trip.
    """

    def __init__(self, scenario: Scenario, table: Dict[int, Sequence[float]],
                 *, sched_cls=EventScheduler, break_present: bool = False):
        self.scenario = scenario
        self.sched = sched_cls(FixedLatency(table), scenario.trigger_fn(),
                               round_interval=scenario.round_interval,
                               lifecycle=scenario.lifecycle_fn())
        self.registry = _Registry(scenario.num_clients)
        self.sched.bind_join_hook(self.registry.apply_join)
        self.break_present = break_present
        self.record = RunRecord(scenario=scenario.name)
        self.plan_clients: Dict[int, List[int]] = {}
        self.pending: List[int] = []

    # -- protocol steps (each one mirrors a FederatedLoRA call site) --------

    def _dispatch(self, pr: int) -> None:
        pool = self.sched.active_clients(self.registry.num)
        clients = ([int(c) for c in pool] if pool is not None
                   else list(range(self.registry.num)))
        self.sched.dispatch(pr, clients)
        self.plan_clients[pr] = clients
        self.pending.append(pr)
        self.record.dispatch_slots += [(pr, c) for c in clients]
        self.record.plan_sizes[pr] = len(clients)

    def _fire(self, fire_time: float, phase: str) -> None:
        """Mirror of ``FederatedLoRA._aggregate_arrivals``: take the ready
        set, assemble the merged cohort over the pending plans that have
        ready members, and run the REAL weight rule (one ghost member is
        appended, as shard padding would, so the ghost-zero rule is
        checked on every fire)."""
        sc = self.scenario
        ready = self.sched.take_ready()
        delivered = tuple(sorted((pr, m, t) for pr, rd in ready.items()
                                 for m, t in rd.items()))
        for pr, m, _ in delivered:
            key = (pr, m)
            self.record.consume_counts[key] = \
                self.record.consume_counts.get(key, 0) + 1
        plans = [pr for pr in self.pending if pr in ready]
        if not plans:
            self.record.fires.append(Fire(fire_time, phase, delivered,
                                          (), (), (), ()))
            return
        members, ranks, n_k, staleness, present = [], [], [], [], []
        off = 0
        for pr in plans:
            clients = self.plan_clients[pr]
            arrived = ready[pr]
            for j, c in enumerate(clients):
                members.append(off + j)
                present.append(j in arrived)
                staleness.append(
                    self.sched.staleness_of(fire_time, arrived[j])
                    if j in arrived else 0)
                ranks.append(sc.client_rank(c))
                n_k.append(sc.client_n_k(c))
            off += len(clients)
        members.append(-1)           # the shard-padding ghost
        ranks_o, n_k_o, stal_o, pres_o = flatten_cohort(
            members, ranks, n_k, staleness, present, sc.r_min)
        weights = cohort_weights(
            n_k_o, stal_o, None if self.break_present else pres_o, sc.gamma)
        self.record.fires.append(Fire(
            fire_time, phase, delivered,
            tuple(int(s) for s in stal_o), tuple(bool(p) for p in pres_o),
            tuple(m < 0 for m in members),
            tuple(float(w) for w in weights)))

    def _capture_dropped(self) -> None:
        """Record cancelled (dropped-out) members before plans can be
        retired and their bookkeeping forgotten."""
        book = self.sched.state_dict()["book"]
        for pr, b in book.items():
            self.record.dropped |= {(int(pr), int(m))
                                    for m in b["dropped"]}

    def _retire(self) -> None:
        self._capture_dropped()
        for pr in self.sched.completed_plans():
            self.sched.forget_plan(pr)
            self.pending.remove(pr)

    def _drain_horizon(self) -> Optional[float]:
        heap = self.sched.state_dict()["heap"]
        return max((item[0] for item in heap), default=None)

    def _finish(self) -> RunRecord:
        self._capture_dropped()
        self.record.final_state = self.sched.state_dict()
        return self.record

    # -- the uninterrupted run ----------------------------------------------

    def run_full(self, *, cuts: bool = False) -> List[_Boundary]:
        """Drive every plan's window plus the drain; with ``cuts`` a
        snapshot is taken at EVERY reachable event boundary."""
        bounds: List[_Boundary] = []

        def mark(kind, plan, window_end=None, horizon=None):
            self.record.boundaries += 1
            self._capture_dropped()
            if cuts:
                bounds.append(_Boundary(
                    kind, plan, window_end, self.sched.state_dict(),
                    len(self.record.fires), tuple(self.pending),
                    {pr: tuple(cl)
                     for pr, cl in self.plan_clients.items()},
                    horizon))

        for pr in range(self.scenario.num_plans):
            self._dispatch(pr)
            end = self.sched.clock.now + self.scenario.round_interval
            mark("dispatch", pr, window_end=end)
            for t in self.sched.advance_window():
                self._fire(t, f"w{pr}")
                mark("fire", pr, window_end=end)
            self._retire()
            mark("window", pr)
        horizon = self._drain_horizon()
        self.record.drain_horizon = horizon
        for t in self.sched.drain():
            self._fire(t, "drain")
            mark("drain-fire", self.scenario.num_plans - 1, horizon=horizon)
        self._finish()
        return bounds


# ---------------------------------------------------------------------------
# checkpoint-cut replay
# ---------------------------------------------------------------------------

def _corrupt(snapshot: dict) -> dict:
    """The replay-divergence positive control: a deliberately torn
    snapshot that must make the replay diverge from the uninterrupted
    run. Three tears, by what the snapshot still holds: lose an
    in-flight arrival; falsely mark a buffered update consumed; or (when
    neither exists) corrupt the dispatch sequence counter -- each is
    observable in the remaining fires or the final state, and none
    violates the clock's monotonicity."""
    snap = copy.deepcopy(snapshot)
    if snap["heap"]:
        snap["heap"] = snap["heap"][:-1]
        return snap
    for b in snap["book"].values():
        pending = [int(m) for m in b["arrived"]
                   if int(m) not in set(b["consumed"])]
        if pending:
            b["consumed"] = sorted(set(b["consumed"]) | {pending[0]})
            return snap
    snap["seq"] = int(snap["seq"]) + 1
    return snap


def replay_from(scenario: Scenario, table: Dict[int, Sequence[float]],
                boundary: _Boundary, base: RunRecord, *,
                corrupt: bool = False) -> List[str]:
    """Restore ``boundary``'s snapshot into a FRESH scheduler, replay the
    remainder of the run, and return the list of divergences from the
    uninterrupted run (empty = bit-equal replay)."""
    sc = scenario
    d = Driver(sc, table)
    snap = _corrupt(boundary.snapshot) if corrupt \
        else copy.deepcopy(boundary.snapshot)
    d.sched.load_state_dict(snap)
    d.pending = list(boundary.pending)
    d.plan_clients = {pr: list(cl)
                      for pr, cl in boundary.plan_clients.items()}
    kind, p = boundary.kind, boundary.plan
    if kind in ("dispatch", "fire"):
        # finish the interrupted window: same end the original used
        for t in d.sched._events(boundary.window_end):
            d._fire(t, f"w{p}")
        d._retire()
        nxt = p + 1
    elif kind == "window":
        nxt = p + 1
    else:                            # "drain-fire": mid-drain resume
        nxt = sc.num_plans
    for pr in range(nxt, sc.num_plans):
        d._dispatch(pr)
        for t in d.sched.advance_window():
            d._fire(t, f"w{pr}")
        d._retire()
    if kind == "drain-fire":
        # the drain horizon is fixed at drain START (events.py): the
        # resume must play out to the ORIGINAL horizon, then force-fire
        if boundary.horizon is not None:
            for t in d.sched._events(boundary.horizon):
                d._fire(t, "drain")
        if d.sched.pending_ready_count > 0:
            d._fire(d.sched._fire(d.sched.clock.now), "drain")
    else:
        for t in d.sched.drain():
            d._fire(t, "drain")
    d._finish()

    at = f"{kind}@plan{p}/fire{boundary.fires_done}"
    mism = []
    expect = [f.key() for f in base.fires[boundary.fires_done:]]
    got = [f.key() for f in d.record.fires]
    if got != expect:
        i = next((i for i, (g, e) in enumerate(zip(got, expect))
                  if g != e), min(len(got), len(expect)))
        mism.append(f"replay from {at}: fires diverge at post-cut fire "
                    f"{i} ({len(got)} vs {len(expect)} fires)")
    if d.record.final_state != base.final_state:
        mism.append(f"replay from {at}: final scheduler state diverges")
    return mism


# ---------------------------------------------------------------------------
# interleaving enumeration with partial-order reduction
# ---------------------------------------------------------------------------

def discover_slots(scenario: Scenario) -> List[Tuple[int, int]]:
    """The (plan, client) dispatch slots the scenario realizes.

    The sampling pool evolves only through SCRIPTED lifecycle events at
    fixed virtual times, never through arrivals, so the slot list is
    latency-independent -- one probe run of the real scheduler discovers
    it (no re-derivation of the pool rule in the model)."""
    draws = max(scenario.num_plans, 1)
    probe_table = {c: (scenario.grid[0],) * draws
                   for c in range(scenario.num_clients + scenario.num_plans)}
    probe = Driver(scenario, probe_table)
    probe.run_full()
    return list(probe.record.dispatch_slots)


def _validate_symmetry(scenario: Scenario) -> None:
    """Interchangeability preconditions (module docstring): identical
    (rank, n_k) within a group and no lifecycle event naming a member."""
    scripted = {ev.client for ev in scenario.lifecycle_fn().events}
    for group in scenario.symmetric:
        shapes = {(scenario.client_rank(c), scenario.client_n_k(c))
                  for c in group}
        assert len(shapes) == 1, \
            f"symmetric group {group} mixes (rank, n_k) shapes {shapes}"
        hit = set(group) & scripted
        assert not hit, f"symmetric clients {hit} appear in the lifecycle"


def canonical_combo(scenario: Scenario, slots: Sequence[Tuple[int, int]],
                    combo: Sequence[float]) -> Tuple[float, ...]:
    """Symmetry-reduced representative: within each plan, the draws
    assigned to a symmetric group are re-dealt in sorted order (slot
    order is ascending client id, so this is a canonical relabeling)."""
    if not scenario.symmetric:
        return tuple(combo)
    group_of = {c: gi for gi, g in enumerate(scenario.symmetric) for c in g}
    lat = list(combo)
    cells: Dict[Tuple[int, int], List[int]] = {}
    for i, (pr, c) in enumerate(slots):
        gi = group_of.get(c)
        if gi is not None:
            cells.setdefault((pr, gi), []).append(i)
    for idxs in cells.values():
        for i, v in zip(idxs, sorted(lat[i] for i in idxs)):
            lat[i] = v
    return tuple(lat)


def signature_of(scenario: Scenario, slots: Sequence[Tuple[int, int]],
                 combo: Sequence[float]) -> Tuple:
    """Canonical schedule signature: the sorted multiset of
    ``(arrival_time, plan, member)``. Assignments sharing it are one
    commuting class (see module docstring)."""
    member_of: Dict[int, int] = {}
    sig = []
    for (pr, _c), lat in zip(slots, combo):
        j = member_of.get(pr, 0)
        member_of[pr] = j + 1
        sig.append((round(pr * scenario.round_interval + lat, 9), pr, j))
    return tuple(sorted(sig))


def table_of(slots: Sequence[Tuple[int, int]],
             combo: Sequence[float]) -> Dict[int, List[float]]:
    """Latency table realizing one assignment: per-client draws in the
    client's dispatch order."""
    table: Dict[int, List[float]] = {}
    for (_pr, c), lat in zip(slots, combo):
        table.setdefault(c, []).append(lat)
    return table


@dataclass
class CheckStats:
    assignments: int = 0
    unique_schedules: int = 0
    fires: int = 0
    boundaries: int = 0
    replays: int = 0

    def to_json(self) -> dict:
        return {"assignments": self.assignments,
                "unique_schedules": self.unique_schedules,
                "por_reduction": self.assignments - self.unique_schedules,
                "fires": self.fires, "boundaries": self.boundaries,
                "replays": self.replays}


def check_scenario(scenario: Scenario, *, replay: bool = True,
                   sched_cls=EventScheduler, break_present: bool = False,
                   corrupt_replay: bool = False,
                   keep_records: bool = False
                   ) -> Tuple[List[Finding], CheckStats, List[RunRecord]]:
    """Exhaustively model-check one scenario: every latency assignment
    (one representative per commuting class), the invariant rules on each
    run, and -- with ``replay`` -- a save -> restore -> replay check from
    every reachable event boundary of every run."""
    _validate_symmetry(scenario)
    slots = discover_slots(scenario)
    stats = CheckStats()
    findings: List[Finding] = []
    records: List[RunRecord] = []
    seen: Set[Tuple] = set()
    for raw in itertools.product(scenario.grid, repeat=len(slots)):
        stats.assignments += 1
        combo = canonical_combo(scenario, slots, raw)
        sig = signature_of(scenario, slots, combo)
        if sig in seen:
            continue                 # commuting class already checked
        seen.add(sig)
        stats.unique_schedules += 1
        table = table_of(slots, combo)
        driver = Driver(scenario, table, sched_cls=sched_cls,
                        break_present=break_present)
        bounds = driver.run_full(cuts=replay)
        rec = driver.record
        rec.signature = sig
        if replay:
            for b in bounds:
                rec.replays += 1
                rec.replay_mismatches += replay_from(
                    scenario, table, b, rec, corrupt=corrupt_replay)
        stats.fires += len(rec.fires)
        stats.boundaries += rec.boundaries
        stats.replays += rec.replays
        ctx = ProgramContext(
            program=scenario.name, kind="protocol", payload=rec,
            meta={"staleness_bound": scenario.staleness_bound,
                  "signature": sig})
        findings.extend(PROTOCOL_RULES.run(ctx))
        if keep_records:
            records.append(rec)
    return findings, stats, records


# ---------------------------------------------------------------------------
# invariant rules
# ---------------------------------------------------------------------------

PROTOCOL_RULES = RuleSet("protocol")


def _sig(ctx: ProgramContext) -> str:
    sig = ctx.meta.get("signature", ())
    return "sched[" + ",".join(f"{t}:{pr}.{m}" for t, pr, m in sig) + "]"


@PROTOCOL_RULES.rule(
    "proto-exactly-once",
    "every dispatched (plan, member) arrival is aggregated exactly once "
    "across all fires, or explicitly cancelled by a dropout -- never "
    "twice, never lost")
def _check_exactly_once(ctx: ProgramContext):
    rec: RunRecord = ctx.payload
    for (pr, m), cnt in sorted(rec.consume_counts.items()):
        if cnt > 1:
            yield (f"plan {pr} member {m} aggregated {cnt} times",
                   _sig(ctx))
    for pr, size in sorted(rec.plan_sizes.items()):
        for m in range(size):
            if (rec.consume_counts.get((pr, m), 0) == 0
                    and (pr, m) not in rec.dropped):
                yield (f"plan {pr} member {m} neither aggregated nor "
                       f"cancelled after drain", _sig(ctx))


@PROTOCOL_RULES.rule(
    "proto-cancelled-consumed",
    "a cancelled (dropped-out) arrival is never consumed by any fire")
def _check_cancelled(ctx: ProgramContext):
    rec: RunRecord = ctx.payload
    for key in sorted(set(rec.consume_counts) & rec.dropped):
        yield (f"plan {key[0]} member {key[1]} was cancelled by a dropout "
               f"AND aggregated", _sig(ctx))


@PROTOCOL_RULES.rule(
    "proto-ghost-weight",
    "present-mask weight conservation: every fire's cohort weights sum "
    "to exactly 1 with absent clients AND ghost members at exactly zero "
    "(the ghost rule)")
def _check_weights(ctx: ProgramContext):
    rec: RunRecord = ctx.payload
    for i, fire in enumerate(rec.fires):
        if not fire.weights:
            continue
        total = float(np.sum(fire.weights))
        if abs(total - 1.0) > WEIGHT_TOL:
            yield (f"fire {i} @ t={fire.time}: weights sum to {total!r}",
                   _sig(ctx))
        for j, (w, p, g) in enumerate(zip(fire.weights, fire.present,
                                          fire.ghost)):
            if g and w != 0.0:
                yield (f"fire {i} @ t={fire.time}: ghost slot {j} got "
                       f"weight {w!r}", _sig(ctx))
            elif not g and not p and w != 0.0:
                yield (f"fire {i} @ t={fire.time}: absent slot {j} got "
                       f"weight {w!r}", _sig(ctx))


@PROTOCOL_RULES.rule(
    "proto-staleness-bound",
    "under the staleness-bound trigger no consumed update's staleness "
    "exceeds the bound; armed via meta['staleness_bound']")
def _check_staleness(ctx: ProgramContext):
    bound = ctx.meta.get("staleness_bound")
    if bound is None:
        return
    rec: RunRecord = ctx.payload
    for i, fire in enumerate(rec.fires):
        for j, (s, p) in enumerate(zip(fire.staleness, fire.present)):
            if p and s > bound:
                yield (f"fire {i} @ t={fire.time}: slot {j} consumed at "
                       f"staleness {s} > bound {bound}", _sig(ctx))


@PROTOCOL_RULES.rule(
    "proto-empty-fire",
    "a trigger firing always consumes at least one buffered update (the "
    "scheduler promises pending_ready_count > 0 at every fire)")
def _check_empty_fire(ctx: ProgramContext):
    rec: RunRecord = ctx.payload
    for i, fire in enumerate(rec.fires):
        if not fire.delivered:
            yield f"fire {i} @ t={fire.time} consumed nothing", _sig(ctx)


@PROTOCOL_RULES.rule(
    "proto-replay-divergence",
    "save -> restore at EVERY reachable event boundary replays bit-equal "
    "to the uninterrupted run (fires and final scheduler state)")
def _check_replay(ctx: ProgramContext):
    rec: RunRecord = ctx.payload
    for msg in rec.replay_mismatches:
        yield msg, _sig(ctx)


# ---------------------------------------------------------------------------
# sabotaged schedulers (positive controls)
# ---------------------------------------------------------------------------

class DoubleConsumeScheduler(EventScheduler):
    """Injected double-fire bug: every fire re-delivers each plan's
    ALREADY-CONSUMED members alongside the fresh ones -- the classic
    double-aggregation protocol bug. ``proto-exactly-once`` must trip."""

    def take_ready(self):
        prev = {pr: {m: b["arrived"][m] for m in sorted(b["consumed"])
                     if m in b["arrived"]}
                for pr, b in self._book.items()}
        out = super().take_ready()
        for pr, extra in prev.items():
            if extra:
                out.setdefault(pr, {}).update(extra)
        return out


class CancelledDeliveryScheduler(EventScheduler):
    """Injected cancellation bug: fires deliver members a dropout already
    cancelled (as if the dropped client's update arrived anyway).
    ``proto-cancelled-consumed`` must trip on any dropout scenario."""

    def take_ready(self):
        out = super().take_ready()
        for pr, b in self._book.items():
            for m in sorted(b["dropped"]):
                out.setdefault(pr, {})[m] = self.clock.now
        return out
