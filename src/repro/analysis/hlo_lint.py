"""hlo_lint: declarative rules over parsed optimized HLO.

Generalizes the one-off (d, n)-materialization tripwire that PR 4 built on
``launch/hlo_walker`` (and that ``tests/test_hlo_guard.py`` used to
hand-roll) into a RuleSet every program of the engine x backend x METHODS
matrix runs through:

  hlo-materialization   NO array at forbidden scale / with forbidden
                        trailing dims -- the "dW never materialized"
                        guarantee of the factored and kernel backends
  hlo-collective-budget collective op count and result-buffer bytes within
                        the per-bucket budget (the sharded engine's
                        "ONE psum per bucket" property)
  hlo-host-transfer     no infeed/outfeed/send/recv and no host-callback
                        custom-calls in a compiled round program
  hlo-dtype-upcast      no f64 arrays ever; optionally no large f32
                        arrays in a program meant to run bf16

All thresholds arrive via ``ProgramContext.meta`` (rules without their
threshold yield nothing -- see ``analysis/rules.py``). Byte/count numbers
come from the trip-count-aware ``hlo_walker.analyze_hlo`` -- the single
source of truth for collective accounting (``launch/hlo_analysis.py`` and
``launch/fl_dryrun.py`` route through it too).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterable, List, Optional, Tuple

from repro.analysis.rules import (Finding, ProgramContext, RuleSet,
                                  SEV_ERROR)
from repro.launch.hlo_walker import (_SHAPE, Computation, HLOStats,
                                     analyze_hlo, parse_hlo)


@dataclass
class HLOProgram:
    """Parsed payload for hlo rules: computations + walker stats."""
    text: str
    comps: Dict[str, Computation]
    stats: HLOStats


def parse_program(text: str) -> HLOProgram:
    comps = parse_hlo(text)
    comps.pop("__entry_name__", None)
    comps.pop("__entry__", None)
    return HLOProgram(text=text, comps=comps, stats=analyze_hlo(text))


def iter_result_arrays(comps: Dict[str, Computation]):
    """(comp_name, op_name, dtype, dims) for every array in every op's
    result type (tuple results yield one entry per element)."""
    for cname, comp in comps.items():
        for op in comp.ops:
            for m in _SHAPE.finditer(op.result_type):
                dims = [int(x) for x in m.group(2).split(",") if x]
                yield cname, op.name, m.group(1), dims


def _elems(dims: List[int]) -> int:
    n = 1
    for d in dims:
        n *= d
    return n


HLO_RULES = RuleSet("hlo")


@HLO_RULES.rule(
    "hlo-materialization",
    "no array reaches the forbidden (d, n) scale: >= meta['forbid_elems'] "
    "elements, or trailing dims equal to meta['forbid_dims'] in either "
    "order (the dense-dW tripwire, walked through while bodies + fusions)")
def _check_materialization(ctx: ProgramContext):
    forbid_elems = ctx.meta.get("forbid_elems")
    forbid_dims = ctx.meta.get("forbid_dims")
    if forbid_elems is None and forbid_dims is None:
        return
    dim_set = set(forbid_dims) if forbid_dims else None
    for cname, oname, dt, dims in iter_result_arrays(ctx.payload.comps):
        n = _elems(dims)
        if forbid_elems is not None and n >= forbid_elems:
            yield (f"{dt}{dims} holds {n} >= {forbid_elems} elements",
                   f"{cname}/{oname}")
        elif dim_set and len(dims) >= 2 and set(dims[-2:]) == dim_set:
            yield (f"{dt}{dims} has forbidden trailing dims "
                   f"{tuple(sorted(dim_set))}", f"{cname}/{oname}")


@HLO_RULES.rule(
    "hlo-collective-budget",
    "trip-count-weighted collective op count <= meta['max_collective_count']"
    " and result-buffer bytes <= meta['max_collective_bytes'] (per-device "
    "program; the sharded bucket's 'one psum' property)")
def _check_collective_budget(ctx: ProgramContext):
    stats: HLOStats = ctx.payload.stats
    max_count = ctx.meta.get("max_collective_count")
    max_bytes = ctx.meta.get("max_collective_bytes")
    count = float(sum(stats.collective_counts.values()))
    byts = stats.total_collective_bytes
    kinds = {k: int(v) for k, v in stats.collective_counts.items() if v}
    if max_count is not None and count > max_count:
        yield (f"{count:.0f} collective ops > budget {max_count} "
               f"({kinds})", "")
    if max_bytes is not None and byts > max_bytes:
        yield (f"{byts:.0f} collective bytes > budget {max_bytes:.0f} "
               f"({kinds})", "")


# host-transfer opcodes + the custom-call targets XLA emits for python
# callbacks (jax.pure_callback / io_callback / debug.callback land as
# custom-call(...) with a target containing "callback")
_HOST_OPS = ("infeed", "outfeed", "send", "send-done", "recv", "recv-done")
_HOST_CALL_MARKERS = ("callback", "host")


@HLO_RULES.rule(
    "hlo-host-transfer",
    "no host-transfer ops (infeed/outfeed/send/recv) and no host-callback "
    "custom-calls: a compiled round program must never synchronize with "
    "the Python host mid-execution")
def _check_host_transfer(ctx: ProgramContext):
    for cname, comp in ctx.payload.comps.items():
        for op in comp.ops:
            if op.opcode in _HOST_OPS:
                yield (f"host-transfer op '{op.opcode}'",
                       f"{cname}/{op.name}")
            elif op.opcode == "custom-call":
                low = op.rest.lower()
                if "custom_call_target" in low and any(
                        m in low for m in _HOST_CALL_MARKERS):
                    yield (f"host-callback custom-call: "
                           f"{op.rest[:80]}", f"{cname}/{op.name}")


@HLO_RULES.rule(
    "hlo-dtype-upcast",
    "no f64 arrays anywhere (meta['allow_f64'] to waive); with "
    "meta['bf16_min_elems'] set, no f32 array of that many elements in a "
    "program meant to run bf16 (an upcast doubles collective + HBM bytes)")
def _check_dtype_upcast(ctx: ProgramContext):
    allow_f64 = ctx.meta.get("allow_f64", False)
    bf16_min = ctx.meta.get("bf16_min_elems")
    for cname, oname, dt, dims in iter_result_arrays(ctx.payload.comps):
        if dt == "f64" and not allow_f64:
            yield (f"f64{dims} in a float32 codebase", f"{cname}/{oname}")
        elif dt == "f32" and bf16_min is not None \
                and _elems(dims) >= bf16_min:
            yield (f"f32{dims} upcast in a bf16 program "
                   f"(>= {bf16_min} elements)", f"{cname}/{oname}")


def lint_hlo(text: str, program: str,
             meta: Optional[dict] = None,
             only: Optional[Iterable[str]] = None,
             payload: Optional[HLOProgram] = None
             ) -> Tuple[List[Finding], HLOProgram]:
    """Run the HLO RuleSet over one compiled program's optimized HLO.

    ``payload`` short-circuits the parse for callers holding a cached
    ``HLOProgram`` (the shared ``analysis/lowering`` cache): each program
    of a sweep is then parsed/walked once, not once per pass."""
    if payload is None:
        payload = parse_program(text)
    ctx = ProgramContext(program=program, kind="hlo", payload=payload,
                         meta=dict(meta or {}))
    return HLO_RULES.run(ctx, only=only), payload


PARITY_RULE = "hlo-collective-parity"


def collective_parity(text_a: str, text_b: str, *, label_a: str,
                      label_b: str, program: str = "parity",
                      rel_tol: float = 0.0) -> List[Finding]:
    """Assert two compiled programs move IDENTICAL collective traffic --
    the kernel == factored invariant (the fused Pallas path changes
    per-shard compute, never the collective). One source of truth for the
    byte accounting ``launch/fl_dryrun.py`` used to duplicate."""
    return collective_parity_stats(
        analyze_hlo(text_a), analyze_hlo(text_b), label_a=label_a,
        label_b=label_b, program=program, rel_tol=rel_tol)


def collective_parity_stats(sa: HLOStats, sb: HLOStats, *, label_a: str,
                            label_b: str, program: str = "parity",
                            rel_tol: float = 0.0) -> List[Finding]:
    """Stats-level parity core: callers with cached walker stats (the
    shared lowering cache) skip the re-parse ``collective_parity`` pays."""
    findings: List[Finding] = []
    kinds = set(sa.collective_bytes) | set(sb.collective_bytes)
    for kind in sorted(kinds):
        ba = float(sa.collective_bytes.get(kind, 0.0))
        bb = float(sb.collective_bytes.get(kind, 0.0))
        tol = rel_tol * max(abs(ba), abs(bb))
        if abs(ba - bb) > tol:
            findings.append(Finding(
                PARITY_RULE, SEV_ERROR, program,
                f"{kind}: {label_a}={ba:.0f}B != {label_b}={bb:.0f}B",
                kind))
        ca = float(sa.collective_counts.get(kind, 0.0))
        cb = float(sb.collective_counts.get(kind, 0.0))
        if ca != cb:
            findings.append(Finding(
                PARITY_RULE, SEV_ERROR, program,
                f"{kind}: {label_a} issues {ca:.0f} ops, {label_b} "
                f"{cb:.0f}", kind))
    return findings
