"""pallas_lint: static validation of every Pallas kernel launch.

``capture_kernels`` patches ``pallas.pallas_call`` so that tracing any
kernel-calling op (free, via ``jax.eval_shape`` -- no compile, no arrays)
records each launch's grid, BlockSpecs, operand shapes, out_shape and
scratch allocations as a ``KernelRecord``. ``KERNEL_REGISTRY`` drives the
public wrappers in ``kernels/ops.py`` (which cover every grid in
``kernels/rank_partition_agg.py``, ``lora_apply``, ``ssd_scan`` and
``flash_attention``) at small shapes AND at deliberately non-divisible
d / n / r / seq extents, so the pad-to-tile + slice-back contract is
probed, not assumed. Rules:

  pallas-grid-blockspec  block ranks match operand ranks, grid entries are
                         positive ints, and every index_map corner maps
                         its block inside the (padded) operand bounds
  pallas-vmem-budget     per-grid-step footprint -- double-buffered in/out
                         blocks + scratch -- within the per-target VMEM
                         budget table (``VMEM_BUDGETS``: v4/v5e/v5p/v6e),
                         selected via meta['vmem_target'] (default v5e =
                         16 MiB); meta['vmem_budget_bytes'] overrides
                         the table for one-off runs
  pallas-pad-coverage    each registry probe at non-divisible extents
                         produced the contract output shapes/dtypes

The records are shape-level facts identical on CPU (interpret mode) and
TPU (Mosaic): the wrappers choose blocks/grids the same way on both, only
the ``interpret`` flag differs -- so the lint is meaningful off-TPU.
"""
from __future__ import annotations

import contextlib
import functools
import itertools
from dataclasses import dataclass, field
from typing import Callable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.analysis.rules import ProgramContext, RuleSet

# Per-target VMEM lint budgets (bytes per core). Conservative figures:
# v5e carries ~16 MiB of VMEM per core; the larger parts ship roughly
# double, but the lint budget deliberately stays below the marketing
# number so double-buffered blocks + scratch leave headroom for Mosaic's
# own spills. Select with meta['vmem_target'] or the sweep's
# ``--vmem-target`` flag; v5e stays the default (the strictest common
# denominator), and an explicit meta['vmem_budget_bytes'] still wins.
VMEM_BUDGETS = {
    "v4": 32 * 1024 * 1024,
    "v5e": 16 * 1024 * 1024,
    "v5p": 32 * 1024 * 1024,
    "v6e": 32 * 1024 * 1024,
}
DEFAULT_VMEM_TARGET = "v5e"
VMEM_BUDGET_BYTES = VMEM_BUDGETS[DEFAULT_VMEM_TARGET]   # back-compat alias


def vmem_budget(meta: Optional[dict] = None) -> int:
    """Budget bytes for a lint run: explicit meta['vmem_budget_bytes'],
    else the meta['vmem_target'] table entry, else the v5e default."""
    meta = meta or {}
    explicit = meta.get("vmem_budget_bytes")
    if explicit is not None:
        return int(explicit)
    target = meta.get("vmem_target", DEFAULT_VMEM_TARGET)
    try:
        return VMEM_BUDGETS[target]
    except KeyError:
        raise KeyError(
            f"unknown vmem_target {target!r}; known: "
            f"{sorted(VMEM_BUDGETS)}") from None


@dataclass
class KernelRecord:
    """One captured (or fabricated) pallas_call launch."""
    name: str
    grid: Tuple[int, ...]
    in_specs: List[Tuple[Optional[Tuple], Optional[Callable]]]
    out_specs: List[Tuple[Optional[Tuple], Optional[Callable]]]
    out_shapes: List[Tuple[Tuple[int, ...], str]]
    scratch_shapes: List[Tuple[Tuple[int, ...], str]]
    arg_shapes: List[Tuple[int, ...]] = field(default_factory=list)
    arg_dtypes: List[str] = field(default_factory=list)
    interpret: bool = False


@dataclass
class ProbeResult:
    """Outcome of one registry pad-coverage probe."""
    name: str
    ok: bool
    detail: str = ""


@dataclass
class PallasPrograms:
    """Payload for the pallas RuleSet."""
    records: List[KernelRecord]
    probes: List[ProbeResult] = field(default_factory=list)


def _kernel_name(fn) -> str:
    inner = getattr(fn, "func", fn)          # unwrap functools.partial
    return getattr(inner, "__name__", repr(fn))


def _spec_list(specs) -> List[Tuple[Optional[Tuple], Optional[Callable]]]:
    if specs is None:
        return []
    if not isinstance(specs, (list, tuple)):
        specs = [specs]
    out = []
    for s in specs:
        out.append((tuple(getattr(s, "block_shape", None) or ())
                    or None, getattr(s, "index_map", None)))
    return out


def _shape_dtype_list(objs) -> List[Tuple[Tuple[int, ...], str]]:
    if objs is None:
        return []
    if not isinstance(objs, (list, tuple)):
        objs = [objs]
    out = []
    for o in objs:
        shape = tuple(getattr(o, "shape", ()) or ())
        dtype = str(jnp.dtype(getattr(o, "dtype", jnp.float32)))
        out.append((shape, dtype))
    return out


@contextlib.contextmanager
def capture_kernels(records: List[KernelRecord]):
    """Patch ``pl.pallas_call`` to append a KernelRecord per launch (and
    per set of operands it is then applied to)."""
    orig = pl.pallas_call

    def patched(kernel, *args, **kwargs):
        grid = kwargs.get("grid")
        if grid is None:
            grid = ()
        elif isinstance(grid, int):
            grid = (grid,)
        rec = KernelRecord(
            name=_kernel_name(kernel),
            grid=tuple(grid),
            in_specs=_spec_list(kwargs.get("in_specs")),
            out_specs=_spec_list(kwargs.get("out_specs")),
            out_shapes=_shape_dtype_list(kwargs.get("out_shape")),
            scratch_shapes=_shape_dtype_list(
                kwargs.get("scratch_shapes")),
            interpret=bool(kwargs.get("interpret", False)))
        inner = orig(kernel, *args, **kwargs)

        @functools.wraps(inner)
        def with_arg_capture(*operands):
            use = rec if not rec.arg_shapes else KernelRecord(
                name=rec.name, grid=rec.grid, in_specs=rec.in_specs,
                out_specs=rec.out_specs, out_shapes=rec.out_shapes,
                scratch_shapes=rec.scratch_shapes, interpret=rec.interpret)
            use.arg_shapes = [tuple(getattr(o, "shape", ()) or ())
                              for o in operands]
            use.arg_dtypes = [str(jnp.dtype(getattr(o, "dtype",
                                                    jnp.float32)))
                              for o in operands]
            if use is not rec:
                records.append(use)
            return inner(*operands)

        records.append(rec)
        return with_arg_capture

    pl.pallas_call = patched
    try:
        yield records
    finally:
        pl.pallas_call = orig


# ---------------------------------------------------------------------------
# kernel registry: every public kernels/ops.py wrapper at small + odd shapes
# ---------------------------------------------------------------------------

def _sds(*shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _ceil_to(x: int, mult: int) -> int:
    return -(-x // mult) * mult


def _entry_lora_apply():
    from repro.kernels import ops
    x, w = _sds(4, 24, 40), _sds(40, 56)
    a, b = _sds(6, 40), _sds(56, 6)
    return (functools.partial(ops.lora_apply, scale=2.0), (x, w, a, b),
            [(4, 24, 56)])


def _entry_batched_lora_apply():
    from repro.kernels import ops
    x, w = _sds(5, 40), _sds(40, 56)
    a_pages, b_pages = _sds(3, 6, 40), _sds(3, 56, 6)
    scales, ids = _sds(3), _sds(5, dtype=jnp.int32)
    return (ops.batched_lora_apply, (x, w, a_pages, b_pages, scales, ids),
            [(5, 56)])


def _entry_rank_partition_agg():
    from repro.kernels import ops
    m, d, r, n = 3, 100, 5, 130
    args = (_sds(m, d, r), _sds(m, r, n), _sds(m, r),
            _sds(d, r), _sds(r, n), _sds(r))
    return ops.rank_partition_agg, args, [(d, n)]


def _entry_rank_partition_agg_layered():
    from repro.kernels import ops
    lyr, m, d, r, n = 2, 3, 50, 5, 70
    args = (_sds(lyr, m, d, r), _sds(lyr, m, r, n), _sds(m, r),
            _sds(lyr, d, r), _sds(lyr, r, n), _sds(r))
    return ops.rank_partition_agg_layered, args, [(lyr, d, n)]


def _entry_factored_stack_gram():
    from repro.kernels import ops
    m, d, r, n = 3, 100, 5, 130
    width = (m + 1) * _ceil_to(r, 8)       # fallback rides as client m+1
    args = (_sds(m, d, r), _sds(m, r, n), _sds(m, r),
            _sds(d, r), _sds(r, n), _sds(r))
    return (ops.factored_stack_gram, args,
            [(d, width), (width, n), (width, width), (width, width)])


def _entry_factored_stack_gram_layered():
    from repro.kernels import ops
    lyr, m, d, r, n = 2, 3, 50, 5, 70
    width = (m + 1) * _ceil_to(r, 8)
    args = (_sds(lyr, m, d, r), _sds(lyr, m, r, n), _sds(m, r),
            _sds(lyr, d, r), _sds(lyr, r, n), _sds(r))
    return (ops.factored_stack_gram_layered, args,
            [(lyr, d, width), (lyr, width, n), (lyr, width, width),
             (lyr, width, width)])


def _entry_ssd_scan():
    from repro.kernels import ops
    b_, l, h, p, g, n = 2, 32, 8, 16, 2, 16
    args = (_sds(b_, l, h, p), _sds(b_, l, h), _sds(h),
            _sds(b_, l, g, n), _sds(b_, l, g, n), _sds(h))
    return (functools.partial(ops.ssd_scan, chunk=16), args,
            [(b_, l, h, p), (b_, h, p, n)])


def _entry_flash_attention():
    from repro.kernels import ops
    b_, lq, lkv, h, d = 1, 40, 50, 2, 32
    args = (_sds(b_, lq, h, d), _sds(b_, lkv, h, d), _sds(b_, lkv, h, d))
    return (functools.partial(ops.flash_attention, causal=False), args,
            [(b_, lq, h, d)])


KERNEL_REGISTRY = (
    ("lora_apply", _entry_lora_apply),
    ("batched_lora_apply", _entry_batched_lora_apply),
    ("rank_partition_agg", _entry_rank_partition_agg),
    ("rank_partition_agg_layered", _entry_rank_partition_agg_layered),
    ("factored_stack_gram", _entry_factored_stack_gram),
    ("factored_stack_gram_layered", _entry_factored_stack_gram_layered),
    ("ssd_scan", _entry_ssd_scan),
    ("flash_attention", _entry_flash_attention),
)


def collect_registry(names: Optional[Sequence[str]] = None
                     ) -> PallasPrograms:
    """Trace every registry entry under capture; probe that the contract
    output shapes come back despite the odd (non-tile-divisible) extents
    every entry deliberately uses."""
    records: List[KernelRecord] = []
    probes: List[ProbeResult] = []
    for name, build in KERNEL_REGISTRY:
        if names is not None and name not in names:
            continue
        fn, args, expected = build()
        before = len(records)
        try:
            with capture_kernels(records):
                out = jax.eval_shape(fn, *args)
        except Exception as e:                     # pragma: no cover
            probes.append(ProbeResult(name, False,
                                      f"trace failed: {e!r}"))
            continue
        got = [tuple(leaf.shape) for leaf in jax.tree_util.tree_leaves(out)]
        want = [tuple(s) for s in expected]
        if got != want:
            probes.append(ProbeResult(
                name, False, f"output shapes {got} != contract {want}"))
        elif len(records) == before:
            probes.append(ProbeResult(
                name, False, "no pallas_call captured -- kernel path "
                             "not taken"))
        else:
            probes.append(ProbeResult(
                name, True, f"{len(records) - before} launch(es)"))
    # keep only fully-captured launches (operand shapes seen)
    records = [r for r in records if r.arg_shapes]
    return PallasPrograms(records=records, probes=probes)


# ---------------------------------------------------------------------------
# rules
# ---------------------------------------------------------------------------

_DTYPE_SIZE = {"float32": 4, "float64": 8, "bfloat16": 2, "float16": 2,
               "int32": 4, "int64": 8, "int8": 1, "bool": 1}


def _block_bytes(block, dtype: str) -> int:
    n = 1
    for b in block or ():
        if b is not None:
            n *= int(b)
    return n * _DTYPE_SIZE.get(dtype, 4)


def _index_map_corners(grid: Tuple[int, ...], cap: int = 64):
    """Grid corner coordinates {0, g-1} per axis (<= cap combinations) --
    enough to bounds-check monotone index maps like this repo's."""
    axes = [sorted({0, g - 1}) for g in grid]
    combos = itertools.islice(itertools.product(*axes), cap)
    return list(combos)


def estimate_vmem(rec: KernelRecord) -> int:
    """Per-grid-step footprint: in/out blocks double-buffered + scratch."""
    total = 0
    for (block, _), dtype in zip(
            rec.in_specs, rec.arg_dtypes + ["float32"] * len(rec.in_specs)):
        total += 2 * _block_bytes(block, dtype)
    for i, (block, _) in enumerate(rec.out_specs):
        dtype = rec.out_shapes[i][1] if i < len(rec.out_shapes) \
            else "float32"
        total += 2 * _block_bytes(block, dtype)
    for shape, dtype in rec.scratch_shapes:
        total += _block_bytes(shape, dtype)
    return total


PALLAS_RULES = RuleSet("pallas")


@PALLAS_RULES.rule(
    "pallas-grid-blockspec",
    "grid entries are positive ints; each BlockSpec's rank matches its "
    "operand; every index_map grid-corner maps its block inside the "
    "(padded) operand bounds")
def _check_grid_blockspec(ctx: ProgramContext):
    for rec in ctx.payload.records:
        loc = rec.name
        for g in rec.grid:
            if not isinstance(g, int) or g <= 0:
                yield f"non-positive/non-static grid entry {g!r} " \
                      f"in grid {rec.grid}", loc
        roles = [("in", rec.in_specs, rec.arg_shapes),
                 ("out", rec.out_specs,
                  [s for s, _ in rec.out_shapes])]
        for role, specs, shapes in roles:
            if shapes and specs and len(specs) != len(shapes):
                yield (f"{role}_specs count {len(specs)} != operand "
                       f"count {len(shapes)}", loc)
            for i, (block, index_map) in enumerate(specs):
                shape = shapes[i] if i < len(shapes) else None
                if block is None or shape is None:
                    continue
                if len(block) != len(shape):
                    yield (f"{role}[{i}] block rank {len(block)} != "
                           f"operand rank {len(shape)} "
                           f"(block {block}, operand {shape})", loc)
                    continue
                if index_map is None or not rec.grid:
                    continue
                try:
                    corners = _index_map_corners(rec.grid)
                    for corner in corners:
                        idx = index_map(*corner)
                        if not isinstance(idx, tuple):
                            idx = (idx,)
                        if len(idx) != len(block):
                            yield (f"{role}[{i}] index_map returns "
                                   f"{len(idx)} indices for rank-"
                                   f"{len(block)} block", loc)
                            break
                        for ax, (bi, bl, dim) in enumerate(
                                zip(idx, block, shape)):
                            if bl is None or not isinstance(bi, int):
                                continue
                            if (bi + 1) * bl > dim:
                                yield (f"{role}[{i}] axis {ax}: block "
                                       f"{bi}*{bl} exceeds operand dim "
                                       f"{dim} at grid corner {corner}",
                                       loc)
                        else:
                            continue
                        break
                except Exception:
                    # symbolic index maps cannot be evaluated statically;
                    # bounds are then checked by the runtime/interpreter
                    continue


@PALLAS_RULES.rule(
    "pallas-vmem-budget",
    "double-buffered in/out blocks + scratch per grid step fit the "
    "meta['vmem_target'] VMEM budget (v4/v5e/v5p/v6e table; default "
    "v5e = 16 MiB; meta['vmem_budget_bytes'] overrides)")
def _check_vmem_budget(ctx: ProgramContext):
    budget = vmem_budget(ctx.meta)
    target = ctx.meta.get("vmem_target", DEFAULT_VMEM_TARGET)
    for rec in ctx.payload.records:
        est = estimate_vmem(rec)
        if est > budget:
            yield (f"~{est / 2 ** 20:.1f} MiB per grid step > {target} "
                   f"budget {budget / 2 ** 20:.1f} MiB (grid {rec.grid})",
                   rec.name)


@PALLAS_RULES.rule(
    "pallas-pad-coverage",
    "every registry probe at non-tile-divisible extents returned the "
    "contract output shapes (pad-to-tile + slice-back discipline)")
def _check_pad_coverage(ctx: ProgramContext):
    for probe in ctx.payload.probes:
        if not probe.ok:
            yield probe.detail, probe.name


def lint_kernels(payload: PallasPrograms, program: str = "kernels",
                 meta: Optional[dict] = None, only=None):
    ctx = ProgramContext(program=program, kind="pallas", payload=payload,
                         meta=dict(meta or {}))
    return PALLAS_RULES.run(ctx, only=only)


def oversized_control() -> PallasPrograms:
    """A fabricated launch that MUST trip both static rules: its BlockSpec
    maps outside the operand and its per-step footprint is ~128 MiB."""
    rec = KernelRecord(
        name="control_oversized",
        grid=(4,),
        in_specs=[((2048, 4096), lambda i: (i, 0))],
        out_specs=[((2048, 4096), lambda i: (i, 0))],
        out_shapes=[((4096, 4096), "float32")],
        scratch_shapes=[],
        arg_shapes=[(4096, 4096)],
        arg_dtypes=["float32"])
    return PallasPrograms(records=[rec], probes=[])
