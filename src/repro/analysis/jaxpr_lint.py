"""jaxpr_lint: rules over traced round-path jaxprs.

Where hlo_lint inspects what XLA compiled, jaxpr_lint inspects what WE
asked for -- before XLA optimizations can mask it. The round-path entry
points (``client.train_group_masked``'s body, ``Aggregator``'s grouped /
stacked / sharded cores, ``svd_realloc_gram``, the event-engine fire path)
are traced with ``jax.make_jaxpr`` on ShapeDtypeStructs (free: no arrays,
no compile) and walked recursively through every sub-jaxpr:

  jaxpr-callback    pure_callback / io_callback / debug_callback /
                    debug_print equations -- each one is a host round-trip
                    that serializes against in-flight device work (the
                    regression class PR 3 fixed by hand)
  jaxpr-host-sync   explicit host-sync primitives (device_get-style
                    transfers that show up as equations)
  jaxpr-f64         any float64 input / output / intermediate aval -- the
                    codebase is float32-only and an accidental promotion
                    doubles every byte count downstream

The walker duck-types sub-jaxprs (anything with ``.eqns``, closed jaxprs
via ``.jaxpr``) so it works across jax versions without importing
``jax.extend``.
"""
from __future__ import annotations

from typing import Iterable, Iterator, List, Optional, Tuple

import jax

from repro.analysis.rules import Finding, ProgramContext, RuleSet

CALLBACK_PRIMS = ("pure_callback", "io_callback", "debug_callback",
                  "debug_print")
HOST_SYNC_PRIMS = ("infeed", "outfeed", "device_put")  # device_put with a
# host target inside a traced program is a transfer; plain device_put of
# constants at trace time does not appear as an equation.


def _as_jaxpr(obj):
    """ClosedJaxpr -> Jaxpr; Jaxpr -> itself; else None (duck-typed)."""
    inner = getattr(obj, "jaxpr", None)
    if inner is not None and hasattr(inner, "eqns"):
        return inner
    if hasattr(obj, "eqns"):
        return obj
    return None


def iter_eqns(jaxpr_like, path: str = "") -> Iterator[Tuple[str, object]]:
    """Depth-first (path, eqn) over a jaxpr and every sub-jaxpr found in
    equation params (scan/while/cond bodies, pjit callees, custom vjps)."""
    jaxpr = _as_jaxpr(jaxpr_like)
    if jaxpr is None:
        return
    for eqn in jaxpr.eqns:
        name = getattr(eqn.primitive, "name", str(eqn.primitive))
        here = f"{path}/{name}" if path else name
        yield here, eqn
        for pval in eqn.params.values():
            vals = pval if isinstance(pval, (list, tuple)) else (pval,)
            for v in vals:
                if _as_jaxpr(v) is not None:
                    yield from iter_eqns(v, here)


def _avals(jaxpr_like):
    jaxpr = _as_jaxpr(jaxpr_like)
    if jaxpr is None:
        return
    for kind, vs in (("invar", jaxpr.invars), ("outvar", jaxpr.outvars),
                     ("constvar", jaxpr.constvars)):
        for v in vs:
            aval = getattr(v, "aval", None)
            if aval is not None:
                yield kind, aval


JAXPR_RULES = RuleSet("jaxpr")


@JAXPR_RULES.rule(
    "jaxpr-callback",
    "no pure_callback / io_callback / debug_callback / debug_print "
    "equations anywhere in the traced round path (each is a host "
    "round-trip serializing against in-flight device work); "
    "meta['allow_callbacks'] to waive")
def _check_callbacks(ctx: ProgramContext):
    if ctx.meta.get("allow_callbacks"):
        return
    for path, eqn in iter_eqns(ctx.payload):
        name = getattr(eqn.primitive, "name", "")
        if name in CALLBACK_PRIMS:
            cb = eqn.params.get("callback", None)
            detail = f" ({cb})" if cb is not None else ""
            yield f"host callback '{name}'{detail}", path


@JAXPR_RULES.rule(
    "jaxpr-host-sync",
    "no explicit host-sync primitives (infeed/outfeed/device transfers "
    "appearing as traced equations)")
def _check_host_sync(ctx: ProgramContext):
    for path, eqn in iter_eqns(ctx.payload):
        name = getattr(eqn.primitive, "name", "")
        if name in HOST_SYNC_PRIMS:
            yield f"host-sync primitive '{name}'", path


@JAXPR_RULES.rule(
    "jaxpr-f64",
    "no float64 aval on any input / output / equation result: the round "
    "path is float32-only and a silent x64 promotion doubles every "
    "downstream byte count; meta['allow_f64'] to waive")
def _check_f64(ctx: ProgramContext):
    if ctx.meta.get("allow_f64"):
        return
    import numpy as np
    for kind, aval in _avals(ctx.payload):
        if getattr(aval, "dtype", None) == np.float64:
            yield f"float64 {kind} {aval}", kind
    for path, eqn in iter_eqns(ctx.payload):
        for v in eqn.outvars:
            aval = getattr(v, "aval", None)
            if aval is not None and getattr(aval, "dtype", None) \
                    == np.float64:
                yield f"float64 intermediate {aval}", path


def trace(fn, *args, **kwargs):
    """``jax.make_jaxpr`` over ShapeDtypeStruct (or concrete) arguments --
    the standard way to obtain a lintable payload for an entry point."""
    return jax.make_jaxpr(fn)(*args, **kwargs)


def lint_jaxpr(jaxpr_like, program: str, meta: Optional[dict] = None,
               only: Optional[Iterable[str]] = None) -> List[Finding]:
    ctx = ProgramContext(program=program, kind="jaxpr", payload=jaxpr_like,
                         meta=dict(meta or {}))
    return JAXPR_RULES.run(ctx, only=only)


def jaxpr_stats(jaxpr_like) -> dict:
    """Cheap size stats for the audit artifact."""
    n_eqns = 0
    prims = set()
    for _, eqn in iter_eqns(jaxpr_like):
        n_eqns += 1
        prims.add(getattr(eqn.primitive, "name", "?"))
    return {"eqns": n_eqns, "distinct_primitives": len(prims)}
