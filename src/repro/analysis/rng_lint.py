"""RNG/determinism dataflow lint for the federated round path (DESIGN.md §10).

Two passes over two layers of randomness:

* **Key-provenance dataflow (kind "rng-flow")** -- traces a round-path
  function to its jaxpr and tracks every PRNG key through the program:
  ``random_wrap``/``random_unwrap`` alias (an old-style uint32 key and
  its typed wrapping are ONE key), ``random_split``/``random_fold_in``
  derive fresh keys, ``random_bits`` extracts entropy. The lint follows
  keys across ``pjit``/call sub-jaxprs (inner invars unify with outer
  operands), so `jax.random.normal(key)` consuming a key inside three
  nested pjits still counts against the OUTER key. Rules: a key whose
  entropy is extracted twice (the classic key-reuse correlation bug),
  and a key both sampled-from and split/folded (the sample-then-derive
  hazard: the derived stream overlaps the sample).

* **Host determinism (kind "rng-host")** -- AST rules over round-path
  source files: unseeded ``np.random.default_rng()`` (irreproducible
  stream), host-clock reads (``time.time()`` & friends) on the
  virtual-clock round path, two call sites constructing
  ``np.random.SeedSequence`` entropy with the same shape (per-client
  stream collision: both sites derive the SAME stream for a client),
  and aggregation inputs iterated from a set (hash-order-sensitive
  client iteration). Intentional uses carry a same-line waiver comment:
  ``# host-clock: ok (<why>)`` / ``# rng: ok (<why>)``.

Both passes feed the PR-6 rules/report engine; ``tools/verify_protocol.py``
sweeps them (with positive controls) into ``AUDIT_protocol.json``.
"""
from __future__ import annotations

import ast
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import jax

from repro.analysis.rules import Finding, ProgramContext, RuleSet

# ---------------------------------------------------------------------------
# key-provenance dataflow over jaxprs
# ---------------------------------------------------------------------------

_ALIAS_PRIMS = {"random_wrap", "random_unwrap"}
_DERIVE_PRIMS = {"random_split", "random_fold_in"}
_CONSUME_PRIMS = {"random_bits"}


@dataclass
class KeyRecord:
    """One key identity (an alias class of jaxpr vars)."""
    name: str
    consumers: List[str] = field(default_factory=list)   # eqn paths
    derivations: List[str] = field(default_factory=list)


@dataclass
class KeyFlowReport:
    """Payload of the rng-flow pass: every key identity of one traced
    round-path function, with where it was consumed and derived-from."""
    keys: List[KeyRecord] = field(default_factory=list)
    eqns: int = 0

    def stats(self) -> dict:
        return {"keys": len(self.keys), "eqns": self.eqns,
                "consumptions": sum(len(k.consumers) for k in self.keys),
                "derivations": sum(len(k.derivations) for k in self.keys)}


def _is_var(v) -> bool:
    """jaxpr Var (not a Literal -- Literals also carry ``aval`` and are
    unhashable, so they can never be env keys)."""
    return hasattr(v, "aval") and v.__class__.__name__ != "Literal"


class _KeyFlow:
    """Union-of-aliases key tracker walked over a (closed) jaxpr,
    recursing into call-like sub-jaxprs with inner invars unified to the
    outer operands."""

    def __init__(self):
        self.records: Dict[int, KeyRecord] = {}
        self._next = 0
        self.eqns = 0

    def fresh(self, name: str) -> int:
        kid = self._next
        self._next += 1
        self.records[kid] = KeyRecord(name=name)
        return kid

    def walk(self, jaxpr, env: Dict, path: str) -> None:
        for i, eqn in enumerate(jaxpr.eqns):
            self.eqns += 1
            prim = eqn.primitive.name
            here = f"{path}/{i}:{prim}"
            op0 = eqn.invars[0] if eqn.invars else None

            def rid(var, label):
                """Key id of an operand var (fresh root if unseen)."""
                if var is None or not _is_var(var):
                    return None
                if var not in env:
                    env[var] = self.fresh(label)
                return env[var]

            if prim in _ALIAS_PRIMS:
                env[eqn.outvars[0]] = rid(op0, f"{here}<-arg")
            elif prim in _DERIVE_PRIMS:
                kid = rid(op0, f"{here}<-arg")
                if kid is not None:
                    self.records[kid].derivations.append(here)
                env[eqn.outvars[0]] = self.fresh(here)
            elif prim in _CONSUME_PRIMS:
                kid = rid(op0, f"{here}<-arg")
                if kid is not None:
                    self.records[kid].consumers.append(here)
            else:
                subs = _sub_jaxprs(eqn)
                if subs:
                    for sub in subs:
                        inner = getattr(sub, "jaxpr", sub)
                        sub_env = dict(env)
                        # unify inner invars with outer operands (exact
                        # for pjit/core_call; positional best-effort for
                        # scan/while whose invars carry extra consts)
                        for iv, ov in zip(inner.invars, eqn.invars):
                            if _is_var(ov) and ov in env:
                                sub_env[iv] = env[ov]
                        self.walk(inner, sub_env, here)


def _sub_jaxprs(eqn) -> List:
    subs = []
    for v in eqn.params.values():
        vals = v if isinstance(v, (tuple, list)) else (v,)
        for x in vals:
            if hasattr(x, "eqns") or hasattr(x, "jaxpr"):
                subs.append(x)
    return subs


def key_flow(fn, *args) -> KeyFlowReport:
    """Trace ``fn(*args)`` and return its key-provenance report."""
    closed = jax.make_jaxpr(fn)(*args)
    flow = _KeyFlow()
    env: Dict = {}
    for i, var in enumerate(closed.jaxpr.invars):
        env[var] = flow.fresh(f"arg{i}")
    flow.walk(closed.jaxpr, env, "")
    rep = KeyFlowReport(eqns=flow.eqns)
    # only identities that ever touched the key machinery are keys
    rep.keys = [r for r in flow.records.values()
                if r.consumers or r.derivations]
    return rep


RNG_FLOW_RULES = RuleSet("rng-flow")


@RNG_FLOW_RULES.rule(
    "rng-key-reuse",
    "a PRNG key's entropy is extracted by two or more samplers -- the "
    "draws are correlated, not independent")
def _check_key_reuse(ctx: ProgramContext):
    rep: KeyFlowReport = ctx.payload
    for k in rep.keys:
        if len(k.consumers) >= 2:
            yield (f"key {k.name} consumed {len(k.consumers)} times: "
                   + ", ".join(k.consumers[:3]), k.consumers[1])


@RNG_FLOW_RULES.rule(
    "rng-sample-then-derive",
    "a key is both sampled-from AND split/folded: the derived streams "
    "overlap the sample's entropy; derive first, sample from children")
def _check_sample_derive(ctx: ProgramContext):
    rep: KeyFlowReport = ctx.payload
    for k in rep.keys:
        if k.consumers and k.derivations:
            yield (f"key {k.name} sampled at {k.consumers[0]} and "
                   f"derived at {k.derivations[0]}", k.derivations[0])


def lint_key_flow(program: str, fn, *args,
                  meta: Optional[dict] = None) -> Tuple[List[Finding], dict]:
    rep = key_flow(fn, *args)
    ctx = ProgramContext(program=program, kind="rng-flow", payload=rep,
                         meta=meta or {})
    return RNG_FLOW_RULES.run(ctx), rep.stats()


# ---------------------------------------------------------------------------
# host determinism rules (AST over round-path source)
# ---------------------------------------------------------------------------

_CLOCK_CALLS = {("time", "time"), ("time", "monotonic"),
                ("time", "perf_counter"), ("datetime", "now"),
                ("datetime", "utcnow")}


@dataclass
class HostSource:
    """Payload of the rng-host pass: one parsed round-path source file."""
    name: str
    tree: ast.AST
    lines: List[str]

    def waived(self, lineno: int, tag: str) -> bool:
        line = self.lines[lineno - 1] if lineno - 1 < len(self.lines) else ""
        return f"# {tag}: ok" in line


def parse_host_source(name: str, source: str) -> HostSource:
    return HostSource(name=name, tree=ast.parse(source),
                      lines=source.splitlines())


def _dotted(node: ast.AST) -> Tuple[str, ...]:
    """('np', 'random', 'default_rng')-style path of a call target."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
    return tuple(reversed(parts))


RNG_HOST_RULES = RuleSet("rng-host")


@RNG_HOST_RULES.rule(
    "rng-unseeded-default-rng",
    "np.random.default_rng() with no seed: the stream is irreproducible "
    "-- derive it from a seeded SeedSequence (waiver: '# rng: ok')")
def _check_unseeded(ctx: ProgramContext):
    src: HostSource = ctx.payload
    for node in ast.walk(src.tree):
        if (isinstance(node, ast.Call) and not node.args and not node.keywords
                and _dotted(node.func)[-2:] == ("random", "default_rng")
                and not src.waived(node.lineno, "rng")):
            yield ("unseeded np.random.default_rng()",
                   f"{src.name}:{node.lineno}")


@RNG_HOST_RULES.rule(
    "rng-host-clock",
    "host-clock read on the virtual-clock round path: times must come "
    "from the event scheduler's clock (waiver: '# host-clock: ok')")
def _check_host_clock(ctx: ProgramContext):
    src: HostSource = ctx.payload
    for node in ast.walk(src.tree):
        if (isinstance(node, ast.Call)
                and _dotted(node.func)[-2:] in _CLOCK_CALLS
                and not src.waived(node.lineno, "host-clock")):
            yield (f"host clock read {'.'.join(_dotted(node.func))}()",
                   f"{src.name}:{node.lineno}")


@RNG_HOST_RULES.rule(
    "rng-seed-collision",
    "two call sites build np.random.SeedSequence entropy of the same "
    "shape: per-client streams from the two sites collide draw-for-draw "
    "-- disambiguate with a distinct literal tag (waiver: '# rng: ok')")
def _check_seed_collision(ctx: ProgramContext):
    src: HostSource = ctx.payload
    sites: Dict[Tuple, List[int]] = {}
    for node in ast.walk(src.tree):
        if not (isinstance(node, ast.Call)
                and _dotted(node.func)[-1:] == ("SeedSequence",)
                and node.args):
            continue
        ent = node.args[0]
        if not isinstance(ent, (ast.List, ast.Tuple)):
            continue
        sig = tuple(("const", e.value) if isinstance(e, ast.Constant)
                    else ("expr",) for e in ent.elts)
        if not src.waived(node.lineno, "rng"):
            sites.setdefault(sig, []).append(node.lineno)
    for sig, linenos in sorted(sites.items()):
        if len(linenos) > 1:
            yield (f"SeedSequence entropy shape {sig} built at lines "
                   f"{linenos}: same-client streams collide",
                   f"{src.name}:{linenos[1]}")


# call targets that take a key WITHOUT consuming its entropy
_KEY_NONCONSUMING = {"split", "fold_in", "PRNGKey", "key_data",
                     "wrap_key_data", "clone"}


@RNG_HOST_RULES.rule(
    "rng-host-key-reuse",
    "one PRNGKey variable feeds two or more consuming calls in the same "
    "function: the draws share a stream and correlate (the init-then-"
    "sample serving bug) -- jax.random.split first (waiver: '# rng: ok')")
def _check_host_key_reuse(ctx: ProgramContext):
    src: HostSource = ctx.payload
    for fn in ast.walk(src.tree):
        if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        key_names = set()
        for node in ast.walk(fn):
            if (isinstance(node, ast.Assign)
                    and isinstance(node.value, ast.Call)
                    and _dotted(node.value.func)[-1:] == ("PRNGKey",)):
                key_names.update(t.id for t in node.targets
                                 if isinstance(t, ast.Name))
        if not key_names:
            continue
        uses: Dict[str, List[int]] = {}
        for node in ast.walk(fn):
            if not isinstance(node, ast.Call):
                continue
            if _dotted(node.func)[-1:] and \
                    _dotted(node.func)[-1] in _KEY_NONCONSUMING:
                continue
            for arg in list(node.args) + [kw.value for kw in node.keywords]:
                if isinstance(arg, ast.Name) and arg.id in key_names:
                    uses.setdefault(arg.id, []).append(node.lineno)
        for name, linenos in sorted(uses.items()):
            live = [ln for ln in sorted(linenos)
                    if not src.waived(ln, "rng")]
            if len(live) >= 2:
                yield (f"PRNGKey variable '{name}' consumed at lines "
                       f"{live}: the draws share one stream",
                       f"{src.name}:{live[1]}")


@RNG_HOST_RULES.rule(
    "rng-order-sensitive-iteration",
    "iteration directly over a set feeds hash-membership-history order "
    "into round-path state -- iterate sorted(...) (waiver: '# rng: ok')")
def _check_set_iteration(ctx: ProgramContext):
    src: HostSource = ctx.payload

    def is_set_expr(e):
        return (isinstance(e, (ast.Set, ast.SetComp))
                or (isinstance(e, ast.Call)
                    and _dotted(e.func)[-1:] == ("set",))
                or (isinstance(e, ast.BinOp)
                    and isinstance(e.op, (ast.BitAnd, ast.BitOr, ast.Sub))
                    and (is_set_expr(e.left) or is_set_expr(e.right))))

    def hit(iter_expr, lineno):
        if is_set_expr(iter_expr) and not src.waived(lineno, "rng"):
            yield (f"iterating a set directly", f"{src.name}:{lineno}")

    for node in ast.walk(src.tree):
        if isinstance(node, ast.For):
            yield from hit(node.iter, node.lineno)
        elif isinstance(node, (ast.ListComp, ast.GeneratorExp,
                               ast.DictComp, ast.SetComp)):
            for gen in node.generators:
                yield from hit(gen.iter, node.lineno)


def lint_host_source(program: str, source: str,
                     meta: Optional[dict] = None
                     ) -> Tuple[List[Finding], dict]:
    src = parse_host_source(program, source)
    ctx = ProgramContext(program=program, kind="rng-host", payload=src,
                         meta=meta or {})
    n_nodes = sum(1 for _ in ast.walk(src.tree))
    return RNG_HOST_RULES.run(ctx), {"ast_nodes": n_nodes,
                                     "lines": len(src.lines)}


# ---------------------------------------------------------------------------
# deliberately-broken programs (positive controls for the sweep)
# ---------------------------------------------------------------------------

def broken_key_reuse(key):
    """One key, two samplers: rng-key-reuse must trip."""
    a = jax.random.normal(key, (2,))
    b = jax.random.uniform(key, (2,))
    return a + b


BROKEN_HOST_CLOCK = (
    "import time\n"
    "def round_stats():\n"
    "    t0 = time.time()\n"
    "    return {'wall': time.time() - t0}\n"
)

BROKEN_UNSEEDED = (
    "import numpy as np\n"
    "def jitter():\n"
    "    return np.random.default_rng().random()\n"
)

BROKEN_SEED_COLLISION = (
    "import numpy as np\n"
    "def latency_rng(seed, client):\n"
    "    return np.random.default_rng(np.random.SeedSequence([seed, client]))\n"
    "def batch_rng(seed, client):\n"
    "    return np.random.default_rng(np.random.SeedSequence([seed, client]))\n"
)

BROKEN_HOST_KEY_REUSE = (
    "import jax\n"
    "def setup(model, seed):\n"
    "    key = jax.random.PRNGKey(seed)\n"
    "    params = model.init(key)\n"
    "    prompts = jax.random.randint(key, (4, 32), 0, 100)\n"
    "    return params, prompts\n"
)

BROKEN_SET_ITERATION = (
    "import numpy as np\n"
    "def aggregate(updates, clients):\n"
    "    return np.mean([updates[c] for c in set(clients)], axis=0)\n"
)
