"""Shared lowering cache for the static-analysis sweeps.

``tools/lint_programs.py`` (PR 6) lowered each engine x method x backend
program privately per pass, and the complexity certifier would lower the
same programs again at every ladder point. This module gives both one
cache keyed by :class:`ProgramPoint` -- the full parameterization of an
aggregation program (engine, method, backend, d, n, rank levels, clients
per group, bucket width, pipeline depth, shard count). Each distinct
point is lowered + compiled ONCE per process; the parsed
``hlo_lint.HLOProgram`` payload and the ``liveness`` stats are computed
lazily and memoized on the entry, so the lint passes, the collective-
parity pass and the certifier all analyze one artifact.

The aval builders are the PR-6 ones generalized from module constants to
the point's fields; ``tools/lint_programs.py`` now imports them from
here (single source of truth for the matrix shapes).
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Dict, Optional, Tuple

AVG_METHODS = ("fedavg", "hetlora", "ffa", "flora")
SVD_METHODS = ("flexlora", "raflora")
BACKENDS = ("dense", "factored", "kernel")
ENGINES = ("sequential", "batched", "async", "event", "sharded")


@dataclass(frozen=True)
class ProgramPoint:
    """One fully-parameterized aggregation program in the sweep matrix."""

    engine: str
    method: str
    backend: str
    d: int = 160
    n: int = 192
    rank_levels: Tuple[int, ...] = (4, 8)
    m_per_group: int = 2            # clients per rank group
    p_bucket: int = 2               # adapters per bucket (grouped rows)
    depth: int = 1                  # pipeline depth (async rows use 2)
    shards: int = 0                 # sharded rows: 0 = all visible devices

    @property
    def r_max(self) -> int:
        return max(self.rank_levels)

    @property
    def cohort(self) -> int:
        return self.m_per_group * len(self.rank_levels) * self.depth

    def scaled(self, **kw) -> "ProgramPoint":
        return replace(self, **kw)


@dataclass
class LoweredProgram:
    """Cache entry: compiled HLO text + lazily parsed/analyzed views."""

    point: ProgramPoint
    text: str
    _payload: Optional[object] = None
    _liveness: Optional[object] = None

    @property
    def payload(self):
        """``hlo_lint.HLOProgram`` (parsed comps + walker stats)."""
        if self._payload is None:
            from repro.analysis import hlo_lint
            self._payload = hlo_lint.parse_program(self.text)
        return self._payload

    @property
    def liveness(self):
        """``liveness.LivenessStats`` of the compiled program."""
        if self._liveness is None:
            from repro.analysis.liveness import analyze_liveness
            self._liveness = analyze_liveness(self.text)
        return self._liveness


_CACHE: Dict[ProgramPoint, LoweredProgram] = {}


def cache_info() -> dict:
    return {"entries": len(_CACHE)}


def clear_cache() -> None:
    _CACHE.clear()


def _f32(*shape):
    import jax
    import jax.numpy as jnp
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def _warg_for(pt: ProgramPoint, m: int):
    """Weight-argument aval: (M,) for the avg family, omega (M, r_max)
    for the SVD family."""
    return _f32(m) if pt.method in AVG_METHODS else _f32(m, pt.r_max)


def _stacked_avals(pt: ProgramPoint, with_fallback: bool):
    m = pt.m_per_group * len(pt.rank_levels)
    bs, as_ = _f32(m, pt.d, pt.r_max), _f32(m, pt.r_max, pt.n)
    gb, ga = _f32(pt.d, pt.r_max), _f32(pt.r_max, pt.n)
    fb = _f32(pt.r_max) if with_fallback else None
    return bs, as_, _warg_for(pt, m), gb, ga, fb


def _grouped_avals(pt: ProgramPoint, with_fallback: bool):
    group_bs, group_as = [], []
    m = 0
    for r in pt.rank_levels:
        g = pt.m_per_group * pt.depth
        m += g
        group_bs.append(tuple(_f32(g, pt.d, r) for _ in range(pt.p_bucket)))
        group_as.append(tuple(_f32(g, r, pt.n) for _ in range(pt.p_bucket)))
    gbs = tuple(_f32(pt.d, pt.r_max) for _ in range(pt.p_bucket))
    gas = tuple(_f32(pt.r_max, pt.n) for _ in range(pt.p_bucket))
    fb = _f32(pt.r_max) if with_fallback else None
    return (tuple(group_bs), tuple(group_as), _warg_for(pt, m), gbs, gas,
            fb)


def _lower_text(pt: ProgramPoint) -> str:
    """Optimized HLO of the engine's per-bucket aggregation program."""
    import jax
    import jax.numpy as jnp
    from repro.core import aggregation

    fallback = pt.method == "raflora"
    if pt.engine == "sequential":
        bs, as_, warg, gb, ga, fb = _stacked_avals(pt, fallback)
        low = aggregation._stacked_core.lower(
            bs, as_, warg, gb, ga, fb, r_max=pt.r_max, backend=pt.backend,
            method=pt.method)
    elif pt.engine in ("batched", "async", "event"):
        # async consumes depth x M buffered clients; the event fire path
        # dispatches the SAME grouped program (present mask = omega data)
        gbs_, gas_, warg, gbs, gas, fb = _grouped_avals(pt, fallback)
        low = aggregation._grouped_core.lower(
            gbs_, gas_, warg, gbs, gas, fb, r_max=pt.r_max,
            backend=pt.backend, method=pt.method)
    elif pt.engine == "sharded":
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.launch.mesh import make_fl_mesh
        mesh = make_fl_mesh(pt.shards)
        n_dev = mesh.shape["data"]
        cl = NamedSharding(mesh, P("data"))
        sds = jax.ShapeDtypeStruct
        group_bs, group_as, group_w = [], [], []
        for r in pt.rank_levels:
            group_bs.append((sds((n_dev, pt.d, r), jnp.float32,
                                 sharding=cl),))
            group_as.append((sds((n_dev, r, pt.n), jnp.float32,
                                 sharding=cl),))
            group_w.append(sds(
                (n_dev,) + (() if pt.method in AVG_METHODS
                            else (pt.r_max,)),
                jnp.float32, sharding=cl))
        fb = _f32(pt.r_max) if fallback else None
        gbs = (_f32(pt.d, pt.r_max),)
        gas = (_f32(pt.r_max, pt.n),)
        fn = aggregation.sharded_grouped_fn(mesh, pt.r_max, pt.backend,
                                            pt.method)
        low = fn.lower(tuple(group_bs), tuple(group_as), tuple(group_w),
                       gbs, gas, fb)
    else:
        raise ValueError(pt.engine)
    return low.compile().as_text()


def lower_program(pt: ProgramPoint) -> LoweredProgram:
    """Cached lower+compile of ``pt`` (one compile per distinct point per
    process, shared by every analysis pass)."""
    hit = _CACHE.get(pt)
    if hit is None:
        hit = _CACHE[pt] = LoweredProgram(pt, _lower_text(pt))
    return hit
