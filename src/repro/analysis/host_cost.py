"""host_cost: host-side cost counters for the numpy round path.

The device programs are certified by static analysis of their HLO; the
*host* round path (planning, registry sampling, weight / staleness
computation, event scheduler) is plain numpy + Python and has no HLO to
walk. This module gives it the same treatment with two signals:

  * **loop iterations** -- federation code calls :func:`tick` at its
    Python loops (one call per loop with ``n=len(...)``, so the hook adds
    O(1) work per loop, not per element). Inactive monitors make ``tick``
    a single global read -- the round path pays one ``is None`` check.
  * **allocated ndarray bytes** -- while a :class:`HostCostMonitor` is
    active, a tracing shim patches the numpy array constructors
    (``np.zeros`` / ``np.asarray`` / ``np.stack`` / ...) on the numpy
    module object and records ``result.nbytes`` per call site. Federation
    modules resolve ``np.X`` at call time through the module, so the shim
    sees every host allocation without touching their code.

Together they give a per-round host cost vector the complexity certifier
(``analysis/complexity.py``) fits scaling exponents over: per-round cost
must track cohort size, NOT registry size -- the tripwire for the
ROADMAP million-client item.

Usage::

    mon = HostCostMonitor()
    with mon:
        for r in range(rounds):
            server.run_round()
            mon.mark(f"round{r}")
    per_round = mon.phases[warmup:]
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

_ACTIVE: Optional["HostCostMonitor"] = None

# numpy constructors worth tracing: everything the round path uses to
# build fresh host arrays. Reductions / ufuncs return tiny scalars and
# are deliberately left alone (patching them would distort timings).
_TRACED_FNS = ("empty", "zeros", "ones", "full", "arange", "array",
               "asarray", "ascontiguousarray", "stack", "concatenate",
               "copy", "pad", "where", "repeat", "tile")


def tick(label: str, n: int = 1) -> None:
    """Record ``n`` iterations of the host loop ``label`` (no-op unless a
    monitor is active)."""
    mon = _ACTIVE
    if mon is not None:
        mon.loop_iters[label] = mon.loop_iters.get(label, 0) + int(n)


def alloc(label: str, nbytes: int) -> None:
    """Record an explicit host allocation (for buffers built outside the
    traced numpy constructors)."""
    mon = _ACTIVE
    if mon is not None:
        mon.alloc_bytes[label] = mon.alloc_bytes.get(label, 0) + int(nbytes)


@dataclass
class HostPhase:
    """Counter deltas between two ``mark()`` calls (one round, usually)."""

    label: str
    loop_iters: int = 0
    alloc_bytes: int = 0
    loop_detail: Dict[str, int] = field(default_factory=dict)

    def to_json(self) -> dict:
        return {"label": self.label, "loop_iters": self.loop_iters,
                "alloc_bytes": self.alloc_bytes,
                "loop_detail": dict(sorted(self.loop_detail.items()))}


class HostCostMonitor:
    """Context manager accumulating host-cost counters; ``mark(label)``
    closes a phase with the deltas since the previous mark (mirrors
    ``dispatch_audit.DispatchMonitor``)."""

    def __init__(self):
        self.loop_iters: Dict[str, int] = {}
        self.alloc_bytes: Dict[str, int] = {}
        self.phases: List[HostPhase] = []
        self._last = (0, 0)
        self._last_loops: Dict[str, int] = {}
        self._saved: Dict[str, object] = {}

    # -- lifecycle ---------------------------------------------------------
    def __enter__(self) -> "HostCostMonitor":
        global _ACTIVE
        if _ACTIVE is not None:
            raise RuntimeError("nested HostCostMonitor")
        self._patch_numpy()
        _ACTIVE = self
        self._last = (0, 0)
        self._last_loops = {}
        return self

    def __exit__(self, *exc) -> bool:
        global _ACTIVE
        _ACTIVE = None
        for name, orig in self._saved.items():
            setattr(np, name, orig)
        self._saved.clear()
        return False

    def _patch_numpy(self) -> None:
        for name in _TRACED_FNS:
            orig = getattr(np, name)
            self._saved[name] = orig

            def traced(*args, __orig=orig, __label=f"np.{name}", **kw):
                out = __orig(*args, **kw)
                nb = getattr(out, "nbytes", None)
                if nb:
                    mon = _ACTIVE
                    if mon is not None:
                        mon.alloc_bytes[__label] = (
                            mon.alloc_bytes.get(__label, 0) + int(nb))
                return out

            setattr(np, name, traced)

    # -- accounting --------------------------------------------------------
    @property
    def total_loop_iters(self) -> int:
        return sum(self.loop_iters.values())

    @property
    def total_alloc_bytes(self) -> int:
        return sum(self.alloc_bytes.values())

    def mark(self, label: str) -> HostPhase:
        """Close the current phase: counters since the previous mark."""
        now = (self.total_loop_iters, self.total_alloc_bytes)
        detail = {k: v - self._last_loops.get(k, 0)
                  for k, v in self.loop_iters.items()
                  if v - self._last_loops.get(k, 0)}
        ph = HostPhase(label, loop_iters=now[0] - self._last[0],
                       alloc_bytes=now[1] - self._last[1],
                       loop_detail=detail)
        self._last = now
        self._last_loops = dict(self.loop_iters)
        self.phases.append(ph)
        return ph

    def stats(self) -> dict:
        return {
            "phases": [p.to_json() for p in self.phases],
            "loop_iters": dict(sorted(self.loop_iters.items())),
            "alloc_bytes": dict(sorted(self.alloc_bytes.items())),
            "total_loop_iters": self.total_loop_iters,
            "total_alloc_bytes": self.total_alloc_bytes,
        }


def measure_rounds(server, rounds: int = 3, warmup: int = 1,
                   flush: bool = True) -> dict:
    """Run ``rounds`` federated rounds under a monitor and return the
    mean per-round host cost over the post-warmup phases.

    The warmup rounds absorb jit tracing (tracing runs Python, inflating
    loop/alloc counters) so the steady-state mean reflects the recurring
    host cost the scaling contracts constrain.
    """
    mon = HostCostMonitor()
    with mon:
        for r in range(rounds):
            server.run_round()
            if flush:
                server.flush_stats()
            mon.mark(f"round{r}")
    steady = mon.phases[warmup:] or mon.phases
    k = float(len(steady))
    return {
        "loop_iters": sum(p.loop_iters for p in steady) / k,
        "alloc_bytes": sum(p.alloc_bytes for p in steady) / k,
        "phases": [p.to_json() for p in mon.phases],
    }
