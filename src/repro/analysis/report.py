"""Audit report assembly + the tracked ``AUDIT_program_lint.json`` schema.

Artifact schema (``schema`` bumps on breaking change)::

    {
      "schema": 1,
      "matrix": {...sweep parameters...},
      "summary": {"programs": N, "errors": E, "warnings": W,
                  "controls": C, "controls_failed": [names], "ok": bool},
      "controls": {name: {"tripped": bool, "rule": id, "detail": str}},
      "programs": [
        {"program": name, "kind": "hlo|jaxpr|pallas|dispatch",
         "status": "ok|fail",
         "stats": {...pass-specific numbers...},
         "findings": [{"rule", "severity", "message", "location"}]}
      ]
    }

Programs are sorted by name and the writer is deterministic (no
timestamps), so the tracked artifact diffs cleanly across runs.

*Positive controls* are deliberately-broken programs each rule must flag
(ISSUE 6 acceptance): a control that does NOT trip marks the whole report
failed -- a lint gate whose tripwires are dead is worse than none.
"""
from __future__ import annotations

import json
import os
import tempfile
from dataclasses import dataclass, field
from typing import Dict, List, Optional

from repro.analysis.rules import Finding, SEV_ERROR

SCHEMA_VERSION = 1


@dataclass
class ProgramAudit:
    """Lint outcome for one program of the sweep matrix."""
    program: str
    kind: str
    findings: List[Finding] = field(default_factory=list)
    stats: Dict = field(default_factory=dict)

    @property
    def errors(self) -> List[Finding]:
        return [f for f in self.findings if f.severity == SEV_ERROR]

    @property
    def ok(self) -> bool:
        return not self.errors

    def to_json(self) -> dict:
        return {
            "program": self.program,
            "kind": self.kind,
            "status": "ok" if self.ok else "fail",
            "stats": self.stats,
            "findings": [f.to_json() for f in self.findings],
        }


@dataclass
class Control:
    """A positive control: ``rule`` must have tripped on the broken
    program for the report to pass. A control whose pass RAISED is
    recorded with ``error`` set and counts as failed the same as one
    that silently did not trip (a tripwire that crashes is just as dead
    as one that never fires)."""
    name: str
    rule: str
    tripped: bool
    detail: str = ""
    error: str = ""

    def to_json(self) -> dict:
        out = {"tripped": self.tripped, "rule": self.rule,
               "detail": self.detail}
        if self.error:
            out["error"] = self.error
        return out


class AuditReport:
    def __init__(self, matrix: Optional[dict] = None):
        self.matrix = matrix or {}
        self.programs: List[ProgramAudit] = []
        self.controls: Dict[str, Control] = {}

    def add(self, audit: ProgramAudit) -> ProgramAudit:
        self.programs.append(audit)
        return audit

    def add_control(self, name: str, rule: str, findings: List[Finding],
                    detail: str = "") -> Control:
        """Record a positive control: pass iff ``rule`` appears in the
        findings produced on the deliberately-broken program."""
        tripped = any(f.rule == rule for f in findings)
        ctl = Control(name, rule, tripped,
                      detail or "; ".join(f.message for f in findings[:2]))
        self.controls[name] = ctl
        return ctl

    def add_control_error(self, name: str, rule: str,
                          exc: BaseException) -> Control:
        """Record a control whose pass raised: never tripped, and the
        exception is preserved in the artifact for diagnosis."""
        ctl = Control(name, rule, tripped=False,
                      detail=f"control pass raised {type(exc).__name__}",
                      error=repr(exc))
        self.controls[name] = ctl
        return ctl

    def run_control(self, name: str, rule: str, fn,
                    detail: str = "") -> Control:
        """Run the control pass ``fn() -> findings`` and record it;
        an exception inside the pass fails the control (and thus the
        report) instead of aborting the whole sweep."""
        try:
            findings = fn()
        except Exception as exc:     # noqa: BLE001 -- any crash = dead
            return self.add_control_error(name, rule, exc)
        return self.add_control(name, rule, findings, detail)

    @property
    def failed_programs(self) -> List[ProgramAudit]:
        return [p for p in self.programs if not p.ok]

    @property
    def failed_controls(self) -> List[str]:
        return sorted(n for n, c in self.controls.items() if not c.tripped)

    @property
    def ok(self) -> bool:
        return not self.failed_programs and not self.failed_controls

    def summary(self) -> dict:
        return {
            "programs": len(self.programs),
            "errors": sum(len(p.errors) for p in self.programs),
            "warnings": sum(len(p.findings) - len(p.errors)
                            for p in self.programs),
            "controls": len(self.controls),
            "controls_failed": self.failed_controls,
            "ok": self.ok,
        }

    def to_json(self) -> dict:
        return {
            "schema": SCHEMA_VERSION,
            "matrix": self.matrix,
            "summary": self.summary(),
            "controls": {n: self.controls[n].to_json()
                         for n in sorted(self.controls)},
            "programs": [p.to_json() for p in
                         sorted(self.programs, key=lambda p: p.program)],
        }

    def write(self, path: str) -> None:
        """Atomic write (tmp + rename) so a crashed sweep never leaves a
        truncated tracked artifact."""
        payload = json.dumps(self.to_json(), indent=1, sort_keys=False)
        d = os.path.dirname(os.path.abspath(path)) or "."
        fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                f.write(payload + "\n")
            os.replace(tmp, path)
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise
