"""Synthetic datasets standing in for the paper's CIFAR100 / 20NG / GSM8K.

The container has no external datasets (repro band 2/5), so the accuracy
experiments run on controlled synthetic tasks that preserve the properties
the paper's phenomena depend on:

  * many classes (so Dirichlet / pathological label skew bites),
  * class structure richer than rank r_1 can express (so higher-rank
    adapters genuinely help and rank collapse genuinely hurts),
  * per-client distribution shift.

``ClusterClassification`` draws class prototypes in a D-dim latent space and
emits patch-sequence inputs (frontend-embedding format, consumed by the
vit-base-reduced model). A class is a *mixture* of ``modes_per_class``
prototype modes, so the Bayes-optimal adapter update has rank well above
r_1 -- the knob that makes collapse measurable in accuracy.

``SequenceCopy`` is a token-level LM task (granite/qwen-reduced style
models) where each client's data uses a distinct permutation vocabulary
mapping -- the GSM8K-proxy for decoder models.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Tuple

import numpy as np


@dataclass
class ClusterClassification:
    num_classes: int = 20
    dim: int = 64                # latent / embedding dim
    patches: int = 16            # sequence length of patch embeddings
    modes_per_class: int = 4     # intra-class modes -> high-rank structure
    noise: float = 0.6
    samples_per_class: int = 100
    seed: int = 0

    def generate(self) -> Tuple[np.ndarray, np.ndarray]:
        """Returns (x (N, patches, dim) f32, y (N,) i32)."""
        rng = np.random.default_rng(self.seed)
        protos = rng.normal(
            size=(self.num_classes, self.modes_per_class, self.patches,
                  self.dim)).astype(np.float32)
        xs, ys = [], []
        for c in range(self.num_classes):
            modes = rng.integers(0, self.modes_per_class,
                                 size=self.samples_per_class)
            base = protos[c, modes]                       # (S, P, D)
            x = base + self.noise * rng.normal(
                size=base.shape).astype(np.float32)
            xs.append(x.astype(np.float32))
            ys.append(np.full(self.samples_per_class, c, np.int32))
        x = np.concatenate(xs)
        y = np.concatenate(ys)
        order = rng.permutation(len(y))
        return x[order], y[order]

    def train_test_split(self, test_frac: float = 0.2):
        x, y = self.generate()
        n_test = int(len(y) * test_frac)
        return (x[n_test:], y[n_test:]), (x[:n_test], y[:n_test])


@dataclass
class SequenceCopy:
    """Next-token prediction with client-specific structure.

    Sequences are [pattern tokens ... delimiter, pattern tokens] -- the model
    must copy the prefix after the delimiter. The "label" used for non-IID
    partitioning is the pattern family id.
    """

    vocab_size: int = 256
    seq_len: int = 32
    num_families: int = 20
    samples_per_family: int = 100
    seed: int = 0

    def generate(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Returns (tokens (N, L), targets (N, L), family (N,))."""
        rng = np.random.default_rng(self.seed)
        half = self.seq_len // 2
        delim = self.vocab_size - 1
        toks, fams = [], []
        for f in range(self.num_families):
            # each family draws from a distinct sub-vocabulary band
            lo = 1 + (f * (self.vocab_size - 2)) // self.num_families
            hi = 1 + ((f + 1) * (self.vocab_size - 2)) // self.num_families
            pat = rng.integers(lo, max(hi, lo + 1),
                               size=(self.samples_per_family, half - 1))
            seq = np.concatenate(
                [pat, np.full((self.samples_per_family, 1), delim), pat,
                 np.zeros((self.samples_per_family,
                           self.seq_len - 2 * half + 1), np.int64)], axis=1)
            toks.append(seq[:, :self.seq_len])
            fams.append(np.full(self.samples_per_family, f, np.int32))
        tokens = np.concatenate(toks).astype(np.int32)
        family = np.concatenate(fams)
        order = rng.permutation(len(family))
        tokens = tokens[order]
        family = family[order]
        targets = np.roll(tokens, -1, axis=1)
        targets[:, -1] = 0
        return tokens, targets, family


def batches(x: np.ndarray, y: np.ndarray, batch_size: int,
            rng: np.random.Generator, epochs: int = 1):
    """Shuffled minibatch iterator over one client's shard."""
    n = len(y)
    for _ in range(epochs):
        order = rng.permutation(n)
        for i in range(0, n - batch_size + 1, batch_size):
            sel = order[i:i + batch_size]
            yield x[sel], y[sel]
        if n < batch_size:  # tiny shard: one padded batch
            sel = rng.choice(n, size=batch_size, replace=True)
            yield x[sel], y[sel]
