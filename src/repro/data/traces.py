"""Replayable client-latency traces (JSONL).

The event-driven round scheduler (``federation/events.py``) draws one
latency sample per dispatched client. A *trace* is that sample stream
written down: one JSON object per line, in global dispatch order,

    {"client": 3, "latency": 1.8042}

so replaying a trace through ``events.TraceLatency`` reproduces the exact
arrival schedule of the recorded run -- the federated trajectory becomes a
pure function of (seed, trace). Traces are the bridge to REAL system
measurements: a production deployment can log per-client round-trip times
in this format and the simulator replays them bit-for-bit.

Records are kept deliberately minimal (client id + latency seconds in
VIRTUAL time units). Dispatch times are not recorded because the scheduler
re-derives them: plan i dispatches at ``i * round_interval``, so the trace
stays valid under a different ``round_interval`` or trigger -- only the
latency draws are pinned.
"""
from __future__ import annotations

import json
from dataclasses import dataclass
from typing import Iterable, List, Sequence


@dataclass(frozen=True)
class TraceRecord:
    """One latency draw: ``client`` (registry id) took ``latency`` virtual
    seconds to return its update after its plan's dispatch."""

    client: int
    latency: float


def write_trace(path: str, records: Iterable[TraceRecord]) -> None:
    """Write records as JSONL (one object per line, dispatch order)."""
    with open(path, "w") as f:
        for rec in records:
            f.write(json.dumps({"client": int(rec.client),
                                "latency": float(rec.latency)}) + "\n")


def read_trace(path: str) -> List[TraceRecord]:
    """Load a JSONL trace written by ``write_trace`` (blank lines skipped)."""
    out: List[TraceRecord] = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            obj = json.loads(line)
            out.append(TraceRecord(client=int(obj["client"]),
                                   latency=float(obj["latency"])))
    return out


def constant_trace(schedule: Sequence[int],
                   latency: float = 1.0) -> List[TraceRecord]:
    """The unit-latency trace for a known dispatch ``schedule`` (client ids
    in dispatch order): every client takes exactly ``latency`` virtual
    seconds. Under this trace the event-driven engine's count trigger
    reduces to the fixed ``pipeline_depth`` cadence (DESIGN.md §7)."""
    return [TraceRecord(client=int(c), latency=float(latency))
            for c in schedule]


def trace_schedule(records: Sequence[TraceRecord]) -> List[int]:
    """The dispatch-order client id sequence of a trace."""
    return [rec.client for rec in records]
