from repro.data.partition import (dirichlet_partition, iid_partition,
                                  make_partition, pathological_partition)
from repro.data.synthetic import ClusterClassification, SequenceCopy, batches

__all__ = ["ClusterClassification", "SequenceCopy", "batches",
           "dirichlet_partition", "iid_partition", "make_partition",
           "pathological_partition"]
