"""Non-IID client partitioning: Dirichlet and pathological label skew.

Matches the paper's setups: regular Dirichlet(alpha) partitioning, and the
pathological c<labels>(alpha) setting where each client holds at most
``labels_per_client`` labels with Dirichlet-weighted proportions.
"""
from __future__ import annotations

from typing import List

import numpy as np


def iid_partition(labels: np.ndarray, num_clients: int,
                  rng: np.random.Generator) -> List[np.ndarray]:
    idx = rng.permutation(len(labels))
    return [np.sort(s) for s in np.array_split(idx, num_clients)]


def dirichlet_partition(labels: np.ndarray, num_clients: int, alpha: float,
                        rng: np.random.Generator,
                        min_per_client: int = 2) -> List[np.ndarray]:
    """Regular Dirichlet label-skew partitioning."""
    classes = np.unique(labels)
    shards: List[list] = [[] for _ in range(num_clients)]
    for c in classes:
        idx_c = rng.permutation(np.where(labels == c)[0])
        props = rng.dirichlet(np.full(num_clients, alpha))
        cuts = (np.cumsum(props) * len(idx_c)).astype(int)[:-1]
        for shard, part in zip(shards, np.split(idx_c, cuts)):
            shard.extend(part.tolist())
    # ensure every client has at least a few samples
    all_idx = rng.permutation(len(labels))
    out = []
    spare = 0
    for shard in shards:
        if len(shard) < min_per_client:
            extra = all_idx[spare:spare + min_per_client]
            spare += min_per_client
            shard = list(shard) + extra.tolist()
        out.append(np.sort(np.asarray(shard, dtype=np.int64)))
    return out


def pathological_partition(labels: np.ndarray, num_clients: int,
                           labels_per_client: int, alpha: float,
                           rng: np.random.Generator) -> List[np.ndarray]:
    """c<labels>(alpha): each client restricted to a label subset, with
    Dirichlet-distributed proportions over that subset."""
    classes = np.unique(labels)
    by_class = {c: rng.permutation(np.where(labels == c)[0]).tolist()
                for c in classes}
    cursor = {c: 0 for c in classes}
    shards: List[np.ndarray] = []
    per_client = len(labels) // num_clients
    for _ in range(num_clients):
        chosen = rng.choice(classes, size=min(labels_per_client, len(classes)),
                            replace=False)
        props = rng.dirichlet(np.full(len(chosen), alpha))
        counts = np.maximum((props * per_client).astype(int), 1)
        take: list = []
        for c, cnt in zip(chosen, counts):
            pool = by_class[c]
            start = cursor[c]
            grabbed = pool[start:start + cnt]
            if len(grabbed) < cnt:  # wrap around if the class is exhausted
                grabbed = grabbed + pool[:cnt - len(grabbed)]
                cursor[c] = cnt - len(grabbed)
            else:
                cursor[c] = start + cnt
            take.extend(grabbed)
        shards.append(np.sort(np.asarray(take, dtype=np.int64)))
    return shards


def make_partition(kind: str, labels: np.ndarray, num_clients: int, *,
                   alpha: float = 1.0, labels_per_client: int = 20,
                   seed: int = 0) -> List[np.ndarray]:
    rng = np.random.default_rng(seed)
    if kind == "iid":
        return iid_partition(labels, num_clients, rng)
    if kind == "dirichlet":
        return dirichlet_partition(labels, num_clients, alpha, rng)
    if kind == "pathological":
        return pathological_partition(labels, num_clients, labels_per_client,
                                      alpha, rng)
    raise ValueError(f"unknown partition kind {kind!r}")
