from repro.checkpointing.checkpoint import (load_flat, load_metadata,
                                            load_pytree, save_flat,
                                            save_pytree)

__all__ = ["load_flat", "load_metadata", "load_pytree", "save_flat",
           "save_pytree"]
