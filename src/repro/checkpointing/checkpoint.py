"""Round-resumable checkpointing: pytrees <-> npz with path-keyed arrays.

Both the array payload (``.npz``) and the metadata sidecar (``.meta.json``)
are written ATOMICALLY: content goes to a temp file in the target directory
first and is moved into place with ``os.replace``. With async checkpointing
overlapping training a crash mid-save is a live possibility; a torn write
must leave either the previous complete checkpoint or the new one, never a
half-written npz that ``restore()`` half-loads.

Path spellings: every entry point accepts both ``save("ckpt")`` and
``save("ckpt.npz")``. The npz always lands at ``<stem>.npz`` and the
metadata at ``<stem>.meta.json`` (stem = path with any trailing ``.npz``
stripped), so the two spellings are interchangeable between save and load.
"""
from __future__ import annotations

import json
import os
import tempfile
from typing import Any, Dict, Optional

import jax
import numpy as np


def _stem(path: str) -> str:
    """Normalize both accepted spellings to the extensionless stem."""
    return path[:-len(".npz")] if path.endswith(".npz") else path


def _atomic_savez(npz_path: str, arrays: dict) -> None:
    """np.savez to a temp file in the target dir, then os.replace."""
    dirname = os.path.dirname(npz_path) or "."
    os.makedirs(dirname, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=dirname, suffix=".npz")
    try:
        with os.fdopen(fd, "wb") as f:
            np.savez(f, **arrays)
        os.replace(tmp, npz_path)
    except BaseException:
        if os.path.exists(tmp):
            os.unlink(tmp)
        raise


def _atomic_write_text(path: str, text: str) -> None:
    dirname = os.path.dirname(path) or "."
    os.makedirs(dirname, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=dirname, suffix=".json")
    try:
        with os.fdopen(fd, "w") as f:
            f.write(text)
        os.replace(tmp, path)
    except BaseException:
        if os.path.exists(tmp):
            os.unlink(tmp)
        raise


def _flatten(tree) -> dict:
    flat = {}

    def visit(path, x):
        if x is None:
            return x
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = np.asarray(x)
        return x

    jax.tree_util.tree_map_with_path(visit, tree,
                                     is_leaf=lambda x: x is None)
    return flat


def _json_safe(obj):
    """Recursively convert numpy scalars/arrays so metadata always dumps.

    Server metadata now carries rng bit-generator state, energy traces and
    round history; numpy integer/float scalars sneak in easily and
    ``json.dump`` rejects them."""
    if isinstance(obj, dict):
        return {str(k): _json_safe(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_json_safe(v) for v in obj]
    if isinstance(obj, np.ndarray):
        return obj.tolist()
    if isinstance(obj, np.integer):
        return int(obj)
    if isinstance(obj, np.floating):
        return float(obj)
    if isinstance(obj, np.bool_):
        return bool(obj)
    return obj


def save_pytree(path: str, tree, metadata: Optional[dict] = None) -> None:
    """Atomically write ``<stem>.npz`` (and ``<stem>.meta.json``)."""
    stem = _stem(path)
    _atomic_savez(stem + ".npz", _flatten(tree))
    if metadata is not None:
        _atomic_write_text(stem + ".meta.json",
                           json.dumps(_json_safe(metadata)))


def load_pytree(path: str, like) -> Any:
    """Restore into the structure of ``like`` (shape/dtype template)."""
    data = np.load(_stem(path) + ".npz")

    def fetch(p, x):
        if x is None:
            return None
        key = "/".join(str(getattr(q, "key", getattr(q, "idx", q)))
                       for q in p)
        arr = data[key]
        assert arr.shape == tuple(x.shape), (key, arr.shape, x.shape)
        return jax.numpy.asarray(arr, dtype=x.dtype)

    return jax.tree_util.tree_map_with_path(fetch, like,
                                            is_leaf=lambda x: x is None)


def load_metadata(path: str) -> Optional[dict]:
    """Metadata for either path spelling.

    The canonical location is ``<stem>.meta.json``; ``<path>.meta.json`` is
    also probed so sidecars written next to an explicit ``.npz`` spelling by
    older code keep loading. (The old implementation built
    ``<path>.npz.meta.json`` -- a name no writer ever produced -- and then
    string-replaced it back, a dead branch this replaces.)
    """
    for candidate in (_stem(path) + ".meta.json", path + ".meta.json"):
        if os.path.exists(candidate):
            with open(candidate) as f:
                return json.load(f)
    return None


# -- flat, template-free array blobs ----------------------------------------
#
# ``save_pytree``/``load_pytree`` need a pytree template on load. Server
# momentum state and the async engine's pending-plan buffer have no natural
# template at restore time (their structure depends on what was in flight),
# so they serialize as FLAT string-keyed array dicts instead.

def save_flat(path: str, arrays: Dict[str, np.ndarray]) -> None:
    """Atomically write a flat {key: array} dict to ``<stem>.npz``."""
    _atomic_savez(_stem(path) + ".npz",
                  {k: np.asarray(v) for k, v in arrays.items()})


def load_flat(path: str) -> Dict[str, np.ndarray]:
    """Load a flat {key: array} dict saved by ``save_flat``."""
    with np.load(_stem(path) + ".npz") as data:
        return {k: data[k] for k in data.files}
