"""Round-resumable checkpointing: pytrees <-> npz with path-keyed arrays."""
from __future__ import annotations

import json
import os
from typing import Any, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> dict:
    flat = {}

    def visit(path, x):
        if x is None:
            return x
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = np.asarray(x)
        return x

    jax.tree_util.tree_map_with_path(visit, tree,
                                     is_leaf=lambda x: x is None)
    return flat


def _json_safe(obj):
    """Recursively convert numpy scalars/arrays so metadata always dumps.

    Server metadata now carries rng bit-generator state, energy traces and
    round history; numpy integer/float scalars sneak in easily and
    ``json.dump`` rejects them."""
    if isinstance(obj, dict):
        return {str(k): _json_safe(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_json_safe(v) for v in obj]
    if isinstance(obj, np.ndarray):
        return obj.tolist()
    if isinstance(obj, np.integer):
        return int(obj)
    if isinstance(obj, np.floating):
        return float(obj)
    if isinstance(obj, np.bool_):
        return bool(obj)
    return obj


def save_pytree(path: str, tree, metadata: Optional[dict] = None) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _flatten(tree)
    np.savez(path, **flat)
    if metadata is not None:
        with open(path + ".meta.json", "w") as f:
            json.dump(_json_safe(metadata), f)


def load_pytree(path: str, like) -> Any:
    """Restore into the structure of ``like`` (shape/dtype template)."""
    if not path.endswith(".npz"):
        path = path + ".npz"
    data = np.load(path)

    def fetch(p, x):
        if x is None:
            return None
        key = "/".join(str(getattr(q, "key", getattr(q, "idx", q)))
                       for q in p)
        arr = data[key]
        assert arr.shape == tuple(x.shape), (key, arr.shape, x.shape)
        return jax.numpy.asarray(arr, dtype=x.dtype)

    return jax.tree_util.tree_map_with_path(fetch, like,
                                            is_leaf=lambda x: x is None)


def load_metadata(path: str) -> Optional[dict]:
    meta_path = (path if path.endswith(".npz") else path + ".npz") + ".meta.json"
    meta_path = meta_path.replace(".npz.meta.json", ".meta.json") \
        if not os.path.exists(meta_path) else meta_path
    candidates = [path + ".meta.json", meta_path]
    for c in candidates:
        if os.path.exists(c):
            with open(c) as f:
                return json.load(f)
    return None
