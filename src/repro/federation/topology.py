"""Client registry: heterogeneous rank assignment + data shard bookkeeping."""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Sequence

import numpy as np

from repro.configs.base import FLConfig, LoRAConfig


@dataclass
class ClientRegistry:
    """K clients, each with a LoRA rank drawn from the configured levels
    (paper: uniform over {8,16,32,48,64} by default) and a data shard."""

    ranks: np.ndarray                 # (K,) int
    shards: List[np.ndarray]          # per-client sample indices
    rank_levels: Sequence[int]

    @classmethod
    def create(cls, fl: FLConfig, lora: LoRAConfig,
               shards: List[np.ndarray],
               rng: Optional[np.random.Generator] = None) -> "ClientRegistry":
        rng = rng or np.random.default_rng(fl.seed)
        k = fl.num_clients
        assert len(shards) == k, (len(shards), k)
        ranks = rng.choice(lora.rank_levels, size=k, p=lora.rank_probs)
        return cls(ranks=ranks.astype(int), shards=shards,
                   rank_levels=tuple(lora.rank_levels))

    @property
    def num_clients(self) -> int:
        return len(self.ranks)

    def num_samples(self, k: int) -> int:
        return len(self.shards[k])

    def add_client(self, rank: int, shard: np.ndarray) -> int:
        """Register a NEW client mid-run (event-driven "join" lifecycle
        event) and return its id. Ids are append-only so plans and shards
        recorded before the join stay valid."""
        cid = self.num_clients
        self.ranks = np.append(self.ranks, int(rank)).astype(int)
        self.shards.append(np.asarray(shard, dtype=np.int64))
        return cid

    def sample_round(self, m: int, rng: np.random.Generator,
                     active: Optional[np.ndarray] = None) -> np.ndarray:
        """Uniform sampling without replacement (Alg. 1 line 3).

        ``active`` (event-driven engine): restrict sampling to this client
        pool -- dropouts leave it, rejoined/joined clients enter it. A
        round never samples more clients than are active. ``active=None``
        keeps the exact historical rng consumption, so scenarios without
        lifecycle events reproduce cadence-engine sampling bit-for-bit."""
        if active is None:
            return rng.choice(self.num_clients, size=m, replace=False)
        active = np.asarray(active)
        m = min(int(m), active.size)
        return active[rng.choice(active.size, size=m, replace=False)]

    def coverage(self) -> np.ndarray:
        from repro.core.partitions import coverage
        return coverage(self.rank_levels, self.ranks)
