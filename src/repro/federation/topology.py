"""Client registry: heterogeneous rank assignment + data shard bookkeeping."""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Sequence

import numpy as np

from repro.configs.base import FLConfig, LoRAConfig


@dataclass
class ClientRegistry:
    """K clients, each with a LoRA rank drawn from the configured levels
    (paper: uniform over {8,16,32,48,64} by default) and a data shard."""

    ranks: np.ndarray                 # (K,) int
    shards: List[np.ndarray]          # per-client sample indices
    rank_levels: Sequence[int]

    @classmethod
    def create(cls, fl: FLConfig, lora: LoRAConfig,
               shards: List[np.ndarray],
               rng: Optional[np.random.Generator] = None) -> "ClientRegistry":
        rng = rng or np.random.default_rng(fl.seed)
        k = fl.num_clients
        assert len(shards) == k, (len(shards), k)
        ranks = rng.choice(lora.rank_levels, size=k, p=lora.rank_probs)
        return cls(ranks=ranks.astype(int), shards=shards,
                   rank_levels=tuple(lora.rank_levels))

    @property
    def num_clients(self) -> int:
        return len(self.ranks)

    def num_samples(self, k: int) -> int:
        return len(self.shards[k])

    def sample_round(self, m: int, rng: np.random.Generator) -> np.ndarray:
        """Uniform sampling without replacement (Alg. 1 line 3)."""
        return rng.choice(self.num_clients, size=m, replace=False)

    def coverage(self) -> np.ndarray:
        from repro.core.partitions import coverage
        return coverage(self.rank_levels, self.ranks)
