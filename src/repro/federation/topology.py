"""Client registry: heterogeneous rank assignment + data shard bookkeeping."""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Sequence

import numpy as np

from repro.analysis import host_cost
from repro.configs.base import FLConfig, LoRAConfig


@dataclass
class ClientRegistry:
    """K clients, each with a LoRA rank drawn from the configured levels
    (paper: uniform over {8,16,32,48,64} by default) and a data shard."""

    ranks: np.ndarray                 # (K,) int
    shards: List[np.ndarray]          # per-client sample indices
    rank_levels: Sequence[int]

    @classmethod
    def create(cls, fl: FLConfig, lora: LoRAConfig,
               shards: List[np.ndarray],
               rng: Optional[np.random.Generator] = None) -> "ClientRegistry":
        rng = rng or np.random.default_rng(fl.seed)
        k = fl.num_clients
        assert len(shards) == k, (len(shards), k)
        ranks = rng.choice(lora.rank_levels, size=k, p=lora.rank_probs)
        return cls(ranks=ranks.astype(int), shards=shards,
                   rank_levels=tuple(lora.rank_levels))

    @property
    def num_clients(self) -> int:
        return len(self.ranks)

    def num_samples(self, k: int) -> int:
        return len(self.shards[k])

    def add_client(self, rank: int, shard: np.ndarray) -> int:
        """Register a NEW client mid-run (event-driven "join" lifecycle
        event) and return its id. Ids are append-only so plans and shards
        recorded before the join stay valid."""
        cid = self.num_clients
        # np.append copies the whole (K,) rank vector -- an O(K) cost per
        # JOIN event (not per round); the host-cost shim records it
        host_cost.tick("registry/add_client")
        self.ranks = np.append(self.ranks, int(rank)).astype(int)
        self.shards.append(np.asarray(shard, dtype=np.int64))
        return cid

    def inflate(self, total_clients: int,
                rng: Optional[np.random.Generator] = None) -> None:
        """Grow the registry to ``total_clients`` with synthetic clients
        for scale testing: ranks drawn from the configured levels, data
        shards ALIASED round-robin onto the existing shard arrays (no
        data copies -- a million-client registry stays a rank vector plus
        a list of references). Ids are append-only, so existing plans and
        the rng sampling stream stay valid."""
        k = self.num_clients
        extra = int(total_clients) - k
        if extra <= 0:
            return
        rng = rng or np.random.default_rng(0)
        new_ranks = rng.choice(list(self.rank_levels), size=extra)
        self.ranks = np.concatenate(
            [self.ranks, new_ranks.astype(int)])
        self.shards.extend(self.shards[i % k] for i in range(extra))

    def sample_round(self, m: int, rng: np.random.Generator,
                     active: Optional[np.ndarray] = None) -> np.ndarray:
        """Uniform sampling without replacement (Alg. 1 line 3).

        ``active`` (event-driven engine): restrict sampling to this client
        pool -- dropouts leave it, rejoined/joined clients enter it. A
        round never samples more clients than are active. ``active=None``
        keeps the exact historical rng consumption, so scenarios without
        lifecycle events reproduce cadence-engine sampling bit-for-bit."""
        if active is None:
            host_cost.tick("registry/sample", m)
            return rng.choice(self.num_clients, size=m, replace=False)
        active = np.asarray(active)
        host_cost.tick("registry/active_pool", active.size)
        m = min(int(m), active.size)
        return active[rng.choice(active.size, size=m, replace=False)]

    def coverage(self) -> np.ndarray:
        from repro.core.partitions import coverage
        return coverage(self.rank_levels, self.ranks)
