"""Client-side local fine-tuning: LoRA-only gradients, AdamW, jitted per rank.

The client receives the (conceptually truncated) global adapters; we keep
the r_max-sized factors resident and run with static ``lora_rank=r_k``, which
slices the factors inside the forward -- mathematically identical to
truncate-then-train (gradients outside the slice are exactly zero) while
keeping one params pytree shape for all clients. The jit cache keys on r_k,
so there are at most |rank_levels| compilations.
"""
from __future__ import annotations

import functools
from typing import Callable, Dict, Iterable, Tuple

import jax
import jax.numpy as jnp

from repro.core.lora import merge_lora, split_lora
from repro.models.transformer import Model
from repro.optim import AdamW


class LocalTrainer:
    def __init__(self, model: Model, *, weight_decay: float = 0.0,
                 freeze_a: bool = False):
        self.model = model
        self.opt = AdamW(weight_decay=weight_decay)
        self.freeze_a = freeze_a   # FFA-LoRA: train only the B factors
        self._step_cache: Dict[int, Callable] = {}

    def _make_step(self, rank: int) -> Callable:
        model, opt = self.model, self.opt
        scale = (self.model.lora.scaling(rank)
                 if self.model.lora is not None else 1.0)

        def loss_fn(lora, base, batch):
            params = merge_lora(base, lora)
            loss, metrics = model.train_loss(params, batch, lora_rank=rank,
                                             lora_scale=scale)
            return loss, metrics

        freeze_a = self.freeze_a

        @jax.jit
        def step(lora, opt_state, base, batch, lr):
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(lora, base, batch)
            if freeze_a:  # FFA-LoRA: zero the A-factor gradients
                import jax.tree_util as jtu
                grads = jtu.tree_map_with_path(
                    lambda p, g: (jnp.zeros_like(g)
                                  if g is not None
                                  and getattr(p[-1], "key", "") == "lora_a"
                                  else g),
                    grads, is_leaf=lambda x: x is None)
            lora, opt_state = opt.update(grads, opt_state, lora, lr)
            return lora, opt_state, metrics

        return step

    def step_fn(self, rank: int) -> Callable:
        if rank not in self._step_cache:
            self._step_cache[rank] = self._make_step(rank)
        return self._step_cache[rank]

    def train(self, base, global_lora, rank: int,
              batch_iter: Iterable[dict], lr: float) -> Tuple[dict, dict]:
        """Run local epochs; returns (trained lora tree, last metrics)."""
        step = self.step_fn(int(rank))
        opt_state = self.opt.init(global_lora)
        lora = global_lora
        metrics = {}
        for batch in batch_iter:
            lora, opt_state, metrics = step(lora, opt_state, base, batch,
                                            jnp.float32(lr))
        return lora, metrics
