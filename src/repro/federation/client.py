"""Client-side local fine-tuning: LoRA-only gradients, AdamW, jitted per rank.

The client receives the (conceptually truncated) global adapters; we keep
the r_max-sized factors resident and run with static ``lora_rank=r_k``, which
slices the factors inside the forward -- mathematically identical to
truncate-then-train (gradients outside the slice are exactly zero) while
keeping one params pytree shape for all clients. The jit cache keys on r_k,
so there are at most |rank_levels| compilations.

``train_group`` is the batched round engine's per-rank-group entry point:
clients of one rank level train as ONE ``jax.vmap``-ed, jitted multi-client
step over the client axis of stacked LoRA trees -- same per-client math as
``train`` (the vmap wraps the exact same step function), one XLA dispatch
per group instead of one per client per step.

``train_group_masked`` goes further and batches ALL rank levels into a
single dispatch: every client runs at static ``lora_rank=r_max`` with its
adapter factors zero-masked beyond its own rank r_k and its own
``lora_scale`` vmapped in. This is EXACT, not an approximation: the masked
slices contribute zero to the forward, their gradients are identically zero
(each is a product with the other, zeroed, factor), so AdamW leaves them at
zero -- bit-for-bit the state sequential training leaves OUTSIDE its r_k
slice, which aggregation zero-pads anyway. One compilation and one XLA
dispatch cover the whole heterogeneous round.

``dispatch_group_masked`` wraps either masked runner as a NON-BLOCKING
handle pair (factor stacks, loss array) for the async round engine: jax's
async dispatch returns enqueued arrays immediately, so the server can
pipeline the next round's training against the current round's aggregation
without any ``jax.block_until_ready``/host-transfer synchronization point.
"""
from __future__ import annotations

import functools
from typing import Callable, Dict, Iterable, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.lora import merge_lora, split_lora
from repro.models.transformer import Model
from repro.optim import AdamW


def _stack_steps(xs) -> "np.ndarray":
    """Batch-leaf stacking on the HOST when the leaves are numpy (the
    data-pipeline common case): an eager ``jnp.stack`` would synchronize
    with in-flight device work on jax's CPU client, serializing the async
    round engine's pipeline. Device-array leaves fall back to jnp.stack.
    Shared by the trainers' step-axis stacking here and the server's
    client-axis stacking (``federation/server.py``)."""
    if all(isinstance(x, np.ndarray) for x in xs):
        return np.stack(xs)
    return jnp.stack(xs)


class LocalTrainer:
    def __init__(self, model: Model, *, weight_decay: float = 0.0,
                 freeze_a: bool = False):
        self.model = model
        self.opt = AdamW(weight_decay=weight_decay)
        self.freeze_a = freeze_a   # FFA-LoRA: train only the B factors
        self._step_cache: Dict[int, Callable] = {}
        self._vstep_cache: Dict[Tuple[int, int], Callable] = {}

    def _zero_frozen(self, grads):
        """FFA-LoRA: zero the A-factor gradients."""
        import jax.tree_util as jtu
        return jtu.tree_map_with_path(
            lambda p, g: (jnp.zeros_like(g)
                          if g is not None
                          and getattr(p[-1], "key", "") == "lora_a"
                          else g),
            grads, is_leaf=lambda x: x is None)

    def _make_raw_step(self, rank: int) -> Callable:
        """The un-jitted single-client step; shared by ``step_fn`` (jit) and
        ``group_runner`` (jit(vmap)) so both engines run identical math."""
        model, opt = self.model, self.opt
        scale = (self.model.lora.scaling(rank)
                 if self.model.lora is not None else 1.0)

        def loss_fn(lora, base, batch):
            params = merge_lora(base, lora)
            loss, metrics = model.train_loss(params, batch, lora_rank=rank,
                                             lora_scale=scale)
            return loss, metrics

        freeze_a = self.freeze_a

        def step(lora, opt_state, base, batch, lr):
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(lora, base, batch)
            if freeze_a:
                grads = self._zero_frozen(grads)
            lora, opt_state = opt.update(grads, opt_state, lora, lr)
            return lora, opt_state, metrics

        return step

    def _make_raw_step_scaled(self) -> Callable:
        """Like ``_make_raw_step`` but at static ``lora_rank=r_max`` with a
        TRACED per-client ``lora_scale`` -- the all-rank masked runner vmaps
        over it."""
        model, opt = self.model, self.opt
        r_max = model.lora.r_max

        def loss_fn(lora, base, batch, scale):
            params = merge_lora(base, lora)
            loss, metrics = model.train_loss(params, batch, lora_rank=r_max,
                                             lora_scale=scale)
            return loss, metrics

        freeze_a = self.freeze_a

        def step(lora, opt_state, base, batch, lr, scale):
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(lora, base, batch, scale)
            if freeze_a:
                grads = self._zero_frozen(grads)
            lora, opt_state = opt.update(grads, opt_state, lora, lr)
            return lora, opt_state, metrics

        return step

    def step_fn(self, rank: int) -> Callable:
        if rank not in self._step_cache:
            self._step_cache[rank] = jax.jit(self._make_raw_step(rank))
        return self._step_cache[rank]

    def group_runner(self, rank: int, steps: int) -> Callable:
        """One jitted call running ALL ``steps`` local steps of a rank
        group: a vmap of the per-client step over the client axis, unrolled
        over the (small, static) local step count so the whole group's local
        training is a single XLA dispatch. Cache keys on (rank, steps);
        jit re-specializes per group size via the stacked shapes."""
        key = (rank, steps)
        if key not in self._vstep_cache:
            raw = self._make_raw_step(rank)
            vstep = jax.vmap(raw, in_axes=(0, 0, None, 0, None))

            def run(lora, opt_state, base, stacks, lr):
                metrics = {}
                for t in range(steps):     # static unroll (1-2 typically)
                    batch = jax.tree.map(lambda x: x[t], stacks)
                    lora, opt_state, metrics = vstep(lora, opt_state, base,
                                                     batch, lr)
                return lora, metrics

            self._vstep_cache[key] = jax.jit(run)
        return self._vstep_cache[key]

    def _masked_run_fn(self, steps: int) -> Callable:
        """The un-jitted all-rank masked group body. The client axis size is
        read from ``mask`` at trace time, so the SAME function serves the
        whole-round jit (``masked_runner``) and the per-shard body of the
        sharded round engine (``masked_runner_sharded``), which hands it the
        local client block of each mesh shard."""
        raw = self._make_raw_step_scaled()
        vstep = jax.vmap(raw, in_axes=(0, 0, None, 0, None, 0))
        opt = self.opt

        def run(global_lora, base, stacks, lr, mask, scales):
            size = mask.shape[0]

            def tile_mask(path, x):
                if x is None:
                    return None
                t = jnp.repeat(x[None], size, axis=0)
                key_ = getattr(path[-1], "key", "")
                lead = (1,) * (x.ndim - 2)
                if key_ == "lora_a":   # (M, ..., r_max, in): mask rows
                    return t * mask.reshape(
                        (size,) + lead + (mask.shape[1], 1)).astype(t.dtype)
                if key_ == "lora_b":   # (M, ..., out, r_max): mask cols
                    return t * mask.reshape(
                        (size,) + lead + (1, mask.shape[1])).astype(t.dtype)
                return t               # lora_m and anything else
            lora = jax.tree_util.tree_map_with_path(
                tile_mask, global_lora, is_leaf=lambda x: x is None)
            opt_state = opt.init(lora)
            opt_state = opt_state._replace(
                step=jnp.zeros((size,), jnp.int32))
            metrics = {}
            for t in range(steps):     # static unroll (1-2 typically)
                batch = jax.tree.map(lambda x: x[t], stacks)
                lora, opt_state, metrics = vstep(lora, opt_state, base,
                                                 batch, lr, scales)
            return lora, metrics

        return run

    def masked_runner(self, steps: int) -> Callable:
        """One jitted call training ALL clients of a round regardless of
        rank: tile + rank-mask the global adapters inside the program, then
        unrolled vmapped steps at static r_max with per-client scales.
        Cache keys on steps; jit re-specializes per round size."""
        key = ("masked", steps)
        if key not in self._vstep_cache:
            self._vstep_cache[key] = jax.jit(self._masked_run_fn(steps))
        return self._vstep_cache[key]

    def masked_runner_sharded(self, steps: int, mesh) -> Callable:
        """The all-rank masked runner as a ``shard_map`` over the mesh's
        ``data`` axis (DESIGN.md §5): each shard runs the IDENTICAL masked
        vmapped step body on its contiguous block of the client axis, with
        base weights and global adapters replicated. Per-client training is
        independent, so device placement changes nothing mathematically --
        batched == sharded up to XLA scheduling round-off.

        Cache keys on (steps, mesh); jit re-specializes per shard size."""
        key = ("sharded", steps, mesh)
        if key not in self._vstep_cache:
            from jax.experimental.shard_map import shard_map
            from repro.sharding.specs import round_engine_specs
            run = self._masked_run_fn(steps)
            spec = round_engine_specs()
            sharded = shard_map(
                run, mesh=mesh,
                in_specs=(spec.replicated, spec.replicated, spec.batch_stack,
                          spec.replicated, spec.clients, spec.clients),
                out_specs=(spec.clients, spec.clients),
                check_rep=False)
            self._vstep_cache[key] = jax.jit(sharded)
        return self._vstep_cache[key]

    def dispatch_group_masked(self, base, global_lora, ranks: Sequence[int],
                              batch_stacks: List[dict], lr: float,
                              mesh=None) -> Tuple[dict, object]:
        """Non-blocking all-rank group dispatch: (factor stacks, loss handle).

        The async round engine's entry point. Both returns are plain jax
        arrays produced by the jitted (or shard_mapped, when ``mesh`` is
        given) masked runner -- jax's async dispatch means this function
        returns as soon as the computation is ENQUEUED; nothing here (and
        nothing the caller does short of ``np.asarray``/item reads) blocks
        on device execution, so round t+1's training can be in flight while
        round t's aggregation is still running. The loss handle is
        ``metrics["loss"]`` unmaterialized (None when the group ran zero
        steps); callers convert it to floats only at finalize time.
        """
        if mesh is not None:
            lora_g, metrics = self.train_group_masked_sharded(
                base, global_lora, ranks, batch_stacks, lr, mesh)
        else:
            lora_g, metrics = self.train_group_masked(
                base, global_lora, ranks, batch_stacks, lr)
        return lora_g, metrics.get("loss")

    def train(self, base, global_lora, rank: int,
              batch_iter: Iterable[dict], lr: float) -> Tuple[dict, dict]:
        """Run local epochs; returns (trained lora tree, last metrics)."""
        step = self.step_fn(int(rank))
        opt_state = self.opt.init(global_lora)
        lora = global_lora
        metrics = {}
        for batch in batch_iter:
            lora, opt_state, metrics = step(lora, opt_state, base, batch,
                                            jnp.float32(lr))
        return lora, metrics

    def train_group(self, base, global_lora, rank: int,
                    batch_stacks: List[dict], lr: float,
                    size: int) -> Tuple[dict, dict]:
        """Train ``size`` same-rank clients as one vmapped step sequence.

        ``batch_stacks``: list over local steps of batch pytrees with a
        leading client axis of length ``size`` (step t holds client i's t-th
        batch at index i). Returns (lora tree with leading client axis,
        last-step metrics with leading client axis).
        """
        lora = jax.tree.map(
            lambda x: jnp.repeat(x[None], size, axis=0), global_lora)
        if not batch_stacks:
            return lora, {}
        runner = self.group_runner(int(rank), len(batch_stacks))
        # (T, G, ...) step-major stacks so the runner slices per step
        stacks = jax.tree.map(lambda *xs: jnp.stack(xs), *batch_stacks)
        opt_state = self.opt.init(lora)
        # per-client step counters: AdamW's bias correction must see the
        # same step index as the sequential engine
        opt_state = opt_state._replace(step=jnp.zeros((size,), jnp.int32))
        return runner(lora, opt_state, base, stacks, jnp.float32(lr))

    def train_group_masked(self, base, global_lora, ranks: Sequence[int],
                           batch_stacks: List[dict],
                           lr: float) -> Tuple[dict, dict]:
        """Train a mixed-rank client group in ONE jitted dispatch.

        Exact equivalence with per-rank training (see module docstring):
        client k's factors are zero-masked beyond rank r_k, runs at static
        r_max with its own lora_scale. Returned factor stacks carry zeros
        beyond each client's rank -- exactly the zero-padded layout
        ``pad_stack``/aggregation expect, so no per-rank re-slicing is
        needed downstream.

        ``batch_stacks``: list over local steps of batch pytrees with a
        leading client axis of length ``len(ranks)``.
        """
        r_max = self.model.lora.r_max
        mask = (np.arange(r_max)[None, :]
                < np.asarray(ranks)[:, None]).astype(np.float32)
        scales = np.asarray([self.model.lora.scaling(int(r))
                             for r in ranks], np.float32)
        runner = self.masked_runner(len(batch_stacks))
        stacks = (jax.tree.map(lambda *xs: _stack_steps(xs), *batch_stacks)
                  if batch_stacks else ())
        return runner(global_lora, base, stacks, np.float32(lr),
                      mask, scales)

    def train_group_masked_sharded(self, base, global_lora,
                                   ranks: Sequence[int],
                                   batch_stacks: List[dict], lr: float,
                                   mesh) -> Tuple[dict, dict]:
        """``train_group_masked`` with the client axis sharded over the
        mesh's ``data`` axis (one shard_map dispatch for the whole group).

        The caller must have padded the client axis to a multiple of the
        data-axis size (``federation/server.py`` does this with zero-weight
        ghost clients); each shard trains its contiguous block. Returned
        factor stacks (and metrics) come back as globally-addressable arrays
        sharded over the client axis, ready for the sharded aggregation.
        """
        r_max = self.model.lora.r_max
        n_shards = mesh.shape["data"]
        assert len(ranks) % n_shards == 0, (len(ranks), n_shards)
        mask = (np.arange(r_max)[None, :]
                < np.asarray(ranks)[:, None]).astype(np.float32)
        scales = np.asarray([self.model.lora.scaling(int(r))
                             for r in ranks], np.float32)
        runner = self.masked_runner_sharded(len(batch_stacks), mesh)
        stacks = (jax.tree.map(lambda *xs: _stack_steps(xs), *batch_stacks)
                  if batch_stacks else ())
        return runner(global_lora, base, stacks, np.float32(lr),
                      mask, scales)
