from repro.federation.client import LocalTrainer
from repro.federation.events import (BimodalLatency, BufferTrigger,
                                     ClientLifecycle, ConstantLatency,
                                     CountTrigger, EventScheduler,
                                     LatencyModel, LifecycleEvent,
                                     LognormalLatency, RecordingLatency,
                                     StalenessBoundTrigger,
                                     StragglerTailLatency, TimeoutTrigger,
                                     TraceLatency, VirtualClock)
from repro.federation.server import FederatedLoRA, RoundStats
from repro.federation.topology import ClientRegistry

__all__ = ["BimodalLatency", "BufferTrigger", "ClientLifecycle",
           "ClientRegistry", "ConstantLatency", "CountTrigger",
           "EventScheduler", "FederatedLoRA", "LatencyModel",
           "LifecycleEvent", "LocalTrainer", "LognormalLatency",
           "RecordingLatency", "RoundStats", "StalenessBoundTrigger",
           "StragglerTailLatency", "TimeoutTrigger", "TraceLatency",
           "VirtualClock"]
