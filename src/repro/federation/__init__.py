from repro.federation.client import LocalTrainer
from repro.federation.server import FederatedLoRA, RoundStats
from repro.federation.topology import ClientRegistry

__all__ = ["ClientRegistry", "FederatedLoRA", "LocalTrainer", "RoundStats"]
