"""Event-driven round scheduling on a deterministic virtual clock.

The async round engine (DESIGN.md §6) buffers trained plans on a FIXED
cadence (``pipeline_depth``). Real FedLoRA deployments are driven by
wall-clock client latency instead: heterogeneous system resources make
high-rank clients slow, stragglers trickle in, clients drop out mid-round.
This module turns the async engine into a simulation-grade scheduler
(DESIGN.md §7):

* ``VirtualClock`` -- deterministic virtual time. Plan i dispatches at
  ``i * round_interval``; client k of that plan ARRIVES at dispatch time +
  its sampled latency. Nothing reads the host clock, so runs are exactly
  reproducible and checkpointable.
* ``LatencyModel`` family -- seeded per-client latency draws: lognormal
  (the classic straggler-free heavy tail), bimodal (two device classes),
  straggler-tail (a designated straggler subset multiplied by a tail
  scale), constant (the unit-latency trace that reduces the whole machine
  back to the fixed cadence), and ``TraceLatency`` which replays a JSONL
  trace recorded by ``RecordingLatency`` (``repro/data/traces.py``).
* ``BufferTrigger`` family -- pluggable "when to aggregate" policies
  evaluated event-by-event: ``CountTrigger`` (>= K arrived updates),
  ``TimeoutTrigger`` (virtual seconds since the last aggregation),
  ``StalenessBoundTrigger`` (the oldest buffered arrival may not exceed a
  staleness bound).
* ``ClientLifecycle`` -- timed dropout / rejoin / mid-run join events:
  a dropped client leaves the sampling pool and its in-flight updates are
  cancelled; a joined client enters the registry and the pool.

Staleness is ARRIVAL-TIME-derived: an update that arrived at time ``a``
and is aggregated at time ``T`` carries staleness
``floor((T - a) / round_interval)``. Under the unit-latency trace
(latency == round_interval) this reduces EXACTLY to the cadence engine's
plan-age staleness, which is what makes the count trigger with a unit
trace bit-equal to ``pipeline_depth=k`` (tests/test_events.py).

The scheduler owns only EVENT state (clock, arrival heap, per-plan arrival
bookkeeping, latency rng streams); trained factor stacks stay on the
server's pending plans. ``state_dict``/``load_state_dict`` round-trip the
whole thing through checkpoint metadata (JSON-safe), so save -> restore ->
run equals the uninterrupted event-driven run exactly.
"""
from __future__ import annotations

import heapq
import math
from dataclasses import dataclass
from typing import Callable, Dict, Iterator, List, Optional, Sequence, Set

import numpy as np

from repro.analysis import host_cost
from repro.data.traces import TraceRecord


# ---------------------------------------------------------------------------
# virtual clock
# ---------------------------------------------------------------------------

class VirtualClock:
    """Monotone deterministic simulation time (virtual seconds)."""

    def __init__(self, now: float = 0.0):
        self.now = float(now)

    def advance(self, t: float) -> None:
        assert t >= self.now - 1e-9, (t, self.now)
        self.now = max(self.now, float(t))

    def __repr__(self):
        return f"VirtualClock(now={self.now:.4f})"


# ---------------------------------------------------------------------------
# latency models
# ---------------------------------------------------------------------------

class LatencyModel:
    """Seeded per-client latency draws.

    Each client gets its OWN ``np.random.Generator`` stream (spawned from
    ``SeedSequence([seed, client])``), so a client's latency sequence does
    not depend on which other clients were sampled around it -- scenario
    edits (dropouts, different triggers) perturb only what they touch.
    Streams are created lazily and their bit-generator states are
    checkpointable (``state_dict``)."""

    def __init__(self, seed: int = 0):
        self.seed = int(seed)
        self._rngs: Dict[int, np.random.Generator] = {}

    def _rng(self, client: int) -> np.random.Generator:
        if client not in self._rngs:
            self._rngs[client] = np.random.default_rng(
                np.random.SeedSequence([self.seed, int(client)]))
        return self._rngs[client]

    def sample(self, client: int) -> float:
        raise NotImplementedError

    # -- checkpointing -------------------------------------------------------

    def state_dict(self) -> dict:
        # sorted client order: the serialized form must be byte-stable
        # regardless of which client sampled first (dict insertion order
        # is first-draw order, which scenario edits perturb)
        return {"rng": {str(c): self._rngs[c].bit_generator.state
                        for c in sorted(self._rngs)}}

    def load_state_dict(self, state: Optional[dict]) -> None:
        self._rngs = {}
        if not state:
            return
        for c, st in state.get("rng", {}).items():
            rng = self._rng(int(c))
            rng.bit_generator.state = st


class ConstantLatency(LatencyModel):
    """Every client takes exactly ``latency`` virtual seconds. With
    ``latency == round_interval`` this is the unit-latency trace: the
    count trigger reduces to the fixed pipeline cadence."""

    def __init__(self, latency: float = 1.0):
        super().__init__(seed=0)
        assert latency > 0, latency
        self.latency = float(latency)

    def sample(self, client: int) -> float:
        return self.latency


class LognormalLatency(LatencyModel):
    """``median * exp(sigma * N(0,1))`` per draw -- the standard
    heavy-ish-tailed client round-trip model."""

    def __init__(self, median: float = 1.0, sigma: float = 0.25,
                 seed: int = 0):
        super().__init__(seed=seed)
        assert median > 0, median
        self.median = float(median)
        self.sigma = float(sigma)

    def sample(self, client: int) -> float:
        z = float(self._rng(client).standard_normal())
        return self.median * math.exp(self.sigma * z)


class BimodalLatency(LatencyModel):
    """Two device classes: a draw is ``slow`` with probability
    ``slow_prob``, else ``fast`` (each jittered by a small lognormal)."""

    def __init__(self, fast: float = 1.0, slow: float = 4.0,
                 slow_prob: float = 0.3, jitter: float = 0.1, seed: int = 0):
        super().__init__(seed=seed)
        assert fast > 0 and slow > 0 and 0.0 <= slow_prob <= 1.0
        self.fast, self.slow = float(fast), float(slow)
        self.slow_prob = float(slow_prob)
        self.jitter = float(jitter)

    def sample(self, client: int) -> float:
        rng = self._rng(client)
        base = self.slow if rng.random() < self.slow_prob else self.fast
        return base * math.exp(self.jitter * float(rng.standard_normal()))


class StragglerTailLatency(LatencyModel):
    """Lognormal base latency with a designated straggler subset whose
    draws are multiplied by ``tail_scale``.

    Membership is either explicit (``straggler_clients``, e.g. "the
    high-rank clients" for the rank-collapse regression scenario) or drawn
    deterministically per client with probability ``straggler_frac`` from
    the seed -- the same client is a straggler in every run of a seed."""

    def __init__(self, median: float = 1.0, sigma: float = 0.2,
                 tail_scale: float = 6.0, straggler_frac: float = 0.25,
                 straggler_clients: Optional[Sequence[int]] = None,
                 seed: int = 0):
        super().__init__(seed=seed)
        assert median > 0 and tail_scale >= 1.0
        self.median, self.sigma = float(median), float(sigma)
        self.tail_scale = float(tail_scale)
        self.straggler_frac = float(straggler_frac)
        self.straggler_clients = (None if straggler_clients is None
                                  else set(int(c) for c in straggler_clients))

    def is_straggler(self, client: int) -> bool:
        if self.straggler_clients is not None:
            return int(client) in self.straggler_clients
        # deterministic membership: own stream, disjoint from the draw rng
        u = np.random.default_rng(
            np.random.SeedSequence([self.seed, 7919, int(client)])).random()
        return bool(u < self.straggler_frac)

    def sample(self, client: int) -> float:
        z = float(self._rng(client).standard_normal())
        lat = self.median * math.exp(self.sigma * z)
        return lat * self.tail_scale if self.is_straggler(client) else lat


class TraceLatency(LatencyModel):
    """Strict replay of a recorded trace: the i-th ``sample`` call must be
    for the i-th record's client and returns its recorded latency. This
    pins the whole arrival schedule, making a run a pure function of
    (server seed, trace)."""

    def __init__(self, records: Sequence[TraceRecord]):
        super().__init__(seed=0)
        self.records = list(records)
        self.pos = 0

    def sample(self, client: int) -> float:
        assert self.pos < len(self.records), \
            f"trace exhausted after {self.pos} draws"
        rec = self.records[self.pos]
        assert rec.client == int(client), \
            (f"trace replay diverged at draw {self.pos}: "
             f"recorded client {rec.client}, asked for {client}")
        self.pos += 1
        return rec.latency

    def state_dict(self) -> dict:
        return {"pos": self.pos}

    def load_state_dict(self, state: Optional[dict]) -> None:
        self.pos = int(state["pos"]) if state else 0


class RecordingLatency(LatencyModel):
    """Tee wrapper: samples ``inner`` and records every draw as a
    ``TraceRecord`` (write with ``repro.data.traces.write_trace``)."""

    def __init__(self, inner: LatencyModel):
        super().__init__(seed=0)
        self.inner = inner
        self.records: List[TraceRecord] = []

    def sample(self, client: int) -> float:
        lat = self.inner.sample(client)
        self.records.append(TraceRecord(client=int(client), latency=lat))
        return lat

    def state_dict(self) -> dict:
        return {"inner": self.inner.state_dict(),
                "records": [[r.client, r.latency] for r in self.records]}

    def load_state_dict(self, state: Optional[dict]) -> None:
        if not state:
            self.records = []
            self.inner.load_state_dict(None)
            return
        self.inner.load_state_dict(state.get("inner"))
        self.records = [TraceRecord(client=int(c), latency=float(l))
                        for c, l in state.get("records", [])]


# ---------------------------------------------------------------------------
# buffer triggers
# ---------------------------------------------------------------------------

class BufferTrigger:
    """When does the buffered aggregation fire?

    Two hooks, both side-effect-free:

    * ``on_arrival(sched)`` -- checked after each arrival event; return
      True to fire AT the arrival's timestamp.
    * ``deadline(sched)`` -- an absolute virtual time at which the trigger
      fires regardless of further arrivals (None = no deadline). The
      scheduler fires deadlines in event order, so a timeout expiring
      before the next arrival aggregates WITHOUT it.

    The scheduler guarantees ``pending_ready_count > 0`` at every fire
    (an empty buffer never aggregates) and resets ``last_fire`` itself.
    """

    def on_arrival(self, sched: "EventScheduler") -> bool:
        return False

    def deadline(self, sched: "EventScheduler") -> Optional[float]:
        return None

    def describe(self) -> str:
        return type(self).__name__


class CountTrigger(BufferTrigger):
    """Fire when >= ``k`` client updates are buffered (FedBuff's K). With
    the unit-latency trace and ``k = depth * clients_per_round`` this is
    bit-equal to the ``pipeline_depth=depth`` cadence."""

    def __init__(self, k: int):
        assert k >= 1, k
        self.k = int(k)

    def on_arrival(self, sched: "EventScheduler") -> bool:
        return sched.pending_ready_count >= self.k

    def describe(self) -> str:
        return f"count>={self.k}"


class TimeoutTrigger(BufferTrigger):
    """Fire ``timeout`` virtual seconds after the previous fire (provided
    anything is buffered; an empty buffer defers to the next arrival)."""

    def __init__(self, timeout: float):
        assert timeout > 0, timeout
        self.timeout = float(timeout)

    def on_arrival(self, sched: "EventScheduler") -> bool:
        # an arrival landing after an empty-buffer expiry fires immediately
        return sched.clock.now >= sched.last_fire + self.timeout - 1e-9

    def deadline(self, sched: "EventScheduler") -> Optional[float]:
        if sched.pending_ready_count == 0:
            return None
        return sched.last_fire + self.timeout

    def describe(self) -> str:
        return f"timeout={self.timeout}"


class StalenessBoundTrigger(BufferTrigger):
    """Fire before any buffered arrival's staleness would exceed
    ``max_staleness`` (staleness = floor(age / round_interval)): the
    deadline is ``oldest arrival + max_staleness * round_interval``, so an
    update is always aggregated at staleness <= max_staleness."""

    def __init__(self, max_staleness: int):
        assert max_staleness >= 0, max_staleness
        self.max_staleness = int(max_staleness)

    def deadline(self, sched: "EventScheduler") -> Optional[float]:
        oldest = sched.oldest_ready_time
        if oldest is None:
            return None
        return oldest + self.max_staleness * sched.round_interval

    def describe(self) -> str:
        return f"staleness<={self.max_staleness}"


# ---------------------------------------------------------------------------
# client lifecycle
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class LifecycleEvent:
    """A timed client lifecycle change.

    kind="dropout": ``client`` leaves the sampling pool at ``time``; its
    in-flight (dispatched, not yet arrived) updates are cancelled -- they
    never reach the server. Already-arrived updates still aggregate.
    kind="rejoin":  ``client`` re-enters the sampling pool.
    kind="join":    a NEW client appears mid-run. ``client`` is the id it
    takes (must equal the registry size at apply time -- explicit so replay
    after a checkpoint restore is idempotent); ``rank``/``shard`` describe
    it for ``ClientRegistry.add_client``.
    """

    time: float
    kind: str            # "dropout" | "rejoin" | "join"
    client: int
    rank: Optional[int] = None
    shard: Optional[np.ndarray] = None

    def __post_init__(self):
        assert self.kind in ("dropout", "rejoin", "join"), self.kind


class ClientLifecycle:
    """A time-ordered scenario script of lifecycle events."""

    def __init__(self, events: Sequence[LifecycleEvent] = ()):
        self.events = sorted(events, key=lambda e: (e.time, e.client))

    def __len__(self):
        return len(self.events)


# ---------------------------------------------------------------------------
# the canonical sweep scenario (shared by bench_round_latency --engine event
# and fl_dryrun --trigger, so the dry-run cohort analysis always describes
# the same trigger/latency configuration the tracked benchmark rows record)
# ---------------------------------------------------------------------------

def standard_trigger(name: str, clients_per_round: int) -> BufferTrigger:
    """The sweep's trigger instances: count = a 2-round cohort (the
    pipeline_depth=2 analogue), a 2-virtual-second timeout, staleness
    bound 1."""
    return {"count": CountTrigger(2 * clients_per_round),
            "timeout": TimeoutTrigger(2.0),
            "staleness": StalenessBoundTrigger(1)}[name]


def standard_straggler_latency(straggler_frac: float,
                               seed: int = 0) -> StragglerTailLatency:
    """The sweep's latency model: lognormal(0.9, 0.2) with a x6 straggler
    tail drawn at ``straggler_frac``."""
    return StragglerTailLatency(median=0.9, sigma=0.2, tail_scale=6.0,
                                straggler_frac=straggler_frac, seed=seed)


# ---------------------------------------------------------------------------
# the scheduler
# ---------------------------------------------------------------------------

@dataclass
class FireRecord:
    """One buffered-aggregation firing (for tests and the latency bench)."""

    time: float
    consumed: int
    max_staleness: int
    trigger: str


class EventScheduler:
    """Arrival-event bookkeeping between the server's round stages.

    Protocol (driven by ``FederatedLoRA``):

    1. ``active_clients(n)`` -> sampling pool for the next plan.
    2. ``dispatch(plan_round, clients)`` after the plan's training is
       dispatched: samples one latency per client, schedules arrivals.
    3. ``for fire_time in advance_window():`` -- advances the clock one
       ``round_interval``, processing arrivals and lifecycle events in
       time order. Each yield is a trigger firing; the consumer MUST call
       ``take_ready()`` (and aggregate) before resuming iteration.
    4. ``completed_plans()`` / ``forget_plan`` retire fully-consumed plans.
    5. ``drain()`` at end of run: processes every remaining arrival, then
       force-fires whatever is left buffered.
    """

    def __init__(self, latency: LatencyModel, trigger: BufferTrigger, *,
                 round_interval: float = 1.0,
                 lifecycle: Optional[ClientLifecycle] = None):
        assert round_interval > 0, round_interval
        self.latency = latency
        self.trigger = trigger
        self.round_interval = float(round_interval)
        self.lifecycle = lifecycle or ClientLifecycle()
        self.clock = VirtualClock()
        self.last_fire = 0.0
        self.fire_log: List[FireRecord] = []
        self._heap: List[tuple] = []    # (time, seq, plan_round, member, client)
        self._seq = 0
        # plan_round -> {"size", "arrived" {member: time}, "consumed" set,
        #                "dropped" set}
        self._book: Dict[int, dict] = {}
        self._inactive: Set[int] = set()
        self._lc_idx = 0
        self._on_join: Optional[Callable[[LifecycleEvent], None]] = None

    # -- pool / dispatch -----------------------------------------------------

    def bind_join_hook(self, hook: Callable[[LifecycleEvent], None]) -> None:
        """Server hook applying "join" events to its client registry."""
        self._on_join = hook

    def active_clients(self, num_clients: int) -> Optional[np.ndarray]:
        """Sampling pool for the next plan; None = every client (the exact
        rng-stream-preserving fast path)."""
        if not self._inactive:
            return None
        # O(num_clients) pool scan -- only on the lifecycle-event slow
        # path; the host-cost registry contract rides on the None fast
        # path above staying the common case
        host_cost.tick("events/active_scan", num_clients)
        pool = np.array([c for c in range(num_clients)
                         if c not in self._inactive], dtype=np.int64)
        assert pool.size > 0, "every client has dropped out"
        return pool

    def dispatch(self, plan_round: int, clients: Sequence[int]) -> None:
        host_cost.tick("events/dispatch", len(clients))
        t = self.clock.now
        self._book[plan_round] = {"size": len(clients), "arrived": {},
                                  "consumed": set(), "dropped": set()}
        for member, client in enumerate(clients):
            lat = float(self.latency.sample(int(client)))
            assert lat > 0, (client, lat)
            heapq.heappush(self._heap,
                           (t + lat, self._seq, int(plan_round),
                            int(member), int(client)))
            self._seq += 1

    # -- buffer state --------------------------------------------------------

    @property
    def pending_ready_count(self) -> int:
        """Arrived-but-unaggregated client updates across all plans."""
        return sum(len(b["arrived"]) - len(b["consumed"])
                   for b in self._book.values())

    @property
    def oldest_ready_time(self) -> Optional[float]:
        times = [t for b in self._book.values()
                 for m, t in b["arrived"].items() if m not in b["consumed"]]
        return min(times) if times else None

    def staleness_of(self, fire_time: float, arrival_time: float) -> int:
        """Arrival-time-derived staleness: whole ``round_interval``s the
        update waited in the buffer. Reduces to the cadence engine's
        plan-age staleness under the unit-latency trace (DESIGN.md §7)."""
        age = (fire_time - arrival_time) / self.round_interval
        return max(0, int(math.floor(age + 1e-9)))

    def take_ready(self) -> Dict[int, Dict[int, float]]:
        """{plan_round: {member: arrival_time}} of every buffered update,
        marking them consumed. Called by the aggregation at a fire."""
        out: Dict[int, Dict[int, float]] = {}
        host_cost.tick("events/book_scan", len(self._book))
        # explicit client-iteration order: ascending plan round, ascending
        # member within a plan -- the aggregation's client axis (and thus
        # the fire log and the consumed bookkeeping) must not depend on
        # dict insertion history
        for pr in sorted(self._book):
            b = self._book[pr]
            ready = {m: b["arrived"][m] for m in sorted(b["arrived"])
                     if m not in b["consumed"]}
            if ready:
                out[pr] = ready
                b["consumed"].update(ready)
                host_cost.tick("events/ready", len(ready))
        if out:
            stal = max(self.staleness_of(self.clock.now, t)
                       for rd in out.values() for t in rd.values())
            self.fire_log.append(FireRecord(
                time=self.clock.now,
                consumed=sum(len(rd) for rd in out.values()),
                max_staleness=stal, trigger=self.trigger.describe()))
        return out

    def completed_plans(self) -> List[int]:
        """Plan rounds whose every member has been consumed or dropped
        (ascending plan order -- explicit, not insertion-dependent)."""
        return [pr for pr in sorted(self._book)
                if (len(self._book[pr]["consumed"])
                    + len(self._book[pr]["dropped"]))
                >= self._book[pr]["size"]]

    def forget_plan(self, plan_round: int) -> None:
        self._book.pop(plan_round, None)

    # -- the event loop ------------------------------------------------------

    def _process_lifecycle(self, ev: LifecycleEvent) -> None:
        if ev.kind == "dropout":
            self._inactive.add(ev.client)
            # cancel in-flight arrivals: the dropped client never reports
            kept = []
            for item in self._heap:
                if item[4] == ev.client:
                    self._book[item[2]]["dropped"].add(item[3])
                else:
                    kept.append(item)
            if len(kept) != len(self._heap):
                self._heap = kept
                heapq.heapify(self._heap)
        elif ev.kind == "rejoin":
            self._inactive.discard(ev.client)
        else:                               # join
            assert self._on_join is not None, \
                "join events need a bound registry hook"
            self._on_join(ev)

    def _fire(self, t: float) -> float:
        self.clock.advance(t)
        self.last_fire = self.clock.now
        return self.clock.now

    def _events(self, end: float) -> Iterator[float]:
        """Process arrivals + lifecycle events with time <= ``end`` in
        time order, yielding trigger fire times; the clock lands at
        ``end``."""
        while True:
            # next event: lifecycle events tie-break BEFORE arrivals at the
            # same timestamp (a dropout at t cancels an arrival at t)
            lc = (self.lifecycle.events[self._lc_idx]
                  if self._lc_idx < len(self.lifecycle.events) else None)
            arr = self._heap[0] if self._heap else None
            pick_lc = lc is not None and (arr is None or lc.time <= arr[0])
            nxt_time = (lc.time if pick_lc else
                        arr[0] if arr is not None else None)
            bound = min(nxt_time if nxt_time is not None else math.inf, end)
            # deadline fires come first: a timeout expiring before the next
            # event aggregates without it
            dl = self.trigger.deadline(self)
            if (dl is not None and dl <= bound + 1e-9
                    and self.pending_ready_count > 0):
                before = self.pending_ready_count
                yield self._fire(max(dl, self.clock.now))
                assert self.pending_ready_count < before, \
                    "fire consumer must take_ready()"
                continue
            if nxt_time is None or nxt_time > end:
                break
            if pick_lc:
                self.clock.advance(lc.time)
                self._lc_idx += 1
                self._process_lifecycle(lc)
                continue
            t, _, pr, member, client = heapq.heappop(self._heap)
            self.clock.advance(t)
            self._book[pr]["arrived"][member] = t
            if (self.pending_ready_count > 0
                    and self.trigger.on_arrival(self)):
                before = self.pending_ready_count
                yield self._fire(t)
                assert self.pending_ready_count < before, \
                    "fire consumer must take_ready()"
        self.clock.advance(end)

    def advance_window(self) -> Iterator[float]:
        """One round's event window: everything due in
        ``(now, now + round_interval]``, the clock left at the window end."""
        return self._events(self.clock.now + self.round_interval)

    def drain(self) -> Iterator[float]:
        """End-of-run: play events out to the ARRIVAL horizon (the last
        in-flight arrival -- triggers still apply on the way), then
        force-fire whatever is left buffered AT the horizon. The clock
        stops there: lifecycle events scripted beyond the horizon are
        irrelevant to draining and must not inflate the final staleness
        or the recorded virtual times."""
        if self._heap:
            yield from self._events(max(item[0] for item in self._heap))
        if self.pending_ready_count > 0:
            yield self._fire(self.clock.now)

    # -- checkpointing -------------------------------------------------------

    def state_dict(self) -> dict:
        return {
            "now": self.clock.now,
            "last_fire": self.last_fire,
            "seq": self._seq,
            "lc_idx": self._lc_idx,
            "inactive": sorted(self._inactive),
            "heap": [list(item) for item in sorted(self._heap)],
            # sorted plan/member order (not insertion order): the
            # serialized state -- and therefore checkpoint metadata -- is
            # byte-stable across runs that built the book differently
            "book": {str(pr): {"size": self._book[pr]["size"],
                               "arrived": {str(m):
                                           self._book[pr]["arrived"][m]
                                           for m in sorted(
                                               self._book[pr]["arrived"])},
                               "consumed": sorted(self._book[pr]["consumed"]),
                               "dropped": sorted(self._book[pr]["dropped"])}
                     for pr in sorted(self._book)},
            "fires": [[f.time, f.consumed, f.max_staleness, f.trigger]
                      for f in self.fire_log],
            "latency": self.latency.state_dict(),
        }

    def load_state_dict(self, state: Optional[dict]) -> None:
        """Reset to the checkpoint's event state (None: pristine). "join"
        lifecycle events before the restored cursor are replayed through
        the registry hook (idempotent: the event carries its client id)."""
        self.clock = VirtualClock(0.0 if not state else state["now"])
        self.last_fire = 0.0 if not state else float(state["last_fire"])
        self._seq = 0 if not state else int(state["seq"])
        self._lc_idx = 0 if not state else int(state["lc_idx"])
        self._inactive = (set() if not state
                          else set(int(c) for c in state["inactive"]))
        self._heap = ([] if not state else
                      [(float(t), int(s), int(pr), int(m), int(c))
                       for t, s, pr, m, c in state["heap"]])
        heapq.heapify(self._heap)
        self._book = {}
        self.fire_log = []
        if state:
            for pr, b in state["book"].items():
                self._book[int(pr)] = {
                    "size": int(b["size"]),
                    "arrived": {int(m): float(t)
                                for m, t in b["arrived"].items()},
                    "consumed": set(int(m) for m in b["consumed"]),
                    "dropped": set(int(m) for m in b["dropped"])}
            self.fire_log = [FireRecord(time=float(t), consumed=int(n),
                                        max_staleness=int(s), trigger=str(tr))
                             for t, n, s, tr in state.get("fires", [])]
        self.latency.load_state_dict(None if not state
                                     else state.get("latency"))
        for ev in self.lifecycle.events[:self._lc_idx]:
            if ev.kind == "join" and self._on_join is not None:
                self._on_join(ev)
