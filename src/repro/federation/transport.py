"""Compressed update transport with error feedback (DESIGN.md §12).

Clients ship quantized U/V factors instead of f32: each paper-layout
factor pair (B (…, d, r), A (…, r, n)) is encoded per RANK COLUMN with
absmax scales -- B's scale is the absmax over its d rows per column
((…, 1, r)), A's the absmax over its n entries per row ((…, r, 1)) --
so the quantization grid adapts per rank direction and a zero column
(every column beyond a client's rank level r_k in the masked-training
layout) gets scale 0 and decodes to EXACTLY zero. Rank-level awareness
therefore costs nothing: the omega zero-columns of Eq. 6/7 stay zero
bit-for-bit, and the rank-partition weighting math downstream is
unchanged because every consumer dequantizes BEFORE weighting (the
Eq. 8 fallback client and async staleness discounts act on dequantized
contributions).

Error feedback (the EF-SGD / 1-bit-Adam residual trick): the encoder
compresses x' = x + e where e is the client's accumulated quantization
residual from its previous participation, then stores e' = x' - deq(q).
Summed over K rounds the residuals telescope,

    sum_t deq(q_t) = sum_t x_t + e_0 - e_K,

so the compressed update SUM tracks the uncompressed sum to within one
residual -- compression noise does not accumulate. Accumulators are
host-side f32 numpy per (client, adapter), flushed lazily from device
handles so the async engine's non-blocking dispatch discipline is
preserved, and ride ``save()``/``restore()`` bit-exactly via the flat
npz machinery.

Optional top-k rank sparsification drops all but the k most energetic
rank columns (energy = ||B_col|| * ||A_row||) before quantization; the
dropped mass lands in the error-feedback residual and re-enters next
round.

``QuantFactor`` is a NamedTuple (= a jax pytree node), so quantized
pairs flow through the existing plan buffers, jit dispatches and
shard_map programs untouched; dequantization happens ONCE at
stack-build time inside ``core/aggregation.py`` / ``kernels/ops.py``.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Dict, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

MODES = ("int8", "bf16")


class QuantFactor(NamedTuple):
    """One quantized factor: integer/bf16 payload + f32 per-column scales.

    ``q``      -- payload, int8 (absmax grid) or bf16 (scale == 1)
    ``scale``  -- f32, (…, 1, r) for B factors / (…, r, 1) for A factors;
                  exactly 0.0 for all-zero columns so they decode to 0
    """
    q: jnp.ndarray
    scale: jnp.ndarray


def is_quantized(x) -> bool:
    """Duck-typed: True for QuantFactor (incl. across module reloads)."""
    return hasattr(x, "q") and hasattr(x, "scale")


def dequantize(x):
    """QuantFactor -> f32 array; plain arrays pass through untouched."""
    if is_quantized(x):
        return x.q.astype(jnp.float32) * x.scale
    return x


def _quantize(x: jnp.ndarray, axis: int, mode: str) -> QuantFactor:
    """Per-column absmax quantization along ``axis`` (kept as size 1)."""
    if mode == "bf16":
        ones = jnp.ones_like(jnp.max(x, axis=axis, keepdims=True))
        return QuantFactor(x.astype(jnp.bfloat16), ones)
    amax = jnp.max(jnp.abs(x), axis=axis, keepdims=True)
    scale = amax / 127.0
    safe = jnp.where(scale > 0, scale, 1.0)
    q = jnp.clip(jnp.round(x / safe), -127, 127).astype(jnp.int8)
    q = jnp.where(scale > 0, q, jnp.zeros_like(q))
    return QuantFactor(q, scale)


def _topk_mask(b: jnp.ndarray, a: jnp.ndarray, k: int) -> jnp.ndarray:
    """(…, r) keep-mask of the k most energetic rank columns."""
    eb = jnp.sqrt(jnp.sum(b * b, axis=-2))          # (…, r)
    ea = jnp.sqrt(jnp.sum(a * a, axis=-1))          # (…, r)
    energy = eb * ea
    thr = -jnp.sort(-energy, axis=-1)[..., k - 1:k]  # k-th largest
    # strictly-positive threshold only: when fewer than k columns are
    # nonzero the threshold is 0 and every nonzero column survives
    return ((energy >= thr) | (thr <= 0)).astype(b.dtype)


@partial(jax.jit, static_argnames=("mode", "top_k"))
def _encode_pair(b, a, eb, ea, *, mode: str, top_k: Optional[int]):
    """Quantize one (B, A) pair with error feedback.

    Returns (qb, qa, rb, ra): the QuantFactor pair and the NEW residuals
    (x + e - deq), all as unmaterialized device handles -- callers must
    not block on them (async overlap discipline, DESIGN.md §6)."""
    xb = b.astype(jnp.float32) + eb
    xa = a.astype(jnp.float32) + ea
    yb, ya = xb, xa
    if top_k is not None and top_k < b.shape[-1]:
        mask = _topk_mask(xb, xa, top_k)
        yb = xb * mask[..., None, :]
        ya = xa * mask[..., :, None]
    qb = _quantize(yb, axis=-2, mode=mode)          # B: absmax over d rows
    qa = _quantize(ya, axis=-1, mode=mode)          # A: absmax over n cols
    rb = xb - dequantize(qb)
    ra = xa - dequantize(qa)
    return qb, qa, rb, ra


@dataclasses.dataclass(frozen=True)
class TransportConfig:
    """Client->server update compression knobs.

    ``mode``            -- "int8" (per-column absmax grid) or "bf16"
    ``error_feedback``  -- carry per-client residual accumulators
    ``top_k``           -- keep only the k most energetic rank columns
                           per adapter (None: keep all)
    """
    mode: str = "int8"
    error_feedback: bool = True
    top_k: Optional[int] = None

    def __post_init__(self):
        assert self.mode in MODES, self.mode
        assert self.top_k is None or self.top_k >= 1, self.top_k


def _is_magnitude(parent) -> bool:
    """DoRA magnitude entries ((parent, "m")) ship uncompressed: they are
    (…, out)-shaped FedAvg'd vectors, not rank-structured factors."""
    return (isinstance(parent, tuple) and len(parent) == 2
            and parent[1] == "m")


class UpdateTransport:
    """Stateful encoder: per-client error-feedback accumulators + the
    jitted quantizer, shared by all five round engines.

    Accumulators are HOST numpy ((eb, ea) f32 per (client, adapter)),
    but freshly-encoded residuals enter a pending list as device handles
    and materialize lazily (``_flush``) at the NEXT encode / state read:
    between dispatches the host stays jax-free, so the async engine's
    in-flight overlap survives compression."""

    def __init__(self, config: Optional[TransportConfig] = None, **kw):
        self.cfg = config if config is not None else TransportConfig(**kw)
        # cid -> parent -> (eb, ea) f32 numpy
        self._acc: Dict[int, Dict[tuple, Tuple[np.ndarray, np.ndarray]]] = {}
        # (client ids per stacked position | [cid], {parent: (rb, ra)},
        #  stacked?) -- residual handles awaiting materialization
        self._pending: List[tuple] = []

    # -- encoding ------------------------------------------------------------

    def encode_group(self, client_ids: List[int],
                     factors: Dict[tuple, object]) -> Dict[tuple, object]:
        """Encode one grouped-engine factor stack ({parent: (B, A)} with
        leading client axis). ``client_ids[j]`` is the GLOBAL client id at
        stacked position j, or -1 for a sharded ghost (zero residual in,
        residual out discarded)."""
        self._flush()
        out: Dict[tuple, object] = {}
        residuals: Dict[tuple, tuple] = {}
        for parent, val in factors.items():
            if _is_magnitude(parent):
                out[parent] = val
                continue
            b, a = val
            eb, ea = self._residual_stack(client_ids, parent, b.shape,
                                          a.shape)
            qb, qa, rb, ra = _encode_pair(b, a, eb, ea, mode=self.cfg.mode,
                                          top_k=self.cfg.top_k)
            out[parent] = (qb, qa)
            residuals[parent] = (rb, ra)
        if self.cfg.error_feedback and residuals:
            self._pending.append((list(client_ids), residuals, True))
        return out

    def encode_client(self, cid: int,
                      factors: Dict[tuple, object]) -> Dict[tuple, object]:
        """Sequential-engine variant: one client's per-rank factors
        ((…, d, r_k) / (…, r_k, n), no client axis)."""
        self._flush()
        out: Dict[tuple, object] = {}
        residuals: Dict[tuple, tuple] = {}
        for parent, val in factors.items():
            if _is_magnitude(parent):
                out[parent] = val
                continue
            b, a = val
            eb, ea = self._residual_one(cid, parent, b.shape, a.shape)
            qb, qa, rb, ra = _encode_pair(b, a, eb, ea, mode=self.cfg.mode,
                                          top_k=self.cfg.top_k)
            out[parent] = (qb, qa)
            residuals[parent] = (rb, ra)
        if self.cfg.error_feedback and residuals:
            self._pending.append(([cid], residuals, False))
        return out

    # -- error-feedback accumulators ----------------------------------------

    def _residual_stack(self, client_ids, parent, b_shape, a_shape):
        """Previous residuals stacked in client order (zeros when absent
        or shape-mismatched, e.g. a client re-encoding at a new r_max)."""
        eb = np.zeros(b_shape, np.float32)
        ea = np.zeros(a_shape, np.float32)
        for j, cid in enumerate(client_ids):
            got = self._acc.get(cid, {}).get(parent)
            if got is not None and got[0].shape == b_shape[1:] \
                    and got[1].shape == a_shape[1:]:
                eb[j], ea[j] = got
        return eb, ea

    def _residual_one(self, cid, parent, b_shape, a_shape):
        got = self._acc.get(cid, {}).get(parent)
        if got is not None and got[0].shape == tuple(b_shape) \
                and got[1].shape == tuple(a_shape):
            return got
        return (np.zeros(b_shape, np.float32), np.zeros(a_shape, np.float32))

    def _flush(self) -> None:
        """Materialize pending residual handles into the accumulators.
        Called lazily (next encode / state read), so the handles are a
        full round old and the transfer never stalls in-flight work."""
        for client_ids, residuals, stacked in self._pending:
            for parent, (rb, ra) in residuals.items():
                rb = np.asarray(rb, dtype=np.float32)
                ra = np.asarray(ra, dtype=np.float32)
                if stacked:
                    for j, cid in enumerate(client_ids):
                        if cid >= 0:    # sharded ghosts carry no residual
                            self._acc.setdefault(cid, {})[parent] = \
                                (rb[j], ra[j])
                else:
                    self._acc.setdefault(client_ids[0], {})[parent] = \
                        (rb, ra)
        self._pending = []

    # -- checkpoint state (flat npz, bit-exact f32) --------------------------

    def has_state(self) -> bool:
        return bool(self._acc) or bool(self._pending)

    def state_arrays(self) -> Dict[str, np.ndarray]:
        """{"c{cid}/{adapter path}/b|a": residual} -- sorted, flat,
        np.float32 throughout, so save_flat/load_flat round-trips the
        accumulators bit-exactly."""
        self._flush()
        out: Dict[str, np.ndarray] = {}
        for cid in sorted(self._acc):
            for parent, (eb, ea) in self._acc[cid].items():
                key = f"c{cid}/" + "/".join(parent)
                out[key + "/b"] = eb
                out[key + "/a"] = ea
        return out

    def load_state_arrays(self, arrays: Dict[str, np.ndarray]) -> None:
        self.reset()
        pairs: Dict[tuple, dict] = {}
        for key, arr in arrays.items():
            cid_s, rest = key.split("/", 1)
            path, leaf = rest.rsplit("/", 1)
            pairs.setdefault((int(cid_s[1:]), tuple(path.split("/"))),
                             {})[leaf] = np.asarray(arr, dtype=np.float32)
        for (cid, parent), ba in pairs.items():
            self._acc.setdefault(cid, {})[parent] = (ba["b"], ba["a"])

    def reset(self) -> None:
        self._acc = {}
        self._pending = []

    # -- reporting -----------------------------------------------------------

    def payload_bytes(self, d: int, n: int, r: int) -> int:
        """Wire bytes of one encoded (B, A) adapter pair at (d, r, n)."""
        itemsize = 1 if self.cfg.mode == "int8" else 2
        return (d * r + r * n) * itemsize + (r + r) * 4   # payload + scales
