"""Federated server: Algorithm 1 round loop with pluggable aggregation.

Per round: uniform client sampling -> broadcast (rank-truncated adapters) ->
parallel local training -> rank-partitioned (or baseline) aggregation ->
SVD reallocation -> energy bookkeeping. The server state is checkpointable
and the whole loop is architecture-agnostic: it sees only adapter factor
trees from ``repro.core.lora``.

TPU mapping note (DESIGN.md §5): in the simulated runtime clients execute
sequentially on one device; on a pod, client local steps are data-parallel
over the ``data`` mesh axis and the stacked-factor contraction
sum_k B_k diag(omega_k) A_k lowers to an all-reduce of per-shard partial
sums (see launch/fl_dryrun.py).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FLConfig, LoRAConfig
from repro.core.aggregation import Aggregator
from repro.core.energy import EnergyTrace
from repro.core.lora import merge_lora, split_lora
from repro.federation.client import LocalTrainer
from repro.federation.topology import ClientRegistry
from repro.models.transformer import Model
from repro.optim import get_schedule


@dataclass
class RoundStats:
    round: int
    clients: List[int]
    ranks: List[int]
    lr: float
    mean_client_loss: float
    sigma_probe: Optional[np.ndarray]  # singular values of probe adapter
    wall_time_s: float


class FederatedLoRA:
    """End-to-end heterogeneous-rank FedLoRA driver."""

    def __init__(self, model: Model, fl: FLConfig, lora: LoRAConfig,
                 registry: ClientRegistry,
                 batch_fn: Callable[[int, np.random.Generator], list],
                 *, base_params=None, seed: Optional[int] = None,
                 backend: str = "factored",
                 partial_up_to: Optional[int] = None,
                 server_momentum=None):
        """batch_fn(client_id, rng) -> list of training batches (dicts)."""
        self.model = model
        self.fl = fl
        self.lora_cfg = lora
        self.registry = registry
        self.batch_fn = batch_fn
        self.rng = np.random.default_rng(fl.seed if seed is None else seed)
        params = base_params if base_params is not None else model.init(
            jax.random.PRNGKey(fl.seed))
        self.base, self.global_lora = split_lora(params)
        self.trainer = LocalTrainer(model, weight_decay=fl.weight_decay,
                                    freeze_a=(fl.aggregator == "ffa"))
        self.server_momentum = server_momentum  # FactoredServerMomentum|None
        self.aggregator = Aggregator(fl.aggregator, lora.rank_levels,
                                     backend=backend,
                                     partial_up_to=partial_up_to)
        self.schedule = get_schedule(fl.lr_schedule, fl.learning_rate,
                                     fl.num_rounds)
        self.round_idx = 0
        self.energy = EnergyTrace(lora.rank_levels)
        self.history: List[RoundStats] = []

    # -- adapter plumbing ---------------------------------------------------

    def _extract_factors(self, lora_tree, rank: int) -> Dict[tuple, tuple]:
        """{adapter_path: (B (…, d_in, r_k), A (…, r_k, d_out))}.

        Model layout: lora_a (…, r_max, in), lora_b (…, out, r_max).
        Paper layout: B = lora_a^T restricted to r_k, A = lora_b^T.
        """
        from repro.core.lora import _is_lora_path
        pairs: Dict[tuple, dict] = {}

        def collect(path, x):
            if x is not None and _is_lora_path(path):
                parent = tuple(str(getattr(p, "key", p)) for p in path[:-1])
                kind = {"lora_a": "a", "lora_b": "b",
                        "lora_m": "m"}[path[-1].key]
                pairs.setdefault(parent, {})[kind] = x
            return x

        jax.tree_util.tree_map_with_path(collect, lora_tree,
                                         is_leaf=lambda x: x is None)
        out = {}
        for parent, ab in pairs.items():
            a_model = ab["a"]           # (…, r_max, in)
            b_model = ab["b"]           # (…, out, r_max)
            b_paper = jnp.swapaxes(a_model, -2, -1)[..., :rank]   # (…, in, r_k)
            a_paper = jnp.swapaxes(b_model, -2, -1)[..., :rank, :]  # (…, r_k, out)
            out[parent] = (b_paper, a_paper)
            if "m" in ab:               # DoRA magnitude: FedAvg'd separately
                out[(parent, "m")] = ab["m"]
        return out

    def _write_factors(self, results: Dict[tuple, tuple]) -> None:
        """Write aggregated (b_g, a_g) back into the global lora tree."""
        from repro.core.lora import _is_lora_path

        def rebuild(path, x):
            if x is None or not _is_lora_path(path):
                return x
            parent = tuple(str(getattr(p, "key", p)) for p in path[:-1])
            if path[-1].key == "lora_m":
                m_new = results.get((parent, "m"))
                return x if m_new is None else m_new.astype(x.dtype)
            b_g, a_g = results[parent]
            if path[-1].key == "lora_a":
                return jnp.swapaxes(b_g, -2, -1).astype(x.dtype)
            return jnp.swapaxes(a_g, -2, -1).astype(x.dtype)

        self.global_lora = jax.tree_util.tree_map_with_path(
            rebuild, self.global_lora, is_leaf=lambda x: x is None)

    def _merge_flora_delta(self, deltas: Dict[tuple, jnp.ndarray]) -> None:
        """FLoRA: fold dW into the base dense weights (cold-start restart)."""
        def apply(path, x):
            if x is None:
                return x
            key = getattr(path[-1], "key", None)
            if key != "w":
                return x
            parent = tuple(str(getattr(p, "key", p)) for p in path[:-1])
            if parent in deltas:
                return (x.astype(jnp.float32)
                        + deltas[parent].astype(jnp.float32)).astype(x.dtype)
            return x

        self.base = jax.tree_util.tree_map_with_path(
            apply, self.base, is_leaf=lambda x: x is None)

    # -- the round ----------------------------------------------------------

    def run_round(self) -> RoundStats:
        t0 = time.time()
        fl = self.fl
        m = fl.clients_per_round
        clients = self.registry.sample_round(m, self.rng).tolist()
        ranks = [int(self.registry.ranks[c]) for c in clients]
        n_k = [max(self.registry.num_samples(c), 1) for c in clients]
        lr = self.schedule(self.round_idx)

        # local training (sequential simulation of the parallel clients)
        client_factors: List[Dict[tuple, tuple]] = []
        losses = []
        for cid, rank in zip(clients, ranks):
            batches = self.batch_fn(cid, self.rng)
            trained, metrics = self.trainer.train(
                self.base, self.global_lora, rank, batches, lr)
            client_factors.append(self._extract_factors(trained, rank))
            losses.append(float(metrics.get("loss", jnp.nan)))

        # aggregate every adapter
        results, deltas = {}, {}
        sigma_probe = None
        global_factors = self._extract_factors(self.global_lora,
                                               self.lora_cfg.r_max)
        w_clients = jnp.asarray(np.asarray(n_k) / np.sum(n_k))
        for parent in client_factors[0]:
            if isinstance(parent, tuple) and len(parent) == 2 \
                    and parent[1] == "m":
                # DoRA magnitudes: weighted FedAvg (not rank-structured)
                ms = jnp.stack([cf[parent] for cf in client_factors])
                wshape = (-1,) + (1,) * (ms.ndim - 1)
                results[parent] = jnp.sum(
                    w_clients.reshape(wshape) * ms, axis=0)
                continue
            factors = [cf[parent] for cf in client_factors]
            g_b, g_a = global_factors[parent]
            res = self.aggregator.aggregate_layer(factors, ranks, n_k,
                                                  global_b=g_b, global_a=g_a)
            if self.server_momentum is not None:
                results[parent] = self.server_momentum.apply(
                    parent, (g_b, g_a), (res.b_g, res.a_g),
                    self.lora_cfg.r_max)
            else:
                results[parent] = (res.b_g, res.a_g)
            if res.merge_delta is not None:
                deltas[parent] = res.merge_delta
            if sigma_probe is None and res.sigma is not None:
                sig = np.asarray(res.sigma)
                sigma_probe = sig if sig.ndim == 1 else sig.mean(axis=0)
        self._write_factors(results)
        if deltas:
            self._merge_flora_delta(deltas)
        if sigma_probe is not None:
            self.energy.record(jnp.asarray(sigma_probe))

        stats = RoundStats(
            round=self.round_idx, clients=clients, ranks=ranks, lr=lr,
            mean_client_loss=float(np.mean(losses)),
            sigma_probe=sigma_probe, wall_time_s=time.time() - t0)
        self.history.append(stats)
        self.round_idx += 1
        return stats

    def run(self, rounds: Optional[int] = None,
            eval_fn: Optional[Callable] = None,
            eval_every: int = 10) -> List[RoundStats]:
        rounds = rounds if rounds is not None else self.fl.num_rounds
        for _ in range(rounds):
            self.run_round()
            if eval_fn is not None and self.round_idx % eval_every == 0:
                eval_fn(self)
        return self.history

    # -- evaluation / state --------------------------------------------------

    def global_params(self):
        return merge_lora(self.base, self.global_lora)

    def evaluate(self, batch: dict) -> dict:
        params = self.global_params()
        _, metrics = self.model.train_loss(params, batch,
                                           lora_rank=self.lora_cfg.r_max)
        return {k: float(v) for k, v in metrics.items()}

    def save(self, path: str) -> None:
        from repro.checkpointing.checkpoint import save_pytree
        save_pytree(path + ".base", self.base)
        save_pytree(path + ".lora", self.global_lora,
                    metadata={"round": self.round_idx,
                              "method": self.fl.aggregator})

    def restore(self, path: str) -> None:
        from repro.checkpointing.checkpoint import load_metadata, load_pytree
        self.base = load_pytree(path + ".base", self.base)
        self.global_lora = load_pytree(path + ".lora", self.global_lora)
        meta = load_metadata(path + ".lora")
        if meta:
            self.round_idx = meta.get("round", self.round_idx)
