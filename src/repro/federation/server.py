"""Federated server: Algorithm 1 round loop with pluggable aggregation.

Per round: uniform client sampling -> broadcast (rank-truncated adapters) ->
parallel local training -> rank-partitioned (or baseline) aggregation ->
SVD reallocation -> energy bookkeeping. The server state is checkpointable
and the whole loop is architecture-agnostic: it sees only adapter factor
trees from ``repro.core.lora``.

Two round engines (DESIGN.md "Batched round engine"):

* ``round_engine="batched"`` (default): ALL sampled clients train as ONE
  vmapped, jitted multi-client step over stacked LoRA trees -- each
  client's factors rank-masked and its lora scale vmapped, which is exact
  (client.py) -- and aggregation stacks every same-shape adapter into one
  (M, P, ..., d, r) bucket and runs one jitted weighted-contraction +
  batched QR/SVD realloc per bucket (the "kernel" backend lowers a bucket
  through the fused layer-batched Pallas grids -- sqrt-weighted factor
  stacks + (R, R) Gram cores feeding the Gram-core SVD realloc, so dW is
  never materialized; DESIGN.md §4.3).
* ``round_engine="sequential"``: the original per-client / per-adapter
  reference loop, kept for bit-level comparison (tests assert the two match
  to float tolerance) and for debugging.

* ``round_engine="sharded"`` (DESIGN.md §5): the batched engine's
  dispatches as shard_map programs over a mesh's ``data`` axis. Sampled
  clients are partitioned round-robin across shards (padded to equal
  per-shard counts with zero-weight ghost clients), local training runs
  the IDENTICAL masked vmapped step body on each shard's client block, and
  the stacked-factor contraction sum_k B_k diag(omega_k) A_k is computed
  as per-shard partials reduced by ONE ``jax.lax.psum`` per bucket before
  the unchanged SVD reallocation (launch/fl_dryrun.py lowers the very same
  program on the mocked production pod mesh). Every backend is
  engine-complete here, including "kernel": each shard builds its local
  zero-scattered (d+n, R) factor-stack partial with the layered Pallas
  grid over its resident clients only, the psum stays one (d+n, R)
  all-reduce, and the Gram-core realloc runs on the reduced stack
  (DESIGN.md §4.3 -- no silent einsum downgrade).

* ``round_engine="async"`` (DESIGN.md §6): the round as explicit
  plan -> train -> aggregate STAGES with FedBuff-style BUFFERED
  aggregation. Every round plans and dispatches one ``RoundPlan``'s masked
  vmapped local training as non-blocking jax handles
  (``client.dispatch_group_masked``) into a ``pipeline_depth``-deep buffer;
  when the buffer fills, ONE staleness-discounted bucketed aggregation +
  SVD realloc consumes every pending plan. Plan age in rounds is its
  staleness (mixed 0..depth-1 inside each aggregation); clients'
  aggregation weights are discounted by ``gamma**staleness`` folded into
  the n_k-derived weights (``core.aggregation.staleness_discount`` --
  ghost-client zero-weighting and the Eq. 8 fallback untouched).
  Aggregation, SVD, momentum and the global write-back amortize over depth
  rounds, and the host path between dispatches is deliberately jax-free
  (numpy batches/weights, flush-time-only device reads) so training
  dispatches pipeline against in-flight aggregation work instead of
  synchronizing with it. ``pipeline_depth=1`` reduces exactly to the
  batched engine (zero staleness is an arithmetic no-op); an optional mesh
  routes both stages through the sharded dispatches instead.

* ``round_engine="async"`` + ``event_scheduler=`` (DESIGN.md §7): the
  buffered aggregation driven by ARRIVAL EVENTS on a deterministic virtual
  clock instead of the fixed cadence. Each dispatched client's update
  arrives after a seeded per-client latency draw
  (``federation/events.py``); pluggable buffer triggers (count / virtual
  timeout / staleness bound) decide when the buffered aggregation fires,
  consuming exactly the updates that have arrived -- partial cohorts ride
  the ghost-client zero-weight rule (``present`` mask), staleness is
  arrival-time-derived (``floor(wait / round_interval)``), and client
  lifecycle events (dropout / rejoin / mid-run join) reshape the sampling
  pool between rounds. The count trigger under the unit-latency trace is
  bit-equal to the ``pipeline_depth=k`` cadence path
  (tests/test_events.py).
"""
from __future__ import annotations

import dataclasses
import functools
import time
import warnings
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis import host_cost
from repro.configs.base import FLConfig, LoRAConfig
from repro.core.aggregation import Aggregator, cohort_weights, weighted_avg
from repro.core.energy import EnergyTrace
from repro.core.lora import merge_lora, split_lora
from repro.federation.client import LocalTrainer, _stack_steps
from repro.federation.topology import ClientRegistry
from repro.federation.transport import (QuantFactor, TransportConfig,
                                        UpdateTransport)
from repro.models.transformer import Model
from repro.optim import get_schedule


@dataclass
class RoundStats:
    round: int
    clients: List[int]
    ranks: List[int]
    lr: float
    mean_client_loss: float
    sigma_probe: Optional[np.ndarray]  # singular values of probe adapter
    wall_time_s: float
    # event-driven engine: the virtual-clock time at the round's window end
    virtual_time: Optional[float] = None


@dataclass
class BucketedUpdate:
    """Aggregation output of the grouped engines, kept STACKED per shape
    bucket: ``buckets`` entries are (adapter parents, B stack (P, …, d, r),
    A stack (P, …, r, n)); ``mags`` holds DoRA magnitudes. Never unstacked
    per adapter on the hot path -- the write-back slices inside ONE jitted
    program (``_write_bucketed``), because every eager slice is a separate
    computation against jax's bounded CPU in-flight queue and would stall
    the async engine's dispatch pipeline."""

    buckets: List[tuple] = field(default_factory=list)
    mags: Dict = field(default_factory=dict)


@functools.partial(jax.jit, static_argnames=("bucket_parents",))
def _write_bucketed(lora_tree, bucket_stacks, mags, *, bucket_parents):
    """Write a ``BucketedUpdate`` back into the model-layout lora tree as
    one XLA program (swapaxes/slice/astype plumbing included)."""
    from repro.core.lora import _is_lora_path
    lookup = {p: (bi, j) for bi, group in enumerate(bucket_parents)
              for j, p in enumerate(group)}

    def rebuild(path, x):
        if x is None or not _is_lora_path(path):
            return x
        parent = tuple(str(getattr(p, "key", p)) for p in path[:-1])
        if path[-1].key == "lora_m":
            m_new = mags.get((parent, "m"))
            return x if m_new is None else m_new.astype(x.dtype)
        bi, j = lookup[parent]
        b_g, a_g = bucket_stacks[bi]
        if path[-1].key == "lora_a":
            return jnp.swapaxes(b_g[j], -2, -1).astype(x.dtype)
        return jnp.swapaxes(a_g[j], -2, -1).astype(x.dtype)

    return jax.tree_util.tree_map_with_path(rebuild, lora_tree,
                                            is_leaf=lambda x: x is None)


def flatten_cohort(members, ranks, n_k, staleness=None, present=None,
                   r_min: int = 1):
    """Permute per-sampled-client vectors into stacked group-member order.

    ``members[j]`` is the sampled-client index at stacked position j, or -1
    for a GHOST (shard padding): ghosts take rank ``r_min``, zero samples,
    zero staleness and are never present, so every weight they receive is
    identically zero. This is the single member-rebase rule shared by the
    grouped engines (``_aggregate_grouped``) and the protocol checker's
    ghost-rule invariant (``analysis/protocol.py``) -- the checker verifies
    the very arrays the aggregation consumes."""
    ranks_o = [ranks[i] if i >= 0 else r_min for i in members]
    n_k_o = [n_k[i] if i >= 0 else 0 for i in members]
    stal_o = (None if staleness is None else
              [staleness[i] if i >= 0 else 0 for i in members])
    pres_o = (None if present is None else
              [bool(present[i]) if i >= 0 else False for i in members])
    return ranks_o, n_k_o, stal_o, pres_o


@dataclass
class RoundPlan:
    """One round's sampled work order, carried between the round stages.

    Everything rng-dependent (client sample, data batches) is fixed at PLAN
    time, so the sampling stream is identical across engines and pipeline
    depths. After the train stage the plan carries the dispatched group
    factor stacks and per-group loss handles -- unmaterialized jax arrays
    (``client.dispatch_group_masked``), which is what lets the async engine
    buffer trained-but-not-yet-aggregated rounds without blocking.
    """

    round: int                 # the logical round this plan aggregates into
    version: int               # global model version when training dispatched
    clients: List[int]
    ranks: List[int]
    n_k: List[int]
    lr: float
    client_batches: Optional[list] = None   # dropped once training dispatched
    # grouped engines: [(members, r_max, {adapter_path: stacked factors})]
    group_factors: Optional[list] = None
    loss_parts: Optional[list] = None       # [(members, loss handle | None)]
    # sequential engine: per-client factor dicts + eager float losses
    client_factors: Optional[list] = None
    losses: Optional[list] = None


class FederatedLoRA:
    """End-to-end heterogeneous-rank FedLoRA driver."""

    def __init__(self, model: Model, fl: FLConfig, lora: LoRAConfig,
                 registry: ClientRegistry,
                 batch_fn: Callable[[int, np.random.Generator], list],
                 *, base_params=None, seed: Optional[int] = None,
                 backend: str = "factored",
                 partial_up_to: Optional[int] = None,
                 server_momentum=None,
                 round_engine: str = "batched",
                 mesh=None,
                 pipeline_depth: int = 1,
                 staleness_gamma: float = 1.0,
                 event_scheduler=None,
                 transport=None):
        """batch_fn(client_id, rng) -> list of training batches (dicts).

        ``round_engine="sharded"`` runs the batched engine's dispatches as
        shard_map programs over ``mesh``'s ``data`` axis (defaults to a
        1-D mesh over every visible device, ``launch/mesh.py::make_fl_mesh``).

        ``round_engine="async"`` buffers rounds: up to ``pipeline_depth``
        trained plans are in flight (training dispatched, aggregation
        pending), one buffered aggregation consumes them all, and stale
        contributions are discounted by ``staleness_gamma**staleness``
        (gamma=1: no discount). ``pipeline_depth=1`` IS the batched engine.
        An explicit ``mesh`` routes the async stages through the sharded
        dispatches.

        ``event_scheduler`` (requires ``round_engine="async"``): an
        ``events.EventScheduler`` replacing the fixed cadence with
        arrival-event buffer triggers on the virtual clock (see module
        docstring / DESIGN.md §7).

        ``transport``: a ``transport.UpdateTransport`` (or
        ``TransportConfig``) compressing client->server factor uploads:
        int8/bf16 per-column quantization with per-client error-feedback
        accumulators, dequantized once at aggregation stack-build time
        (DESIGN.md §12). None ships f32 factors unchanged.
        """
        assert round_engine in ("batched", "sequential", "sharded",
                                "async"), round_engine
        assert pipeline_depth >= 1, pipeline_depth
        assert 0.0 < staleness_gamma <= 1.0, staleness_gamma
        assert event_scheduler is None or round_engine == "async", \
            "event_scheduler rides round_engine='async'"
        self.round_engine = round_engine
        self.pipeline_depth = pipeline_depth if round_engine == "async" else 1
        self.staleness_gamma = staleness_gamma
        if round_engine == "sharded" and mesh is None:
            from repro.launch.mesh import make_fl_mesh
            mesh = make_fl_mesh()
        if mesh is not None:
            assert "data" in mesh.axis_names, mesh.axis_names
        self.mesh = mesh
        self.model = model
        self.fl = fl
        self.lora_cfg = lora
        self.registry = registry
        self.batch_fn = batch_fn
        self.rng = np.random.default_rng(fl.seed if seed is None else seed)
        params = base_params if base_params is not None else model.init(
            jax.random.PRNGKey(fl.seed))
        self.base, self.global_lora = split_lora(params)
        self.trainer = LocalTrainer(model, weight_decay=fl.weight_decay,
                                    freeze_a=(fl.aggregator == "ffa"))
        if isinstance(transport, TransportConfig):
            transport = UpdateTransport(transport)
        assert transport is None or isinstance(transport, UpdateTransport), \
            transport
        self.transport = transport
        self.server_momentum = server_momentum  # FactoredServerMomentum|None
        self.aggregator = Aggregator(fl.aggregator, lora.rank_levels,
                                     backend=backend,
                                     partial_up_to=partial_up_to)
        self.schedule = get_schedule(fl.lr_schedule, fl.learning_rate,
                                     fl.num_rounds)
        self.round_idx = 0
        # serving hot-swap (DESIGN.md §11): every aggregation landing bumps
        # the adapter version and fires the post-aggregate hooks with the
        # fresh global factors -- sync engines at round finalize, async /
        # event engines whenever their buffer fires (incl. drain_pending)
        self.adapter_version = 0
        self._post_aggregate_hooks: List[Callable] = []
        self.energy = EnergyTrace(lora.rank_levels)
        self.history: List[RoundStats] = []
        self._extract_jit = None   # lazily-built jitted factor extractor
        # async engine state: FIFO of trained-but-unaggregated plans
        # (their rounds are already counted) and the next round to plan
        self._pending: "deque[RoundPlan]" = deque()
        self._plan_idx = 0
        # finalized rounds whose stats still hold unmaterialized handles
        self._stat_queue: deque = deque()
        # event-driven async engine: arrival-event scheduler on the
        # virtual clock; "join" lifecycle events grow the client registry
        self.event_scheduler = None
        if event_scheduler is not None:
            self.set_event_scheduler(event_scheduler)

    def set_event_scheduler(self, scheduler) -> None:
        """Attach an event scheduler before the first round -- lets callers
        inspect the built registry first (e.g. pick the high-rank clients
        as the straggler set) and then wire the scenario."""
        assert self.round_engine == "async", self.round_engine
        assert self.round_idx == 0 and not self._pending, \
            "attach the event scheduler before running rounds"
        self.event_scheduler = scheduler
        scheduler.bind_join_hook(self._apply_join)

    def _apply_join(self, ev) -> None:
        """Apply a "join" lifecycle event to the registry. Idempotent: the
        event declares the id it creates, so replaying the lifecycle prefix
        after a checkpoint restore cannot double-register."""
        if ev.client < self.registry.num_clients:
            return                      # already applied (restore replay)
        assert ev.client == self.registry.num_clients, \
            (ev.client, self.registry.num_clients)
        assert ev.rank is not None and ev.shard is not None, ev
        self.registry.add_client(ev.rank, ev.shard)

    # -- adapter plumbing ---------------------------------------------------

    def _extract_factors(self, lora_tree, rank: int) -> Dict[tuple, tuple]:
        """{adapter_path: (B (…, d_in, r_k), A (…, r_k, d_out))}.

        Model layout: lora_a (…, r_max, in), lora_b (…, out, r_max).
        Paper layout: B = lora_a^T restricted to r_k, A = lora_b^T.
        """
        from repro.core.lora import _is_lora_path
        pairs: Dict[tuple, dict] = {}

        def collect(path, x):
            if x is not None and _is_lora_path(path):
                parent = tuple(str(getattr(p, "key", p)) for p in path[:-1])
                kind = {"lora_a": "a", "lora_b": "b",
                        "lora_m": "m"}[path[-1].key]
                pairs.setdefault(parent, {})[kind] = x
            return x

        jax.tree_util.tree_map_with_path(collect, lora_tree,
                                         is_leaf=lambda x: x is None)
        out = {}
        for parent, ab in pairs.items():
            a_model = ab["a"]           # (…, r_max, in)
            b_model = ab["b"]           # (…, out, r_max)
            b_paper = jnp.swapaxes(a_model, -2, -1)[..., :rank]   # (…, in, r_k)
            a_paper = jnp.swapaxes(b_model, -2, -1)[..., :rank, :]  # (…, r_k, out)
            out[parent] = (b_paper, a_paper)
            if "m" in ab:               # DoRA magnitude: FedAvg'd separately
                out[(parent, "m")] = ab["m"]
        return out

    def _extract_factors_batched(self, lora_tree, rank: int
                                 ) -> Dict[tuple, tuple]:
        """Jitted ``_extract_factors`` (batched engine): the whole tree's
        swapaxes/slice plumbing is one XLA dispatch. Adapter pairs and DoRA
        magnitudes are returned as separate jit outputs because their dict
        keys don't sort against each other (pytree flattening sorts keys)."""
        if self._extract_jit is None:
            def ex(tree, r):
                out = self._extract_factors(tree, r)
                pairs = {k: v for k, v in out.items()
                         if not self._is_magnitude(k)}
                mags = {k: v for k, v in out.items()
                        if self._is_magnitude(k)}
                return pairs, mags
            self._extract_jit = jax.jit(ex, static_argnums=(1,))
        pairs, mags = self._extract_jit(lora_tree, rank)
        return {**pairs, **mags}

    def _write_factors(self, results) -> None:
        """Write aggregated (b_g, a_g) back into the global lora tree.

        ``BucketedUpdate`` (grouped engines) writes in ONE jitted dispatch;
        a per-adapter dict (sequential reference) writes eagerly."""
        if isinstance(results, BucketedUpdate):
            self.global_lora = _write_bucketed(
                self.global_lora,
                tuple((b, a) for _, b, a in results.buckets),
                results.mags,
                bucket_parents=tuple(parents
                                     for parents, _, _ in results.buckets))
        else:
            from repro.core.lora import _is_lora_path

            def rebuild(path, x):
                if x is None or not _is_lora_path(path):
                    return x
                parent = tuple(str(getattr(p, "key", p)) for p in path[:-1])
                if path[-1].key == "lora_m":
                    m_new = results.get((parent, "m"))
                    return x if m_new is None else m_new.astype(x.dtype)
                b_g, a_g = results[parent]
                if path[-1].key == "lora_a":
                    return jnp.swapaxes(b_g, -2, -1).astype(x.dtype)
                return jnp.swapaxes(a_g, -2, -1).astype(x.dtype)

            self.global_lora = jax.tree_util.tree_map_with_path(
                rebuild, self.global_lora, is_leaf=lambda x: x is None)
        # round landing: bump the serving adapter version and notify
        # subscribers (AdapterStore hot-swap) with the new global factors.
        # Hooks degrade to skip-and-warn: a run whose adapters are not
        # servable (DoRA magnitudes rejected by the AdapterStore, non-LoRA
        # variants refused by the serving engine) must not take down the
        # round loop from inside its own landing notification.
        self.adapter_version += 1
        for hook in self._post_aggregate_hooks:
            try:
                hook(self.adapter_version, self.global_lora)
            except Exception as e:  # noqa: BLE001 -- hooks are best-effort
                warnings.warn(
                    f"post-aggregate hook {hook!r} failed at adapter "
                    f"version {self.adapter_version} ({e}); skipping -- "
                    "the round loop continues, the subscriber keeps its "
                    "previous snapshot", RuntimeWarning, stacklevel=2)

    def add_post_aggregate_hook(self, hook) -> None:
        """Register ``hook(adapter_version, global_lora)`` to fire at every
        aggregation landing, across ALL round engines (the single choke
        point is ``_write_factors``)."""
        self._post_aggregate_hooks.append(hook)

    def _merge_flora_delta(self, deltas: Dict[tuple, jnp.ndarray]) -> None:
        """FLoRA: fold dW into the base dense weights (cold-start restart)."""
        def apply(path, x):
            if x is None:
                return x
            key = getattr(path[-1], "key", None)
            if key != "w":
                return x
            parent = tuple(str(getattr(p, "key", p)) for p in path[:-1])
            if parent in deltas:
                return (x.astype(jnp.float32)
                        + deltas[parent].astype(jnp.float32)).astype(x.dtype)
            return x

        self.base = jax.tree_util.tree_map_with_path(
            apply, self.base, is_leaf=lambda x: x is None)

    # -- local training (both engines) --------------------------------------

    def _train_sequential(self, client_batches, ranks, lr, clients):
        """Reference path: one ``trainer.train`` call per sampled client."""
        client_factors: List[Dict[tuple, tuple]] = []
        losses = []
        for batches, rank, cid in zip(client_batches, ranks, clients):
            trained, metrics = self.trainer.train(
                self.base, self.global_lora, rank, batches, lr)
            factors = self._extract_factors(trained, rank)
            if self.transport is not None:
                factors = self.transport.encode_client(cid, factors)
            client_factors.append(factors)
            losses.append(float(metrics.get("loss", jnp.nan)))
        return client_factors, losses

    def _train_grouped(self, client_batches, ranks, lr, clients, *,
                       sharded: bool):
        """Batched AND sharded engines: ONE vmapped, jitted multi-client
        dispatch per step-count group trains every sampled client
        regardless of rank (``train_group_masked``: factors zero-masked
        beyond each client's rank, per-client lora scale vmapped -- exact,
        see client.py). Step counts are homogeneous in the common case.
        Factors stay stacked over each group's client axis -- the grouped
        aggregation consumes them stacked, so nothing is unstacked per
        client.

        ``sharded=True`` additionally pads each group's client axis to a
        multiple of the mesh shard count with GHOST clients and partitions
        it round-robin across shards (stacked position j -> shard j % S, so
        ghosts spread evenly instead of piling onto the last shard) before
        dispatching through the shard_map runner. Ghosts clone the group's
        first member's batches -- any finite data works because their
        aggregation weight is identically zero (n_k=0 => omega=0), and
        cloning keeps their losses/gradients finite so 0-weighted NaNs can
        never poison the cross-shard psum.

        Returns (group_factors, loss_parts): group_factors entries are
        (members, r_max, {adapter_path: stacked factors}) where members[j]
        is the sampled-client index at stacked position j, or -1 for a
        ghost; loss_parts entries are (members, loss handle) with the loss
        handle an UNMATERIALIZED jax array (or None for a zero-step group)
        -- nothing in this function blocks on device execution, so the
        async engine can buffer the whole round as in-flight handles
        (``_losses_from_parts`` materializes them at finalize time)."""
        groups: Dict[int, List[int]] = {}
        for i, batches in enumerate(client_batches):
            groups.setdefault(len(batches), []).append(i)
        host_cost.tick("server/train_groups", len(groups))
        group_factors = []
        loss_parts = []
        r_max = self.lora_cfg.r_max
        r_min = min(self.lora_cfg.rank_levels)
        for steps, idxs in sorted(groups.items()):
            members = idxs
            host_cost.tick("server/train_stack_steps", steps * len(idxs))
            if sharded:
                n_shards = self.mesh.shape["data"]
                members = idxs + [-1] * ((-len(idxs)) % n_shards)
                # round-robin -> contiguous shard blocks: shard s's block
                # holds stacked positions {j : j % S == s} of the original
                # order
                order = sorted(range(len(members)),
                               key=lambda j: (j % n_shards, j // n_shards))
                members = [members[j] for j in order]
            g_ranks = [ranks[i] if i >= 0 else r_min for i in members]
            # stack on the HOST (numpy) -- an eager jnp.stack would
            # synchronize with in-flight device work on the CPU client and
            # break the async engine's overlap; the training dispatch
            # transfers the stacked batches
            stacks = [
                jax.tree.map(lambda *xs: _stack_steps(xs),
                             *[client_batches[i if i >= 0 else idxs[0]][t]
                               for i in members])
                for t in range(steps)]
            lora_g, loss_g = self.trainer.dispatch_group_masked(
                self.base, self.global_lora, g_ranks, stacks, lr,
                mesh=self.mesh if sharded else None)
            # masked training leaves zeros beyond each client's rank, which
            # is exactly the zero-padded (G, ..., d, r_max) stack layout the
            # grouped aggregation expects; _extract_factors is shape-
            # agnostic in the leading axes
            factors = self._extract_factors_batched(lora_g, r_max)
            if self.transport is not None:
                # compress the group's upload: error-feedback accumulators
                # are keyed by GLOBAL client id (the same client carries
                # its residual across rounds); ghosts (-1) get zeros in and
                # their residual out is discarded. Quantization preserves
                # the zero columns beyond each client's rank (absmax 0 ->
                # scale 0), so the grouped stack layout is unchanged.
                gids = [clients[i] if i >= 0 else -1 for i in members]
                factors = self.transport.encode_group(gids, factors)
            group_factors.append((members, r_max, factors))
            loss_parts.append((members, loss_g))
        return group_factors, loss_parts

    @staticmethod
    def _losses_from_parts(loss_parts, num_clients: int) -> List[float]:
        """Materialize per-group loss handles into sampled-client-order
        floats (ghost losses dropped). The one host transfer of the train
        stage, deferred to round finalize so pipelined rounds never block
        on it early."""
        losses = [float("nan")] * num_clients
        for members, loss_g in loss_parts:
            arr = (np.asarray(loss_g) if loss_g is not None
                   else np.full((len(members),), np.nan))
            for j, i in enumerate(members):
                if i >= 0:
                    losses[i] = float(arr[j])
        return losses

    # -- aggregation (both engines) ------------------------------------------

    @staticmethod
    def _is_magnitude(parent) -> bool:
        return (isinstance(parent, tuple) and len(parent) == 2
                and parent[1] == "m")

    def _aggregate_magnitudes(self, client_factors, parents, w_clients,
                              results) -> None:
        """DoRA magnitudes: weighted FedAvg (not rank-structured)."""
        for parent in parents:
            ms = jnp.stack([cf[parent] for cf in client_factors])
            results[parent] = weighted_avg(ms, w_clients)

    def _aggregate_sequential(self, client_factors, ranks, n_k):
        """Reference path: one ``aggregate_layer`` call per adapter."""
        results, deltas, sigmas = {}, {}, {}
        global_factors = self._extract_factors(self.global_lora,
                                               self.lora_cfg.r_max)
        w_clients = jnp.asarray(np.asarray(n_k) / np.sum(n_k))
        parents = list(client_factors[0])
        self._aggregate_magnitudes(
            client_factors, [p for p in parents if self._is_magnitude(p)],
            w_clients, results)
        for parent in parents:
            if self._is_magnitude(parent):
                continue
            factors = [cf[parent] for cf in client_factors]
            g_b, g_a = global_factors[parent]
            res = self.aggregator.aggregate_layer(factors, ranks, n_k,
                                                  global_b=g_b, global_a=g_a)
            self._record_result(parent, (g_b, g_a), res, results, deltas,
                                sigmas)
        return results, deltas, self._sigma_probe(parents, sigmas)

    def _aggregate_grouped(self, group_factors, ranks, n_k, *,
                           sharded: bool, staleness=None, present=None):
        """Batched, sharded AND async engines: bucket adapters by factor
        shape and aggregate each bucket with ONE jitted call.

        The client axis is assembled group-by-group (clients stay in rank-
        group order, with ranks/n_k permuted to match), so each bucket needs
        only one pad + one concatenate per training group instead of
        per-client restacking. ``sharded=True`` routes each bucket through
        ``aggregate_grouped_sharded`` (client axis left sharded over the
        mesh, one psum per bucket); ghost members (-1) ride along with
        n_k=0 so every weight they receive -- including the DoRA magnitude
        FedAvg weights -- is exactly zero.

        ``staleness``: per-sampled-client aggregation ages (async engine);
        folded into every n_k-derived weight via
        ``aggregation.staleness_discount`` with ``self.staleness_gamma``.
        ``present``: per-sampled-client participation mask (event-driven
        engine): not-yet-arrived clients get exactly zero weight everywhere
        -- including the DoRA magnitude FedAvg -- and are excluded from
        membership-derived weighting (``Aggregator._present_weight_args``).
        Server momentum, when configured, applies per bucket in ONE jitted
        dispatch (``FactoredServerMomentum.apply_bucket``) instead of an
        unjitted per-adapter host loop. Returns a ``BucketedUpdate`` (plus
        flora deltas and the lazy sigma probe) -- per-adapter unstacking is
        deferred into the jitted write-back."""
        update = BucketedUpdate()
        deltas = {}
        sigma_probe = None
        r_max = self.lora_cfg.r_max
        r_min = min(self.lora_cfg.rank_levels)
        gamma = self.staleness_gamma
        global_factors = self._extract_factors_batched(self.global_lora,
                                                       r_max)
        # group-order permutation of the client axis (ghosts: rank r_min,
        # zero samples, zero staleness, never present)
        members = [i for mem, _, _ in group_factors for i in mem]
        host_cost.tick("server/agg_members", len(members))
        ranks_o, n_k_o, stal_o, pres_o = flatten_cohort(
            members, ranks, n_k, staleness, present, r_min)
        w_clients = jnp.asarray(cohort_weights(n_k_o, stal_o, pres_o, gamma))
        parents = list(group_factors[0][2])
        for parent in [p for p in parents if self._is_magnitude(p)]:
            # DoRA magnitudes: weighted FedAvg (not rank-structured)
            ms = jnp.concatenate([fg[parent] for _, _, fg in group_factors])
            update.mags[parent] = weighted_avg(ms, w_clients)
        buckets: Dict[tuple, List] = {}
        for parent in parents:
            if self._is_magnitude(parent):
                continue
            gb0, ga0 = global_factors[parent]
            buckets.setdefault((gb0.shape, ga0.shape), []).append(parent)
        host_cost.tick("server/agg_buckets", len(buckets))
        for group in buckets.values():
            args = (
                [[fg[p][0] for p in group] for _, _, fg in group_factors],
                [[fg[p][1] for p in group] for _, _, fg in group_factors],
                ranks_o, n_k_o)
            kwargs = dict(
                global_bs=[global_factors[p][0] for p in group],
                global_as=[global_factors[p][1] for p in group],
                staleness=stal_o, gamma=gamma, present=pres_o)
            if sharded:
                res = self.aggregator.aggregate_grouped_sharded(
                    *args, self.mesh, **kwargs)
            else:
                res = self.aggregator.aggregate_grouped(*args, **kwargs)
            if self.server_momentum is not None:
                # whole-bucket momentum: one jitted stacked-QR-SVD dispatch
                b_new, a_new = self.server_momentum.apply_bucket(
                    tuple(group), [global_factors[p] for p in group],
                    res.b_g, res.a_g, r_max)
            else:
                b_new, a_new = res.b_g, res.a_g
            update.buckets.append((tuple(group), b_new, a_new))
            if res.merge_delta is not None:
                for j, parent in enumerate(group):
                    deltas[parent] = res.merge_delta[j]
            if res.sigma is not None and sigma_probe is None:
                # energy probe = the FIRST adapter's spectrum (bucket order
                # preserves first-seen parent order). Kept as the UNSLICED
                # bucket stack handle -- even an eager slice would
                # synchronize with the device; flush_stats slices/averages
                # in numpy after the one d2h transfer.
                sigma_probe = ("bucket_stack", res.sigma)
        return update, deltas, sigma_probe

    def _record_result(self, parent, global_pair, res, results, deltas,
                       sigmas) -> None:
        if self.server_momentum is not None:
            results[parent] = self.server_momentum.apply(
                parent, global_pair, (res.b_g, res.a_g), self.lora_cfg.r_max)
        else:
            results[parent] = (res.b_g, res.a_g)
        if res.merge_delta is not None:
            deltas[parent] = res.merge_delta
        if res.sigma is not None:
            sigmas[parent] = res.sigma

    @staticmethod
    def _sigma_probe(parents, sigmas) -> Optional[jnp.ndarray]:
        """First adapter's spectrum (layer-averaged) as the energy probe.

        Returned UNMATERIALIZED (a lazy jax array): reading it is the round's
        device-sync point, so it happens at stat-materialization time, not
        inside the aggregate stage."""
        for parent in parents:
            if parent in sigmas:
                sig = jnp.asarray(sigmas[parent])
                return sig if sig.ndim == 1 else sig.mean(axis=0)
        return None

    # -- the round: plan -> train -> aggregate stages ------------------------

    def _now(self) -> float:
        """The round-stat clock. With an event scheduler this is the
        VIRTUAL clock -- the event-driven round path must not read the
        host clock (runs would stop being a pure function of the seed;
        the rng/determinism lint bans ``time.time()`` there), so its
        ``wall_time_s`` is virtual seconds. The wall-clock engines keep
        real wall time."""
        if self.event_scheduler is not None:
            return self.event_scheduler.clock.now
        return time.time()  # host-clock: ok (wall-clock engines only)

    @property
    def _sharded_dispatch(self) -> bool:
        """Whether the grouped stages run through the shard_map dispatches
        (the sharded engine always; the async engine iff given a mesh)."""
        return (self.round_engine == "sharded"
                or (self.round_engine == "async" and self.mesh is not None))

    def _plan_round(self) -> RoundPlan:
        """PLAN stage: sample clients/ranks/n_k/lr and draw data batches.

        Consumes the rng in strict round order (one ``sample_round`` + one
        ``batch_fn`` per client), so the sampling stream is identical across
        engines AND pipeline depths -- a resumed or re-depth'd run sees the
        same clients.

        With an event scheduler the sample is drawn from the ACTIVE client
        pool (dropouts excluded, joined clients included); scenarios with
        no lifecycle events keep ``active=None`` and therefore the exact
        historical rng stream."""
        fl = self.fl
        active = (None if self.event_scheduler is None else
                  self.event_scheduler.active_clients(
                      self.registry.num_clients))
        clients = self.registry.sample_round(fl.clients_per_round,
                                             self.rng,
                                             active=active).tolist()
        host_cost.tick("server/plan_clients", len(clients))
        plan = RoundPlan(
            round=self._plan_idx, version=self.round_idx, clients=clients,
            ranks=[int(self.registry.ranks[c]) for c in clients],
            n_k=[max(self.registry.num_samples(c), 1) for c in clients],
            lr=self.schedule(self._plan_idx),
            client_batches=[self.batch_fn(cid, self.rng) for cid in clients])
        self._plan_idx += 1
        return plan

    def _train_stage(self, plan: RoundPlan) -> None:
        """TRAIN stage: dispatch the plan's local training. Grouped engines
        are non-blocking (jax handles stay enqueued); the sequential
        reference trains eagerly."""
        if self.round_engine == "sequential":
            plan.client_factors, plan.losses = self._train_sequential(
                plan.client_batches, plan.ranks, plan.lr, plan.clients)
        else:
            plan.group_factors, plan.loss_parts = self._train_grouped(
                plan.client_batches, plan.ranks, plan.lr, plan.clients,
                sharded=self._sharded_dispatch)
        plan.client_batches = None     # free the host-side batch copies

    def _aggregate_stage(self, plan: RoundPlan, staleness: int = 0):
        """AGGREGATE stage: bucketed aggregation + SVD realloc (+ bucketed
        server momentum) of one trained plan against the CURRENT global
        adapters, discounting by the plan's staleness."""
        if self.round_engine == "sequential":
            return self._aggregate_sequential(plan.client_factors,
                                              plan.ranks, plan.n_k)
        return self._aggregate_grouped(
            plan.group_factors, plan.ranks, plan.n_k,
            sharded=self._sharded_dispatch,
            staleness=[staleness] * len(plan.clients))

    def _finalize_round(self, plan: RoundPlan, results, deltas, sigma_probe,
                        t0: float) -> RoundStats:
        """Write back the aggregate (``results=None`` on async buffer-fill
        rounds: the global model is unchanged), record energy/stats,
        advance the round counter.

        All host sync points (loss materialization, sigma probe) are
        deferred through the stat queue: the synchronous engines flush it
        immediately (keep=0 -- identical behavior to before), while the
        async engine keeps up to ``pipeline_depth - 1`` rounds' stats as
        unmaterialized handles so the host never waits for the device
        inside the pipelined window. The returned RoundStats object is
        patched IN PLACE when its handles materialize; ``run()``, ``save``
        and ``drain_pending`` flush, so histories read after any of those
        are always complete."""
        if results is not None:
            self._write_factors(results)
        if deltas:
            self._merge_flora_delta(deltas)
        stats = RoundStats(
            round=plan.round, clients=plan.clients, ranks=plan.ranks,
            lr=plan.lr, mean_client_loss=float("nan"),
            sigma_probe=None, wall_time_s=self._now() - t0)
        self.history.append(stats)
        self.round_idx += 1
        self._stat_queue.append((stats, plan, sigma_probe))
        keep = (self.pipeline_depth - 1
                if self.round_engine == "async" else 0)
        self.flush_stats(keep=keep)
        return stats

    @staticmethod
    def _materialize_probe(sigma_probe) -> Optional[np.ndarray]:
        """One d2h transfer + numpy slice/average of a probe handle."""
        if sigma_probe is None:
            return None
        if (isinstance(sigma_probe, tuple)
                and sigma_probe[0] == "bucket_stack"):
            arr = np.asarray(sigma_probe[1])[0]
        else:
            arr = np.asarray(sigma_probe)
        return arr if arr.ndim == 1 else arr.mean(axis=0)

    def flush_stats(self, keep: int = 0) -> None:
        """Materialize queued round stats (oldest first) until at most
        ``keep`` remain pending: loss handles -> mean client loss, sigma
        probe -> energy trace + history entry. The event-driven engine can
        fire several aggregations inside one round's window, so an entry
        may carry a LIST of probe handles -- each is recorded in the energy
        trace; the round's stats keep the last."""
        while len(self._stat_queue) > keep:
            stats, plan, sigma_probe = self._stat_queue.popleft()
            probes = (sigma_probe if isinstance(sigma_probe, list)
                      else [sigma_probe])
            for handle in probes:
                probe = self._materialize_probe(handle)
                if probe is not None:
                    self.energy.record(probe)
                    stats.sigma_probe = probe
            losses = (plan.losses if plan.losses is not None
                      else self._losses_from_parts(plan.loss_parts,
                                                   len(plan.ranks)))
            # nanmean: a zero-batch client trains 0 steps and reports NaN --
            # a per-client condition that must not poison the round stat
            loss_arr = np.asarray(losses, dtype=np.float64)
            stats.mean_client_loss = (
                float(np.nanmean(loss_arr))
                if not np.all(np.isnan(loss_arr)) else float("nan"))

    def run_round(self) -> RoundStats:
        if self.round_engine == "async":
            return self._run_round_async()
        t0 = self._now()
        plan = self._plan_round()
        self._train_stage(plan)
        results, deltas, sigma_probe = self._aggregate_stage(plan)
        return self._finalize_round(plan, results, deltas, sigma_probe, t0)

    def _run_round_async(self) -> RoundStats:
        """One async round: plan + dispatch this round's training
        (non-blocking -- nothing here waits on the device), buffer the
        plan, and run ONE buffered aggregation when ``pipeline_depth``
        plans are pending.

        This is FedBuff-style buffered aggregation on a deterministic
        cadence: the server applies one staleness-discounted aggregation
        per ``pipeline_depth`` training rounds, consuming the whole buffer
        in one bucketed dispatch. Plan age in rounds IS the staleness
        (mixed 0..depth-1 within every aggregation), so
        ``staleness_gamma`` shifts relative weight toward fresher rounds.
        The wins: (a) aggregation + SVD realloc + global write-back +
        momentum amortize over depth rounds (fewer server steps for the
        same training throughput -- measurable even on a serial host), and
        (b) training dispatches never wait for aggregation, so on parallel
        hardware round t+1's local training overlaps the buffered
        aggregation's device time. ``pipeline_depth=1`` aggregates every
        round with zero staleness -- exactly the batched engine.

        Buffer-fill rounds report their training losses; sigma_probe (and
        an energy-trace entry) appears on aggregation rounds only.

        With an ``event_scheduler`` the cadence is replaced by arrival
        events on the virtual clock (``_run_round_event``).
        """
        if self.event_scheduler is not None:
            return self._run_round_event()
        t0 = self._now()
        plan = self._plan_round()
        self._train_stage(plan)
        self._pending.append(plan)
        results, deltas, sigma_probe = None, None, None
        if len(self._pending) >= self.pipeline_depth:
            results, deltas, sigma_probe = self._aggregate_buffer(plan.round)
        return self._finalize_round(plan, results, deltas, sigma_probe, t0)

    # -- event-driven async rounds (DESIGN.md §7) ----------------------------

    def _run_round_event(self) -> RoundStats:
        """One event-driven round: plan + dispatch training at the current
        virtual time, register per-client arrival events, then advance the
        clock one ``round_interval`` processing arrivals / lifecycle events
        in order. Every trigger firing runs ONE buffered aggregation over
        exactly the arrived-but-unaggregated updates (partial cohorts ride
        the ghost zero-weight rule) and applies it immediately, so later
        fires in the same window see the updated global adapters."""
        t0 = self._now()
        sched = self.event_scheduler
        plan = self._plan_round()
        self._train_stage(plan)
        self._pending.append(plan)
        sched.dispatch(plan.round, plan.clients)
        probes = []
        for fire_time in sched.advance_window():
            probe = self._fire_aggregation(fire_time)
            if probe is not None:
                probes.append(probe)
        self._retire_completed()
        stats = self._finalize_round(plan, None, None, probes or None, t0)
        stats.virtual_time = sched.clock.now
        return stats

    def _fire_aggregation(self, fire_time: float):
        """Aggregate every arrived-but-unaggregated client update at one
        trigger firing and apply it to the global adapters. Returns the
        (lazy) sigma probe handle, or None if nothing was buffered."""
        results, deltas, sigma_probe = self._aggregate_arrivals(fire_time)
        if results is None:
            return None
        self._write_factors(results)
        if deltas:
            self._merge_flora_delta(deltas)
        return sigma_probe

    def _aggregate_arrivals(self, fire_time: float):
        """The event-driven buffered aggregation: merge the pending plans
        that have ready (arrived, unconsumed) members into one bucketed
        step -- full factor stacks with a ``present`` mask, so a plan can
        be consumed across several fires, each member exactly once.
        Staleness is arrival-time-derived (``EventScheduler.staleness_of``).
        """
        sched = self.event_scheduler
        ready = sched.take_ready()
        plans = [p for p in self._pending if p.round in ready]
        if not plans:
            return None, None, None
        ranks, n_k, group_factors = self._merge_plan_groups(plans)
        staleness, present = [], []
        for p in plans:
            arrived = ready[p.round]
            for j in range(len(p.clients)):
                present.append(j in arrived)
                staleness.append(
                    sched.staleness_of(fire_time, arrived[j])
                    if j in arrived else 0)
        return self._aggregate_grouped(
            group_factors, ranks, n_k, sharded=self._sharded_dispatch,
            staleness=staleness, present=present)

    def _retire_completed(self) -> None:
        """Drop pending plans whose every member has been aggregated or
        lost to a dropout -- their factor stacks are no longer needed
        (loss handles stay on the stat queue until flushed)."""
        done = set(self.event_scheduler.completed_plans())
        if not done:
            return
        for p in self._pending:
            if p.round in done:
                p.group_factors = None
                self.event_scheduler.forget_plan(p.round)
        self._pending = deque(p for p in self._pending
                              if p.round not in done)

    @staticmethod
    def _merge_plan_groups(plans):
        """Merge pending plans' rank-group factor stacks onto ONE sampled-
        client axis: member indices rebase by each plan's offset (ghosts
        stay -1). The single rebase rule shared by the cadence buffer and
        the event-driven arrival aggregation -- their bit-equivalence
        depends on it."""
        ranks = [r for p in plans for r in p.ranks]
        n_k = [n for p in plans for n in p.n_k]
        group_factors, off = [], 0
        for p in plans:
            group_factors += [
                ([m + off if m >= 0 else -1 for m in mem], r_max, fg)
                for mem, r_max, fg in p.group_factors]
            off += len(p.clients)
        return ranks, n_k, group_factors

    def _aggregate_buffer(self, as_of_round: int):
        """Aggregate EVERY pending plan in one buffered, staleness-
        discounted bucketed step (plan age in rounds = staleness). Member
        indices are offset into the merged sampled-client axis; the merged
        client set runs through the SAME grouped bucket pipeline as a
        single round's."""
        plans = list(self._pending)
        self._pending.clear()
        ranks, n_k, group_factors = self._merge_plan_groups(plans)
        staleness = [as_of_round - p.round
                     for p in plans for _ in p.clients]
        out = self._aggregate_grouped(
            group_factors, ranks, n_k,
            sharded=self._sharded_dispatch, staleness=staleness)
        for p in plans:
            # consumed by the aggregation dispatch; only loss_parts are
            # still needed (stat flush) -- dropping the factor-stack refs
            # caps retained memory at the buffer itself, not depth extra
            # rounds of trained factors riding the stat queue
            p.group_factors = None
        return out

    def drain_pending(self) -> Optional[np.ndarray]:
        """Flush a partially filled aggregation buffer early: run the
        buffered aggregation now instead of waiting for the cadence (e.g.
        before a final evaluation). No new round is recorded -- the
        pending plans' rounds already reported their stats -- but the
        aggregate updates the global model, the energy trace, and the last
        history entry's sigma probe. Returns the probe (None if nothing
        was pending).

        Event-driven engine: the remaining arrival events are played out
        (triggers still fire where due), then whatever is left buffered is
        force-aggregated at the final virtual time -- in-flight updates of
        dropped-out clients stay lost, by design."""
        if self.event_scheduler is not None:
            self.flush_stats()   # queued probes precede the drain's fires
            probe = None
            for fire_time in self.event_scheduler.drain():
                handle = self._fire_aggregation(fire_time)
                p = self._materialize_probe(handle)
                if p is not None:
                    self.energy.record(p)
                    probe = p
            self._retire_completed()
            if probe is not None and self.history:
                self.history[-1].sigma_probe = probe
            return probe
        if not self._pending:
            return None
        as_of = self._pending[-1].round
        results, deltas, sigma_probe = self._aggregate_buffer(as_of)
        self._write_factors(results)
        if deltas:
            self._merge_flora_delta(deltas)
        self.flush_stats()
        probe = self._materialize_probe(sigma_probe)
        if probe is not None:
            self.energy.record(probe)
            if self.history:
                self.history[-1].sigma_probe = probe
        return probe

    def run(self, rounds: Optional[int] = None,
            eval_fn: Optional[Callable] = None,
            eval_every: int = 10) -> List[RoundStats]:
        rounds = rounds if rounds is not None else self.fl.num_rounds
        for _ in range(rounds):
            self.run_round()
            if eval_fn is not None and self.round_idx % eval_every == 0:
                self.flush_stats()      # eval callbacks see complete history
                eval_fn(self)
        self.flush_stats()
        return self.history

    # -- evaluation / state --------------------------------------------------

    def global_params(self):
        return merge_lora(self.base, self.global_lora)

    def evaluate(self, batch: dict) -> dict:
        params = self.global_params()
        _, metrics = self.model.train_loss(params, batch,
                                           lora_rank=self.lora_cfg.r_max)
        return {k: float(v) for k, v in metrics.items()}

    @staticmethod
    def _stats_to_meta(s: RoundStats) -> dict:
        d = dataclasses.asdict(s)
        if d["sigma_probe"] is not None:
            d["sigma_probe"] = np.asarray(d["sigma_probe"]).tolist()
        return d

    @staticmethod
    def _stats_from_meta(d: dict) -> RoundStats:
        d = dict(d)
        if d.get("sigma_probe") is not None:
            d["sigma_probe"] = np.asarray(d["sigma_probe"], np.float32)
        return RoundStats(**d)

    # -- pending-plan (de)serialization: the async engine's in-flight buffer
    #
    # A pending plan's training was dispatched against global adapters that
    # may no longer exist by save time, so re-planning from the rng on
    # restore could NOT reproduce it -- the trained factor stacks themselves
    # are checkpointed (flat arrays, no pytree template needed on load).
    # Key encoding: "g{gi}/P/{adapter path}/b|a" for factor pairs,
    # "g{gi}/M/{adapter path}" for DoRA magnitudes, "g{gi}/loss" for the
    # per-group loss vector. Transport-quantized pairs store payload and
    # scale separately ("bq"/"bs" and "aq"/"as" leaves) so a mid-buffer
    # checkpoint round-trips the COMPRESSED plan bit-exactly (int8 payload
    # + f32 scales) instead of a dequantized approximation.

    @staticmethod
    def _factor_arrays(arrays: Dict[str, np.ndarray], key: str, val,
                       leaf: str) -> None:
        if isinstance(val, QuantFactor) or hasattr(val, "q"):
            arrays[f"{key}/{leaf}q"] = np.asarray(val.q)
            arrays[f"{key}/{leaf}s"] = np.asarray(val.scale)
        else:
            arrays[f"{key}/{leaf}"] = np.asarray(val)

    @staticmethod
    def _plan_arrays(plan: RoundPlan) -> Dict[str, np.ndarray]:
        arrays: Dict[str, np.ndarray] = {}
        for gi, (members, r_max, factors) in enumerate(plan.group_factors):
            for parent, val in factors.items():
                if FederatedLoRA._is_magnitude(parent):
                    arrays[f"g{gi}/M/" + "/".join(parent[0])] = \
                        np.asarray(val)
                else:
                    b, a = val
                    key = f"g{gi}/P/" + "/".join(parent)
                    FederatedLoRA._factor_arrays(arrays, key, b, "b")
                    FederatedLoRA._factor_arrays(arrays, key, a, "a")
        for gi, (_, loss_g) in enumerate(plan.loss_parts):
            if loss_g is not None:
                arrays[f"g{gi}/loss"] = np.asarray(loss_g)
        return arrays

    @staticmethod
    def _plan_meta(plan: RoundPlan) -> dict:
        return {"round": plan.round, "version": plan.version,
                "clients": plan.clients, "ranks": plan.ranks,
                "n_k": plan.n_k, "lr": plan.lr,
                "groups": [{"members": list(members), "r_max": r_max}
                           for members, r_max, _ in plan.group_factors]}

    @staticmethod
    def _plan_from_arrays(meta: dict, arrays: Dict[str, np.ndarray]
                          ) -> RoundPlan:
        group_factors, loss_parts = [], []
        for gi, g in enumerate(meta["groups"]):
            factors: Dict[tuple, object] = {}
            prefix = f"g{gi}/"
            pairs: Dict[tuple, dict] = {}
            for key, arr in arrays.items():
                if not key.startswith(prefix):
                    continue
                rest = key[len(prefix):]
                if rest.startswith("M/"):
                    factors[(tuple(rest[2:].split("/")), "m")] = \
                        jnp.asarray(arr)
                elif rest.startswith("P/"):
                    path, leaf = rest[2:].rsplit("/", 1)
                    pairs.setdefault(tuple(path.split("/")), {})[leaf] = \
                        jnp.asarray(arr)
            for parent, ba in pairs.items():
                factors[parent] = (
                    QuantFactor(ba["bq"], ba["bs"]) if "bq" in ba
                    else ba["b"],
                    QuantFactor(ba["aq"], ba["as"]) if "aq" in ba
                    else ba["a"])
            members = [int(m) for m in g["members"]]
            group_factors.append((members, int(g["r_max"]), factors))
            loss = arrays.get(prefix + "loss")
            loss_parts.append((members,
                               None if loss is None else jnp.asarray(loss)))
        return RoundPlan(
            round=int(meta["round"]), version=int(meta["version"]),
            clients=[int(c) for c in meta["clients"]],
            ranks=[int(r) for r in meta["ranks"]],
            n_k=[int(n) for n in meta["n_k"]], lr=float(meta["lr"]),
            group_factors=group_factors, loss_parts=loss_parts)

    def save(self, path: str) -> None:
        from repro.checkpointing.checkpoint import save_flat, save_pytree
        self.flush_stats()      # checkpointed history/energy are complete
        save_pytree(path + ".base", self.base)
        # full server state rides in the metadata: rng stream, energy trace,
        # and round history -- without them a resumed run samples a
        # DIFFERENT client sequence and judges collapse on a truncated trace
        meta = {"round": self.round_idx,
                "adapter_version": self.adapter_version,
                "method": self.fl.aggregator,
                "rng_state": self.rng.bit_generator.state,
                "energy": self.energy.state_dict(),
                "history": [self._stats_to_meta(s) for s in self.history]}
        # server momentum: without its (B_m, A_m) pairs a resumed
        # beta > 0 run silently restarts momentum from zero and diverges
        # from the uninterrupted run
        if self.server_momentum is not None and self.server_momentum.state:
            save_flat(path + ".momentum",
                      self.server_momentum.state_arrays())
            meta["momentum"] = True
        # compressed transport: per-client error-feedback accumulators ride
        # as flat f32 arrays (bit-exact) -- without them a resumed run
        # re-quantizes from zero residual and diverges from the
        # uninterrupted compressed run
        if self.transport is not None:
            save_flat(path + ".transport", self.transport.state_arrays())
            meta["transport"] = True
        # async engine: dispatched-but-unaggregated plans ride along so a
        # resumed run aggregates the SAME trained factors the uninterrupted
        # run would have
        if self._pending:
            meta["pending"] = [self._plan_meta(p) for p in self._pending]
            for i, plan in enumerate(self._pending):
                save_flat(path + f".pending{i}", self._plan_arrays(plan))
        # event-driven engine: the virtual clock, the in-flight arrival
        # queue, per-plan arrival/consumption bookkeeping and the latency
        # models' rng streams -- without them a resumed run re-draws
        # latencies and fires triggers at different virtual times
        if self.event_scheduler is not None:
            meta["events"] = self.event_scheduler.state_dict()
        save_pytree(path + ".lora", self.global_lora, metadata=meta)

    def restore(self, path: str) -> None:
        from repro.checkpointing.checkpoint import (load_flat, load_metadata,
                                                    load_pytree)
        self.base = load_pytree(path + ".base", self.base)
        self.global_lora = load_pytree(path + ".lora", self.global_lora)
        # in-flight state always resets to the CHECKPOINT's -- restoring
        # onto a server that has already run rounds (a mid-experiment
        # rollback) must not leak its pre-restore stat handles, pending
        # plans, or momentum into the restored run
        self._stat_queue.clear()
        self._pending.clear()
        if self.server_momentum is not None:
            self.server_momentum.state = None
        if self.transport is not None:
            self.transport.reset()
        meta = load_metadata(path + ".lora")
        if meta:
            self.round_idx = meta.get("round", self.round_idx)
            self.adapter_version = meta.get("adapter_version",
                                            self.adapter_version)
            if meta.get("rng_state") is not None:
                # restore IN PLACE on the server's seeded stream: no fresh
                # unseeded generator is ever constructed on the round path
                # (the checkpointed state overwrites whatever the stream
                # has drawn, which is the whole point of restore)
                self.rng.bit_generator.state = meta["rng_state"]
            if meta.get("energy") is not None:
                self.energy = EnergyTrace.from_state(meta["energy"])
            if meta.get("history") is not None:
                self.history = [self._stats_from_meta(d)
                                for d in meta["history"]]
            if meta.get("momentum") and self.server_momentum is not None:
                self.server_momentum.load_state_arrays(
                    load_flat(path + ".momentum"))
            if self.transport is not None:
                if meta.get("transport"):
                    self.transport.load_state_arrays(
                        load_flat(path + ".transport"))
                else:
                    # back-compat: a checkpoint written before the
                    # compressed transport existed carries no accumulator
                    # state -- resume with zero residuals instead of
                    # KeyError'ing (the telescoping restarts at e_0 = 0)
                    warnings.warn(
                        "checkpoint predates the compressed update "
                        "transport; error-feedback accumulators "
                        "initialize to zero", RuntimeWarning,
                        stacklevel=2)
            for i, pm in enumerate(meta.get("pending") or []):
                self._pending.append(self._plan_from_arrays(
                    pm, load_flat(path + f".pending{i}")))
            if self.event_scheduler is not None:
                # resets to the CHECKPOINT's event state (pristine when the
                # checkpoint was not event-driven); replays applied "join"
                # events so the registry matches the restored round
                self.event_scheduler.load_state_dict(meta.get("events"))
            else:
                # an event-driven checkpoint resumed without a scheduler
                # would re-draw latencies and fire on the wrong cadence --
                # refuse instead of silently diverging
                assert meta.get("events") is None, \
                    ("checkpoint carries event-scheduler state; attach an "
                     "EventScheduler before restore()")
        # pending plans belong to ALREADY-COUNTED rounds (the buffered-
        # aggregation cadence), so planning resumes at round_idx itself
        self._plan_idx = self.round_idx
