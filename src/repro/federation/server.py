"""Federated server: Algorithm 1 round loop with pluggable aggregation.

Per round: uniform client sampling -> broadcast (rank-truncated adapters) ->
parallel local training -> rank-partitioned (or baseline) aggregation ->
SVD reallocation -> energy bookkeeping. The server state is checkpointable
and the whole loop is architecture-agnostic: it sees only adapter factor
trees from ``repro.core.lora``.

Two round engines (DESIGN.md "Batched round engine"):

* ``round_engine="batched"`` (default): ALL sampled clients train as ONE
  vmapped, jitted multi-client step over stacked LoRA trees -- each
  client's factors rank-masked and its lora scale vmapped, which is exact
  (client.py) -- and aggregation stacks every same-shape adapter into one
  (M, P, ..., d, r) bucket and runs one jitted weighted-contraction +
  batched QR/SVD realloc per bucket (the "kernel" backend lowers a bucket
  through a single layer-batched Pallas grid).
* ``round_engine="sequential"``: the original per-client / per-adapter
  reference loop, kept for bit-level comparison (tests assert the two match
  to float tolerance) and for debugging.

* ``round_engine="sharded"`` (DESIGN.md §5): the batched engine's
  dispatches as shard_map programs over a mesh's ``data`` axis. Sampled
  clients are partitioned round-robin across shards (padded to equal
  per-shard counts with zero-weight ghost clients), local training runs
  the IDENTICAL masked vmapped step body on each shard's client block, and
  the stacked-factor contraction sum_k B_k diag(omega_k) A_k is computed
  as per-shard partials reduced by ONE ``jax.lax.psum`` per bucket before
  the unchanged SVD reallocation (launch/fl_dryrun.py lowers the very same
  program on the mocked production pod mesh).
"""
from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FLConfig, LoRAConfig
from repro.core.aggregation import Aggregator, weighted_avg
from repro.core.energy import EnergyTrace
from repro.core.lora import merge_lora, split_lora
from repro.federation.client import LocalTrainer
from repro.federation.topology import ClientRegistry
from repro.models.transformer import Model
from repro.optim import get_schedule


@dataclass
class RoundStats:
    round: int
    clients: List[int]
    ranks: List[int]
    lr: float
    mean_client_loss: float
    sigma_probe: Optional[np.ndarray]  # singular values of probe adapter
    wall_time_s: float


class FederatedLoRA:
    """End-to-end heterogeneous-rank FedLoRA driver."""

    def __init__(self, model: Model, fl: FLConfig, lora: LoRAConfig,
                 registry: ClientRegistry,
                 batch_fn: Callable[[int, np.random.Generator], list],
                 *, base_params=None, seed: Optional[int] = None,
                 backend: str = "factored",
                 partial_up_to: Optional[int] = None,
                 server_momentum=None,
                 round_engine: str = "batched",
                 mesh=None):
        """batch_fn(client_id, rng) -> list of training batches (dicts).

        ``round_engine="sharded"`` runs the batched engine's dispatches as
        shard_map programs over ``mesh``'s ``data`` axis (defaults to a
        1-D mesh over every visible device, ``launch/mesh.py::make_fl_mesh``).
        """
        assert round_engine in ("batched", "sequential", "sharded"), \
            round_engine
        self.round_engine = round_engine
        if round_engine == "sharded" and mesh is None:
            from repro.launch.mesh import make_fl_mesh
            mesh = make_fl_mesh()
        if mesh is not None:
            assert "data" in mesh.axis_names, mesh.axis_names
        self.mesh = mesh
        self.model = model
        self.fl = fl
        self.lora_cfg = lora
        self.registry = registry
        self.batch_fn = batch_fn
        self.rng = np.random.default_rng(fl.seed if seed is None else seed)
        params = base_params if base_params is not None else model.init(
            jax.random.PRNGKey(fl.seed))
        self.base, self.global_lora = split_lora(params)
        self.trainer = LocalTrainer(model, weight_decay=fl.weight_decay,
                                    freeze_a=(fl.aggregator == "ffa"))
        self.server_momentum = server_momentum  # FactoredServerMomentum|None
        self.aggregator = Aggregator(fl.aggregator, lora.rank_levels,
                                     backend=backend,
                                     partial_up_to=partial_up_to)
        self.schedule = get_schedule(fl.lr_schedule, fl.learning_rate,
                                     fl.num_rounds)
        self.round_idx = 0
        self.energy = EnergyTrace(lora.rank_levels)
        self.history: List[RoundStats] = []
        self._extract_jit = None   # lazily-built jitted factor extractor

    # -- adapter plumbing ---------------------------------------------------

    def _extract_factors(self, lora_tree, rank: int) -> Dict[tuple, tuple]:
        """{adapter_path: (B (…, d_in, r_k), A (…, r_k, d_out))}.

        Model layout: lora_a (…, r_max, in), lora_b (…, out, r_max).
        Paper layout: B = lora_a^T restricted to r_k, A = lora_b^T.
        """
        from repro.core.lora import _is_lora_path
        pairs: Dict[tuple, dict] = {}

        def collect(path, x):
            if x is not None and _is_lora_path(path):
                parent = tuple(str(getattr(p, "key", p)) for p in path[:-1])
                kind = {"lora_a": "a", "lora_b": "b",
                        "lora_m": "m"}[path[-1].key]
                pairs.setdefault(parent, {})[kind] = x
            return x

        jax.tree_util.tree_map_with_path(collect, lora_tree,
                                         is_leaf=lambda x: x is None)
        out = {}
        for parent, ab in pairs.items():
            a_model = ab["a"]           # (…, r_max, in)
            b_model = ab["b"]           # (…, out, r_max)
            b_paper = jnp.swapaxes(a_model, -2, -1)[..., :rank]   # (…, in, r_k)
            a_paper = jnp.swapaxes(b_model, -2, -1)[..., :rank, :]  # (…, r_k, out)
            out[parent] = (b_paper, a_paper)
            if "m" in ab:               # DoRA magnitude: FedAvg'd separately
                out[(parent, "m")] = ab["m"]
        return out

    def _extract_factors_batched(self, lora_tree, rank: int
                                 ) -> Dict[tuple, tuple]:
        """Jitted ``_extract_factors`` (batched engine): the whole tree's
        swapaxes/slice plumbing is one XLA dispatch. Adapter pairs and DoRA
        magnitudes are returned as separate jit outputs because their dict
        keys don't sort against each other (pytree flattening sorts keys)."""
        if self._extract_jit is None:
            def ex(tree, r):
                out = self._extract_factors(tree, r)
                pairs = {k: v for k, v in out.items()
                         if not self._is_magnitude(k)}
                mags = {k: v for k, v in out.items()
                        if self._is_magnitude(k)}
                return pairs, mags
            self._extract_jit = jax.jit(ex, static_argnums=(1,))
        pairs, mags = self._extract_jit(lora_tree, rank)
        return {**pairs, **mags}

    def _write_factors(self, results: Dict[tuple, tuple]) -> None:
        """Write aggregated (b_g, a_g) back into the global lora tree."""
        from repro.core.lora import _is_lora_path

        def rebuild(path, x):
            if x is None or not _is_lora_path(path):
                return x
            parent = tuple(str(getattr(p, "key", p)) for p in path[:-1])
            if path[-1].key == "lora_m":
                m_new = results.get((parent, "m"))
                return x if m_new is None else m_new.astype(x.dtype)
            b_g, a_g = results[parent]
            if path[-1].key == "lora_a":
                return jnp.swapaxes(b_g, -2, -1).astype(x.dtype)
            return jnp.swapaxes(a_g, -2, -1).astype(x.dtype)

        self.global_lora = jax.tree_util.tree_map_with_path(
            rebuild, self.global_lora, is_leaf=lambda x: x is None)

    def _merge_flora_delta(self, deltas: Dict[tuple, jnp.ndarray]) -> None:
        """FLoRA: fold dW into the base dense weights (cold-start restart)."""
        def apply(path, x):
            if x is None:
                return x
            key = getattr(path[-1], "key", None)
            if key != "w":
                return x
            parent = tuple(str(getattr(p, "key", p)) for p in path[:-1])
            if parent in deltas:
                return (x.astype(jnp.float32)
                        + deltas[parent].astype(jnp.float32)).astype(x.dtype)
            return x

        self.base = jax.tree_util.tree_map_with_path(
            apply, self.base, is_leaf=lambda x: x is None)

    # -- local training (both engines) --------------------------------------

    def _train_sequential(self, client_batches, ranks, lr):
        """Reference path: one ``trainer.train`` call per sampled client."""
        client_factors: List[Dict[tuple, tuple]] = []
        losses = []
        for batches, rank in zip(client_batches, ranks):
            trained, metrics = self.trainer.train(
                self.base, self.global_lora, rank, batches, lr)
            client_factors.append(self._extract_factors(trained, rank))
            losses.append(float(metrics.get("loss", jnp.nan)))
        return client_factors, losses

    def _train_grouped(self, client_batches, ranks, lr, *, sharded: bool):
        """Batched AND sharded engines: ONE vmapped, jitted multi-client
        dispatch per step-count group trains every sampled client
        regardless of rank (``train_group_masked``: factors zero-masked
        beyond each client's rank, per-client lora scale vmapped -- exact,
        see client.py). Step counts are homogeneous in the common case.
        Factors stay stacked over each group's client axis -- the grouped
        aggregation consumes them stacked, so nothing is unstacked per
        client.

        ``sharded=True`` additionally pads each group's client axis to a
        multiple of the mesh shard count with GHOST clients and partitions
        it round-robin across shards (stacked position j -> shard j % S, so
        ghosts spread evenly instead of piling onto the last shard) before
        dispatching through the shard_map runner. Ghosts clone the group's
        first member's batches -- any finite data works because their
        aggregation weight is identically zero (n_k=0 => omega=0), and
        cloning keeps their losses/gradients finite so 0-weighted NaNs can
        never poison the cross-shard psum.

        Returns (group_factors, losses): group_factors entries are
        (members, r_max, {adapter_path: stacked factors}) where members[j]
        is the sampled-client index at stacked position j, or -1 for a
        ghost; losses in sampled-client order (ghost losses dropped)."""
        groups: Dict[int, List[int]] = {}
        for i, batches in enumerate(client_batches):
            groups.setdefault(len(batches), []).append(i)
        group_factors = []
        losses = [float("nan")] * len(ranks)
        r_max = self.lora_cfg.r_max
        r_min = min(self.lora_cfg.rank_levels)
        for steps, idxs in sorted(groups.items()):
            members = idxs
            if sharded:
                n_shards = self.mesh.shape["data"]
                members = idxs + [-1] * ((-len(idxs)) % n_shards)
                # round-robin -> contiguous shard blocks: shard s's block
                # holds stacked positions {j : j % S == s} of the original
                # order
                order = sorted(range(len(members)),
                               key=lambda j: (j % n_shards, j // n_shards))
                members = [members[j] for j in order]
            g_ranks = [ranks[i] if i >= 0 else r_min for i in members]
            stacks = [
                jax.tree.map(lambda *xs: jnp.stack(xs),
                             *[client_batches[i if i >= 0 else idxs[0]][t]
                               for i in members])
                for t in range(steps)]
            if sharded:
                lora_g, metrics = self.trainer.train_group_masked_sharded(
                    self.base, self.global_lora, g_ranks, stacks, lr,
                    self.mesh)
            else:
                lora_g, metrics = self.trainer.train_group_masked(
                    self.base, self.global_lora, g_ranks, stacks, lr)
            loss_g = np.asarray(metrics.get(
                "loss", jnp.full((len(members),), jnp.nan)))
            # masked training leaves zeros beyond each client's rank, which
            # is exactly the zero-padded (G, ..., d, r_max) stack layout the
            # grouped aggregation expects; _extract_factors is shape-
            # agnostic in the leading axes
            group_factors.append((members, r_max,
                                  self._extract_factors_batched(lora_g,
                                                                r_max)))
            for j, i in enumerate(members):
                if i >= 0:
                    losses[i] = float(loss_g[j])
        return group_factors, losses

    # -- aggregation (both engines) ------------------------------------------

    @staticmethod
    def _is_magnitude(parent) -> bool:
        return (isinstance(parent, tuple) and len(parent) == 2
                and parent[1] == "m")

    def _aggregate_magnitudes(self, client_factors, parents, w_clients,
                              results) -> None:
        """DoRA magnitudes: weighted FedAvg (not rank-structured)."""
        for parent in parents:
            ms = jnp.stack([cf[parent] for cf in client_factors])
            results[parent] = weighted_avg(ms, w_clients)

    def _aggregate_sequential(self, client_factors, ranks, n_k):
        """Reference path: one ``aggregate_layer`` call per adapter."""
        results, deltas, sigmas = {}, {}, {}
        global_factors = self._extract_factors(self.global_lora,
                                               self.lora_cfg.r_max)
        w_clients = jnp.asarray(np.asarray(n_k) / np.sum(n_k))
        parents = list(client_factors[0])
        self._aggregate_magnitudes(
            client_factors, [p for p in parents if self._is_magnitude(p)],
            w_clients, results)
        for parent in parents:
            if self._is_magnitude(parent):
                continue
            factors = [cf[parent] for cf in client_factors]
            g_b, g_a = global_factors[parent]
            res = self.aggregator.aggregate_layer(factors, ranks, n_k,
                                                  global_b=g_b, global_a=g_a)
            self._record_result(parent, (g_b, g_a), res, results, deltas,
                                sigmas)
        return results, deltas, self._sigma_probe(parents, sigmas)

    def _aggregate_grouped(self, group_factors, ranks, n_k, *,
                           sharded: bool):
        """Batched AND sharded engines: bucket adapters by factor shape and
        aggregate each bucket with ONE jitted call.

        The client axis is assembled group-by-group (clients stay in rank-
        group order, with ranks/n_k permuted to match), so each bucket needs
        only one pad + one concatenate per training group instead of
        per-client restacking. ``sharded=True`` routes each bucket through
        ``aggregate_grouped_sharded`` (client axis left sharded over the
        mesh, one psum per bucket); ghost members (-1) ride along with
        n_k=0 so every weight they receive -- including the DoRA magnitude
        FedAvg weights -- is exactly zero."""
        results, deltas, sigmas = {}, {}, {}
        r_max = self.lora_cfg.r_max
        r_min = min(self.lora_cfg.rank_levels)
        global_factors = self._extract_factors_batched(self.global_lora,
                                                       r_max)
        # group-order permutation of the client axis (ghosts: rank r_min,
        # zero samples)
        members = [i for mem, _, _ in group_factors for i in mem]
        ranks_o = [ranks[i] if i >= 0 else r_min for i in members]
        n_k_o = [n_k[i] if i >= 0 else 0 for i in members]
        w_np = np.asarray(n_k_o, dtype=np.float64)
        w_clients = jnp.asarray(w_np / w_np.sum())
        parents = list(group_factors[0][2])
        for parent in [p for p in parents if self._is_magnitude(p)]:
            # DoRA magnitudes: weighted FedAvg (not rank-structured)
            ms = jnp.concatenate([fg[parent] for _, _, fg in group_factors])
            results[parent] = weighted_avg(ms, w_clients)
        buckets: Dict[tuple, List] = {}
        for parent in parents:
            if self._is_magnitude(parent):
                continue
            gb0, ga0 = global_factors[parent]
            buckets.setdefault((gb0.shape, ga0.shape), []).append(parent)
        for group in buckets.values():
            args = (
                [[fg[p][0] for p in group] for _, _, fg in group_factors],
                [[fg[p][1] for p in group] for _, _, fg in group_factors],
                ranks_o, n_k_o)
            kwargs = dict(
                global_bs=[global_factors[p][0] for p in group],
                global_as=[global_factors[p][1] for p in group])
            if sharded:
                res = self.aggregator.aggregate_grouped_sharded(
                    *args, self.mesh, **kwargs)
            else:
                res = self.aggregator.aggregate_grouped(*args, **kwargs)
            for j, parent in enumerate(group):
                res_j = type(res)(
                    res.b_g[j], res.a_g[j],
                    None if res.sigma is None else res.sigma[j],
                    None if res.merge_delta is None else res.merge_delta[j])
                self._record_result(parent, global_factors[parent], res_j,
                                    results, deltas, sigmas)
        return results, deltas, self._sigma_probe(parents, sigmas)

    def _record_result(self, parent, global_pair, res, results, deltas,
                       sigmas) -> None:
        if self.server_momentum is not None:
            results[parent] = self.server_momentum.apply(
                parent, global_pair, (res.b_g, res.a_g), self.lora_cfg.r_max)
        else:
            results[parent] = (res.b_g, res.a_g)
        if res.merge_delta is not None:
            deltas[parent] = res.merge_delta
        if res.sigma is not None:
            sigmas[parent] = res.sigma

    @staticmethod
    def _sigma_probe(parents, sigmas) -> Optional[np.ndarray]:
        """First adapter's spectrum (layer-averaged) as the energy probe."""
        for parent in parents:
            if parent in sigmas:
                sig = np.asarray(sigmas[parent])
                return sig if sig.ndim == 1 else sig.mean(axis=0)
        return None

    # -- the round ----------------------------------------------------------

    def run_round(self) -> RoundStats:
        t0 = time.time()
        fl = self.fl
        m = fl.clients_per_round
        clients = self.registry.sample_round(m, self.rng).tolist()
        ranks = [int(self.registry.ranks[c]) for c in clients]
        n_k = [max(self.registry.num_samples(c), 1) for c in clients]
        lr = self.schedule(self.round_idx)
        # one batch_fn call per client, in sampled order, regardless of
        # engine -- keeps the data rng stream identical across engines
        client_batches = [self.batch_fn(cid, self.rng) for cid in clients]

        if self.round_engine == "sequential":
            client_factors, losses = self._train_sequential(
                client_batches, ranks, lr)
            results, deltas, sigma_probe = self._aggregate_sequential(
                client_factors, ranks, n_k)
        else:
            sharded = self.round_engine == "sharded"
            group_factors, losses = self._train_grouped(
                client_batches, ranks, lr, sharded=sharded)
            results, deltas, sigma_probe = self._aggregate_grouped(
                group_factors, ranks, n_k, sharded=sharded)

        self._write_factors(results)
        if deltas:
            self._merge_flora_delta(deltas)
        if sigma_probe is not None:
            self.energy.record(jnp.asarray(sigma_probe))

        # nanmean: a zero-batch client trains 0 steps and reports NaN --
        # that is a per-client condition and must not poison the round stat
        loss_arr = np.asarray(losses, dtype=np.float64)
        mean_loss = (float(np.nanmean(loss_arr))
                     if not np.all(np.isnan(loss_arr)) else float("nan"))
        stats = RoundStats(
            round=self.round_idx, clients=clients, ranks=ranks, lr=lr,
            mean_client_loss=mean_loss,
            sigma_probe=sigma_probe, wall_time_s=time.time() - t0)
        self.history.append(stats)
        self.round_idx += 1
        return stats

    def run(self, rounds: Optional[int] = None,
            eval_fn: Optional[Callable] = None,
            eval_every: int = 10) -> List[RoundStats]:
        rounds = rounds if rounds is not None else self.fl.num_rounds
        for _ in range(rounds):
            self.run_round()
            if eval_fn is not None and self.round_idx % eval_every == 0:
                eval_fn(self)
        return self.history

    # -- evaluation / state --------------------------------------------------

    def global_params(self):
        return merge_lora(self.base, self.global_lora)

    def evaluate(self, batch: dict) -> dict:
        params = self.global_params()
        _, metrics = self.model.train_loss(params, batch,
                                           lora_rank=self.lora_cfg.r_max)
        return {k: float(v) for k, v in metrics.items()}

    @staticmethod
    def _stats_to_meta(s: RoundStats) -> dict:
        d = dataclasses.asdict(s)
        if d["sigma_probe"] is not None:
            d["sigma_probe"] = np.asarray(d["sigma_probe"]).tolist()
        return d

    @staticmethod
    def _stats_from_meta(d: dict) -> RoundStats:
        d = dict(d)
        if d.get("sigma_probe") is not None:
            d["sigma_probe"] = np.asarray(d["sigma_probe"], np.float32)
        return RoundStats(**d)

    def save(self, path: str) -> None:
        from repro.checkpointing.checkpoint import save_pytree
        save_pytree(path + ".base", self.base)
        # full server state rides in the metadata: rng stream, energy trace,
        # and round history -- without them a resumed run samples a
        # DIFFERENT client sequence and judges collapse on a truncated trace
        save_pytree(path + ".lora", self.global_lora,
                    metadata={"round": self.round_idx,
                              "method": self.fl.aggregator,
                              "rng_state": self.rng.bit_generator.state,
                              "energy": self.energy.state_dict(),
                              "history": [self._stats_to_meta(s)
                                          for s in self.history]})

    def restore(self, path: str) -> None:
        from repro.checkpointing.checkpoint import load_metadata, load_pytree
        self.base = load_pytree(path + ".base", self.base)
        self.global_lora = load_pytree(path + ".lora", self.global_lora)
        meta = load_metadata(path + ".lora")
        if meta:
            self.round_idx = meta.get("round", self.round_idx)
            if meta.get("rng_state") is not None:
                rng = np.random.default_rng()
                rng.bit_generator.state = meta["rng_state"]
                self.rng = rng
            if meta.get("energy") is not None:
                self.energy = EnergyTrace.from_state(meta["energy"])
            if meta.get("history") is not None:
                self.history = [self._stats_from_meta(d)
                                for d in meta["history"]]
