"""Reusable end-to-end FedLoRA experiment setup (paper Section 6 proxy).

Builds the synthetic-classification federated task: a reduced ViT-style
encoder (patch-embedding frontend, class logit read from position 0),
non-IID client shards, heterogeneous ranks, and a FederatedLoRA server for
any aggregation method. All the accuracy/energy benchmarks and the
integration tests run through this single harness, mirroring how every
paper experiment shares one training pipeline.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import (ACT_GELU, ATTN_BIDIR, FLConfig,
                                FrontendConfig, LoRAConfig, ModelConfig)
from repro.data import ClusterClassification, batches, make_partition
from repro.federation.server import FederatedLoRA
from repro.federation.topology import ClientRegistry
from repro.models.transformer import Model


def fedvit_config(d_model: int = 128, num_layers: int = 2,
                  num_classes: int = 20, patches: int = 8) -> ModelConfig:
    """Tiny ViT-family encoder for the CPU-scale paper experiments."""
    return ModelConfig(
        name="fedvit-tiny",
        kind="vlm",
        num_layers=num_layers,
        d_model=d_model,
        num_heads=4,
        num_kv_heads=4,
        head_dim=d_model // 4,
        d_ff=d_model * 4,
        vocab_size=num_classes,
        activation=ACT_GELU,
        attn_type=ATTN_BIDIR,
        rope_type="none",
        qkv_bias=True,
        frontend=FrontendConfig(kind="vision", embed_dim=d_model,
                                tokens_per_item=patches),
        lora_targets=("q_proj", "k_proj", "v_proj", "o_proj",
                      "up_proj", "down_proj"),
        source="paper-proxy: ViT-base downscaled for CPU federated runs",
    )


def _to_batch(x: np.ndarray, y: np.ndarray, num_positions: int) -> dict:
    """Classification batch: label read out at position 0.

    Returned as NUMPY arrays deliberately: batch building is the host-side
    data pipeline, and on jax's CPU client any eager device touch (even a
    transfer) synchronizes with in-flight computations. Keeping batches in
    host memory until the training dispatch transfers them is what lets the
    async round engine overlap round t+1's data pipeline with round t's
    device execution."""
    b = x.shape[0]
    targets = np.zeros((b, num_positions), np.int32)
    targets[:, 0] = y
    mask = np.zeros((b, num_positions), np.float32)
    mask[:, 0] = 1.0
    return {"embeds": np.asarray(x, np.float32), "targets": targets,
            "loss_mask": mask}


@dataclass
class FLExperiment:
    server: FederatedLoRA
    model: Model
    test_batch: dict
    registry: ClientRegistry

    def eval_accuracy(self) -> float:
        return self.server.evaluate(self.test_batch)["accuracy"]


def build_experiment(method: str = "raflora", *,
                     fl_overrides: Optional[dict] = None,
                     lora_overrides: Optional[dict] = None,
                     num_classes: int = 20,
                     d_model: int = 128,
                     modes_per_class: int = 4,
                     noise: float = 0.6,
                     samples_per_class: int = 100,
                     batches_per_round: int = 2,
                     backend: str = "factored",
                     partial_up_to: Optional[int] = None,
                     noisy_low_rank_std: float = 0.0,
                     server_momentum_beta: float = 0.0,
                     round_engine: str = "batched",
                     mesh=None,
                     pipeline_depth: int = 1,
                     staleness_gamma: float = 1.0,
                     event_scheduler=None,
                     transport=None,
                     data_seed: int = 0) -> FLExperiment:
    """``event_scheduler``: an ``events.EventScheduler`` switching the
    async engine from the fixed ``pipeline_depth`` cadence to arrival-event
    buffer triggers on the virtual clock (DESIGN.md §7).

    ``transport``: a ``transport.UpdateTransport``/``TransportConfig``
    compressing client factor uploads (int8/bf16 + error feedback,
    DESIGN.md §12); None ships f32."""
    fl = FLConfig(aggregator=method, num_clients=20, participation=0.25,
                  num_rounds=40, local_batch_size=32, learning_rate=2e-3,
                  partition="pathological", dirichlet_alpha=1.0,
                  labels_per_client=max(num_classes // 4, 2))
    if fl_overrides:
        fl = dataclasses.replace(fl, **fl_overrides)
    lora = LoRAConfig(rank_levels=(4, 8, 16, 24, 32),
                      rank_probs=(0.2, 0.2, 0.2, 0.2, 0.2))
    if lora_overrides:
        lora = dataclasses.replace(lora, **lora_overrides)

    data = ClusterClassification(
        num_classes=num_classes, dim=d_model, patches=8,
        modes_per_class=modes_per_class, noise=noise,
        samples_per_class=samples_per_class, seed=data_seed)
    (x_tr, y_tr), (x_te, y_te) = data.train_test_split()
    shards = make_partition(fl.partition, y_tr, fl.num_clients,
                            alpha=fl.dirichlet_alpha,
                            labels_per_client=fl.labels_per_client,
                            seed=fl.seed)
    cfg = fedvit_config(d_model=d_model, num_classes=num_classes,
                        patches=data.patches)
    model = Model(cfg, lora, dtype=jnp.float32, remat=False,
                  block_q=64, block_kv=64)
    registry = ClientRegistry.create(fl, lora, shards)

    # optional: degrade low-rank clients' data (Table 4 extension)
    x_noisy = x_tr
    if noisy_low_rank_std > 0:
        rng = np.random.default_rng(123)
        x_noisy = x_tr.copy()
        min_rank = min(lora.rank_levels)
        for cid in range(fl.num_clients):
            if registry.ranks[cid] == min_rank:
                idx = registry.shards[cid]
                x_noisy[idx] = x_tr[idx] + noisy_low_rank_std * rng.normal(
                    size=x_tr[idx].shape).astype(np.float32)

    def batch_fn(client_id: int, rng: np.random.Generator) -> list:
        idx = registry.shards[client_id]
        xs, ys = x_noisy[idx], y_tr[idx]
        out = []
        for bx, by in batches(xs, ys, fl.local_batch_size, rng,
                              epochs=fl.local_epochs):
            out.append(_to_batch(bx, by, data.patches))
            if len(out) >= batches_per_round:
                break
        return out

    server_momentum = None
    if server_momentum_beta > 0:
        from repro.core.server_opt import FactoredServerMomentum
        server_momentum = FactoredServerMomentum(beta=server_momentum_beta)
    server = FederatedLoRA(model, fl, lora, registry, batch_fn,
                           backend=backend, partial_up_to=partial_up_to,
                           server_momentum=server_momentum,
                           round_engine=round_engine, mesh=mesh,
                           pipeline_depth=pipeline_depth,
                           staleness_gamma=staleness_gamma,
                           event_scheduler=event_scheduler,
                           transport=transport)
    test_batch = _to_batch(x_te[:512], y_te[:512], data.patches)
    return FLExperiment(server=server, model=model, test_batch=test_batch,
                        registry=registry)
