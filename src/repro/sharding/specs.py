"""Sharding rules: param/batch/cache PartitionSpec trees per architecture.

Layout (DESIGN.md §5), axes (pod, data, model) -- pod only in multi-pod:

  batch                over ("pod","data")  ["data" single-pod]
  weights (in, out)    in -> "data" (FSDP), out -> "model" (TP); transposed
                       for output projections so TP contractions psum once
  embedding (V, D)     vocab -> "model"
  MoE experts (E,...)  expert axis -> "model" (expert parallel)
  LoRA factors         big dim -> "model", rank dim replicated (r <= 256)
  KV caches            head_dim (or MLA latent) -> "model", batch sharded
  SSD state            heads -> "model"

All functions return PartitionSpec trees aligned with the corresponding
pytrees; launch/dryrun.py turns them into NamedShardings.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import PartitionSpec as P

from repro.models.transformer import Model

DATA = "data"
MODEL = "model"


def batch_axes(mesh) -> Tuple[str, ...]:
    return ("pod", DATA) if "pod" in mesh.axis_names else (DATA,)


def client_spec(axes: Tuple[str, ...]) -> P:
    """PartitionSpec sharding a LEADING CLIENT axis over ``axes``.

    The federated round's one sharded axis (DESIGN.md §5): factor stacks,
    omega rows, batch stacks and per-client metrics all shard their client
    dimension over the mesh's batch axes -- ``("data",)`` on the live 1-D
    FL mesh, ``("pod", "data")`` on the multi-pod dry run, where the pod
    axis shares the reduction instead of replicating it. The single
    implementation behind ``sharded_grouped_fn``'s in_specs and the
    fl_dryrun lowerings, so the live engine and the dry run can never
    drift apart on the client layout.
    """
    axes = tuple(axes)
    return P(axes if len(axes) > 1 else axes[0])


class RoundEngineSpecs:
    """PartitionSpecs for the sharded federated round engine (DESIGN.md §5).

    The round's client axis is the ONLY sharded axis: sampled clients are
    partitioned round-robin over the mesh's ``data`` axis, while the frozen
    base weights and the broadcast global adapters stay replicated (they are
    identical on every shard, exactly as every client receives the same
    global adapter in Algorithm 1 line 4).

      replicated   -- base params / global adapters / scalars
      clients      -- leading client axis sharded (factor stacks, masks,
                      scales, per-client metrics)
      batch_stack  -- step-major (T, M, ...) training batch stacks: client
                      axis is axis 1
    """

    replicated = P()
    clients = P(DATA)
    batch_stack = P(None, DATA)


def round_engine_specs() -> RoundEngineSpecs:
    return RoundEngineSpecs()


def sanitize_spec(spec: P, shape, mesh, rescue: bool = True) -> P:
    """Drop mesh axes whose size does not evenly divide the array dim
    (NamedSharding requires even tiling; e.g. vocab 50280 over 16)."""
    out = []
    for i, entry in enumerate(spec):
        if entry is None:
            out.append(None)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        factor = 1
        for a in axes:
            factor *= mesh.shape[a]
        if i < len(shape) and shape[i] % factor == 0 and shape[i] > 0:
            out.append(entry)
        else:
            # try a prefix of the axes tuple that still divides
            kept = []
            f = 1
            for a in axes:
                if i < len(shape) and shape[i] % (f * mesh.shape[a]) == 0:
                    kept.append(a)
                    f *= mesh.shape[a]
            out.append(tuple(kept) if len(kept) > 1
                       else (kept[0] if kept else None))
    # rescue memory-critical 2D weights: if an axis was dropped entirely,
    # move it to another (currently unsharded, divisible) dim
    if not rescue:
        return P(*out)
    dropped = []
    for i, entry in enumerate(spec):
        if entry is not None and out[i] is None and not isinstance(entry, tuple):
            dropped.append(entry)
    for ax in dropped:
        for i in range(len(out)):
            if out[i] is None and i < len(shape) and shape[i] > 0 \
                    and shape[i] % mesh.shape[ax] == 0 and shape[i] >= 1024:
                out[i] = ax
                break
    return P(*out)


def _spec_for_param(path_keys, shape) -> P:
    """Assign a PartitionSpec from the parameter's path and rank."""
    name = path_keys[-1]
    parent = path_keys[-2] if len(path_keys) >= 2 else ""
    joined = "/".join(path_keys)
    stacked = "layers" in path_keys          # leading layer-stack axis
    lead = (None,) if stacked else ()

    def mk(*axes):
        spec = lead + tuple(axes)
        # trim/pad to the actual rank
        spec = spec[:len(shape)]
        spec = spec + (None,) * (len(shape) - len(spec))
        return P(*spec)

    # --- top level ---
    if name == "embed":
        return P(MODEL, None)
    if "lm_head" in path_keys and name == "w":
        return P(None, MODEL)
    if "frontend_proj" in path_keys:
        return P(None, None) if name == "w" else P(None)
    if name in ("scale", "bias") and "norm" in parent:
        return mk(None)
    # --- lora adapters (any depth) ---
    if name == "lora_a":                      # (r, in)
        return mk(None, MODEL)
    if name == "lora_b":                      # (out, r)
        return mk(MODEL, None)
    # --- moe ---
    if "moe" in path_keys:
        if "router" in path_keys:
            return mk(None, None)
        if name in ("w_up", "w_gate", "w_down"):   # (E, d, f)
            return mk(MODEL, None, None)
        # shared expert mlp falls through to generic dense rules below
    # --- ssm ---
    if name == "conv_w":                      # (K, C)
        return mk(None, MODEL)
    if name == "conv_b":
        return mk(MODEL)
    if name in ("A_log", "D", "dt_bias"):
        return mk(None)
    if "ssm" in path_keys and "norm" in path_keys:
        return mk(MODEL)                      # d_inner-sized scale
    if "in_proj" in path_keys:                # (d, proj_out)
        return mk(DATA, MODEL) if name == "w" else mk(MODEL)
    if "out_proj" in path_keys:               # (d_inner, d)
        return mk(MODEL, DATA) if name == "w" else mk(None)
    # --- attention / mlp dense weights ---
    out_projs = ("o", "down")                 # contract model-sharded dim
    if name == "w":
        if parent in out_projs:
            return mk(MODEL, DATA)
        return mk(DATA, MODEL)                # q,k,v,up,gate,mla projections
    if name == "b":
        return mk(None) if parent in out_projs else mk(MODEL)
    if name in ("scale",):                    # norms anywhere
        return mk(None)
    return mk(*([None] * len(shape)))


def param_specs(model: Model, mesh=None):
    """PartitionSpec tree matching model.param_shapes()."""
    shapes = model.param_shapes()

    def assign(path, leaf):
        keys = [str(getattr(p, "key", getattr(p, "idx", p))) for p in path]
        spec = _spec_for_param(keys, leaf.shape)
        if mesh is not None:
            # gather tables must not be rescue-sharded on the feature dim:
            # XLA SPMD mis-partitions jvp-of-gather on feature-sharded
            # tables (dynamic-slice verifier failure) -> replicate instead
            rescue = keys[-1] != "embed"
            spec = sanitize_spec(spec, leaf.shape, mesh, rescue=rescue)
        return spec

    return jax.tree_util.tree_map_with_path(assign, shapes)


def batch_specs(model: Model, batch_shapes: dict, mesh):
    """Batch inputs: leading (batch) dim over the model's batch axes; rest
    replicated. M-RoPE positions (3, B, L) shard dim 1."""
    baxes = tuple(model.batch_axes)

    def assign(key, leaf):
        if key == "positions" and len(leaf.shape) == 3 and leaf.shape[0] == 3:
            spec = P(None, baxes, None)
        elif len(leaf.shape) == 0:
            return P()
        else:
            spec = P(baxes, *([None] * (len(leaf.shape) - 1)))
        return sanitize_spec(spec, leaf.shape, mesh)

    return {k: assign(k, v) for k, v in batch_shapes.items()}


def cache_specs(model: Model, cache_shapes: dict, mesh):
    """KV caches: batch over (pod,)data; head_dim / MLA latent / SSD heads
    over model. Layer-stack leading axis replicated."""
    baxes = tuple(model.batch_axes)
    if "model" in baxes:   # dp strategy: no model axis left for seq/heads
        def assign_dp(path, leaf):
            nd = len(leaf.shape)
            name = str(getattr(path[-1], "key", ""))
            if name == "len":
                return P()
            spec = [None] * nd
            if nd >= 2:
                spec[1] = baxes   # (L, B, ...) batch dim
            return sanitize_spec(P(*spec), leaf.shape, mesh)
        return jax.tree_util.tree_map_with_path(assign_dp, cache_shapes)

    def assign(path, leaf):
        keys = [str(getattr(p, "key", getattr(p, "idx", p))) for p in path]
        name = keys[-1]
        nd = len(leaf.shape)
        if name == "len":
            return P()
        # flash-decode-style: shard the cache SEQUENCE dim over "model" --
        # per-shard partial softmax stats psum tiny (B, H) tensors instead
        # of hd-contraction psums of full score blocks
        if name in ("k", "v"):          # (L, B, S, KVH, hd)
            return P(None, baxes, MODEL, None, None)
        if name == "ckv":               # (L, B, S, R)
            return P(None, baxes, MODEL, None)
        if name == "krope":             # (L, B, S, rd)
            return P(None, baxes, MODEL, None)
        if name == "ssm":               # (L, B, H, P, N)
            return P(None, baxes, MODEL, None, None)
        if name == "conv":              # (L, B, K-1, C)
            return P(None, baxes, None, MODEL)
        return P(*([None] * nd))

    def assign_s(path, leaf):
        return sanitize_spec(assign(path, leaf), leaf.shape, mesh)

    return jax.tree_util.tree_map_with_path(assign_s, cache_shapes)


def residual_spec(mesh, mode: str = "feature") -> P:
    """Activation/residual sharding: batch over (pod,)data plus

      "feature"  -- d_model over "model": every layer all-gathers features
                    for BOTH attention and MLP (baseline)
      "sequence" -- seq over "model" (sequence parallelism): norms and MLP
                    are token-local; only attention gathers the sequence
                    (§Perf iteration B -- roughly halves per-layer gathers)

    Both keep the scan carry (the remat residual) 1/16 per device.
    """
    if mode == "sequence":
        return P(batch_axes(mesh), MODEL, None)
    return P(batch_axes(mesh), None, MODEL)


def dp_param_specs(model: Model, mesh):
    """§Perf iteration C: DP-dominant layout for small models.

    On a fixed 256-chip mesh, 16-way tensor parallelism of a 2-8B model
    trades tiny per-op matmuls for per-layer activation collectives. This
    layout uses BOTH axes as data parallelism: weights are FSDP-sharded on
    their largest divisible dim over ("data","model") combined, batch over
    ("data","model"), activations replicated per device (1 sequence each).
    Collectives = per-layer weight all-gathers + one LoRA-grad reduction.
    """
    shapes = model.param_shapes()
    both = ("data", "model")
    factor = mesh.shape["data"] * mesh.shape["model"]

    def assign(path, leaf):
        dims = list(leaf.shape)
        # shard the largest dim divisible by the combined factor
        order = sorted(range(len(dims)), key=lambda i: -dims[i])
        for i in order:
            if dims[i] >= 1024 and dims[i] % factor == 0:
                spec = [None] * len(dims)
                spec[i] = both
                return P(*spec)
        # fall back to a single-axis shard
        for ax in ("data", "model"):
            for i in order:
                if dims[i] % mesh.shape[ax] == 0 and dims[i] >= 256:
                    spec = [None] * len(dims)
                    spec[i] = ax
                    return P(*spec)
        return P(*([None] * len(dims)))

    return jax.tree_util.tree_map_with_path(assign, shapes)
