from repro.sharding.specs import (batch_axes, batch_specs, cache_specs,
                                  param_specs, residual_spec)

__all__ = ["batch_axes", "batch_specs", "cache_specs", "param_specs",
           "residual_spec"]
