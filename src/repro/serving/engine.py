"""Batched multi-adapter inference engine (DESIGN.md §11).

``ServingEngine`` runs a fixed number of request SLOTS over one jitted
prefill and one jitted decode program. Per-request adapters enter by LEAF
SUBSTITUTION: the published pages (leading page axis P) are gathered by
the slots' page ids into per-slot factors -- lora_a (P, G, r, in) ->
(G, S, r, in) -- and merged over the base params, so the batched leaves
ride the layer ``lax.scan`` exactly like the training-side factors and
``dense_apply`` dispatches to its per-request branch (the paged Pallas
kernel under ``use_kernel``, the batched einsum oracle otherwise).

Version atomicity: every public engine call captures ``store.published``
EXACTLY ONCE at entry; the whole jitted step runs on that snapshot and its
version is appended to ``version_log``. A hot-swap between two steps is
therefore the only place a version change can land -- no request mixes
versions within one step.

Per-slot KV state: one full-``max_len`` cache allocated up front via
``Model.init_cache`` with a VECTOR ``len`` (one length per slot, the
continuous-batching shape the transformer decode path supports), seeded
path-aware from prefill caches by ``seed_cache`` -- SSM ``conv``/``ssm``
states transfer as-is; attention ``k``/``v``/``ckv``/``krope`` leaves
merge on their sequence axis (ring-scattered when the prompt exceeds the
ring length). This replaces the old shape-matching ``grow`` hack that
silently skipped SSM states and mis-padded coincidental dims.
"""
from __future__ import annotations

from typing import Any, List, Sequence

import jax
import jax.numpy as jnp

from repro.core.lora import merge_lora, split_lora
from repro.serving.adapter_store import AdapterStore

_SEQ_KEYS = ("k", "v", "ckv", "krope")   # per-token cache leaves (seq axis 2)
_STATE_KEYS = ("conv", "ssm")            # positionless SSM states


def _leaf_key(path) -> str:
    return str(getattr(path[-1], "key", path[-1]))


def seed_cache(cache, prefill_caches, prompt_len: int, slot_mask):
    """Merge prefill caches into a full-length cache, path-aware.

    cache: the engine's persistent ``init_cache`` pytree (vector ``len``);
    prefill_caches: ``Model.prefill``'s per-layer caches (seq len =
    prompt_len); slot_mask: (S,) bool -- only masked slots are (re)seeded.

    Leaves are merged BY PATH KEY, not by shape: ``conv``/``ssm`` states
    transfer unchanged, sequence leaves pad (or ring-scatter, when
    prompt_len exceeds the ring length S_c) on axis 2 of their stacked
    (G, S, S_c, ...) layout. A dim coincidentally equal to prompt_len is
    never touched.
    """
    mask = jnp.asarray(slot_mask, bool)

    def merge(path, full, got):
        key = _leaf_key(path)
        if key == "len":
            return jnp.where(mask, jnp.int32(prompt_len), full)
        got = got.astype(full.dtype)
        if key in _SEQ_KEYS:
            s_c = full.shape[2]              # stacked leaves: (G, S, S_c, ..)
            if prompt_len <= s_c:
                pad = [(0, 0)] * got.ndim
                pad[2] = (0, s_c - prompt_len)
                new = jnp.pad(got, pad)
            else:
                # ring discipline: token t lives at slot t % S_c; the last
                # S_c prompt positions land on a permutation of 0..S_c-1
                idx = jnp.arange(prompt_len - s_c, prompt_len) % s_c
                new = jnp.zeros_like(full).at[:, :, idx].set(
                    got[:, :, prompt_len - s_c:])
        elif key in _STATE_KEYS:
            new = got
        else:
            raise ValueError(f"unknown cache leaf {key!r} at {path}")
        sel = mask.reshape((1, -1) + (1,) * (new.ndim - 2))
        return jnp.where(sel, new, full)

    flat = {"layers": cache["layers"], "len": cache["len"]}
    got = {"layers": prefill_caches, "len": cache["len"]}
    return jax.tree_util.tree_map_with_path(merge, flat, got)


class ServingEngine:
    """Fixed-slot multi-tenant engine over a published adapter snapshot."""

    def __init__(self, model, params, store: AdapterStore, *,
                 max_len: int, slots: int):
        if store.published is None:
            raise ValueError("AdapterStore has no published snapshot; "
                             "stage adapters and publish() first")
        if model.lora is not None and model.lora.variant != "lora":
            raise NotImplementedError(
                "serving supports plain LoRA adapters only")
        self.model = model
        self.store = store
        self.max_len = int(max_len)
        self.slots = int(slots)
        self.base, _ = split_lora(params)
        # persistent per-slot state
        self.cache = model.init_cache(self.slots, self.max_len)
        self.cache["len"] = jnp.zeros((self.slots,), jnp.int32)
        self.tokens = jnp.zeros((self.slots,), jnp.int32)
        self.slot_pages = jnp.zeros((self.slots,), jnp.int32)
        self.version_log: List[int] = []     # one snapshot version per step

        def substituted(base, pages, page_ids):
            """Merge page-gathered per-slot factors over the base params."""
            def gather(leaf):
                if leaf is None:
                    return None
                # (P, G, ...) -> (S, G, ...) -> (G, S, ...): the scan strips
                # G and dense_apply sees per-slot (S, ...) batched leaves
                return jnp.moveaxis(leaf[page_ids], 0, 1)
            lora = jax.tree.map(gather, pages,
                                is_leaf=lambda x: x is None)
            return merge_lora(base, lora)

        def prefill_impl(base, pages, page_ids, prompts):
            merged = substituted(base, pages, page_ids)
            logits, caches = model.prefill(merged, {"tokens": prompts})
            next_tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
            return next_tok, caches

        def decode_impl(base, pages, page_ids, tokens, cache, active):
            merged = substituted(base, pages, page_ids)
            logits, new_cache = model.decode_step(
                merged, {"token": tokens[:, None]}, cache)
            next_tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
            # inactive slots are frozen: token, length and SSM states hold
            next_tok = jnp.where(active, next_tok, tokens)
            sel = lambda n, o: jax.tree.map(
                lambda a, b: jnp.where(
                    jnp.reshape(active, (1, -1) + (1,) * (a.ndim - 2)), a, b),
                n, o)
            new_cache["layers"] = sel(new_cache["layers"], cache["layers"])
            new_cache["len"] = jnp.where(active, new_cache["len"],
                                         cache["len"])
            return next_tok, new_cache

        self._prefill = jax.jit(prefill_impl)
        self._decode = jax.jit(decode_impl)

    # -- public steps (one snapshot capture per call) ------------------------

    def admit(self, slot_idx: Sequence[int], prompts,
              adapter_ids: Sequence[Any]) -> jnp.ndarray:
        """Prefill ``prompts`` ((n, L) int32) into slots ``slot_idx`` with
        per-request tenants ``adapter_ids``; returns the first greedy token
        per admitted request. One adapter snapshot for the whole call."""
        snap = self.store.published            # THE capture
        self.version_log.append(snap.version)
        slot_idx = list(slot_idx)
        prompts = jnp.asarray(prompts, jnp.int32)
        n, lp = prompts.shape
        assert len(slot_idx) == n == len(list(adapter_ids))
        # full-width prefill: inactive rows run on zeros and are discarded
        full_prompts = jnp.zeros((self.slots, lp), jnp.int32)
        full_prompts = full_prompts.at[jnp.asarray(slot_idx)].set(prompts)
        new_pages = self.slot_pages.at[jnp.asarray(slot_idx)].set(
            snap.page_ids(adapter_ids))
        next_tok, caches = self._prefill(self.base, snap.pages, new_pages,
                                         full_prompts)
        mask = jnp.zeros((self.slots,), bool).at[jnp.asarray(slot_idx)].set(
            True)
        self.cache = seed_cache(self.cache, caches, lp, mask)
        self.tokens = jnp.where(mask, next_tok, self.tokens)
        self.slot_pages = new_pages
        return next_tok[jnp.asarray(slot_idx)]

    def decode(self, active_mask) -> jnp.ndarray:
        """One greedy decode step for every active slot; returns the (S,)
        token vector. One adapter snapshot for the whole step."""
        snap = self.store.published            # THE capture
        self.version_log.append(snap.version)
        active = jnp.asarray(active_mask, bool)
        self.tokens, self.cache = self._decode(
            self.base, snap.pages, self.slot_pages, self.tokens, self.cache,
            active)
        return self.tokens

    # -- introspection -------------------------------------------------------

    def slot_len(self) -> jnp.ndarray:
        return self.cache["len"]
