"""Multi-tenant adapter serving (DESIGN.md §11).

The deployment counterpart of the federated training stack: a paged
adapter cache with atomic round-landing hot-swap (``AdapterStore``), a
batched multi-adapter inference engine over the paged LoRA kernel
(``ServingEngine``), and a continuous-batching request scheduler on the
virtual-clock machinery (``ContinuousBatcher``).
"""
from repro.serving.adapter_store import AdapterStore, PublishedAdapters
from repro.serving.engine import ServingEngine, seed_cache
from repro.serving.scheduler import ContinuousBatcher, ServeRequest

__all__ = ["AdapterStore", "PublishedAdapters", "ServingEngine",
           "seed_cache", "ContinuousBatcher", "ServeRequest"]
