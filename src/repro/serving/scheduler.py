"""Continuous-batching request scheduler (DESIGN.md §11).

Runs a :class:`ServingEngine` under the federation stack's deterministic
``VirtualClock``/latency-model machinery: requests are admitted into free
slots as they arrive (prefill), every active slot advances one token per
scheduler step (decode), and finished requests are evicted so their slots
recycle immediately -- prefill/decode interleave at step granularity, the
standard continuous-batching discipline.

Timing is VIRTUAL and deterministic: a decode step costs ``step_cost``
plus the slowest active slot's latency draw (one seeded per-tenant stream
each, the same :class:`LatencyModel` family the round engines use), and a
prefill admission adds ``prefill_cost``. Per-request latency percentiles
and token throughput therefore replay bit-identically for a fixed
scenario -- these are the rows ``bench_trend`` gates, with wall-clock
medians reported alongside as context only.

All prompts within one batcher share a prompt length (fixed-shape
prefill; heterogeneous lengths would need left-padding the cache seed,
out of scope here) -- asserted at submit().
"""
from __future__ import annotations

import dataclasses
import zlib
from collections import deque
from typing import Any, Deque, Dict, List, Optional

import numpy as np

from repro.federation.events import LatencyModel, VirtualClock


@dataclasses.dataclass
class ServeRequest:
    """One generation request for tenant ``adapter_id``."""
    rid: Any
    prompt: Any                       # (L,) int token ids
    adapter_id: Any
    max_new_tokens: int = 8
    arrival: float = 0.0              # virtual seconds
    # filled by the batcher
    tokens: List[int] = dataclasses.field(default_factory=list)
    t_admit: Optional[float] = None
    t_first: Optional[float] = None
    t_done: Optional[float] = None


class ContinuousBatcher:
    """Admit/evict request scheduler over a fixed-slot engine."""

    def __init__(self, engine, *, clock: Optional[VirtualClock] = None,
                 latency: Optional[LatencyModel] = None,
                 step_cost: float = 0.01, prefill_cost: float = 0.05,
                 eos_token: Optional[int] = None):
        self.engine = engine
        self.clock = clock or VirtualClock()
        self.latency = latency
        self.step_cost = float(step_cost)
        self.prefill_cost = float(prefill_cost)
        self.eos_token = eos_token
        self.queue: Deque[ServeRequest] = deque()
        self.slots: List[Optional[ServeRequest]] = [None] * engine.slots
        self.done: List[ServeRequest] = []
        self._prompt_len: Optional[int] = None
        self.steps = 0

    # -- intake ---------------------------------------------------------------

    def submit(self, req: ServeRequest) -> None:
        lp = len(req.prompt)
        if self._prompt_len is None:
            self._prompt_len = lp
        assert lp == self._prompt_len, (lp, self._prompt_len)
        self.queue.append(req)

    # -- one scheduler step ---------------------------------------------------

    def step(self) -> None:
        """Admit into free slots, then decode every active slot once."""
        free = [i for i, r in enumerate(self.slots) if r is None]
        admits: List[ServeRequest] = []
        idxs: List[int] = []
        while free and self.queue and self.queue[0].arrival <= self.clock.now:
            req = self.queue.popleft()
            slot = free.pop(0)
            self.slots[slot] = req
            admits.append(req)
            idxs.append(slot)
        cost = 0.0
        if admits:
            first = self.engine.admit(
                idxs, np.stack([np.asarray(r.prompt) for r in admits]),
                [r.adapter_id for r in admits])
            for r, tok in zip(admits, np.asarray(first)):
                r.t_admit = self.clock.now
                r.t_first = self.clock.now   # refined after the charge below
                r.tokens.append(int(tok))
            cost += self.prefill_cost
        active = np.asarray([r is not None for r in self.slots], bool)
        if active.any():
            # skip slots whose request completed with the prefill token
            decode_mask = active.copy()
            for i, r in enumerate(self.slots):
                if r is not None and self._finished(r):
                    decode_mask[i] = False
            if decode_mask.any():
                toks = np.asarray(self.engine.decode(decode_mask))
                for i, r in enumerate(self.slots):
                    if r is not None and decode_mask[i]:
                        r.tokens.append(int(toks[i]))
            cost += self.step_cost
            if self.latency is not None:
                draws = [self.latency.sample(self._client_of(r))
                         for r in self.slots if r is not None]
                cost += max(draws)
        if cost:
            self.clock.advance(self.clock.now + cost)
        for r in admits:
            r.t_first = self.clock.now
        # evict finished requests so their slots recycle next step
        for i, r in enumerate(self.slots):
            if r is not None and self._finished(r):
                r.t_done = self.clock.now
                self.done.append(r)
                self.slots[i] = None
        self.steps += 1

    def _client_of(self, req: ServeRequest) -> int:
        # process-independent (built-in hash() is salted): virtual stats
        # must replay bit-identically across sessions for bench_trend
        aid = req.adapter_id
        return aid if isinstance(aid, int) \
            else zlib.crc32(str(aid).encode()) % (2 ** 31)

    def _finished(self, req: ServeRequest) -> bool:
        if self.eos_token is not None and req.tokens \
                and req.tokens[-1] == self.eos_token:
            return True
        return len(req.tokens) >= req.max_new_tokens

    def run(self, max_steps: int = 10_000) -> None:
        """Step until every submitted request completes."""
        for _ in range(max_steps):
            if not self.queue and all(r is None for r in self.slots):
                return
            if self.queue and not any(self.slots) \
                    and self.queue[0].arrival > self.clock.now:
                self.clock.advance(self.queue[0].arrival)
            self.step()
        raise RuntimeError(f"scheduler did not drain in {max_steps} steps")

    # -- stats ----------------------------------------------------------------

    def stats(self) -> Dict[str, float]:
        """Deterministic virtual-time serving metrics over completed
        requests: token throughput and request-latency percentiles."""
        if not self.done:
            return {"completed": 0}
        lats = np.asarray([r.t_done - r.arrival for r in self.done])
        firsts = np.asarray([r.t_first - r.arrival for r in self.done])
        toks = sum(len(r.tokens) for r in self.done)
        elapsed = max(self.clock.now, 1e-9)
        return {
            "completed": float(len(self.done)),
            "tokens": float(toks),
            "virtual_throughput_tok_per_s": toks / elapsed,
            "virtual_p50_s": float(np.percentile(lats, 50)),
            "virtual_p95_s": float(np.percentile(lats, 95)),
            "virtual_ttft_p50_s": float(np.percentile(firsts, 50)),
            "virtual_elapsed_s": float(self.clock.now),
            "steps": float(self.steps),
        }
