"""Paged adapter cache with atomic, versioned hot-swap (DESIGN.md §11).

``AdapterStore`` holds one LoRA adapter tree per tenant, bucketed by rank
level exactly like the aggregation side buckets clients: every staged
adapter belongs to the rank-level bucket of its true rank, and pages are
packed bucket-by-bucket (ascending rank level, insertion order within a
bucket) so same-rank tenants are contiguous in the page axis. Factors are
stored at ``r_max`` width with omega-style zero columns beyond the true
rank -- zero columns are spectrum-inert, so padded pages apply exactly as
their truncated originals (the same convention the aggregators use).

Publishing is ATOMIC: ``publish()`` packs the staged adapters into an
immutable :class:`PublishedAdapters` snapshot under a strictly monotonic
version and flips one reference. Readers (``ServingEngine``) capture the
snapshot once per decode step, so an in-flight step finishes entirely on
the version it started with and no request ever mixes versions within a
step; the next step observes the new version. (CPython reference
assignment is atomic; there is a single writer -- the federation hook or
the operator -- by construction.)

``bind_server`` attaches the store to a :class:`FederatedLoRA` server's
post-aggregation hook: every round landing (sync engines at round
finalize, async/event engines whenever their buffer fires, including
``drain_pending``) re-stages the designated tenant with the new global
factors and publishes under the server's adapter version.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Mapping, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.lora import _is_lora_path

Pages = Any  # lora-tree-shaped pytree; leaves carry a leading page axis


@dataclasses.dataclass(frozen=True)
class PublishedAdapters:
    """Immutable snapshot of the packed adapter pages.

    ``pages`` mirrors the model's lora tree (None at non-lora leaves);
    every array leaf carries a leading page axis P: lora_a (P, ..., r_max,
    in), lora_b (P, ..., out, r_max). ``page_of`` maps tenant id -> page
    index; ``ranks[p]`` is page p's true rank (its rank-level bucket);
    ``scales[p]`` is the LoRA scaling already FOLDED into that page's
    lora_b at packing time, recorded here for introspection only.
    """
    version: int
    pages: Pages
    page_of: Mapping[Any, int]
    ranks: Tuple[int, ...]
    scales: Tuple[float, ...]

    @property
    def num_pages(self) -> int:
        return len(self.ranks)

    def page_ids(self, adapter_ids) -> jnp.ndarray:
        """Map tenant ids -> int32 page indices (host-side)."""
        return jnp.asarray([self.page_of[i] for i in adapter_ids],
                           jnp.int32)


def _mask_and_pad(path, leaf, rank: int, r_max: int):
    """Zero columns >= rank, pad the rank dim to r_max (omega-style)."""
    key = path[-1].key
    if key == "lora_m":
        raise ValueError("DoRA magnitudes are not servable via the paged "
                         "adapter cache (serving supports plain LoRA)")
    ax = leaf.ndim - 2 if key == "lora_a" else leaf.ndim - 1
    r_in = leaf.shape[ax]
    assert r_in <= r_max, (r_in, r_max)
    col = jnp.arange(r_in)
    shape = [1] * leaf.ndim
    shape[ax] = r_in
    leaf = leaf * (col < rank).reshape(shape).astype(leaf.dtype)
    if r_in < r_max:
        pad = [(0, 0)] * leaf.ndim
        pad[ax] = (0, r_max - r_in)
        leaf = jnp.pad(leaf, pad)
    return leaf


class AdapterStore:
    """Rank-level-bucketed tenant adapter store with atomic publish."""

    def __init__(self, rank_levels: Tuple[int, ...],
                 scaling_fn=None):
        self.rank_levels = tuple(sorted(rank_levels))
        self.r_max = max(self.rank_levels)
        # staged: tenant id -> (rank, lora_tree); insertion order preserved
        self._staged: Dict[Any, Tuple[int, Any]] = {}
        self._scaling_fn = scaling_fn or (lambda rank: 1.0)
        self._published: Optional[PublishedAdapters] = None
        self._version = 0

    # -- staging -------------------------------------------------------------

    def put(self, adapter_id, lora_tree, rank: int) -> None:
        """Stage (or replace) a tenant's adapter at its true rank. Takes
        effect only at the next ``publish()``."""
        if rank not in self.rank_levels:
            raise ValueError(f"rank {rank} not in levels {self.rank_levels}")
        self._staged[adapter_id] = (rank, lora_tree)

    def buckets(self) -> Dict[int, list]:
        """rank level -> staged tenant ids (the aggregation-side bucket
        discipline: group by rank level, insertion order within)."""
        out: Dict[int, list] = {lvl: [] for lvl in self.rank_levels}
        for aid, (rank, _) in self._staged.items():
            out[rank].append(aid)
        return out

    # -- publish / read ------------------------------------------------------

    @property
    def published(self) -> Optional[PublishedAdapters]:
        """The live snapshot. Capture ONCE per step; never re-read
        mid-step."""
        return self._published

    @property
    def version(self) -> int:
        return self._version

    def publish(self, version: Optional[int] = None) -> PublishedAdapters:
        """Pack the staged adapters and atomically flip the live snapshot.

        ``version`` defaults to the next monotonic value; an explicit
        version (e.g. the federation server's adapter version) must be
        strictly greater than the current one.
        """
        if not self._staged:
            raise ValueError("publish() with no staged adapters")
        version = self._version + 1 if version is None else int(version)
        if version <= self._version:
            raise ValueError(
                f"version must be monotonic: {version} <= {self._version}")
        order = [aid for lvl in self.rank_levels
                 for aid in self.buckets()[lvl]]
        page_of = {aid: p for p, aid in enumerate(order)}
        ranks = tuple(self._staged[aid][0] for aid in order)
        scales = tuple(float(self._scaling_fn(r)) for r in ranks)
        trees = []
        for aid in order:
            rank, tree = self._staged[aid]
            s = self._scaling_fn(rank)

            def pack(path, leaf):
                if leaf is None or not _is_lora_path(path):
                    return leaf
                leaf = _mask_and_pad(path, leaf, rank, self.r_max)
                if path[-1].key == "lora_b" and s != 1.0:
                    # fold the per-tenant scaling into B so the engine can
                    # run every page at unit scale
                    leaf = leaf * jnp.asarray(s, leaf.dtype)
                return leaf

            trees.append(jax.tree_util.tree_map_with_path(
                pack, tree, is_leaf=lambda x: x is None))
        pages = jax.tree.map(
            lambda *leaves: None if leaves[0] is None else jnp.stack(leaves),
            *trees, is_leaf=lambda x: x is None)
        snap = PublishedAdapters(version=version, pages=pages,
                                 page_of=page_of, ranks=ranks,
                                 scales=scales)
        self._published = snap          # the atomic flip
        self._version = version
        return snap

    # -- federation hook -----------------------------------------------------

    def bind_server(self, server, adapter_id="global",
                    rank: Optional[int] = None) -> None:
        """Attach to ``FederatedLoRA.add_post_aggregate_hook``: every round
        landing re-stages ``adapter_id`` with the freshly aggregated global
        factors and publishes under the server's adapter version."""
        rank = self.r_max if rank is None else rank

        def on_round_landing(version: int, global_lora) -> None:
            self.put(adapter_id, global_lora, rank)
            self.publish(version)

        server.add_post_aggregate_hook(on_round_landing)
