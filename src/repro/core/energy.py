"""Energy-spectrum metrics (Section 3 / Definition 1 of the paper).

"Energy" = squared singular values. ``rho_r`` is the normalized cumulative
energy ratio; rank collapse = (1 - rho_{r_1}) -> 0 over rounds.

All metrics here are computed in NUMPY on purpose: they are host-side
bookkeeping, never traced inside jit, and ``EnergyTrace.record`` runs on
the server's round path with device work in flight -- on jax's CPU client
even tiny eager jnp ops synchronize with the queue, stalling the async
round engine's pipeline. Inputs may still be jax arrays (``np.asarray``
materializes them).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence

import numpy as np


def energies(sigma) -> np.ndarray:
    """e_i = sigma_i^2 (descending order preserved)."""
    return np.square(np.asarray(sigma, np.float32))


def cumulative_energy(sigma, r: int) -> np.ndarray:
    """E_r = sum_{i<=r} e_i."""
    return energies(sigma)[:r].sum()


def rho(sigma, r: int) -> np.ndarray:
    """rho_r = E_r / E_{r_max} in [0, 1]."""
    e = energies(sigma)
    total = e.sum()
    return np.where(total > 0, e[:r].sum() / np.maximum(total, 1e-30), 0.0)


def higher_rank_energy_ratio(sigma, r1: int) -> np.ndarray:
    """1 - rho_{r1}: the quantity whose decay defines rank collapse."""
    return 1.0 - rho(sigma, r1)


def effective_rank(sigma, eps: float = 1e-12) -> np.ndarray:
    """Entropy-based effective rank (Roy & Vetterli): exp(H(p)), p = e/sum e."""
    e = energies(sigma)
    p = e / np.maximum(e.sum(), eps)
    h = -np.sum(np.where(p > 0, p * np.log(np.maximum(p, eps)), 0.0))
    return np.exp(h)


def energy_breakdown(sigma,
                     rank_levels: Sequence[int]) -> dict:
    """Per-partition energy fractions (the stacked bars of Figure 2a/2b)."""
    from repro.core.partitions import partition_bounds
    e = np.asarray(energies(sigma))
    total = max(float(e.sum()), 1e-30)
    out = {}
    for (l, h) in partition_bounds(rank_levels):
        out[f"rank_{l}_{h}"] = float(e[l - 1:h].sum() / total)
    return out


@dataclass
class EnergyTrace:
    """Round-by-round energy statistics of one adapter (or model average)."""

    rank_levels: Sequence[int]
    rho_r1: Optional[list] = None
    eff_rank: Optional[list] = None
    breakdown: Optional[list] = None

    def __post_init__(self):
        # default_factory semantics: None means "fresh empty trace", while
        # caller-provided histories (e.g. checkpoint restore) are kept --
        # the old unconditional reset silently discarded them
        self.rho_r1 = [] if self.rho_r1 is None else list(self.rho_r1)
        self.eff_rank = [] if self.eff_rank is None else list(self.eff_rank)
        self.breakdown = ([] if self.breakdown is None
                          else list(self.breakdown))

    def state_dict(self) -> dict:
        """JSON-serializable trace state for checkpoint metadata."""
        return {"rank_levels": [int(r) for r in self.rank_levels],
                "rho_r1": list(self.rho_r1),
                "eff_rank": list(self.eff_rank),
                "breakdown": list(self.breakdown)}

    @classmethod
    def from_state(cls, state: dict) -> "EnergyTrace":
        return cls(rank_levels=tuple(state["rank_levels"]),
                   rho_r1=state.get("rho_r1"),
                   eff_rank=state.get("eff_rank"),
                   breakdown=state.get("breakdown"))

    def record(self, sigma) -> None:
        r1 = min(self.rank_levels)
        self.rho_r1.append(float(rho(sigma, r1)))
        self.eff_rank.append(float(effective_rank(sigma)))
        self.breakdown.append(energy_breakdown(sigma, self.rank_levels))

    @property
    def higher_rank_ratio(self) -> np.ndarray:
        return 1.0 - np.asarray(self.rho_r1)

    def collapsed(self, threshold: float = 0.05) -> bool:
        """Definition 1: higher-rank energy has become negligible.

        Before any ``record()`` there is no spectrum to judge, so an empty
        trace is never collapsed."""
        if not self.rho_r1:
            return False
        return bool(self.higher_rank_ratio[-1] < threshold)
