"""SVD-based rank reallocation (FlexLoRA Eq. 3-4) -- dense and factored.

``svd_realloc_dense`` is the paper-faithful path: materialize the d x n
aggregate, full SVD, truncate to r_max. O(d*n*min(d,n)) flops, O(d*n) memory.

``svd_realloc_factored`` is our beyond-paper path (DESIGN.md §4.2): the
aggregate is ALWAYS of the form U_c @ V_c with U_c (d, R), V_c (R, n),
R = sum_k r_k << min(d, n), because it is a weighted sum of client low-rank
products. QR-reduce both sides, SVD only the (R x R) core:

    U_c = Q_u R_u,  V_c^T = Q_v R_v
    U_c V_c = Q_u (R_u R_v^T) Q_v^T = Q_u (U_s S V_s^T) Q_v^T

=> singular values of the aggregate are exactly those of the small core.
O((d+n) R^2 + R^3) flops, O((d+n) R) memory -- for nemotron's FFN layer
(18432 x 73728, R ~ 168*... per-round stack) this is ~60x less compute and
~260x less memory than the dense path, with IDENTICAL results up to float
round-off (validated in tests/test_svd.py).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def check_fallback_globals(fallback, global_b, global_a) -> None:
    """A non-None Eq. 8 fallback REQUIRES both global factors.

    Silently dropping the fallback (the old behaviour when ``global_b`` was
    None) degrades raFLoRA's empty-partition case to FlexLoRA-style zeroing,
    so we fail loudly instead."""
    if fallback is None:
        return
    missing = [name for name, g in (("global_b", global_b),
                                    ("global_a", global_a)) if g is None]
    if missing:
        raise ValueError(
            "Eq. 8 empty-partition fallback is set but "
            f"{' and '.join(missing)} {'is' if len(missing) == 1 else 'are'}"
            " missing; pass the current global adapter factors so the "
            "uncovered rank partitions can retain their global slices")


def svd_realloc_dense(dw: jnp.ndarray, r_max: int
                      ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Paper-faithful: SVD the dense aggregate. Returns (B_g, A_g, sigma).

    B_g = U[:, :r] * sigma (d, r_max); A_g = V^T[:r] (r_max, n).
    """
    u, s, vt = jnp.linalg.svd(dw.astype(jnp.float32), full_matrices=False)
    u, s, vt = u[:, :r_max], s[:r_max], vt[:r_max]
    return u * s[None, :], vt, s


def svd_realloc_factored(u_c: jnp.ndarray, v_c: jnp.ndarray, r_max: int
                         ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Factored: SVD of U_c @ V_c without materializing it.

    u_c (d, R); v_c (R, n). Returns (B_g (d, r_max), A_g (r_max, n), sigma).
    If R < r_max the trailing singular values are exactly zero and the
    factors are zero-padded (the aggregate has algebraic rank <= R).
    """
    u_c = u_c.astype(jnp.float32)
    v_c = v_c.astype(jnp.float32)
    q_u, r_u = jnp.linalg.qr(u_c)            # (d, R), (R, R)
    q_v, r_v = jnp.linalg.qr(v_c.T)          # (n, R), (R, R)
    core = r_u @ r_v.T                        # (R, R)
    u_s, s, vt_s = jnp.linalg.svd(core, full_matrices=False)
    u_full = q_u @ u_s                        # (d, R)
    vt_full = vt_s @ q_v.T                    # (R, n)
    r = u_c.shape[1]
    if r >= r_max:
        u_full, s, vt_full = u_full[:, :r_max], s[:r_max], vt_full[:r_max]
    else:
        pad = r_max - r
        u_full = jnp.pad(u_full, ((0, 0), (0, pad)))
        vt_full = jnp.pad(vt_full, ((0, pad), (0, 0)))
        s = jnp.pad(s, (0, pad))
    return u_full * s[None, :], vt_full, s


def svd_realloc_gram(u_c: jnp.ndarray, v_c: jnp.ndarray,
                     g_u: jnp.ndarray, g_v: jnp.ndarray, r_max: int
                     ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Factored SVD realloc from precomputed (R, R) Gram cores
    (DESIGN.md §4.3 -- the kernel backend's route).

    u_c (d, R); v_c (R, n); g_u = U_c^T U_c; g_v = V_c V_c^T. The Pallas
    kernels compute the two Gram accumulations on the MXU; everything here
    is (R x R)-sized except the two final (d, R) @ (R, r_max) /
    (r_max, R) @ (R, n) projections:

        G_u = P_u diag(lam_u) P_u^T   =>   U_c = Q_u S_u P_u^T,
        G_v = P_v diag(lam_v) P_v^T   =>   V_c = P_v S_v Q_v^T,
        U_c V_c = Q_u [S_u (P_u^T P_v) S_v] Q_v^T,

    with S = sqrt(lam) and Q_u = U_c P_u S_u^+ orthonormal on the numerical
    range. SVD of the bracketed (R x R) core gives the spectrum; the
    truncated factors fold Q_u / Q_v back through ONE matmul per side.

    vs the QR route (``svd_realloc_factored``): no (d, R)/(n, R)
    orthogonalization at all -- but the Gram squaring halves the attainable
    precision (singular values below ~sqrt(eps) * sigma_max sit under the
    eigensolver's noise floor). Rank is cut at lam > R * eps * lam_max;
    zero-padded client columns land exactly there and contribute nothing.
    """
    u_c = u_c.astype(jnp.float32)
    v_c = v_c.astype(jnp.float32)
    eps = jnp.finfo(jnp.float32).eps
    rr = u_c.shape[-1]

    def _whiten(gram):
        lam, p = jnp.linalg.eigh(gram.astype(jnp.float32))
        lam = jnp.maximum(lam, 0.0)
        keep = lam > rr * eps * jnp.max(lam)
        s = jnp.where(keep, jnp.sqrt(lam), 0.0)
        inv = jnp.where(keep, 1.0 / jnp.where(keep, jnp.sqrt(lam), 1.0), 0.0)
        return s, inv, p

    s_u, inv_u, p_u = _whiten(g_u)
    s_v, inv_v, p_v = _whiten(g_v)
    core = (s_u[:, None] * (p_u.T @ p_v)) * s_v[None, :]      # (R, R)
    w1, s, w2t = jnp.linalg.svd(core, full_matrices=False)
    left = p_u @ (inv_u[:, None] * w1)                        # (R, R)
    right = (w2t * inv_v[None, :]) @ p_v.T                    # (R, R)
    k = min(rr, r_max)
    b_g = (u_c @ left[:, :k]) * s[None, :k]                   # (d, k)
    a_g = right[:k] @ v_c                                     # (k, n)
    s = s[:k]
    if k < r_max:
        pad = r_max - k
        b_g = jnp.pad(b_g, ((0, 0), (0, pad)))
        a_g = jnp.pad(a_g, ((0, pad), (0, 0)))
        s = jnp.pad(s, (0, pad))
    return b_g, a_g, s


def factored_from_weighted(bs: jnp.ndarray, as_: jnp.ndarray,
                           omega: jnp.ndarray,
                           global_b: Optional[jnp.ndarray] = None,
                           global_a: Optional[jnp.ndarray] = None,
                           fallback: Optional[jnp.ndarray] = None
                           ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Build the stacked factors of sum_k B_k diag(omega_k) A_k [+ fallback].

    bs (M, d, r_max); as_ (M, r_max, n); omega (M, r_max).
    The per-client diagonal is split sqrt-symmetrically between the two
    factors so the stack stays well-conditioned for QR.
    Returns u_c (d, M*r_max [+ r_max]), v_c (matching, n).
    """
    check_fallback_globals(fallback, global_b, global_a)
    m, d, r = bs.shape
    n = as_.shape[-1]
    sq = jnp.sqrt(jnp.maximum(omega, 0.0)).astype(jnp.float32)  # (M, r)
    u_parts = (bs.astype(jnp.float32) * sq[:, None, :])          # (M, d, r)
    v_parts = (as_.astype(jnp.float32) * sq[:, :, None])         # (M, r, n)
    u_c = jnp.moveaxis(u_parts, 0, 1).reshape(d, m * r)
    v_c = v_parts.reshape(m * r, n)
    if fallback is not None:
        fb = jnp.sqrt(jnp.maximum(fallback, 0.0)).astype(jnp.float32)
        u_c = jnp.concatenate([u_c, global_b.astype(jnp.float32) * fb[None, :]],
                              axis=1)
        v_c = jnp.concatenate([v_c, global_a.astype(jnp.float32) * fb[:, None]],
                              axis=0)
    return u_c, v_c


def factored_stack_batched(bs: jnp.ndarray, as_: jnp.ndarray,
                           omega: jnp.ndarray
                           ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """``factored_from_weighted``'s client stack for ANY number of batch
    axes between the client axis and the matrix axes.

    bs (M, *B, d, r); as_ (M, *B, r, n); omega (M, r). Returns
    u_c (*B, d, M*r), v_c (*B, M*r, n) -- the per-client sqrt-split diagonal
    weighting of the 3-D path, applied bucket-wide. The sharded round engine
    builds each mesh shard's LOCAL stack with this and all-reduces the
    result (DESIGN.md §5); no fallback handling here because the Eq. 8
    fallback columns must be appended exactly once, AFTER the cross-shard
    reduction.
    """
    m, r = bs.shape[0], bs.shape[-1]
    d, n = bs.shape[-2], as_.shape[-1]
    lead = bs.shape[1:-2]
    sq = jnp.sqrt(jnp.maximum(omega, 0.0)).astype(jnp.float32)   # (M, r)
    sq_b = sq.reshape((m,) + (1,) * len(lead) + (1, r))
    sq_a = sq.reshape((m,) + (1,) * len(lead) + (r, 1))
    u_parts = bs.astype(jnp.float32) * sq_b                      # (M, *B, d, r)
    v_parts = as_.astype(jnp.float32) * sq_a                     # (M, *B, r, n)
    u_c = jnp.moveaxis(u_parts, 0, -2).reshape(lead + (d, m * r))
    v_c = jnp.moveaxis(v_parts, 0, -3).reshape(lead + (m * r, n))
    return u_c, v_c


def factored_append_fallback(u_c: jnp.ndarray, v_c: jnp.ndarray,
                             global_b: jnp.ndarray, global_a: jnp.ndarray,
                             fallback: jnp.ndarray
                             ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Append the Eq. 8 empty-partition fallback columns to a (possibly
    batch-stacked) factored stack: u_c (*B, d, R), global_b (*B, d, r_max)."""
    fb = jnp.sqrt(jnp.maximum(fallback, 0.0)).astype(jnp.float32)
    u_c = jnp.concatenate(
        [u_c, global_b.astype(jnp.float32) * fb[None, :]], axis=-1)
    v_c = jnp.concatenate(
        [v_c, global_a.astype(jnp.float32) * fb[:, None]], axis=-2)
    return u_c, v_c


def dense_fallback_term(global_b: jnp.ndarray, global_a: jnp.ndarray,
                        fallback: jnp.ndarray) -> jnp.ndarray:
    """The Eq. 8 empty-partition term G_B diag(fallback) G_A, for global
    factors with any leading batch axes. The single implementation behind
    the dense path's fallback, eager AND sharded."""
    return jnp.einsum("...dr,r,...rn->...dn", global_b.astype(jnp.float32),
                      fallback.astype(jnp.float32),
                      global_a.astype(jnp.float32))


def dense_from_weighted(bs: jnp.ndarray, as_: jnp.ndarray, omega: jnp.ndarray,
                        global_b: Optional[jnp.ndarray] = None,
                        global_a: Optional[jnp.ndarray] = None,
                        fallback: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Materialize sum_k B_k diag(omega_k) A_k (+ global fallback slices)."""
    check_fallback_globals(fallback, global_b, global_a)
    dw = jnp.einsum("mdr,mr,mrn->dn", bs.astype(jnp.float32),
                    omega.astype(jnp.float32), as_.astype(jnp.float32))
    if fallback is not None:
        dw = dw + dense_fallback_term(global_b, global_a, fallback)
    return dw
