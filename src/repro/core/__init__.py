"""The paper's contribution: rank-partitioned aggregation for FedLoRA."""
from repro.core.aggregation import (AggregationResult, Aggregator, METHODS,
                                    aggregate_flexlora, aggregate_flora,
                                    aggregate_hetlora, aggregate_raflora,
                                    pad_stack, staleness_discount)
from repro.core.energy import (EnergyTrace, effective_rank, energies,
                               energy_breakdown, higher_rank_energy_ratio,
                               rho)
from repro.core.partitions import (boundaries, boundary_of_index, coverage,
                                   omega_flexlora, omega_raflora,
                                   partition_bounds, prev_boundary)
from repro.core.svd import svd_realloc_dense, svd_realloc_factored
from repro.core.theory import (SampledSim, collapse_bound,
                               contraction_factors, h_sampling,
                               mean_field_floor, mean_field_step,
                               rho_series, simulate_expected)

__all__ = [
    "AggregationResult", "Aggregator", "METHODS", "EnergyTrace", "SampledSim",
    "aggregate_flexlora", "aggregate_flora", "aggregate_hetlora",
    "aggregate_raflora", "boundaries", "boundary_of_index", "collapse_bound",
    "contraction_factors", "coverage", "effective_rank", "energies",
    "energy_breakdown", "h_sampling", "higher_rank_energy_ratio",
    "mean_field_floor", "mean_field_step", "omega_flexlora", "omega_raflora",
    "pad_stack", "partition_bounds", "prev_boundary", "rho", "rho_series",
    "staleness_discount",
    "simulate_expected", "svd_realloc_dense", "svd_realloc_factored",
]
