"""Theorem 1 / Appendix A-B: closed-form rank-collapse dynamics.

Implements the paper's tractable model exactly so the geometric-rate claim
is machine-checkable:

  * ``h(p)``            -- hypergeometric second moment E[(N_i/M)^2] (Eq. 14)
  * ``contraction``     -- q_i = beta^2 h(p_i)
  * ``collapse_bound``  -- C, gamma of Eq. 6; bound 1 - rho <= C gamma^t
  * ``simulate_expected`` -- the linear recursion e^{t+1} = q e^t (Eq. 15)
  * ``simulate_sampled``  -- Monte-Carlo over actual client sampling
                             (Eq. 10-11), for FlexLoRA *and* raFLoRA rules
  * ``mean_field_step``   -- Appendix B recursion with basis-drift kappa and
                             residual delta^2 floors
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence, Tuple

import numpy as np


def h_sampling(p: np.ndarray, K: int, M: int) -> np.ndarray:
    """h(p) = p^2 + (K-M)/(M(K-1)) p(1-p); E[(N/M)^2] under hypergeometric."""
    p = np.asarray(p, dtype=np.float64)
    tau = (K - M) / (M * (K - 1)) if K > 1 else 0.0
    return p * p + tau * p * (1.0 - p)


def contraction_factors(p: np.ndarray, K: int, M: int,
                        beta: float = 1.0) -> np.ndarray:
    """q_i = beta^2 h(p_i) (Eq. 14)."""
    return beta ** 2 * h_sampling(p, K, M)


def collapse_bound(e0: np.ndarray, p: np.ndarray, K: int, M: int,
                   r1: int, beta: float = 1.0) -> Tuple[float, float]:
    """(C, gamma) of Theorem 1. e0: initial energies (r_max,)."""
    q = contraction_factors(p, K, M, beta)
    low = e0[:r1].sum()
    assert low > 0, "Theorem requires nonzero initial shared-rank energy"
    C = e0[r1:].sum() / low
    gamma = q[r1] / q[r1 - 1] if len(q) > r1 else 0.0
    return float(C), float(gamma)


def simulate_expected(e0: np.ndarray, p: np.ndarray, K: int, M: int,
                      rounds: int, beta: float = 1.0) -> np.ndarray:
    """Expected-energy recursion e_i^{(t)} = e_i^{(0)} q_i^t (Eq. 15).

    Returns energies (rounds+1, r_max).
    """
    q = contraction_factors(p, K, M, beta)
    t = np.arange(rounds + 1)[:, None]
    return np.asarray(e0)[None, :] * q[None, :] ** t


def rho_series(energy: np.ndarray, r1: int) -> np.ndarray:
    """rho_{r1}^{(t)} per round from an energy trajectory (T, r_max)."""
    num = energy[:, :r1].sum(axis=1)
    den = energy.sum(axis=1)
    return num / np.maximum(den, 1e-300)


@dataclass
class SampledSim:
    """Monte-Carlo of the Assumption 1-2 model with real client sampling.

    Each round: draw M of K clients without replacement; client k supports
    direction i iff r_k >= i and contributes beta * sigma_i.

      FlexLoRA rule (Eq. 10):  sigma'_i = beta * (N_i / M) * sigma_i
      raFLoRA  rule (Sec. 5):  sigma'_i = beta * sigma_i      if N_{h(i)} > 0
                               sigma'_i = sigma_i             otherwise
                               (effective contributors normalize themselves)
    """

    client_ranks: np.ndarray          # (K,)
    M: int
    beta: float = 1.0
    seed: int = 0

    def run(self, sigma0: np.ndarray, rounds: int, rule: str = "flexlora",
            rank_levels: Optional[Sequence[int]] = None) -> np.ndarray:
        from repro.core.partitions import boundary_of_index
        rng = np.random.default_rng(self.seed)
        K = len(self.client_ranks)
        r_max = len(sigma0)
        sigma = np.asarray(sigma0, dtype=np.float64).copy()
        out = [np.square(sigma)]
        if rule == "raflora":
            levels = rank_levels or sorted(set(self.client_ranks.tolist()))
            h_of_i = boundary_of_index(levels)     # (r_max,)
        for _ in range(rounds):
            sel = rng.choice(K, size=self.M, replace=False)
            ranks = self.client_ranks[sel]
            idx = np.arange(1, r_max + 1)
            n_i = (ranks[:, None] >= idx[None, :]).sum(axis=0)  # (r_max,)
            if rule == "flexlora":
                sigma = self.beta * (n_i / self.M) * sigma
            elif rule == "raflora":
                n_h = np.array([(ranks >= h).sum() for h in h_of_i])
                covered = n_h > 0
                sigma = np.where(covered, self.beta * sigma, sigma)
            else:
                raise ValueError(rule)
            out.append(np.square(sigma))
        return np.asarray(out)                      # (rounds+1, r_max)


def mean_field_step(e: np.ndarray, p: np.ndarray, K: int, M: int, *,
                    beta: float = 1.0, kappa: float = 1.0,
                    delta2: float = 0.0, lam: float = 0.0) -> np.ndarray:
    """One Appendix-B mean-field update:

        E[e^{t+1}] = (1+lam) h(p) E[kappa^2 beta^2] E[e] + delta^2.

    With kappa=1, delta2=0, lam=0 this reduces to the basic recursion.
    """
    qp = (1.0 + lam) * h_sampling(p, K, M) * (kappa ** 2) * (beta ** 2)
    return qp * e + delta2


def mean_field_floor(p: np.ndarray, K: int, M: int, *, beta: float = 1.0,
                     kappa: float = 1.0, delta2: float = 0.0,
                     lam: float = 0.0) -> np.ndarray:
    """Steady-state floor delta^2 / (1 - q') where q' < 1 (Appendix B)."""
    qp = (1.0 + lam) * h_sampling(p, K, M) * (kappa ** 2) * (beta ** 2)
    return np.where(qp < 1.0, delta2 / np.maximum(1.0 - qp, 1e-12), np.inf)
