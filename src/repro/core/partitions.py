"""Rank-partition machinery (Section 5 of the paper).

The ordered client rank levels R = {r_1 < r_2 < ... < r_max} induce
non-overlapping partitions [l, h] with l = prev(h) + 1. For the partition
ending at boundary h only the *effective contributors* C_h = {k : r_k >= h}
participate, weighted n_k / N_h.

Key systems observation (ours): every aggregation rule in this family --
FlexLoRA's uniform averaging AND raFLoRA's rank-partitioned averaging -- can
be written as a single weighted-diagonal factored sum

    dW = sum_k  B_k  diag(omega_k)  A_k,

where omega_k[i] is the weight client k contributes at rank index i.

  FlexLoRA:  omega_k[i] = (n_k / N) * 1[i <= r_k]          (rank-agnostic)
  raFLoRA:   omega_k[i] = (n_k / N_{h(i)}) * 1[r_k >= h(i)] (rank-aware)

with h(i) = min{r in R : r >= i} the boundary of i's partition. This unifies
the implementations, makes the mismatch of Theorem 1 visible as a *weight
matrix difference*, and is the exact contraction computed by the
``rank_partition_agg`` Pallas kernel.
"""
from __future__ import annotations

from typing import List, Sequence, Tuple

import numpy as np


def boundaries(rank_levels: Sequence[int]) -> List[int]:
    """Ordered unique rank boundaries R = {r_1 < ... < r_max}."""
    return sorted(set(int(r) for r in rank_levels))


def prev_boundary(h: int, levels: Sequence[int]) -> int:
    """prev(h) per the paper: 0 for the smallest boundary."""
    bs = boundaries(levels)
    i = bs.index(h)
    return 0 if i == 0 else bs[i - 1]


def partition_bounds(rank_levels: Sequence[int]) -> List[Tuple[int, int]]:
    """Partitions [(l, h)] with 1-indexed inclusive bounds (paper notation)."""
    bs = boundaries(rank_levels)
    out, prev = [], 0
    for h in bs:
        out.append((prev + 1, h))
        prev = h
    return out


def boundary_of_index(rank_levels: Sequence[int]) -> np.ndarray:
    """h(i) for every rank index i in [1, r_max]; returned 0-indexed array of
    length r_max where entry i-1 = h(i)."""
    bs = boundaries(rank_levels)
    r_max = bs[-1]
    out = np.zeros(r_max, dtype=np.int64)
    for (l, h) in partition_bounds(rank_levels):
        out[l - 1:h] = h
    return out


def coverage(rank_levels: Sequence[int], client_ranks: Sequence[int]
             ) -> np.ndarray:
    """Rank coverage p_i = |{k : r_k >= i}| / K for i = 1..r_max (Eq. 1)."""
    r_max = max(rank_levels)
    ranks = np.asarray(client_ranks)
    return np.array([(ranks >= i).mean() for i in range(1, r_max + 1)])


def omega_flexlora(client_ranks: Sequence[int],
                   num_samples: Sequence[float],
                   r_max: int) -> np.ndarray:
    """Rank-agnostic FedAvg weights. Returns (M, r_max)."""
    ranks = np.asarray(client_ranks)
    n = np.asarray(num_samples, dtype=np.float64)
    w = n / n.sum()
    idx = np.arange(1, r_max + 1)
    support = (idx[None, :] <= ranks[:, None]).astype(np.float64)
    return w[:, None] * support


def omega_raflora(client_ranks: Sequence[int],
                  num_samples: Sequence[float],
                  rank_levels: Sequence[int]
                  ) -> Tuple[np.ndarray, np.ndarray]:
    """Rank-partitioned weights (Eq. 8).

    Returns (omega (M, r_max), fallback (r_max,)) where fallback[i] = 1 for
    rank indices whose partition has NO sampled contributor -- those indices
    take the current global slice instead (Eq. 8 second case).
    """
    ranks = np.asarray(client_ranks)
    n = np.asarray(num_samples, dtype=np.float64)
    r_max = max(rank_levels)
    h_of_i = boundary_of_index(rank_levels)          # (r_max,)
    omega = np.zeros((len(ranks), r_max))
    fallback = np.zeros(r_max)
    for i in range(r_max):
        h = h_of_i[i]
        members = ranks >= h
        n_h = n[members].sum()
        if n_h > 0:
            omega[members, i] = n[members] / n_h
        else:
            fallback[i] = 1.0
    return omega, fallback


def effective_contributors(h: int, client_ranks: Sequence[int]) -> np.ndarray:
    """Index mask of C_h = {k : r_k >= h}."""
    return np.asarray(client_ranks) >= h
