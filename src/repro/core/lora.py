"""LoRA adapter tree utilities.

Adapters live inline in the model params as ``lora_a`` (r_max, in) /
``lora_b`` (out, r_max) leaves. The federation layer needs to

  * split params into (base, lora) so clients optimize only adapters;
  * truncate adapters to a client rank r_k (broadcast, Alg. 1 line 4);
  * pad trained rank-r_k adapters back to r_max (upload);
  * enumerate adapters as {path: (B, A)} for the aggregators.

Note the model convention is A: (r, d_in), B: (d_out, r), update = B @ A --
matching the paper's dW = B A with B in R^{d x r}, A in R^{r x n} after the
obvious transpose bookkeeping.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

LORA_KEYS = ("lora_a", "lora_b", "lora_m")  # lora_m: DoRA magnitude


def _is_lora_path(path) -> bool:
    last = path[-1]
    key = getattr(last, "key", None)
    return key in LORA_KEYS


def split_lora(params) -> Tuple[Any, Any]:
    """(base, lora) trees with the SAME structure; non-members are None."""
    base = jax.tree_util.tree_map_with_path(
        lambda p, x: None if _is_lora_path(p) else x, params)
    lora = jax.tree_util.tree_map_with_path(
        lambda p, x: x if _is_lora_path(p) else None, params)
    return base, lora


def merge_lora(base, lora):
    """Inverse of split_lora."""
    return jax.tree.map(lambda b, l: b if l is None else l, base, lora,
                        is_leaf=lambda x: x is None)


def lora_only(params):
    """Prune the tree down to only adapter leaves (for optimizer state)."""
    _, lora = split_lora(params)
    return lora


def adapter_paths(params) -> Dict[str, Dict[str, jnp.ndarray]]:
    """{dotted/path: {"a": A, "b": B}} for every adapter in the tree."""
    out: Dict[str, Dict[str, jnp.ndarray]] = {}

    def visit(path, x):
        if _is_lora_path(path):
            parent = "/".join(str(getattr(p, "key", p)) for p in path[:-1])
            kind = "a" if path[-1].key == "lora_a" else "b"
            out.setdefault(parent, {})[kind] = x
        return x

    jax.tree_util.tree_map_with_path(visit, params)
    return out


def truncate_adapters(lora_tree, rank: int):
    """Broadcast step: slice every adapter to the client's rank r_k."""

    def trunc(path, x):
        if x is None:
            return None
        if path[-1].key == "lora_m":
            return x                      # magnitudes are not rank-indexed
        if path[-1].key == "lora_a":
            return x[..., :rank, :]
        return x[..., :, :rank]

    return jax.tree_util.tree_map_with_path(
        lambda p, x: trunc(p, x) if x is not None else None, lora_tree,
        is_leaf=lambda x: x is None)


def pad_adapters(lora_tree, r_max: int):
    """Upload step: zero-pad rank-r_k adapters back to r_max."""

    def pad(path, x):
        if x is None:
            return None
        if path[-1].key == "lora_m":
            return x
        if path[-1].key == "lora_a":
            r = x.shape[-2]
            if r == r_max:
                return x
            cfgpad = [(0, 0)] * x.ndim
            cfgpad[-2] = (0, r_max - r)
            return jnp.pad(x, cfgpad)
        r = x.shape[-1]
        if r == r_max:
            return x
        cfgpad = [(0, 0)] * x.ndim
        cfgpad[-1] = (0, r_max - r)
        return jnp.pad(x, cfgpad)

    return jax.tree_util.tree_map_with_path(
        lambda p, x: pad(p, x) if x is not None else None, lora_tree,
        is_leaf=lambda x: x is None)


def map_adapters(fn: Callable, lora_tree):
    """Apply fn(parent_path, {"a": A, "b": B}) -> {"a": A', "b": B'} to every
    adapter pair in the tree; returns a new tree."""
    # collect pairs
    pairs: Dict[str, Dict[str, Any]] = {}

    def collect(path, x):
        if x is not None and _is_lora_path(path):
            parent = tuple(path[:-1])
            kind = "a" if path[-1].key == "lora_a" else "b"
            pairs.setdefault(parent, {})[kind] = x
        return x

    jax.tree_util.tree_map_with_path(collect, lora_tree,
                                     is_leaf=lambda x: x is None)
    results = {parent: fn(parent, ab) for parent, ab in pairs.items()}

    def rebuild(path, x):
        if x is None or not _is_lora_path(path):
            return x
        parent = tuple(path[:-1])
        kind = "a" if path[-1].key == "lora_a" else "b"
        return results[parent][kind]

    return jax.tree_util.tree_map_with_path(rebuild, lora_tree,
                                            is_leaf=lambda x: x is None)
