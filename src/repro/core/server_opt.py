"""Server-side optimization on LOW-RANK factors (beyond-paper).

FedOpt-style server momentum normally operates on the dense pseudo-gradient
Delta_t = W_g^{t+1} - W_g^t -- at LoRA scale that would materialize d x n
buffers per layer per round. Here momentum itself stays FACTORED: every
quantity (momentum m_t, update delta, new global) is a rank-r_max (B, A)
pair maintained by stacked-QR-SVD truncation:

    Delta_t = B'A' - BA                      (rank <= 2 r_max, as a stack)
    m_t     = trunc_svd([sqrt(beta) B_m | B' | B],
                        [sqrt(beta) A_m ; A' ; -A])       (rank r_max)
    W^{t+1} = trunc_svd([B | eta B_m^t], [A ; A_m^t])     (rank r_max)

The SVD truncations introduce the same rank-r_max projection the base
method already applies each round, so the approximation error is of the
same order as FlexLoRA/raFLoRA's own reallocation. Composes with any
aggregation method; exercised in tests/test_server_opt.py.

Two call surfaces:

* ``apply`` -- one adapter at a time (the sequential reference engine).
* ``apply_bucket`` -- one JITTED dispatch per shape bucket (the batched /
  sharded / async round engines): the whole bucket's layer-stacked factors
  run the identical stacked-QR-SVD math vmapped over every leading batch
  axis, preserving the engines' one-dispatch-per-bucket design.
  ``bucket_calls`` counts those dispatches so ``bench_round_latency`` can
  assert momentum adds <= 1 per bucket per round. Bucketed state lives
  STACKED under the bucket key (no per-adapter slice ops on the hot path),
  but checkpoints always serialize per adapter (``state_arrays``), so they
  are engine-portable and the async engine's buffered deltas always land in
  the same keyed slot regardless of which round delivered them.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.svd import svd_realloc_factored


def _stack(*pairs):
    """pairs of (B (…, d, r), A (…, r, n)) -> concatenated factors."""
    us = jnp.concatenate([b for b, _ in pairs], axis=-1)
    vs = jnp.concatenate([a for _, a in pairs], axis=-2)
    return us, vs


def _trunc(u, v, r_max):
    """Rank-r_max truncation of a factor stack, batched over ANY leading
    axes (scalar pair, (L, d, R) scan stacks, (P, L, d, R) buckets)."""
    if u.ndim == 2:
        b, a, _ = svd_realloc_factored(u, v, r_max)
        return b, a
    lead = u.shape[:-2]
    d, rr = u.shape[-2:]
    n = v.shape[-1]
    b, a, _ = jax.vmap(lambda uu, vv: svd_realloc_factored(uu, vv, r_max))(
        u.reshape((-1, d, rr)), v.reshape((-1, rr, n)))
    return b.reshape(lead + (d, r_max)), a.reshape(lead + (r_max, n))


def _momentum_step(old_b, old_a, new_b, new_a, state_b, state_a, beta, eta,
                   r_max):
    """One momentum update on (possibly batch-stacked) factor pairs.

    state_b/state_a of None means "no accumulated momentum yet" (the first
    round): m_0 = Delta_0 exactly, matching the dense FedAvgM recursion with
    zero-initialized momentum.
    """
    du, dv = _stack((new_b, new_a), (old_b, -old_a))
    if state_b is None:
        mu, mv = du, dv
    else:
        sq = beta ** 0.5
        mu, mv = _stack((sq * state_b, sq * state_a), (du, dv))
    b_m, a_m = _trunc(mu, mv, r_max)
    gu, gv = _stack((old_b, old_a), (eta * b_m, a_m))
    b_g, a_g = _trunc(gu, gv, r_max)
    return b_g, a_g, b_m, a_m


@functools.partial(jax.jit, static_argnames=("beta", "eta", "r_max"))
def _bucket_core(old_bs, old_as, new_b, new_a, state_b, state_a, *,
                 beta, eta, r_max):
    """The whole bucket's momentum update as ONE XLA program.

    old_bs/old_as: tuples over bucket adapters of (…, d, r_max) /
    (…, r_max, n) arrays (stacked inside the program, so the assembly costs
    no extra dispatch); new_b/new_a: the aggregation result's
    (P, …, d, r_max)/(P, …, r_max, n) stacks; state_b/state_a: the
    bucket-stacked momentum state, or None on the first round.
    """
    return _momentum_step(jnp.stack(old_bs), jnp.stack(old_as),
                          new_b, new_a, state_b, state_a,
                          beta, eta, r_max)


@dataclass
class FactoredServerMomentum:
    """FedAvgM on factored adapters. state: {adapter: (B_m, A_m)}."""

    beta: float = 0.9
    eta: float = 1.0
    state: Optional[Dict] = None
    # jitted bucket dispatches issued so far (bench_round_latency asserts
    # momentum adds <= 1 dispatch per bucket per round)
    bucket_calls: int = 0

    def apply(self, adapter_key, old_ba: Tuple, new_ba: Tuple,
              r_max: int) -> Tuple:
        """old/new (B, A) for one adapter; returns momentum-corrected (B, A).
        """
        if self.state is None:
            self.state = {}
        b_old, a_old = old_ba
        b_new, a_new = new_ba
        prev = self.state.get(adapter_key)
        b_g, a_g, b_m, a_m = _momentum_step(
            b_old, a_old, b_new, a_new,
            None if prev is None else prev[0],
            None if prev is None else prev[1],
            self.beta, self.eta, r_max)
        self.state[adapter_key] = (b_m, a_m)
        return b_g, a_g

    def apply_bucket(self, adapter_keys: Sequence, old_pairs: Sequence[Tuple],
                     new_b, new_a, r_max: int) -> Tuple:
        """Momentum for a whole shape bucket in ONE jitted dispatch.

        ``old_pairs``: the per-adapter global (B, A) pairs in bucket order;
        ``new_b``/``new_a``: the aggregation result's stacked
        (P, …, d, r_max)/(P, …, r_max, n) factors (the layout
        ``Aggregator.aggregate_grouped`` returns). Identical math to
        per-adapter ``apply``, batched over the bucket axis.

        State for a bucket lives STACKED under the tuple-of-adapter-keys
        bucket key -- reading/writing it enqueues no per-adapter slice ops,
        which matters because jax's CPU client bounds in-flight
        computations and the async engine lives or dies by a shallow
        dispatch queue. Per-adapter entries (from ``apply`` or a restored
        checkpoint) are migrated into the bucket stack on first use.
        """
        if self.state is None:
            self.state = {}
        bucket_key = tuple(adapter_keys)
        prev = self.state.get(bucket_key)
        if prev is None and all(k in self.state for k in adapter_keys):
            # one-time migration: per-adapter entries -> bucket stack
            prev = (jnp.stack([self.state[k][0] for k in adapter_keys]),
                    jnp.stack([self.state[k][1] for k in adapter_keys]))
            for k in adapter_keys:
                del self.state[k]
        b_g, a_g, b_m, a_m = _bucket_core(
            tuple(b for b, _ in old_pairs),
            tuple(a for _, a in old_pairs),
            new_b, new_a,
            None if prev is None else prev[0],
            None if prev is None else prev[1],
            beta=self.beta, eta=self.eta, r_max=r_max)
        self.bucket_calls += 1
        self.state[bucket_key] = (b_m, a_m)
        return b_g, a_g

    # -- checkpointing ------------------------------------------------------

    @staticmethod
    def _is_bucket_key(key) -> bool:
        return (isinstance(key, tuple) and len(key) > 0
                and isinstance(key[0], tuple))

    def state_arrays(self) -> Dict[str, np.ndarray]:
        """Flat path-keyed arrays for ``checkpointing.save_flat``.

        Always serialized PER ADAPTER (bucket stacks are sliced), so
        checkpoints are engine-portable regardless of which call surface
        produced the state. Keys: ``<adapter path joined by '/'>`` +
        ``"/B_m"`` | ``"/A_m"``; adapter paths contain no slashes, so the
        encoding is invertible.
        """
        out: Dict[str, np.ndarray] = {}
        for key, (b_m, a_m) in (self.state or {}).items():
            if self._is_bucket_key(key):
                for j, adapter in enumerate(key):
                    name = "/".join(adapter)
                    out[name + "/B_m"] = np.asarray(b_m[j])
                    out[name + "/A_m"] = np.asarray(a_m[j])
            else:
                name = "/".join(key) if isinstance(key, tuple) else str(key)
                out[name + "/B_m"] = np.asarray(b_m)
                out[name + "/A_m"] = np.asarray(a_m)
        return out

    def load_state_arrays(self, arrays: Dict[str, np.ndarray]) -> None:
        """Inverse of ``state_arrays``: rebuild {adapter: (B_m, A_m)}."""
        state: Dict = {}
        for name, arr in arrays.items():
            path, leaf = name.rsplit("/", 1)
            key = tuple(path.split("/"))
            pair = state.setdefault(key, [None, None])
            pair[0 if leaf == "B_m" else 1] = jnp.asarray(arr)
        for key, (b_m, a_m) in state.items():
            assert b_m is not None and a_m is not None, key
        self.state = {k: (b, a) for k, (b, a) in state.items()}
