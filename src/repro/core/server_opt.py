"""Server-side optimization on LOW-RANK factors (beyond-paper).

FedOpt-style server momentum normally operates on the dense pseudo-gradient
Delta_t = W_g^{t+1} - W_g^t -- at LoRA scale that would materialize d x n
buffers per layer per round. Here momentum itself stays FACTORED: every
quantity (momentum m_t, update delta, new global) is a rank-r_max (B, A)
pair maintained by stacked-QR-SVD truncation:

    Delta_t = B'A' - BA                      (rank <= 2 r_max, as a stack)
    m_t     = trunc_svd([sqrt(beta) B_m | B' | B],
                        [sqrt(beta) A_m ; A' ; -A])       (rank r_max)
    W^{t+1} = trunc_svd([B | eta B_m^t], [A ; A_m^t])     (rank r_max)

The SVD truncations introduce the same rank-r_max projection the base
method already applies each round, so the approximation error is of the
same order as FlexLoRA/raFLoRA's own reallocation. Composes with any
aggregation method; exercised in tests/test_server_opt.py.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import jax.numpy as jnp

from repro.core.svd import svd_realloc_factored


def _stack(*pairs):
    """pairs of (B (…, d, r), A (…, r, n)) -> concatenated factors."""
    us = jnp.concatenate([b for b, _ in pairs], axis=-1)
    vs = jnp.concatenate([a for _, a in pairs], axis=-2)
    return us, vs


def _trunc(u, v, r_max):
    if u.ndim == 3:  # layer-stacked: vmap
        import jax
        b, a, _ = jax.vmap(lambda uu, vv: svd_realloc_factored(uu, vv, r_max)
                           )(u, v)
        return b, a
    b, a, _ = svd_realloc_factored(u, v, r_max)
    return b, a


@dataclass
class FactoredServerMomentum:
    """FedAvgM on factored adapters. state: {adapter: (B_m, A_m)}."""

    beta: float = 0.9
    eta: float = 1.0
    state: Optional[Dict] = None

    def apply(self, adapter_key, old_ba: Tuple, new_ba: Tuple,
              r_max: int) -> Tuple:
        """old/new (B, A) for one adapter; returns momentum-corrected (B, A).
        """
        if self.state is None:
            self.state = {}
        b_old, a_old = old_ba
        b_new, a_new = new_ba
        # delta = new - old as a factor stack (sign folded into A)
        du, dv = _stack((b_new, a_new), (b_old, -a_old))
        if adapter_key in self.state:
            b_m, a_m = self.state[adapter_key]
            sq = self.beta ** 0.5
            mu, mv = _stack((sq * b_m, sq * a_m), (du, dv))
        else:
            mu, mv = du, dv
        b_m, a_m = _trunc(mu, mv, r_max)
        self.state[adapter_key] = (b_m, a_m)
        # W_new = W_old + eta * m
        gu, gv = _stack((b_old, a_old), (self.eta * b_m, a_m))
        return _trunc(gu, gv, r_max)
