"""Server-side aggregation rules for heterogeneous-rank FedLoRA.

Implements the paper's method and every baseline it compares against
(Table 1), all over one stacked-factor representation:

  bs    (M, d, r_max)   client B factors, zero-padded above r_k
  as_   (M, r_max, n)   client A factors, zero-padded below r_k
  ranks (M,)            client ranks
  n_k   (M,)            client sample counts

Methods
  fedavg    -- homogeneous FedAvg of factors (FedIT); requires equal ranks
  hetlora   -- zero-pad, average B and A SEPARATELY (aggregation bias!)
  flora     -- stacking: dW = sum w_k B_k A_k merged into the base weights,
               adapters re-initialized (cold start) -- bias-free, expensive
  flexlora  -- dW = sum (n_k/N) B_k A_k, SVD realloc (rank collapse!)
  raflora   -- rank-partitioned dW (Eq. 8), SVD realloc  <- the paper

``backend="dense"`` materializes dW (paper-faithful); ``backend="factored"``
uses the QR low-rank SVD (beyond-paper, bit-compatible up to float error);
``backend="kernel"`` routes the weighted contraction through the Pallas
rank-partition kernel (TPU path, interpret-mode on CPU).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import partitions as parts
from repro.core.svd import (dense_from_weighted, factored_from_weighted,
                            svd_realloc_dense, svd_realloc_factored)


@dataclass
class AggregationResult:
    b_g: jnp.ndarray                  # (d, r_max)
    a_g: jnp.ndarray                  # (r_max, n)
    sigma: Optional[jnp.ndarray]      # singular values (r_max,) or None
    merge_delta: Optional[jnp.ndarray] = None  # FLoRA: dW folded into base


def pad_stack(factors: Sequence[Tuple[jnp.ndarray, jnp.ndarray]],
              r_max: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """[(B_k (d, r_k), A_k (r_k, n))] -> padded stacks (M,d,r_max),(M,r_max,n)."""
    bs, as_ = [], []
    for b, a in factors:
        r = b.shape[-1]
        pad_b = [(0, 0)] * b.ndim
        pad_b[-1] = (0, r_max - r)
        pad_a = [(0, 0)] * a.ndim
        pad_a[-2] = (0, r_max - r)
        bs.append(jnp.pad(b, pad_b))
        as_.append(jnp.pad(a, pad_a))
    return jnp.stack(bs), jnp.stack(as_)


def _weights(n_k: Sequence[float]) -> np.ndarray:
    n = np.asarray(n_k, dtype=np.float64)
    return n / n.sum()


# ---------------------------------------------------------------------------
# aggregation rules
# ---------------------------------------------------------------------------

def aggregate_fedavg(bs, as_, ranks, n_k) -> AggregationResult:
    """Homogeneous FedAvg of the raw factors (FedIT). Biased mixing of
    B and A -- included as the homogeneous baseline."""
    ranks = np.asarray(ranks)
    assert (ranks == ranks[0]).all(), "fedavg requires homogeneous ranks"
    w = jnp.asarray(_weights(n_k), dtype=bs.dtype)
    wshape = (-1,) + (1,) * (bs.ndim - 1)
    b_g = (w.reshape(wshape) * bs).sum(0)
    a_g = (w.reshape(wshape) * as_).sum(0)
    return AggregationResult(b_g, a_g, None)


def aggregate_hetlora(bs, as_, ranks, n_k) -> AggregationResult:
    """HetLoRA: zero-padding alignment, separate averaging of B and A.
    E[B]E[A] != E[BA] -- the aggregation bias the later methods remove."""
    w = jnp.asarray(_weights(n_k), dtype=bs.dtype)
    wshape = (-1,) + (1,) * (bs.ndim - 1)
    b_g = (w.reshape(wshape) * bs).sum(0)
    a_g = (w.reshape(wshape) * as_).sum(0)
    return AggregationResult(b_g, a_g, None)


def aggregate_flora(bs, as_, ranks, n_k) -> AggregationResult:
    """FLoRA: stacking-based, bias-free. The aggregate dW = sum w_k B_k A_k
    is merged into the base weights and adapters restart from scratch
    (cold start). Communication cost O(M (d+n) r) is charged by the cost
    model in benchmarks/bench_cost.py."""
    w = jnp.asarray(_weights(n_k), dtype=jnp.float32)
    dw = jnp.einsum("m,m...dr,m...rn->...dn", w, bs.astype(jnp.float32),
                    as_.astype(jnp.float32))
    r_max = bs.shape[-1]
    d, n = bs.shape[-2], as_.shape[-1]
    lead = bs.shape[1:-2]
    # cold start: fresh (zero) global adapter; dW returned for base merge
    b_g = jnp.zeros(lead + (d, r_max), jnp.float32)
    a_g = jnp.zeros(lead + (r_max, n), jnp.float32)
    return AggregationResult(b_g, a_g, None, merge_delta=dw)


def aggregate_flexlora(bs, as_, ranks, n_k, *, backend: str = "factored"
                       ) -> AggregationResult:
    """FlexLoRA: rank-agnostic weighted sum + SVD realloc (Eqs. 2-4)."""
    r_max = bs.shape[-1]
    omega = jnp.asarray(parts.omega_flexlora(ranks, n_k, r_max))
    return _weighted_svd(bs, as_, omega, None, None, None, r_max, backend)


def aggregate_raflora(bs, as_, ranks, n_k, *, rank_levels: Sequence[int],
                      global_b=None, global_a=None,
                      backend: str = "factored") -> AggregationResult:
    """raFLoRA: rank-partitioned aggregation (Eq. 8 / Algorithm 1)."""
    r_max = max(rank_levels)
    omega_np, fallback_np = parts.omega_raflora(ranks, n_k, rank_levels)
    omega = jnp.asarray(omega_np)
    fallback = jnp.asarray(fallback_np)
    if not np.any(fallback_np):
        fallback = None
    return _weighted_svd(bs, as_, omega, global_b, global_a, fallback,
                         r_max, backend)


def _weighted_svd(bs, as_, omega, global_b, global_a, fallback, r_max,
                  backend) -> AggregationResult:
    """Weighted-diagonal contraction + SVD realloc.

    Accepts either unstacked factors (M, d, r) or layer-stacked (M, L, d, r)
    -- the latter vmaps the whole pipeline over the layer axis (our models
    stack per-layer params for lax.scan).
    """
    if bs.ndim == 4:  # (M, L, d, r): vmap over the layer axis
        def one_layer(bs_l, as_l, gb_l, ga_l):
            res = _weighted_svd(bs_l, as_l, omega, gb_l, ga_l, fallback,
                                r_max, backend)
            sig = res.sigma if res.sigma is not None else jnp.zeros((r_max,))
            return res.b_g, res.a_g, sig
        gb = global_b if global_b is not None else \
            jnp.zeros((bs.shape[1], bs.shape[2], r_max), jnp.float32)
        ga = global_a if global_a is not None else \
            jnp.zeros((as_.shape[1], r_max, as_.shape[3]), jnp.float32)
        b_g, a_g, sigma = jax.vmap(one_layer, in_axes=(1, 1, 0, 0))(
            bs, as_, gb, ga)
        return AggregationResult(b_g, a_g, sigma)
    if backend == "dense":
        dw = dense_from_weighted(bs, as_, omega, global_b, global_a, fallback)
        b_g, a_g, sigma = svd_realloc_dense(dw, r_max)
    elif backend == "factored":
        u_c, v_c = factored_from_weighted(bs, as_, omega, global_b, global_a,
                                          fallback)
        b_g, a_g, sigma = svd_realloc_factored(u_c, v_c, r_max)
    elif backend == "kernel":
        from repro.kernels import ops as kernel_ops
        dw = kernel_ops.rank_partition_agg(bs, as_, omega, global_b, global_a,
                                           fallback)
        b_g, a_g, sigma = svd_realloc_dense(dw, r_max)
    else:
        raise ValueError(f"unknown backend {backend!r}")
    return AggregationResult(b_g, a_g, sigma)


# ---------------------------------------------------------------------------
# method registry + per-adapter driver
# ---------------------------------------------------------------------------

METHODS = ("fedavg", "hetlora", "flora", "flexlora", "raflora", "ffa")


def aggregate_ffa(bs, as_, ranks, n_k, *, global_b) -> AggregationResult:
    """FFA-LoRA (paper ref [9]): the random-init DOWN factor is FROZEN at
    its shared global value; only the UP factor is trained and averaged --
    removes the E[B]E[A] != E[BA] bias in the homogeneous setting.

    Layout note: the server maps model lora_a -> first factor here, so the
    FROZEN factor is ``bs``/``global_b`` and the averaged one is ``as_``.
    Heterogeneous ranks: zero-padded averaging (HetLoRA-style) on the
    trained factor.
    """
    w = jnp.asarray(_weights(n_k), dtype=as_.dtype)
    wshape = (-1,) + (1,) * (as_.ndim - 1)
    a_g = (w.reshape(wshape) * as_).sum(0)
    return AggregationResult(global_b, a_g, None)


@dataclass
class Aggregator:
    """Aggregates a round of client adapter uploads, layer by layer."""

    method: str
    rank_levels: Sequence[int]
    backend: str = "factored"
    # raFLoRA partial variants (Fig. 5a): apply effective-contributor
    # weighting only up to this boundary; higher partitions use FlexLoRA
    # weights. None = full raFLoRA.
    partial_up_to: Optional[int] = None

    def __post_init__(self):
        assert self.method in METHODS, self.method

    def aggregate_layer(self, factors, ranks, n_k, global_b=None,
                        global_a=None) -> AggregationResult:
        """factors: [(B_k (d, r_k), A_k (r_k, n))] for one adapter layer."""
        r_max = max(self.rank_levels)
        bs, as_ = pad_stack(factors, r_max)
        if self.method == "fedavg":
            return aggregate_fedavg(bs, as_, ranks, n_k)
        if self.method == "hetlora":
            return aggregate_hetlora(bs, as_, ranks, n_k)
        if self.method == "ffa":
            return aggregate_ffa(bs, as_, ranks, n_k, global_b=global_b)
        if self.method == "flora":
            return aggregate_flora(bs, as_, ranks, n_k)
        if self.method == "flexlora":
            return aggregate_flexlora(bs, as_, ranks, n_k,
                                      backend=self.backend)
        # raflora (optionally partial)
        if self.partial_up_to is None:
            return aggregate_raflora(
                bs, as_, ranks, n_k, rank_levels=self.rank_levels,
                global_b=global_b, global_a=global_a, backend=self.backend)
        return self._aggregate_partial(bs, as_, ranks, n_k, global_b, global_a)

    def _aggregate_partial(self, bs, as_, ranks, n_k, global_b, global_a
                           ) -> AggregationResult:
        """raFLoRA-a/b/c variants: rank-aware weights for partitions up to
        ``partial_up_to``; FlexLoRA weights above (Fig. 5a)."""
        r_max = max(self.rank_levels)
        om_ra, fb = parts.omega_raflora(ranks, n_k, self.rank_levels)
        om_flex = parts.omega_flexlora(ranks, n_k, r_max)
        cut = self.partial_up_to
        omega = np.concatenate([om_ra[:, :cut], om_flex[:, cut:]], axis=1)
        fb = np.concatenate([fb[:cut], np.zeros(r_max - cut)])
        fallback = jnp.asarray(fb) if fb.any() else None
        return _weighted_svd(bs, as_, jnp.asarray(omega), global_b, global_a,
                             fallback, r_max, self.backend)
